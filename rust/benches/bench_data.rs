//! bench_data: Photon Data Source throughput — category samplers, merged
//! client streams, and validation-set generation. The stream must outrun
//! the train step by a wide margin (it shares the single core).

use photon::benchkit::{bench, bench_header, Recorder};
use photon::data::corpus::{CategorySampler, SyntheticCorpus};
use photon::data::partition::Partition;
use photon::data::stream::TokenStream;
use photon::util::rng::Rng;

fn main() {
    let _quick = bench_header("bench_data: corpus & stream token throughput");
    let mut rec = Recorder::new("data");
    for vocab in [256usize, 1024] {
        let corpus = SyntheticCorpus::pile(vocab);
        let sampler = CategorySampler::new(&corpus.categories[0]);
        let mut rng = Rng::new(1);
        let r = bench(&format!("category_sampler/v{vocab}/seq128"), 0.5, || {
            std::hint::black_box(sampler.sequence(128, &mut rng));
        });
        rec.add(&r, "tok", 128.0);

        let p = Partition::heterogeneous(&corpus, 8, 3);
        let mut stream =
            TokenStream::bind(&p.assignment[0], &corpus.categories, 33, 1).unwrap();
        let r = bench(&format!("client_stream/v{vocab}/batch8x33"), 0.5, || {
            std::hint::black_box(stream.next_batch(8));
        });
        rec.add(&r, "tok", 8.0 * 33.0);
    }

    // Validation-set generation (done once per federation startup).
    let corpus = SyntheticCorpus::c4(512);
    let p = Partition::iid(&corpus, 8);
    let r = bench("validation_batches/8x(4x33)", 0.5, || {
        let ds = photon::data::source::DataSource::new(corpus.clone(), p.clone(), 1);
        std::hint::black_box(ds.validation_batches(8, 4, 33).unwrap());
    });
    rec.add(&r, "tok", (8 * 4 * 33) as f64);

    rec.finish().expect("writing BENCH_data.json");
}
