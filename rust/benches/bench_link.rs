//! bench_link: Photon-Link serialize/compress/decode throughput on
//! model-payload sizes from the artifact ladder, including the zero-copy
//! (codec `none`) frame round-trip. Emits `BENCH_link.json` (compare
//! against the committed baseline with `tools/bench_compare.py`).

use photon::benchkit::{bench, bench_header, Recorder};
use photon::link::{decode_bytes_ref, decode_model, encode_model, MsgKind};
use photon::testkit::rand_vec;
use photon::util::rng::Rng;

fn main() {
    let quick = bench_header("bench_link: payload encode/decode throughput");
    let mut rec = Recorder::new("link");
    let sizes: &[usize] = if quick { &[213_568] } else { &[32_928, 213_568, 4_526_016] };
    for &n in sizes {
        let mut rng = Rng::new(2);
        // Realistic payload: small-magnitude weights (compressible sign/exp bits).
        let payload = rand_vec(&mut rng, n, 0.02);
        let mb = (n * 4) as f64 / 1e6;

        let r = bench(&format!("encode/raw/{n}"), 0.4, || {
            std::hint::black_box(encode_model(MsgKind::GlobalModel, &payload, false).unwrap());
        });
        rec.add(&r, "MB", mb);
        let r = bench(&format!("encode/deflate/{n}"), 0.8, || {
            std::hint::black_box(encode_model(MsgKind::GlobalModel, &payload, true).unwrap());
        });
        rec.add(&r, "MB", mb);

        let raw = encode_model(MsgKind::GlobalModel, &payload, false).unwrap();
        let comp = encode_model(MsgKind::GlobalModel, &payload, true).unwrap();
        println!(
            "  deflate ratio: {:.1}% ({} -> {} bytes)",
            100.0 * comp.len() as f64 / raw.len() as f64,
            raw.len(),
            comp.len()
        );
        let r = bench(&format!("decode/raw/{n}"), 0.4, || {
            std::hint::black_box(decode_model(&raw).unwrap());
        });
        rec.add(&r, "MB", mb);
        let r = bench(&format!("decode/deflate/{n}"), 0.4, || {
            std::hint::black_box(decode_model(&comp).unwrap());
        });
        rec.add(&r, "MB", mb);
        // The zero-copy body path on its own: checksum + header hardening,
        // body borrowed straight out of the frame (no payload copy).
        let r = bench(&format!("decode_ref/raw/{n}"), 0.4, || {
            std::hint::black_box(decode_bytes_ref(&raw).unwrap());
        });
        rec.add(&r, "MB", mb);
        // Full frame round-trip with codec none — the fleet hot path for an
        // uncompressed update: one exact-capacity alloc in, zero copies out.
        let r = bench(&format!("frame_roundtrip/none/{n}"), 0.4, || {
            let f = encode_model(MsgKind::GlobalModel, &payload, false).unwrap();
            std::hint::black_box(decode_bytes_ref(&f).unwrap());
        });
        rec.add(&r, "MB", mb);
        println!();
    }
    rec.finish().expect("writing BENCH_link.json");
}
