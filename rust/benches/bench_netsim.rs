//! bench_netsim: the analytic communication model — evaluation cost of the
//! full `comm` sweep (it must be effectively free) plus a printed summary
//! of the headline ratios at paper scale.

use photon::benchkit::{bench, bench_header, Recorder};
use photon::netsim::*;

fn main() {
    let _quick = bench_header("bench_netsim: cost-model evaluation");
    let mut rec = Recorder::new("netsim");
    let payloads: Vec<u64> =
        vec![223_000_000, 423_000_000, 1_300_000_000, 4_700_000_000, 25_800_000_000];

    let r = bench("full_sweep/5_models_x_3_links", 0.2, || {
        let mut acc = 0.0f64;
        for &p in &payloads {
            for link in [&DATACENTER, &CLOUD_WAN, &BROADBAND] {
                acc += comm_ratio(p, 8, 20, 500);
                acc += fed_comm_fraction(p, link, 500, 1.0);
                acc += ddp_steps_secs(p, 8, link, 500, 1.0);
            }
        }
        std::hint::black_box(acc);
    });
    rec.add_result(&r);

    println!("\nheadline ratios at paper scale (τ=500, 8 workers):");
    for (&p, name) in payloads.iter().zip(["75M", "125M", "350M", "1.3B", "7B"]) {
        println!(
            "  {name:>5}: DDP/FL = {:.0}x, WAN comm fraction = {:.2}%",
            comm_ratio(p, 8, 20, 500),
            100.0 * fed_comm_fraction(p, &CLOUD_WAN, 500, 1.0)
        );
    }

    rec.finish().expect("writing BENCH_netsim.json");
}
