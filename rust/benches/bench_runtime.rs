//! bench_runtime: train/eval/score step latency per ladder size — the L3
//! hot path (each federated round is τ·K of these). Regenerates the data
//! behind EXPERIMENTS.md §Perf (L3 step-latency table).

use photon::benchkit::{bench, bench_header, Recorder};
use photon::data::corpus::SyntheticCorpus;
use photon::data::partition::Partition;
use photon::data::stream::TokenStream;
use photon::model::init::init_params;
use photon::runtime::{Runtime, TrainState};

fn main() {
    let quick = bench_header("bench_runtime: AOT step latency per model size");
    let mut rec = Recorder::new("runtime");
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let sizes: &[&str] = if quick {
        &["m75a", "m350a"]
    } else {
        &["m75a", "m125a", "m350a", "m1ba", "m3ba", "m7ba", "tiny_pallas"]
    };
    for name in sizes {
        let model = match rt.load_model(name) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skipping {name}: {e}");
                continue;
            }
        };
        let corpus = SyntheticCorpus::c4(model.manifest.config.vocab);
        let partition = Partition::iid(&corpus, 1);
        let mut stream = TokenStream::bind(
            &partition.assignment[0],
            &corpus.categories,
            model.seq_width(),
            1,
        )
        .unwrap();
        let params = init_params(&model.manifest, 1);
        let mut state = TrainState::new(params.clone());
        let tokens = stream.next_batch(model.batch_size());
        let tokens_per_step = (model.batch_size() * model.seq_len()) as f64;

        let r = bench(&format!("{name}/train_step ({} params)", model.n_params()), 2.0, || {
            model.train_step(&mut state, 1e-3, &tokens).unwrap();
        });
        rec.add(&r, "tok", tokens_per_step);
        let k = model.chunk_size();
        let mut chunk_toks = Vec::new();
        for _ in 0..k {
            chunk_toks.extend(stream.next_batch(model.batch_size()));
        }
        let lrs = vec![1e-3f32; k];
        let mut chunk_state = TrainState::new(params.clone());
        let r = bench(&format!("{name}/train_chunk (x{k})"), 2.0, || {
            model.train_chunk(&mut chunk_state, &lrs, &chunk_toks).unwrap();
        });
        rec.add(&r, "tok", tokens_per_step * k as f64);
        let r = bench(&format!("{name}/eval_step"), 1.0, || {
            model.eval_batch(&params, &tokens).unwrap();
        });
        rec.add(&r, "tok", tokens_per_step);
        let mask = vec![1.0f32; model.batch_size() * model.seq_len()];
        let r = bench(&format!("{name}/score_step"), 1.0, || {
            model.score_batch(&params, &tokens, &mask).unwrap();
        });
        rec.add_result(&r);
    }

    rec.finish().expect("writing BENCH_runtime.json");
}
