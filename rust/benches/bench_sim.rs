//! bench_sim: the event-driven wall-clock simulator — schedule replay and
//! full-timeline simulation cost at fleet scale (it must stay cheap enough
//! to sweep ladders × τ × policies interactively), plus a printed policy
//! comparison at paper scale.

use photon::benchkit::{bench, bench_header, Recorder};
use photon::cluster::faults::FaultPlan;
use photon::config::ExperimentConfig;
use photon::netsim::CLOUD_WAN;
use photon::sim::{
    fleet_profiles, AggregationPolicy, RoundPlan, SimConfig, Simulator, DEFAULT_MFU,
};

fn main() {
    let quick = bench_header("bench_sim: wall-clock federation simulator");
    let mut rec = Recorder::new("sim");
    let (p, k, rounds) = if quick { (64, 16, 20) } else { (512, 64, 50) };

    let mut cfg = ExperimentConfig::wallclock(p, k, rounds, 500, 3);
    cfg.faults = FaultPlan::new(0.05, 0.2, 3);
    let n_params = 110_890_000u64; // paper 125M
    let payload = n_params * 4;
    let profiles = fleet_profiles(
        cfg.fleet.as_ref().unwrap(),
        n_params,
        256 * 2048,
        DEFAULT_MFU,
    );

    let r = bench(&format!("plan/replay_{p}x{k}x{rounds}"), 0.3, || {
        std::hint::black_box(RoundPlan::from_config(&cfg));
    });
    rec.add(&r, "round", rounds as f64);

    let plan = RoundPlan::from_config(&cfg);
    for policy in [
        AggregationPolicy::Sync,
        AggregationPolicy::SemiSync { deadline_factor: 1.5 },
        AggregationPolicy::Overlap,
    ] {
        let r = bench(
            &format!("sim/{}_{p}x{k}x{rounds}", policy.label()),
            0.3,
            || {
                let sc = SimConfig::new(payload, CLOUD_WAN, policy);
                std::hint::black_box(
                    Simulator::new(plan.clone(), profiles.clone(), sc).run(),
                );
            },
        );
        rec.add(&r, "round", rounds as f64);
    }

    println!("\nsimulated wall-clock at paper scale (τ=500, 1 Gbit/s WAN):");
    for policy in [
        AggregationPolicy::Sync,
        AggregationPolicy::SemiSync { deadline_factor: 1.5 },
        AggregationPolicy::Overlap,
    ] {
        let sc = SimConfig::new(payload, CLOUD_WAN, policy);
        let rep = Simulator::new(plan.clone(), profiles.clone(), sc).run();
        println!(
            "  {:<9} total {:>10.1}s  mean round {:>8.1}s  comm {:>5.2}%  late {}",
            policy.label(),
            rep.total_secs,
            rep.mean_round_secs(),
            100.0 * rep.comm_fraction(),
            rep.late_total,
        );
    }

    rec.finish().expect("writing BENCH_sim.json");
}
