//! bench_round: one full federated round end-to-end (sample → τ local
//! steps × K clients → aggregate → outer step → eval) on the 75M-analogue.
//! This is the paper's system-level unit of work; EXPERIMENTS.md §Perf
//! tracks its breakdown.

use photon::benchkit::{bench, bench_header};
use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::runtime::Runtime;

fn main() {
    let quick = bench_header("bench_round: full federated round (m75a)");
    let rt = Runtime::cpu().expect("pjrt client");
    let model = std::rc::Rc::new(rt.load_model("m75a").expect("run `make artifacts`"));

    for (k, tau) in [(4usize, 10u64), (8, 20)] {
        if quick && k == 8 {
            continue;
        }
        let mut cfg = ExperimentConfig::quickstart("m75a");
        cfg.n_clients = k;
        cfg.clients_per_round = k;
        cfg.rounds = usize::MAX / 2; // never stop via run(); we call run_round
        cfg.local_steps = tau;
        cfg.eval_batches = 2;
        let mut fed = Federation::with_model(cfg, model.clone()).unwrap();
        let r = bench(&format!("round/K{k}/tau{tau}"), 3.0, || {
            fed.run_round().unwrap();
        });
        r.print_with_throughput("client-step", (k as u64 * tau) as f64);
    }

    // Breakdown: eval-only cost (the non-training part of a round).
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.eval_batches = 4;
    let fed = Federation::with_model(cfg, model).unwrap();
    let r = bench("eval_global/4_batches", 1.0, || {
        fed.eval_global().unwrap();
    });
    r.print();
}
