//! bench_round: one full federated round end-to-end (sample → τ local
//! steps × K clients → aggregate → outer step → eval) on the 75M-analogue.
//! This is the paper's system-level unit of work; EXPERIMENTS.md §Perf
//! tracks its breakdown. The trailing section compares the round engine's
//! sequential path against the worker pool at K ≥ 8 — the speedup the
//! ISSUE-1 acceptance criteria track.

use photon::benchkit::{bench, bench_header, Recorder};
use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::runtime::Runtime;

fn main() {
    let quick = bench_header("bench_round: full federated round (m75a)");
    let mut rec = Recorder::new("round");
    let rt = Runtime::cpu().expect("pjrt client");
    let model = std::sync::Arc::new(rt.load_model("m75a").expect("run `make artifacts`"));

    for (k, tau) in [(4usize, 10u64), (8, 20)] {
        if quick && k == 8 {
            continue;
        }
        let mut cfg = ExperimentConfig::quickstart("m75a");
        cfg.n_clients = k;
        cfg.clients_per_round = k;
        cfg.rounds = usize::MAX / 2; // never stop via run(); we call run_round
        cfg.local_steps = tau;
        cfg.eval_batches = 2;
        let mut fed = Federation::with_model(cfg, model.clone()).unwrap();
        let r = bench(&format!("round/K{k}/tau{tau}"), 3.0, || {
            fed.run_round().unwrap();
        });
        rec.add(&r, "client-step", (k as u64 * tau) as f64);
    }

    // Round-engine scaling: identical work, workers 1 vs auto. Host-side
    // work overlaps under the default serialized dispatch; expect the gap
    // to widen further with --parallel-dispatch runtimes.
    let k = 8usize;
    let tau = if quick { 5u64 } else { 20 };
    let mut means = Vec::new();
    for workers in [1usize, 0] {
        let mut cfg = ExperimentConfig::quickstart("m75a");
        cfg.n_clients = k;
        cfg.clients_per_round = k;
        cfg.rounds = usize::MAX / 2;
        cfg.local_steps = tau;
        cfg.eval_batches = 2;
        cfg.exec.workers = workers;
        let mut fed = Federation::with_model(cfg, model.clone()).unwrap();
        let label = if workers == 0 { "auto".to_string() } else { workers.to_string() };
        let r = bench(&format!("round_engine/K{k}/tau{tau}/workers_{label}"), 3.0, || {
            fed.run_round().unwrap();
        });
        rec.add(&r, "client-step", (k as u64 * tau) as f64);
        means.push(r.mean.as_secs_f64());
    }
    if let [seq, par] = means[..] {
        println!("round_engine speedup (workers auto vs 1): {:.2}x", seq / par);
    }

    // Breakdown: eval-only cost (the non-training part of a round).
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.eval_batches = 4;
    let fed = Federation::with_model(cfg, model).unwrap();
    let r = bench("eval_global/4_batches", 1.0, || {
        fed.eval_global().unwrap();
    });
    rec.add_result(&r);

    rec.finish().expect("writing BENCH_round.json");
}
