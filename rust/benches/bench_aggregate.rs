//! bench_aggregate: the Photon Aggregator's vector-math hot path — client
//! mean, pseudo-gradient, and each outer optimizer, across payload sizes
//! matching the artifact ladder. Emits `BENCH_aggregate.json` (compare
//! against the committed baseline with `tools/bench_compare.py`).
//!
//! The `streaming_fold/1Mx1k` pair is the perf-plane acceptance bench:
//! the chunked fold vs the retained scalar reference at 1k clients × 1M
//! params (the rows alias 8 distinct buffers, so the working set stays
//! ~32 MB while the fold still reads 10⁹ row elements per iteration).

use photon::benchkit::{bench, bench_header, Recorder};
use photon::metrics::{mean_pairwise_cosine, mean_pairwise_cosine_from_gram};
use photon::model::vecmath::{
    mean_into, reference, streaming_aggregate, streaming_fold, sub_into, weighted_mean_into,
    AggScratch,
};
use photon::optim::outer::{OuterHyper, OuterOpt, OuterOptKind};
use photon::testkit::rand_vec;
use photon::util::rng::Rng;

fn main() {
    let quick = bench_header("bench_aggregate: outer-optimizer & aggregation throughput");
    let mut rec = Recorder::new("aggregate");
    let sizes: &[usize] = if quick {
        &[32_928, 713_952]
    } else {
        &[32_928, 95_568, 213_568, 713_952, 1_640_576, 4_526_016]
    };
    let k = 8;
    for &n in sizes {
        let mut rng = Rng::new(1);
        let clients: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(&mut rng, n, 0.1)).collect();
        let rows: Vec<&[f32]> = clients.iter().map(|c| c.as_slice()).collect();
        let weights = vec![1.0f64; k];
        let mut mean = vec![0.0f32; n];
        let mut pg = vec![0.0f32; n];
        let mut global = rand_vec(&mut rng, n, 0.1);

        let r = bench(&format!("mean_into/{n}x{k}"), 0.5, || {
            mean_into(&rows, &mut mean);
        });
        rec.add(&r, "param", (n * k) as f64);
        let r = bench(&format!("weighted_mean_into/{n}x{k}"), 0.5, || {
            weighted_mean_into(&rows, &weights, &mut mean);
        });
        rec.add(&r, "param", (n * k) as f64);
        let r = bench(&format!("pseudo_grad(sub_into)/{n}"), 0.3, || {
            sub_into(&global, &mean, &mut pg);
        });
        rec.add(&r, "param", n as f64);

        // The round engine's aggregation paths, old vs new: the streaming
        // pass fuses mean + pg + delta norms + K×K cosine Gram with no
        // O(K·N) allocation; the materialized path is what federation.rs
        // used to do per round.
        let mut scratch = AggScratch::new();
        let r = bench(&format!("streaming_aggregate/{n}x{k}"), 0.5, || {
            let stats =
                streaming_aggregate(&rows, &weights, &global, &mut mean, &mut pg, &mut scratch);
            std::hint::black_box(mean_pairwise_cosine_from_gram(stats.k, &stats.gram));
        });
        rec.add(&r, "param", (n * k) as f64);
        let r = bench(&format!("materialized_aggregate/{n}x{k}"), 0.5, || {
            weighted_mean_into(&rows, &weights, &mut mean);
            sub_into(&global, &mean, &mut pg);
            let deltas: Vec<Vec<f32>> = clients
                .iter()
                .map(|c| {
                    let mut d = vec![0.0f32; n];
                    sub_into(c, &mean, &mut d);
                    d
                })
                .collect();
            std::hint::black_box(mean_pairwise_cosine(&deltas));
        });
        rec.add(&r, "param", (n * k) as f64);

        for (name, kind) in [
            ("fedavg", OuterOptKind::FedAvg),
            ("fednesterov", OuterOptKind::FedMomentum { nesterov: true }),
            ("fedadam", OuterOptKind::FedAdam),
            ("fedyogi", OuterOptKind::FedYogi),
        ] {
            let mut opt = OuterOpt::new(kind, OuterHyper::default(), n);
            let r = bench(&format!("outer/{name}/{n}"), 0.3, || {
                opt.step(&mut global, &pg);
            });
            rec.add(&r, "param", n as f64);
        }
        println!();
    }

    // Acceptance pair: vectorized fold vs scalar reference at 1k clients ×
    // 1M params (run in quick mode too — this IS the committed trajectory).
    {
        let n = 1_000_000usize;
        let big_k = 1_000usize;
        let distinct = 8usize;
        let mut rng = Rng::new(7);
        let bufs: Vec<Vec<f32>> = (0..distinct).map(|_| rand_vec(&mut rng, n, 0.1)).collect();
        let rows: Vec<&[f32]> = (0..big_k).map(|i| bufs[i % distinct].as_slice()).collect();
        let weights: Vec<f64> = (0..big_k).map(|i| 1.0 + (i % 5) as f64).collect();
        let global = rand_vec(&mut rng, n, 0.1);
        let mut mean = vec![0.0f32; n];
        let mut pg = vec![0.0f32; n];
        let mut scratch = AggScratch::new();

        let r = bench("streaming_fold/1Mx1k", 1.0, || {
            streaming_fold(&rows, &weights, &global, &mut mean, &mut pg, &mut scratch);
            std::hint::black_box((&mean, &pg));
        });
        rec.add(&r, "param", (n * big_k) as f64);
        let fold_params_per_sec = (n * big_k) as f64 / r.mean.as_secs_f64();

        let r = bench("streaming_fold_scalar/1Mx1k", 1.0, || {
            reference::weighted_mean_into(&rows, &weights, &mut mean);
            reference::sub_into(&global, &mean, &mut pg);
            std::hint::black_box((&mean, &pg));
        });
        rec.add(&r, "param", (n * big_k) as f64);
        let scalar_params_per_sec = (n * big_k) as f64 / r.mean.as_secs_f64();

        println!(
            "streaming_fold speedup vs scalar reference: {:.2}x",
            fold_params_per_sec / scalar_params_per_sec
        );
    }

    rec.finish().expect("writing BENCH_aggregate.json");
}
