//! bench_codec: lossy update-codec throughput — q8/q4 per-block
//! stochastic-rounding encode/decode and top-k partial-select encode —
//! on update sizes from the artifact ladder. Emits `BENCH_codec.json`
//! (compare against the committed baseline with `tools/bench_compare.py`).

use photon::benchkit::{bench, bench_header, Recorder};
use photon::compress::UpdateCodec;
use photon::testkit::rand_vec;
use photon::util::rng::Rng;

fn main() {
    let quick = bench_header("bench_codec: lossy update-codec throughput");
    let mut rec = Recorder::new("codec");
    let sizes: &[usize] = if quick { &[213_568] } else { &[213_568, 1_640_576] };
    for &n in sizes {
        let mut rng = Rng::new(5);
        let delta = rand_vec(&mut rng, n, 0.02);
        for codec in [
            UpdateCodec::Q8 { block: 256 },
            UpdateCodec::Q4 { block: 256 },
            UpdateCodec::TopK { keep_permille: 50 },
        ] {
            let mut residual = Vec::new();
            let r = bench(&format!("encode/{}/{n}", codec.label()), 0.4, || {
                let mut res = residual.clone(); // error feedback must not drift across iters
                std::hint::black_box(codec.encode_delta(&delta, 11, &mut res).unwrap());
            });
            rec.add(&r, "param", n as f64);

            let body = codec.encode_delta(&delta, 11, &mut residual).unwrap().unwrap();
            let r = bench(&format!("decode/{}/{n}", codec.label()), 0.4, || {
                std::hint::black_box(codec.decode_delta(&body, n).unwrap());
            });
            rec.add(&r, "param", n as f64);
        }
        println!();
    }
    rec.finish().expect("writing BENCH_codec.json");
}
