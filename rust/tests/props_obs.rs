//! Property + golden tests for the observability plane (`obs`) —
//! artifact-free (the fleet-level keystone, event log → `to_trace` ⇔
//! `Server::trace()`, lives in `integration_chaos.rs` next to its parity
//! peers).
//!
//! Pinned here:
//! - the JSONL schema, byte-for-byte, via `tests/fixtures/obs/golden.jsonl`
//!   (committed bytes must round-trip the codec AND be reproduced exactly
//!   by a fixed-clock `EventSink` replaying the same events);
//! - `photon top --replay` determinism: the golden log renders to the
//!   committed `golden_frame.txt` / `golden_stats.txt`, byte-identical,
//!   twice;
//! - reducer invariants over generated round scripts with shrinking
//!   (grants = folds + cuts per round, commit mirrors folds, stale
//!   re-application is dropped, never double-counted);
//! - crash-torn logs: the tail reader skips garbage, holds truncated
//!   last lines until completed, and `read_log` never errors on a file
//!   that is mid-write.

use std::fs;
use std::io::Write;
use std::path::PathBuf;

use photon::chaos::{Migration, RoundTrace, Trace};
use photon::obs::{
    read_log, render_frame, render_stats, to_trace, validate_log_text, Event,
    EventRecord, EventSink, Mode, Tail, ViewState,
};
use photon::testkit;
use photon::util::rng::Rng;

/// The crate root, robust to running from the repo root or `rust/`.
fn fixture_path(name: &str) -> PathBuf {
    for cand in ["tests/fixtures/obs", "rust/tests/fixtures/obs"] {
        let p = PathBuf::from(cand).join(name);
        if p.is_file() {
            return p;
        }
    }
    panic!("fixture {name} not found under tests/fixtures/obs");
}

fn golden_text(name: &str) -> String {
    let p = fixture_path(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// The exact event sequence behind `golden.jsonl`: two rounds over two
/// workers exercising every kind — grants, folds, a malformed frame, a
/// mid-round migration, a deadline cut, a rejoin, a stall backstop cut,
/// commits, shutdown.
fn golden_events() -> Vec<Event> {
    vec![
        Event::ServerStart {
            session: "0x2a".into(),
            rounds: 2,
            n_clients: 6,
            clients_per_round: 4,
        },
        Event::WorkerJoin { worker: 0, name: "loopback-0".into() },
        Event::WorkerJoin { worker: 1, name: "loopback-1".into() },
        Event::LeaseGrant { round: 0, client: 0, worker: 0 },
        Event::LeaseGrant { round: 0, client: 2, worker: 1 },
        Event::LeaseGrant { round: 0, client: 3, worker: 0 },
        Event::LeaseGrant { round: 0, client: 5, worker: 1 },
        Event::LeaseFold { round: 0, client: 0, worker: 0 },
        Event::LeaseFold { round: 0, client: 2, worker: 1 },
        Event::Malformed { round: 0, worker: Some(1) },
        Event::Migration { round: 0, client: 5, from: 1, to: 0 },
        Event::LeaseFold { round: 0, client: 5, worker: 0 },
        Event::Cut { round: 0, clients: vec![3] },
        Event::RoundCommit {
            round: 0,
            participated: 3,
            nll: 5.25,
            comm_bytes_wire: 49152,
            wall_us: 1500,
        },
        Event::WorkerRejoin { round: 1, worker: 1, name: "loopback-1".into() },
        Event::LeaseGrant { round: 1, client: 1, worker: 0 },
        Event::LeaseGrant { round: 1, client: 4, worker: 1 },
        Event::LeaseFold { round: 1, client: 1, worker: 0 },
        Event::Stall {
            round: Some(1),
            waited_us: 2_000_000,
            detail: "1 lease(s) pending past the liveness backstop".into(),
        },
        Event::Cut { round: 1, clients: vec![4] },
        Event::RoundCommit {
            round: 1,
            participated: 1,
            nll: 4.5,
            comm_bytes_wire: 16384,
            wall_us: 2500,
        },
        Event::Shutdown { rounds: 2 },
    ]
}

fn golden_records() -> Vec<EventRecord> {
    golden_text("golden.jsonl")
        .lines()
        .map(|l| EventRecord::parse(l).expect("golden line must parse"))
        .collect()
}

#[test]
fn golden_log_validates_and_round_trips_byte_exactly() {
    let text = golden_text("golden.jsonl");
    assert_eq!(validate_log_text(&text).unwrap(), 22, "22 committed events");
    for line in text.lines() {
        let rec = EventRecord::parse(line).unwrap();
        assert_eq!(rec.to_line(), line, "re-serialization must be byte-stable");
    }
}

#[test]
fn fixed_clock_sink_reproduces_the_golden_bytes() {
    // The committed fixture is not hand-blessed prose: a deterministic
    // sink replaying the same events must regenerate it byte-for-byte,
    // so the writer can never drift from the file silently.
    let sink = EventSink::memory_fixed(1000, 10);
    for ev in golden_events() {
        sink.emit(ev);
    }
    assert_eq!(sink.emitted(), 22);
    assert_eq!(sink.dump().unwrap(), golden_text("golden.jsonl"));
}

#[test]
fn golden_replay_renders_byte_identical_frames_and_stats() {
    let records = golden_records();
    let mut view = ViewState::default();
    view.apply_all(&records);
    let frame = render_frame(&view, Mode::Replay);
    assert_eq!(frame, golden_text("golden_frame.txt"), "cockpit frame drifted");
    assert_eq!(
        frame,
        render_frame(&view, Mode::Replay),
        "rendering must be a pure function of the view"
    );
    assert_eq!(render_stats(&view), golden_text("golden_stats.txt"));
}

#[test]
fn golden_log_folds_to_the_expected_trace() {
    let expected = Trace {
        rounds: vec![
            RoundTrace {
                round: 0,
                cut: vec![3],
                migrations: vec![Migration { client: 5, from: 1, to: 0 }],
                rejoined: vec![],
            },
            RoundTrace { round: 1, cut: vec![4], migrations: vec![], rejoined: vec![1] },
        ],
    };
    assert_eq!(to_trace(&golden_records()), expected);
}

#[test]
fn until_seq_prefix_replay_stops_cleanly_mid_run() {
    // `photon top --replay --until-seq 13` semantics: everything through
    // the first commit, nothing after.
    let mut view = ViewState::default();
    for rec in &golden_records() {
        if rec.seq > 13 {
            break;
        }
        view.apply(rec);
    }
    assert_eq!(view.applied, 14);
    assert_eq!(view.committed_rounds(), 1);
    assert_eq!(view.total_folded(), 3);
    assert_eq!(view.final_nll(), Some(5.25));
    assert_eq!(view.stalls, 0);
    assert!(!view.shutdown, "shutdown is past the cursor");
}

/// One generated round for the reducer property: which clients are
/// granted, how many of them fold (the rest are cut), and whether a
/// migration / stall lands in between.
#[derive(Clone, Debug)]
struct RoundScript {
    clients: Vec<u64>,
    folds: usize,
    migrate: bool,
    stall: bool,
}

fn gen_script(rng: &mut Rng) -> Vec<RoundScript> {
    let rounds = 1 + rng.usize_below(6);
    (0..rounds)
        .map(|_| {
            let k = 1 + rng.usize_below(5);
            let clients: Vec<u64> =
                rng.choose_k(8, k).into_iter().map(|c| c as u64).collect();
            RoundScript {
                folds: rng.usize_below(k + 1),
                migrate: rng.bool(0.3),
                stall: rng.bool(0.2),
                clients,
            }
        })
        .collect()
}

/// Expand a script into the records a well-behaved server would emit,
/// with consecutive `seq` and deterministic `ts_us`.
fn script_records(script: &[RoundScript]) -> Vec<EventRecord> {
    let mut out = Vec::new();
    let mut push = |event: Event| {
        let seq = out.len() as u64;
        out.push(EventRecord { seq, ts_us: 1_000 + seq, event });
    };
    push(Event::ServerStart {
        session: "0xfeed".into(),
        rounds: script.len() as u64,
        n_clients: 8,
        clients_per_round: 8,
    });
    for (r, plan) in script.iter().enumerate() {
        let round = r as u64;
        for &c in &plan.clients {
            push(Event::LeaseGrant { round, client: c, worker: c % 2 });
        }
        if plan.migrate {
            let c = plan.clients[0];
            push(Event::Migration { round, client: c, from: c % 2, to: (c + 1) % 2 });
        }
        if plan.stall {
            push(Event::Stall { round: Some(round), waited_us: 50, detail: "s".into() });
        }
        for &c in &plan.clients[..plan.folds] {
            push(Event::LeaseFold { round, client: c, worker: c % 2 });
        }
        let mut cut: Vec<u64> = plan.clients[plan.folds..].to_vec();
        cut.sort_unstable();
        if !cut.is_empty() {
            push(Event::Cut { round, clients: cut });
        }
        push(Event::RoundCommit {
            round,
            participated: plan.folds as u64,
            nll: 5.0 - 0.125 * round as f64,
            comm_bytes_wire: 1024 * plan.clients.len() as u64,
            wall_us: 900 + round,
        });
    }
    push(Event::Shutdown { rounds: script.len() as u64 });
    out
}

#[test]
fn reducer_invariants_hold_over_generated_round_scripts() {
    testkit::check_cases(
        "obs reducer invariants",
        0x0B5_1234,
        60,
        gen_script,
        |s| testkit::shrink_vec(s),
        |script| {
            let records = script_records(script);
            let mut view = ViewState::default();
            view.apply_all(&records);
            if view.applied != records.len() as u64 {
                return Err(format!(
                    "applied {} of {} records",
                    view.applied,
                    records.len()
                ));
            }
            for (r, plan) in script.iter().enumerate() {
                let row = view
                    .rounds
                    .get(&(r as u64))
                    .ok_or_else(|| format!("round {r} missing from timeline"))?;
                if row.granted != plan.clients.len() as u64 {
                    return Err(format!("round {r}: granted {}", row.granted));
                }
                if row.folded + row.cut != row.granted {
                    return Err(format!(
                        "round {r}: folded {} + cut {} != granted {} (exactly-once)",
                        row.folded, row.cut, row.granted
                    ));
                }
                if !row.committed || row.participated != row.folded {
                    return Err(format!(
                        "round {r}: commit participated {} != folded {}",
                        row.participated, row.folded
                    ));
                }
            }
            if view.committed_rounds() != script.len() as u64 {
                return Err("committed-round count drifted".into());
            }
            let wire: u64 = script.iter().map(|p| 1024 * p.clients.len() as u64).sum();
            if view.total_wire_bytes != wire {
                return Err(format!("wire bytes {} != {wire}", view.total_wire_bytes));
            }
            let stalls = script.iter().filter(|p| p.stall).count() as u64;
            if view.stalls != stalls || !view.shutdown {
                return Err("stall/shutdown accounting drifted".into());
            }
            // Idempotence: re-applying the whole stream is a pure no-op
            // apart from the stale-drop counter.
            let mut replayed = view.clone();
            replayed.apply_all(&records);
            let mut expect = view.clone();
            expect.dropped_stale += records.len() as u64;
            if replayed != expect {
                return Err("stale re-application mutated the view".into());
            }
            // And the serialized form survives the validator.
            let text: String =
                records.iter().map(|r| r.to_line() + "\n").collect();
            match validate_log_text(&text) {
                Ok(n) if n == records.len() => Ok(()),
                Ok(n) => Err(format!("validator counted {n}/{}", records.len())),
                Err(e) => Err(format!("validator rejected emitted log: {e:#}")),
            }
        },
    );
}

#[test]
fn tail_skips_garbage_and_holds_truncated_lines() {
    let dir = std::env::temp_dir().join(format!("photon_obs_tail_{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    let path = dir.join("events.jsonl");

    let records = golden_records();
    let mut f = fs::File::create(&path).unwrap();
    // Two good lines, one garbage line, then half of a third record —
    // a crash-torn log mid-write.
    writeln!(f, "{}", records[0].to_line()).unwrap();
    writeln!(f, "{}", records[1].to_line()).unwrap();
    writeln!(f, "{{\"seq\":oops not json").unwrap();
    let third = records[2].to_line();
    write!(f, "{}", &third[..third.len() / 2]).unwrap();
    f.flush().unwrap();

    let mut tail = Tail::open(&path).unwrap();
    let batch = tail.poll().unwrap();
    assert_eq!(batch, records[..2].to_vec(), "good prefix parses");
    assert_eq!(tail.skipped, 1, "garbage line is counted, not fatal");
    assert!(tail.pending_bytes() > 0, "truncated line stays buffered");

    // The writer completes the line: the next poll yields exactly it.
    write!(f, "{}\n", &third[third.len() / 2..]).unwrap();
    f.flush().unwrap();
    let batch = tail.poll().unwrap();
    assert_eq!(batch, vec![records[2].clone()]);
    assert_eq!(tail.pending_bytes(), 0);

    // One-shot read_log: an unterminated but parseable final line counts.
    let mut f = fs::File::options().append(true).open(&path).unwrap();
    write!(f, "{}", records[3].to_line()).unwrap();
    f.flush().unwrap();
    let (all, skipped) = read_log(&path).unwrap();
    assert_eq!(skipped, 1, "the garbage line again");
    assert_eq!(all.len(), 4, "terminated prefix + parseable unterminated tail");
    assert_eq!(all[3], records[3]);

    fs::remove_dir_all(&dir).ok();
}

#[test]
fn file_sink_writes_a_followable_log() {
    let dir = std::env::temp_dir().join(format!("photon_obs_sink_{}", std::process::id()));
    let path = dir.join("nested/events.jsonl"); // parent dirs are created
    let sink = EventSink::to_file(&path).unwrap();
    sink.emit(Event::ServerStart {
        session: "0x1".into(),
        rounds: 1,
        n_clients: 2,
        clients_per_round: 2,
    });
    sink.emit(Event::Shutdown { rounds: 1 });
    // Per-line flushing means a concurrent reader sees whole lines
    // without the sink being dropped first.
    let (records, skipped) = read_log(&path).unwrap();
    assert_eq!(skipped, 0);
    assert_eq!(records.len(), 2);
    assert_eq!(records[1].event, Event::Shutdown { rounds: 1 });
    let text = fs::read_to_string(&path).unwrap();
    assert_eq!(validate_log_text(&text).unwrap(), 2);
    fs::remove_dir_all(&dir).ok();
}
