//! Integration: full federated rounds over real artifacts.
//! Requires `make artifacts`.

use std::sync::Arc;

use photon::cluster::faults::FaultPlan;
use photon::cluster::hardware::{ClientHardware, FleetSpec, NodeSpec, A40};
use photon::config::{CorpusKind, ExperimentConfig, OptStatePolicy};
use photon::coordinator::{run_centralized, Federation};
use photon::data::corpus::SyntheticCorpus;
use photon::data::partition::Partition;
use photon::data::stream::TokenStream;
use photon::model::init::init_params;
use photon::runtime::{ModelRuntime, Runtime, TrainState};

fn model() -> Arc<ModelRuntime> {
    // Per-thread cache: cargo runs tests on multiple threads and each test
    // mutates the shared dispatch policy, so giving every test thread its
    // own runtime keeps them independent. Compiling m75a is cheap (<1 s).
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.rounds = 3;
    cfg.local_steps = 8;
    cfg.eval_batches = 2;
    cfg
}

#[test]
fn federated_training_reduces_perplexity() {
    let mut fed = Federation::with_model(base_cfg(), model()).unwrap();
    let hist = fed.run().unwrap();
    assert_eq!(hist.len(), 3);
    assert!(
        hist.last().unwrap().server_ppl < hist[0].server_ppl,
        "ppl {} -> {}",
        hist[0].server_ppl,
        hist.last().unwrap().server_ppl
    );
    assert_eq!(hist[0].participated, 4);
    assert!(hist[0].comm_bytes > 0);
}

#[test]
fn federation_is_deterministic() {
    let run = || {
        let mut fed = Federation::with_model(base_cfg(), model()).unwrap();
        fed.run().unwrap();
        (fed.global.clone(), fed.log.rounds.last().unwrap().server_ppl)
    };
    let (g1, p1) = run();
    let (g2, p2) = run();
    assert_eq!(g1, g2);
    assert_eq!(p1, p2);
}

#[test]
fn single_client_fedavg_equals_local_training() {
    // P=K=1, FedAvg η_s=1, stateless: one round ≡ τ local steps from init.
    let mut cfg = base_cfg();
    cfg.n_clients = 1;
    cfg.clients_per_round = 1;
    cfg.rounds = 1;
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    fed.run().unwrap();

    // Manual replica of the node's local round.
    let m = model();
    let corpus = SyntheticCorpus::c4(m.manifest.config.vocab);
    let partition = Partition::iid(&corpus, 1);
    let mut stream = TokenStream::bind(
        &partition.assignment[0],
        &corpus.categories,
        m.seq_width(),
        cfg.seed, // island 0 => seed ^ 0
    )
    .unwrap();
    let mut st = TrainState::new(init_params(&m.manifest, cfg.seed));
    for t in 0..cfg.local_steps {
        let toks = stream.next_batch(m.batch_size());
        let lr = cfg.schedule.lr(t + 1) as f32;
        m.train_step(&mut st, lr, &toks).unwrap();
    }
    // FedAvg applies θ − (θ − mean) in f32; allow one-ulp rounding per coord.
    assert_eq!(fed.global.len(), st.params.len());
    for (i, (a, b)) in fed.global.iter().zip(&st.params).enumerate() {
        assert!(
            (a - b).abs() <= 1e-6 * b.abs().max(1e-3),
            "federation(P=1) != local training at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn partial_participation_runs_and_rotates_clients() {
    let mut cfg = base_cfg();
    cfg.n_clients = 16;
    cfg.clients_per_round = 2;
    cfg.rounds = 4;
    let mut fed = Federation::with_model(cfg, model()).unwrap();
    let hist = fed.run().unwrap();
    assert!(hist.iter().all(|r| r.participated == 2));
    assert!(hist.last().unwrap().server_ppl < hist[0].server_ppl * 1.05);
}

#[test]
fn full_dropout_leaves_model_unchanged() {
    let mut cfg = base_cfg();
    cfg.rounds = 1;
    cfg.faults = FaultPlan { dropout_prob: 1.0, straggler_prob: 0.0, straggler_fraction: 0.5, seed: 1 };
    let mut fed = Federation::with_model(cfg, model()).unwrap();
    let before = fed.global.clone();
    let rec = fed.run_round().unwrap();
    assert_eq!(rec.participated, 0);
    assert_eq!(fed.global, before);
}

#[test]
fn stragglers_still_converge() {
    let mut cfg = base_cfg();
    cfg.faults = FaultPlan { dropout_prob: 0.2, straggler_prob: 0.5, straggler_fraction: 0.5, seed: 3 };
    let mut fed = Federation::with_model(cfg, model()).unwrap();
    let hist = fed.run().unwrap();
    assert!(hist.last().unwrap().server_ppl < hist[0].server_ppl);
}

#[test]
fn keepopt_differs_from_stateless() {
    let mut c1 = base_cfg();
    c1.rounds = 2;
    let mut c2 = c1.clone();
    c2.opt_state = OptStatePolicy::KeepOpt;
    let mut f1 = Federation::with_model(c1, model()).unwrap();
    let mut f2 = Federation::with_model(c2, model()).unwrap();
    f1.run().unwrap();
    f2.run().unwrap();
    assert_ne!(f1.global, f2.global, "KeepOpt must change the trajectory");
}

#[test]
fn island_subfederation_runs() {
    // Clients with two WAN-separated nodes run an inner sub-federation
    // (Algorithm 1 L.19-24) and still converge.
    let mut cfg = base_cfg();
    cfg.n_clients = 2;
    cfg.clients_per_round = 2;
    let wan_client = ClientHardware {
        nodes: vec![NodeSpec { gpu: A40, n_gpus: 1, intra_gbps: 600.0 }; 2],
        inter_gbps: 0.1,
    };
    cfg.fleet = Some(FleetSpec { clients: vec![wan_client.clone(), wan_client] });
    let mut fed = Federation::with_model(cfg, model()).unwrap();
    let hist = fed.run().unwrap();
    assert!(hist.last().unwrap().server_ppl < hist[0].server_ppl * 1.05);
    assert!(fed.global.iter().all(|v| v.is_finite()));
}

#[test]
fn single_island_fleet_matches_no_fleet() {
    // Well-connected single-node clients must be exactly the default path.
    let c1 = base_cfg();
    let mut c2 = base_cfg();
    c2.fleet = Some(FleetSpec::uniform(c2.n_clients, A40, 1));
    let mut f1 = Federation::with_model(c1, model()).unwrap();
    let mut f2 = Federation::with_model(c2, model()).unwrap();
    f1.run().unwrap();
    f2.run().unwrap();
    assert_eq!(f1.global, f2.global);
}

#[test]
fn centralized_baseline_converges_and_aligns_rounds() {
    let cfg = base_cfg();
    let log = run_centralized(&cfg, &model()).unwrap();
    assert_eq!(log.rounds.len(), cfg.rounds);
    assert!(log.rounds.last().unwrap().server_ppl < log.rounds[0].server_ppl);
    assert!(log.rounds.iter().all(|r| r.comm_bytes == 0));
}

#[test]
fn parallel_round_engine_is_bit_exact() {
    // The acceptance bar for the round engine: with a fixed seed, the
    // RoundRecord stream and the global model produced with a worker pool
    // must be bit-identical to the sequential path (wall time excepted).
    let run = |workers: usize| {
        let mut cfg = base_cfg();
        cfg.n_clients = 8;
        cfg.clients_per_round = 8;
        cfg.faults = FaultPlan::new(0.2, 0.3, 5); // stragglers + drops too
        cfg.exec.workers = workers;
        let mut fed = Federation::with_model(cfg, model()).unwrap();
        fed.run().unwrap();
        (fed.global.clone(), fed.log.rounds.clone())
    };
    let (g_seq, rec_seq) = run(1);
    let (g_par, rec_par) = run(4);
    assert_eq!(g_seq, g_par, "global model must be bit-identical");
    assert_eq!(rec_seq.len(), rec_par.len());
    for (a, b) in rec_seq.iter().zip(&rec_par) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.server_ppl, b.server_ppl);
        assert_eq!(a.server_nll, b.server_nll);
        assert_eq!(a.client_loss_mean, b.client_loss_mean);
        assert_eq!(a.client_loss_std, b.client_loss_std);
        assert_eq!(a.global_model_norm, b.global_model_norm);
        assert_eq!(a.client_model_norm_mean, b.client_model_norm_mean);
        assert_eq!(a.client_avg_norm, b.client_avg_norm);
        assert_eq!(a.pseudo_grad_norm, b.pseudo_grad_norm);
        assert_eq!(a.step_grad_norm_mean, b.step_grad_norm_mean);
        assert_eq!(a.applied_update_norm_mean, b.applied_update_norm_mean);
        assert_eq!(a.act_norm_mean, b.act_norm_mean);
        assert_eq!(a.momentum_norm, b.momentum_norm);
        assert_eq!(a.client_cosine_mean, b.client_cosine_mean);
        assert_eq!(a.participated, b.participated);
        assert_eq!(a.comm_bytes, b.comm_bytes);
    }
}

#[test]
fn mc4_and_pile_partitions_run() {
    for corpus in [CorpusKind::PileHetero { j: 1 }, CorpusKind::Mc4 { n_langs: 4 }] {
        let mut cfg = base_cfg();
        cfg.n_clients = 8;
        cfg.clients_per_round = 8;
        cfg.rounds = 2;
        cfg.corpus = corpus;
        let mut fed = Federation::with_model(cfg, model()).unwrap();
        let hist = fed.run().unwrap();
        assert!(hist.last().unwrap().server_ppl.is_finite());
    }
}
