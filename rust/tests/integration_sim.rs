//! Integration: the event-driven wall-clock simulator over real round
//! schedules — aggregation-policy ordering under stragglers, the
//! all-dropped round path, τ-hiding of WAN transfers, and end-to-end
//! determinism. Artifact-free: the simulator never loads the model.

use photon::cluster::faults::FaultPlan;
use photon::config::ExperimentConfig;
use photon::netsim::{BROADBAND, CLOUD_WAN, DATACENTER};
use photon::sim::{
    fleet_profiles, AggregationPolicy, ClientProfile, RoundPlan, SimConfig, SimReport,
    Simulator, DEFAULT_MFU,
};

const N_PARAMS: u64 = 110_890_000; // paper 125M
const TOKENS: u64 = 256 * 2048;
const PAYLOAD: u64 = N_PARAMS * 4;

/// A straggler-heavy heterogeneous schedule.
fn straggler_cfg(tau: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::wallclock(8, 8, 12, tau, 7);
    cfg.faults = FaultPlan::new(0.1, 0.4, 7);
    cfg
}

fn run(cfg: &ExperimentConfig, link: photon::netsim::Link, policy: AggregationPolicy) -> SimReport {
    let plan = RoundPlan::from_config(cfg);
    let profiles = fleet_profiles(cfg.fleet.as_ref().unwrap(), N_PARAMS, TOKENS, DEFAULT_MFU);
    Simulator::new(plan, profiles, SimConfig::new(PAYLOAD, link, policy)).run()
}

#[test]
fn semisync_wallclock_never_exceeds_sync_on_stragglers() {
    let cfg = straggler_cfg(100);
    for (name, link) in [
        ("datacenter", DATACENTER),
        ("cloud_wan", CLOUD_WAN),
        ("broadband", BROADBAND),
    ] {
        let sync = run(&cfg, link, AggregationPolicy::Sync);
        let semi = run(
            &cfg,
            link,
            AggregationPolicy::SemiSync { deadline_factor: 1.5 },
        );
        assert!(
            semi.total_secs <= sync.total_secs + 1e-6,
            "{name}: semi {} > sync {}",
            semi.total_secs,
            sync.total_secs
        );
        // The deadline must actually bite on this schedule: the slowest
        // client straggling at 4× blows through 1.5× the nominal round.
        assert!(semi.late_total > 0, "{name}: no client was ever cut");
        assert!(
            semi.total_secs < sync.total_secs,
            "{name}: cutting stragglers must shorten the run"
        );
        // Cut clients ship no update bytes.
        assert!(semi.total_bytes < sync.total_bytes);
    }
}

#[test]
fn overlap_wallclock_never_exceeds_sync() {
    let cfg = straggler_cfg(100);
    for link in [DATACENTER, CLOUD_WAN, BROADBAND] {
        let sync = run(&cfg, link, AggregationPolicy::Sync);
        let over = run(&cfg, link, AggregationPolicy::Overlap);
        assert!(over.total_secs <= sync.total_secs + 1e-6);
        // Same participation: overlap changes timing, not aggregation.
        assert_eq!(over.arrived_total, sync.arrived_total);
        assert_eq!(over.late_total, 0);
    }
}

#[test]
fn wan_hidden_behind_large_tau() {
    // §4.3: at τ=500 the 100 Mbit/s ladder rung is near-datacenter; at
    // τ=5 the WAN transfers dominate. No faults — pure comm accounting.
    let ratio = |tau: u64| {
        let cfg = ExperimentConfig::wallclock(8, 8, 5, tau, 3);
        let bb = run(&cfg, BROADBAND, AggregationPolicy::Sync);
        let dc = run(&cfg, DATACENTER, AggregationPolicy::Sync);
        bb.total_secs / dc.total_secs
    };
    let small = ratio(5);
    let large = ratio(500);
    assert!(large < 1.1, "broadband/datacenter at τ=500: {large}");
    assert!(small > 1.5, "broadband/datacenter at τ=5: {small}");
    assert!(large < small);
}

#[test]
fn all_dropped_rounds_advance_without_time() {
    let mut cfg = ExperimentConfig::wallclock(4, 4, 6, 50, 1);
    cfg.faults = FaultPlan { dropout_prob: 1.0, straggler_prob: 0.0, straggler_fraction: 0.5, seed: 1 };
    let rep = run(&cfg, CLOUD_WAN, AggregationPolicy::SemiSync { deadline_factor: 2.0 });
    assert_eq!(rep.rows.len(), 6);
    assert_eq!(rep.arrived_total, 0);
    assert_eq!(rep.dropped_total, 24);
    assert_eq!(rep.total_bytes, 0);
    assert_eq!(rep.total_secs, 0.0, "drops are known at dispatch");
    for r in &rep.rows {
        assert_eq!(r.slowest_client, -1);
    }
}

#[test]
fn timeline_identical_across_runs_and_consistent() {
    let cfg = straggler_cfg(60);
    for policy in [
        AggregationPolicy::Sync,
        AggregationPolicy::SemiSync { deadline_factor: 1.3 },
        AggregationPolicy::Overlap,
    ] {
        let a = run(&cfg, BROADBAND, policy);
        let b = run(&cfg, BROADBAND, policy);
        assert_eq!(a.rows, b.rows, "{}", policy.label());
        // Per-round accounting: arrived + late + dropped == K, time flows
        // monotonically, rounds abut exactly.
        let mut prev_end = 0.0;
        for r in &a.rows {
            assert_eq!(r.n_arrived + r.n_late + r.n_dropped, 8);
            assert_eq!(r.t_start_secs, prev_end);
            assert!(r.t_end_secs >= r.t_start_secs);
            assert!((r.round_secs - (r.t_end_secs - r.t_start_secs)).abs() < 1e-9);
            prev_end = r.t_end_secs;
        }
        assert_eq!(a.total_secs, prev_end);
    }
}

#[test]
fn federation_plan_replay_matches_direct_plan() {
    // Federation::round_plan is documented to equal RoundPlan::from_config;
    // pin the contract here without loading artifacts.
    let cfg = straggler_cfg(40);
    let a = RoundPlan::from_config(&cfg);
    let b = RoundPlan::from_config(&cfg.clone());
    assert_eq!(a, b);
    assert_eq!(a.rounds.len(), cfg.rounds);
    assert_eq!(a.tau, 40);
}

#[test]
fn uniform_profile_matches_explicit_fleet_of_equals() {
    let cfg = ExperimentConfig::wallclock(3, 3, 4, 20, 9);
    let plan = RoundPlan::from_config(&cfg);
    let sim_cfg = SimConfig::new(1_000_000, CLOUD_WAN, AggregationPolicy::Sync);
    let a = Simulator::uniform(&plan, 0.25, sim_cfg).run();
    let b = Simulator::new(
        plan.clone(),
        vec![ClientProfile { step_secs: 0.25 }; 3],
        sim_cfg,
    )
    .run();
    assert_eq!(a.rows, b.rows);
}
