//! Fixture-driven self-tests for the `photon lint` analysis plane.
//!
//! Every rule has a positive corpus (each `//~ rule` marker line must be
//! flagged, with exactly as many diagnostics as markers) and a negative
//! corpus (idiomatic code, allowlisted paths, reasoned suppressions, and
//! `#[cfg(test)]` bodies must stay silent). Fixtures live under
//! `tests/fixtures/analysis/` and declare the virtual path they lint as
//! on their first line: `// lint-fixture: <path>`.
//!
//! Two meta-tests close the loop: the shipped tree itself must lint
//! clean (so CI's `photon lint` gate cannot rot), and a seeded
//! violation tree must fail (so the gate provably still bites).

use std::fs;
use std::path::PathBuf;

use photon::analysis::{self, locks};

/// The crate root (the directory holding `src/lib.rs`), robust to being
/// run from either the repo root or the `rust/` subdirectory.
fn crate_root() -> PathBuf {
    if let Some(dir) = option_env!("CARGO_MANIFEST_DIR") {
        let p = PathBuf::from(dir);
        if p.join("src/lib.rs").is_file() {
            return p;
        }
    }
    for cand in [".", "rust", ".."] {
        let p = PathBuf::from(cand);
        if p.join("src/lib.rs").is_file() {
            return p;
        }
    }
    panic!("cannot locate the crate root (no src/lib.rs found)");
}

fn fixtures_dir() -> PathBuf {
    crate_root().join("tests/fixtures/analysis")
}

/// Load a fixture and its declared virtual path.
fn fixture(name: &str) -> (String, String) {
    let path = fixtures_dir().join(name);
    let text = fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("read fixture {}: {e}", path.display()));
    let first = text.lines().next().unwrap_or_default();
    let vpath = first
        .strip_prefix("// lint-fixture:")
        .unwrap_or_else(|| panic!("{name}: first line must be `// lint-fixture: <path>`"))
        .trim()
        .to_string();
    (vpath, text)
}

/// Parse `//~ rule [rule ...]` markers: one expected diagnostic per rule
/// token, anchored at the marker's line.
fn expected_markers(text: &str) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if let Some(pos) = line.find("//~") {
            for rule in line[pos + 3..].split_whitespace() {
                out.push((i + 1, rule.to_string()));
            }
        }
    }
    out.sort();
    out
}

/// Lint one fixture and require its diagnostics to match its markers
/// exactly — no misses, no extras.
fn check(name: &str) {
    let (vpath, text) = fixture(name);
    let mut got: Vec<(usize, String)> = analysis::lint_source(&vpath, &text)
        .into_iter()
        .map(|d| (d.line, d.rule.to_string()))
        .collect();
    got.sort();
    let want = expected_markers(&text);
    assert_eq!(
        got, want,
        "{name} (as {vpath}): diagnostics did not match //~ markers"
    );
}

#[test]
fn nondet_map_fixtures() {
    check("nondet_map_bad.rs");
    check("nondet_map_ok.rs");
    check("nondet_map_scope.rs");
}

#[test]
fn nondet_time_fixtures() {
    check("nondet_time_bad.rs");
    check("nondet_time_ok.rs");
    check("nondet_time_allow.rs");
    // obs plane: clock.rs is the sole allowlisted wall-clock site; every
    // other obs/ file is determinism-scoped and must stay clock-free.
    check("nondet_time_obs_clock.rs");
    check("nondet_time_obs_bad.rs");
}

#[test]
fn nondet_rng_fixtures() {
    check("nondet_rng_bad.rs");
    check("nondet_rng_ok.rs");
}

#[test]
fn wire_panic_fixtures() {
    check("wire_panic_bad.rs");
    check("wire_panic_ok.rs");
    // tree mode widened the wire scope: the sub-aggregator's collection
    // path is lint-covered exactly like net/proto.rs.
    check("wire_panic_subagg_bad.rs");
}

#[test]
fn wire_alloc_fixtures() {
    check("wire_alloc_bad.rs");
    check("wire_alloc_ok.rs");
    // ckpt/store.rs (spill-file decoder) is wire scope: torn writes reach
    // it exactly like hostile frames reach the link layer.
    check("wire_alloc_store_bad.rs");
}

#[test]
fn allow_policy_fixtures() {
    check("allow_policy_bad.rs");
}

#[test]
fn lock_fixtures_trip_no_per_file_rules() {
    // The lock corpus is analyzed structurally below; the per-file rules
    // must stay silent on it.
    check("locks_cycle.rs");
    check("locks_ok.rs");
}

/// Golden rendering: exact `file:line [rule] message` output, pinned so
/// diagnostics stay stable for humans and for CI log grepping.
#[test]
fn golden_nondet_map_diagnostics() {
    let (vpath, text) = fixture("nondet_map_bad.rs");
    let rendered: Vec<String> = analysis::lint_source(&vpath, &text)
        .iter()
        .map(|d| d.to_string())
        .collect();
    let map_msg = "std::collections::HashMap in a determinism-scoped module: \
                   hash iteration order varies per process, breaking bit-exact \
                   parity; use BTreeMap or sort before folding";
    let set_msg = "std::collections::HashSet in a determinism-scoped module: \
                   hash iteration order varies per process, breaking bit-exact \
                   parity; use BTreeSet or sort before folding";
    assert_eq!(
        rendered,
        vec![
            format!("metrics/mod.rs:3 [nondet-map] {map_msg}"),
            format!("metrics/mod.rs:4 [nondet-map] {set_msg}"),
            format!("metrics/mod.rs:7 [nondet-map] {map_msg}"),
            format!("metrics/mod.rs:12 [nondet-map] {set_msg}"),
        ]
    );
}

/// Every registered rule has an `--explain` writeup.
#[test]
fn every_rule_is_explained() {
    for &(rule, _) in analysis::RULES {
        let text = analysis::explain::explain(rule)
            .unwrap_or_else(|| panic!("rule {rule} has no --explain writeup"));
        assert!(text.len() > 200, "writeup for {rule} is too thin");
    }
}

fn lock_fixture(name: &str) -> locks::LockReport {
    let (vpath, text) = fixture(name);
    locks::analyze(&[(vpath, text)])
}

#[test]
fn lock_cycle_detected() {
    let rep = lock_fixture("locks_cycle.rs");
    let cycle = rep
        .cycle
        .as_ref()
        .expect("opposite-order acquisitions must produce a cycle witness");
    assert_eq!(cycle.first(), cycle.last(), "witness must close on itself");
    assert!(cycle.iter().any(|l| l == "queue"));
    assert!(cycle.iter().any(|l| l == "slots"));
    let diags = rep.diagnostics();
    assert_eq!(diags.len(), 1, "one diagnostic per cycle witness");
    assert_eq!(diags[0].rule, "lock-order");
}

#[test]
fn lock_consistent_order_is_acyclic() {
    let rep = lock_fixture("locks_ok.rs");
    assert!(rep.cycle.is_none(), "consistent order must not cycle");
    assert_eq!(rep.locks, vec!["queue".to_string(), "slots".to_string()]);
    assert_eq!(rep.edges.len(), 1, "temporaries must not contribute edges");
    assert_eq!(rep.edges[0].from, "queue");
    assert_eq!(rep.edges[0].to, "slots");
    assert!(rep.diagnostics().is_empty());
}

/// Meta-test: the shipped tree lints clean, and its real lock graph is
/// discovered and acyclic. This is the same invocation CI runs.
#[test]
fn shipped_tree_is_clean() {
    let root = crate_root().join("src");
    let report = analysis::lint_tree(&root).expect("lint_tree over src/");
    let rendered: Vec<String> =
        report.diagnostics.iter().map(|d| d.to_string()).collect();
    assert!(
        rendered.is_empty(),
        "shipped tree must lint clean, got {} violation(s):\n{}",
        rendered.len(),
        rendered.join("\n")
    );
    assert!(report.locks.cycle.is_none(), "{}", report.locks.summary());
    assert!(
        report.locks.locks.len() >= 2,
        "the real lock classes should be discovered, got {:?}",
        report.locks.locks
    );
    assert!(
        report.files > 30,
        "suspiciously few files scanned under {}: {}",
        root.display(),
        report.files
    );
}

/// Meta-test: the seeded violation tree (the CI negative gate) fails.
#[test]
fn seeded_violation_tree_fails() {
    let root = fixtures_dir().join("seeded");
    let report = analysis::lint_tree(&root).expect("lint_tree over seeded/");
    assert!(
        !report.diagnostics.is_empty(),
        "the seeded violation must be caught"
    );
    assert!(report.diagnostics.iter().any(|d| d.rule == "nondet-map"));
    // The obs-plane clock allowlist is exactly one file deep: a wall-clock
    // read seeded anywhere else under obs/ must still trip the gate.
    assert!(report.diagnostics.iter().any(|d| d.rule == "nondet-time"));
    // Tree-mode scope extensions: a panic seeded in net/subagg.rs and a
    // decoded-length allocation seeded in ckpt/store.rs must both bite.
    assert!(report
        .diagnostics
        .iter()
        .any(|d| d.rule == "wire-panic" && d.file == "net/subagg.rs"));
    assert!(report
        .diagnostics
        .iter()
        .any(|d| d.rule == "wire-alloc" && d.file == "ckpt/store.rs"));
}
