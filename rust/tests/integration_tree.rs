//! Integration: multi-tier aggregation (`net::subagg`) against the
//! in-process tiered federation. Requires `make artifacts`.
//!
//! The contract under test (ISSUE 9 acceptance): a 3-tier loopback fleet —
//! root server, two sub-aggregators, four workers — bit-equals the
//! in-process `Federation::run` with the same `cfg.tiers`: round records
//! (NLL included), the final global model, and the round checkpoints'
//! bytes. The partition is *config* (`tier_slices` over the sampled
//! cohort), so the pre-folded `(weight, mean)` pairs the sub-aggregators
//! push upstream land on exactly the floats the in-process `tiered_fold`
//! produces. The contract must also survive seeded chaos (crash/rejoin of
//! a sub-aggregator's worker, replayed via the realized trace) and the q8
//! update codec.
//!
//! Two flat-path riders live here too: the `AssignState::Ref` regression
//! test (idle-client assigns shrink once the server's `StateStore` and the
//! worker's cache hold the same generation) and the `#[ignore]`d 100k-
//! client soak (polling accept path + `StateStore` under a fixed resident
//! budget, RSS-checked via `/proc/self/status`).

use std::path::PathBuf;
use std::sync::Arc;

use photon::chaos::{ChaosConfig, Schedule};
use photon::ckpt::{latest_in, Checkpoint};
use photon::cluster::faults::FaultPlan;
use photon::compress::UpdateCodec;
use photon::config::{ExperimentConfig, OptStatePolicy};
use photon::coordinator::Federation;
use photon::metrics::RoundRecord;
use photon::net::{run_loopback, FleetOpts, FleetReport};
use photon::obs;
use photon::optim::schedule::CosineSchedule;
use photon::runtime::{ModelRuntime, Runtime};

fn model() -> Arc<ModelRuntime> {
    // Per-thread cache (same rationale as integration_fed.rs).
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

/// K=5 of P=6 clients over two tiers, dropouts + stragglers in the plan:
/// `tier_slices(5, 2)` gives the sub-aggregators a 3/2 split of every
/// sampled cohort (shrinking with planned dropouts, never re-balancing).
fn tree_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 5;
    cfg.rounds = 3;
    cfg.local_steps = 6;
    cfg.eval_batches = 2;
    cfg.seed = 11;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 18, 2);
    cfg.faults = FaultPlan::new(0.3, 0.3, 11);
    cfg.tiers = 2;
    cfg
}

/// Full participation (K=P=6), no client-level faults: every cut in the
/// chaos test is attributable to the injected worker churn.
fn chaos_tree_cfg(rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = rounds;
    cfg.local_steps = 4;
    cfg.eval_batches = 2;
    cfg.seed = seed;
    let total = rounds as u64 * 4;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), 2);
    cfg.faults = FaultPlan::none();
    cfg.tiers = 2;
    cfg
}

fn assert_parity(reference: &[RoundRecord], live: &[RoundRecord], what: &str) {
    assert_eq!(reference.len(), live.len(), "{what}: round count");
    for (r, n) in reference.iter().zip(live) {
        assert!(
            r.agrees_with(n),
            "{what}: round {} diverged\n  in-process: {r:?}\n  tree fleet: {n:?}",
            r.round
        );
    }
}

/// participated + cut must equal the runnable sample every round — the
/// exactly-once accounting survives the extra tier.
fn assert_exactly_once(report: &FleetReport, k: usize, what: &str) {
    for rec in &report.records {
        let cut = report.trace.cut_for(rec.round).len();
        assert_eq!(
            rec.participated + cut,
            k,
            "{what}: round {} folded {} + cut {cut} != K={k}",
            rec.round,
            rec.participated
        );
    }
}

/// The fleet's member accounting must close: every participant folded by
/// the in-process reference arrived upstream inside some `FoldedPush`.
fn assert_member_accounting(report: &FleetReport, reference: &[RoundRecord]) {
    assert_eq!(report.subaggs.len(), 2, "both sub-aggregators must report");
    let folded: u64 = report.subaggs.iter().map(|s| s.members_folded).sum();
    let participated: usize = reference.iter().map(|r| r.participated).sum();
    assert_eq!(folded as usize, participated, "members folded vs participated");
    for (i, s) in report.subaggs.iter().enumerate() {
        assert!(s.rounds_served >= 1, "sub-aggregator {i} never pushed a round");
        assert_eq!(s.malformed_frames, 0, "sub-aggregator {i} saw bad frames");
    }
}

#[test]
fn tree_fleet_bit_equals_in_process_tiered_run_and_its_checkpoints() {
    let base = std::env::temp_dir().join(format!("photon_tree_{}", std::process::id()));
    let ref_dir = base.join("ref");
    let fleet_dir = base.join("fleet");
    std::fs::create_dir_all(&ref_dir).unwrap();
    std::fs::create_dir_all(&fleet_dir).unwrap();

    let cfg = tree_cfg();
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    fed.ckpt_dir = Some(ref_dir.clone());
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 4,
            subaggs: 2,
            compress: true,
            ckpt_dir: Some(fleet_dir.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "no faults beyond the plan: {:?}", report.cuts);
    assert_parity(&reference, &report.records, "tree fleet");
    assert_eq!(fed.global, report.global, "global model must be bit-identical");
    assert_member_accounting(&report, &reference);

    // Checkpoint-byte parity: the latest round checkpoint written by the
    // tree fleet must be the byte-identical file the in-process run wrote,
    // up to the two wall-clock bookkeeping fields.
    let (round_f, path_f) = latest_in(&fleet_dir).unwrap().expect("fleet checkpoint");
    let (round_r, path_r) = latest_in(&ref_dir).unwrap().expect("reference checkpoint");
    assert_eq!(round_f, round_r, "both runs checkpoint the same final round");
    let mut ck_f = Checkpoint::load(&path_f).unwrap();
    let mut ck_r = Checkpoint::load(&path_r).unwrap();
    ck_f.timestamp = 0;
    ck_f.elapsed_secs = 0.0;
    ck_r.timestamp = 0;
    ck_r.elapsed_secs = 0.0;
    assert_eq!(
        ck_f.encode(),
        ck_r.encode(),
        "checkpoint bytes must match up to wall-clock fields"
    );
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn tree_fleet_with_q8_codec_matches_in_process() {
    // The lossy-codec parity contract (ISSUE 4) survives the extra tier:
    // workers q8-encode their pseudo-deltas, sub-aggregators decode and
    // fold the *decoded* rows (never re-code), and the in-process run
    // replays the identical transform — records (incl. wire-byte
    // accounting) and global model stay bit-equal.
    let mut cfg = tree_cfg();
    cfg.codec = UpdateCodec::Q8 { block: 64 };
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 4, subaggs: 2, compress: true, ..FleetOpts::default() },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "no faults beyond the plan: {:?}", report.cuts);
    assert_parity(&reference, &report.records, "q8 tree fleet");
    assert_eq!(fed.global, report.global, "global model must be bit-identical");
    assert_member_accounting(&report, &reference);
    for r in &reference {
        if r.participated > 0 {
            assert!(
                r.comm_bytes_wire < r.comm_bytes,
                "round {}: wire {} !< dense {}",
                r.round,
                r.comm_bytes_wire,
                r.comm_bytes
            );
        }
    }
}

#[test]
fn subagg_worker_crash_and_rejoin_bit_equals_trace_replay() {
    // Crash-heavy schedule over the tree fleet's four workers: a crashed
    // worker disconnects from its *sub-aggregator* mid-round; with a
    // rejoin it reclaims its slot and pending leases by identity, without
    // one the sub-aggregator's downstream deadline cuts them and the root
    // folds the shrunken push. Either way the realized trace replays
    // bit-exactly through the tiered in-process fold.
    let cfg = chaos_tree_cfg(4, 61);
    let ccfg = ChaosConfig { crash_prob: 0.6, rejoin_prob: 0.7, ..ChaosConfig::none() };
    let schedule = Schedule::generate(0x7EE5_C401, 4, 4, ccfg);
    assert!(!schedule.is_quiet(), "seed must inject crashes");
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            subaggs: 2,
            compress: true,
            deadline_secs: Some(16.0),
            chaos: Some(schedule),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 4, "every round must commit under churn");
    assert_exactly_once(&report, 6, "chaotic tree fleet");

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_trace(&report.trace).unwrap();
    assert_parity(&replayed, &report.records, "chaotic tree fleet vs trace replay");
    assert_eq!(replay.global, report.global, "global model must be bit-identical");
}

#[test]
fn flat_idle_client_assigns_shrink_to_state_refs() {
    // The StateStore regression rider (ISSUE 9 satellite): with a single
    // flat worker, round 0 ships every sampled client's state in full;
    // from round 1 on the server's store generation matches the worker's
    // cache for every client the worker itself advanced (at most one
    // fresh client per round can still need a full state), so the
    // `RoundAssign` frames shrink to `AssignState::Ref` stubs. KeepOpt
    // makes the state mass dominate the frame, so the shrink is stark.
    let mut cfg = tree_cfg();
    cfg.tiers = 1;
    cfg.faults = FaultPlan::none();
    cfg.opt_state = OptStatePolicy::KeepOpt;
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 1, compress: false, ..FleetOpts::default() },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "{:?}", report.cuts);
    assert_parity(&reference, &report.records, "single-worker ref fleet");
    assert_eq!(fed.global, report.global, "Ref assigns must not touch the math");

    let ab = &report.workers[0].assign_bytes;
    assert_eq!(ab.len(), 3, "one RoundAssign per round: {ab:?}");
    // Round 0: 5 full states. Rounds 1-2: at most one client per round is
    // newly sampled (5 of 6 sampled per round), everything else rides as
    // an 9-byte Ref — so later assigns must be well under half of round
    // 0's, not merely smaller.
    assert!(ab[1] < ab[0] / 2, "round 1 assign must shrink: {ab:?}");
    assert!(ab[2] < ab[0] / 2, "round 2 assign must shrink: {ab:?}");
}

/// Resident-set size in KiB via `/proc/self/status` (`None` off-Linux).
fn resident_kib() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = text.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// The scale soak (ISSUE 9 satellite): a 100 000-client federation serves
/// a sampled round through the nonblocking accept/read path with the
/// client-state store pinned to a tiny resident budget, so the cohort's
/// post-round states *must* spill to disk — and the process RSS must stay
/// bounded (no O(n_clients · n_params) resident blow-up). Run via
/// `cargo test -q -- --ignored` (the CI `soak` job budget covers it).
#[test]
#[ignore = "soak: 100k-client round, ~minutes of wall-clock; run with -- --ignored"]
fn soak_100k_client_round_stays_within_state_budget() {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 100_000;
    cfg.clients_per_round = 256;
    cfg.rounds = 1;
    cfg.local_steps = 1;
    cfg.eval_batches = 1;
    cfg.seed = 17;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 2, 1);
    cfg.faults = FaultPlan::none();

    // The soak writes a structured event log (`PHOTON_OBS_LOG` overrides
    // the path): CI schema-checks it with `photon evck` and uploads it as
    // a triage artifact when the soak fails.
    let obs_log = std::env::var("PHOTON_OBS_LOG")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/soak_events.jsonl"));
    let report = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 2,
            compress: true,
            // 8 KiB resident: ~256 stateless client states per round is a
            // couple dozen KiB, so the LRU must spill under this budget.
            state_budget: Some(8 * 1024),
            watchdog_secs: Some(1200.0),
            obs_log: Some(obs_log.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 1, "the sampled round must commit");
    assert_eq!(
        report.records[0].participated, 256,
        "every sampled client must fold"
    );
    assert!(
        report.store_spills > 0,
        "a 8 KiB budget over a 256-client cohort must spill ({} spills)",
        report.store_spills
    );
    let text = std::fs::read_to_string(&obs_log).unwrap();
    let n = obs::validate_log_text(&text).expect("soak event log must validate");
    assert!(n > 0, "the soak must emit events");
    if let Some(kib) = resident_kib() {
        // Generous absolute ceiling: the run holds one model runtime and
        // 100k lightweight client nodes, not 100k resident states. A
        // resident-state leak (the regression this soak pins) would blow
        // past this by an order of magnitude.
        assert!(
            kib < 4 * 1024 * 1024,
            "100k-client round used {kib} KiB resident — state budget leak?"
        );
    }
}
