//! Integration: multi-tier aggregation (`net::subagg`) against the
//! in-process tiered federation. Requires `make artifacts`.
//!
//! The contract under test (ISSUE 9 acceptance): a 3-tier loopback fleet —
//! root server, two sub-aggregators, four workers — bit-equals the
//! in-process `Federation::run` with the same `cfg.tiers`: round records
//! (NLL included), the final global model, and the round checkpoints'
//! bytes. The partition is *config* (`tier_slices` over the sampled
//! cohort), so the pre-folded `(weight, mean)` pairs the sub-aggregators
//! push upstream land on exactly the floats the in-process `tiered_fold`
//! produces. The contract must also survive seeded chaos (crash/rejoin of
//! a sub-aggregator's worker, replayed via the realized trace) and the q8
//! update codec.
//!
//! Two flat-path riders live here too: the `AssignState::Ref` regression
//! test (idle-client assigns shrink once the server's `StateStore` and the
//! worker's cache hold the same generation) and the `#[ignore]`d 100k-
//! client soak (polling accept path + `StateStore` under a fixed resident
//! budget, RSS-checked via `/proc/self/status`).

use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use photon::chaos::{ChaosConfig, Schedule};
use photon::ckpt::{latest_in, Checkpoint};
use photon::cluster::faults::FaultPlan;
use photon::compress::UpdateCodec;
use photon::config::{ExperimentConfig, OptStatePolicy};
use photon::coordinator::{ClientUpdate, Federation};
use photon::metrics::RoundRecord;
use photon::net::proto::{
    self, AssignState, FoldedMember, FoldedPush, Join, Msg, PROTO_VERSION,
};
use photon::net::{run_loopback, FleetOpts, FleetReport, ServeOpts, Server};
use photon::obs;
use photon::optim::schedule::CosineSchedule;
use photon::runtime::{ModelRuntime, Runtime};

fn model() -> Arc<ModelRuntime> {
    // Per-thread cache (same rationale as integration_fed.rs).
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

/// K=5 of P=6 clients over two tiers, dropouts + stragglers in the plan:
/// `tier_slices(5, 2)` gives the sub-aggregators a 3/2 split of every
/// sampled cohort (shrinking with planned dropouts, never re-balancing).
fn tree_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 5;
    cfg.rounds = 3;
    cfg.local_steps = 6;
    cfg.eval_batches = 2;
    cfg.seed = 11;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 18, 2);
    cfg.faults = FaultPlan::new(0.3, 0.3, 11);
    cfg.tiers = 2;
    cfg
}

/// Full participation (K=P=6), no client-level faults: every cut in the
/// chaos test is attributable to the injected worker churn.
fn chaos_tree_cfg(rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = rounds;
    cfg.local_steps = 4;
    cfg.eval_batches = 2;
    cfg.seed = seed;
    let total = rounds as u64 * 4;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), 2);
    cfg.faults = FaultPlan::none();
    cfg.tiers = 2;
    cfg
}

fn assert_parity(reference: &[RoundRecord], live: &[RoundRecord], what: &str) {
    assert_eq!(reference.len(), live.len(), "{what}: round count");
    for (r, n) in reference.iter().zip(live) {
        assert!(
            r.agrees_with(n),
            "{what}: round {} diverged\n  in-process: {r:?}\n  tree fleet: {n:?}",
            r.round
        );
    }
}

/// participated + cut must equal the runnable sample every round — the
/// exactly-once accounting survives the extra tier.
fn assert_exactly_once(report: &FleetReport, k: usize, what: &str) {
    for rec in &report.records {
        let cut = report.trace.cut_for(rec.round).len();
        assert_eq!(
            rec.participated + cut,
            k,
            "{what}: round {} folded {} + cut {cut} != K={k}",
            rec.round,
            rec.participated
        );
    }
}

/// The fleet's member accounting must close: every participant folded by
/// the in-process reference arrived upstream inside some `FoldedPush`.
fn assert_member_accounting(report: &FleetReport, reference: &[RoundRecord]) {
    assert_eq!(report.subaggs.len(), 2, "both sub-aggregators must report");
    let folded: u64 = report.subaggs.iter().map(|s| s.members_folded).sum();
    let participated: usize = reference.iter().map(|r| r.participated).sum();
    assert_eq!(folded as usize, participated, "members folded vs participated");
    for (i, s) in report.subaggs.iter().enumerate() {
        assert!(s.rounds_served >= 1, "sub-aggregator {i} never pushed a round");
        assert_eq!(s.malformed_frames, 0, "sub-aggregator {i} saw bad frames");
    }
}

#[test]
fn tree_fleet_bit_equals_in_process_tiered_run_and_its_checkpoints() {
    let base = std::env::temp_dir().join(format!("photon_tree_{}", std::process::id()));
    let ref_dir = base.join("ref");
    let fleet_dir = base.join("fleet");
    std::fs::create_dir_all(&ref_dir).unwrap();
    std::fs::create_dir_all(&fleet_dir).unwrap();

    let cfg = tree_cfg();
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    fed.ckpt_dir = Some(ref_dir.clone());
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 4,
            subaggs: 2,
            compress: true,
            ckpt_dir: Some(fleet_dir.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "no faults beyond the plan: {:?}", report.cuts);
    assert_parity(&reference, &report.records, "tree fleet");
    assert_eq!(fed.global, report.global, "global model must be bit-identical");
    assert_member_accounting(&report, &reference);

    // Checkpoint-byte parity: the latest round checkpoint written by the
    // tree fleet must be the byte-identical file the in-process run wrote,
    // up to the two wall-clock bookkeeping fields.
    let (round_f, path_f) = latest_in(&fleet_dir).unwrap().expect("fleet checkpoint");
    let (round_r, path_r) = latest_in(&ref_dir).unwrap().expect("reference checkpoint");
    assert_eq!(round_f, round_r, "both runs checkpoint the same final round");
    let mut ck_f = Checkpoint::load(&path_f).unwrap();
    let mut ck_r = Checkpoint::load(&path_r).unwrap();
    ck_f.timestamp = 0;
    ck_f.elapsed_secs = 0.0;
    ck_r.timestamp = 0;
    ck_r.elapsed_secs = 0.0;
    assert_eq!(
        ck_f.encode(),
        ck_r.encode(),
        "checkpoint bytes must match up to wall-clock fields"
    );
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn tree_fleet_with_q8_codec_matches_in_process() {
    // The lossy-codec parity contract (ISSUE 4) survives the extra tier:
    // workers q8-encode their pseudo-deltas, sub-aggregators decode and
    // fold the *decoded* rows (never re-code), and the in-process run
    // replays the identical transform — records (incl. wire-byte
    // accounting) and global model stay bit-equal.
    let mut cfg = tree_cfg();
    cfg.codec = UpdateCodec::Q8 { block: 64 };
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 4, subaggs: 2, compress: true, ..FleetOpts::default() },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "no faults beyond the plan: {:?}", report.cuts);
    assert_parity(&reference, &report.records, "q8 tree fleet");
    assert_eq!(fed.global, report.global, "global model must be bit-identical");
    assert_member_accounting(&report, &reference);
    for r in &reference {
        if r.participated > 0 {
            assert!(
                r.comm_bytes_wire < r.comm_bytes,
                "round {}: wire {} !< dense {}",
                r.round,
                r.comm_bytes_wire,
                r.comm_bytes
            );
        }
    }
}

#[test]
fn subagg_worker_crash_and_rejoin_bit_equals_trace_replay() {
    // Crash-heavy schedule over the tree fleet's four workers: a crashed
    // worker disconnects from its *sub-aggregator* mid-round; with a
    // rejoin it reclaims its slot and pending leases by identity, without
    // one the sub-aggregator's downstream deadline cuts them and the root
    // folds the shrunken push. Either way the realized trace replays
    // bit-exactly through the tiered in-process fold.
    let cfg = chaos_tree_cfg(4, 61);
    let ccfg = ChaosConfig { crash_prob: 0.6, rejoin_prob: 0.7, ..ChaosConfig::none() };
    let schedule = Schedule::generate(0x7EE5_C401, 4, 4, ccfg);
    assert!(!schedule.is_quiet(), "seed must inject crashes");
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            subaggs: 2,
            compress: true,
            deadline_secs: Some(16.0),
            chaos: Some(schedule),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 4, "every round must commit under churn");
    assert_exactly_once(&report, 6, "chaotic tree fleet");

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_trace(&report.trace).unwrap();
    assert_parity(&replayed, &report.records, "chaotic tree fleet vs trace replay");
    assert_eq!(replay.global, report.global, "global model must be bit-identical");
}

#[test]
fn flat_idle_client_assigns_shrink_to_state_refs() {
    // The StateStore regression rider (ISSUE 9 satellite): with a single
    // flat worker, round 0 ships every sampled client's state in full;
    // from round 1 on the server's store generation matches the worker's
    // cache for every client the worker itself advanced (at most one
    // fresh client per round can still need a full state), so the
    // `RoundAssign` frames shrink to `AssignState::Ref` stubs. KeepOpt
    // makes the state mass dominate the frame, so the shrink is stark.
    let mut cfg = tree_cfg();
    cfg.tiers = 1;
    cfg.faults = FaultPlan::none();
    cfg.opt_state = OptStatePolicy::KeepOpt;
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 1, compress: false, ..FleetOpts::default() },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "{:?}", report.cuts);
    assert_parity(&reference, &report.records, "single-worker ref fleet");
    assert_eq!(fed.global, report.global, "Ref assigns must not touch the math");

    let ab = &report.workers[0].assign_bytes;
    assert_eq!(ab.len(), 3, "one RoundAssign per round: {ab:?}");
    // Round 0: 5 full states. Rounds 1-2: at most one client per round is
    // newly sampled (5 of 6 sampled per round), everything else rides as
    // an 9-byte Ref — so later assigns must be well under half of round
    // 0's, not merely smaller.
    assert!(ab[1] < ab[0] / 2, "round 1 assign must shrink: {ab:?}");
    assert!(ab[2] < ab[0] / 2, "round 2 assign must shrink: {ab:?}");
    // With no state budget the store runs generation-only: the federation
    // already owns every client state, so the server must never hold a
    // second resident encoded copy — the Ref shrink above works off the
    // generation ledger alone.
    assert_eq!(
        report.store_resident_peak, 0,
        "no budget ⇒ generation-only store ⇒ zero resident bytes ever"
    );
}

#[test]
fn flake_cut_client_is_reshipped_full_and_replays_bit_exactly() {
    // The Ref-invalidation regression (review fix): a flaked push leaves
    // the worker's cache holding the client's *advanced* state while the
    // server cuts the lease and keeps the pre-round state. The server
    // must drop that connection's generation claim with the cut so the
    // next round re-ships the full pre-round state — a `Ref` into the
    // diverged cache would run the client from the wrong state and
    // silently break the trace-replay contract.
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 4;
    cfg.clients_per_round = 4; // K = P: a cut client is resampled next round
    cfg.rounds = 3;
    cfg.local_steps = 2;
    cfg.eval_batches = 1;
    cfg.seed = 23;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 6, 2);
    cfg.faults = FaultPlan::none();
    cfg.opt_state = OptStatePolicy::KeepOpt; // full states dominate the frame

    // Every (worker, round) cell flakes: one victim frame per round is
    // corrupted on the wire, so its client is deadline-cut every round.
    let ccfg = ChaosConfig { flake_prob: 1.0, ..ChaosConfig::none() };
    let schedule = Schedule::generate(0xF1A4_E001, 1, 3, ccfg);
    assert!(schedule.needs_deadline(), "every cell must flake");

    let cfg_replay = cfg.clone();
    let report = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 1,
            compress: false,
            deadline_secs: Some(6.0),
            chaos: Some(schedule),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 3, "every round must commit");
    assert_eq!(report.workers[0].frames_flaked, 3, "one flake per round");
    for rec in &report.records {
        assert_eq!(
            report.trace.cut_for(rec.round).len(),
            1,
            "round {}: exactly the flake victim is cut",
            rec.round
        );
    }

    // Bit-parity with the in-process replay of the realized cuts — the
    // contract a stale Ref would break.
    let mut replay = Federation::with_model(cfg_replay, model()).unwrap();
    let replayed = replay.run_trace(&report.trace).unwrap();
    assert_parity(&replayed, &report.records, "flaked fleet vs trace replay");
    assert_eq!(replay.global, report.global, "global model must be bit-identical");

    // Structural witness of the fix, independent of which client each
    // round's flake hits: with KeepOpt and compression off, round 0 is the
    // global broadcast (~4n bytes) plus four full states (~8n each), ~36n
    // total. A later round re-shipping the previous round's cut client in
    // full is ~12n; all-Ref (the bug) would be ~4n. ab[0]/6 (~6n)
    // separates the two regimes with margin on both sides.
    let ab = &report.workers[0].assign_bytes;
    assert_eq!(ab.len(), 3, "one RoundAssign per round: {ab:?}");
    for r in 1..ab.len() {
        assert!(
            ab[r] > ab[0] / 6,
            "round {r}: the flake-cut client must ride Full again, not as a \
             Ref into a diverged cache: {ab:?}"
        );
    }
}

#[test]
fn duplicate_member_folded_push_is_cut_not_a_crash() {
    // Review regression: a FoldedPush that repeats a member passes a
    // *self-referential* weight check (the claimed weight is summed over
    // the same duplicated list), but `commit_round_folded` re-derives the
    // weight from the deduplicated slot-ordered accepted updates — so
    // before the strict-slot-order admission check the mismatch surfaced
    // as a commit-time bail that killed the whole run. Malformed ⇒ cut,
    // never crash: the slice must drop through the dropped-client path
    // and the round must still commit, with zero participants.
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 2;
    cfg.clients_per_round = 1;
    cfg.rounds = 1;
    cfg.local_steps = 1;
    cfg.eval_batches = 1;
    cfg.seed = 29;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 2, 1);
    cfg.faults = FaultPlan::none();
    cfg.tiers = 2; // tier_slices(1, 2) = one group of the one sampled client

    let fed = Federation::with_model(cfg, model()).unwrap();
    let mut server = Server::with_federation(
        fed,
        ServeOpts {
            bind: "127.0.0.1:0".into(),
            min_workers: 1,
            compress: false,
            // Budget 0: the assign-time `put` spills to disk, so this run
            // also witnesses spill-directory removal on shutdown.
            state_budget: Some(0),
            ..ServeOpts::default()
        },
    )
    .unwrap();
    let addr = server.local_addr();

    // A hand-rolled sub-aggregator speaking raw proto v4: join, take the
    // slice, answer with a push whose two members are the same client.
    let rogue = std::thread::spawn(move || -> anyhow::Result<()> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        proto::write_msg(
            &mut stream,
            &Msg::SubJoin(Join {
                proto: PROTO_VERSION,
                name: "rogue".into(),
                identity: 0,
            }),
            false,
        )?;
        let Msg::JoinAck(ack) = proto::read_msg(&mut stream)? else {
            anyhow::bail!("expected JoinAck");
        };
        let assign = loop {
            match proto::read_msg(&mut stream)? {
                Msg::RoundAssign(a) => break a,
                Msg::Shutdown => anyhow::bail!("shut down before any assignment"),
                _ => {}
            }
        };
        anyhow::ensure!(assign.tasks.len() == 1, "one group of one client");
        let AssignState::Full(state) = assign.tasks[0].state.clone() else {
            anyhow::bail!("tree assigns are always Full");
        };
        let member = FoldedMember {
            update: ClientUpdate {
                client_id: assign.tasks[0].client as usize,
                params: Vec::new(),
                n_samples: 64.0,
                loss_mean: 2.0,
                loss_last: 2.0,
                step_grad_norm_mean: 0.0,
                applied_update_norm_mean: 0.0,
                act_norm_mean: 0.0,
                model_norm: 0.0,
                steps_done: 1,
                wire_bytes: 0,
            },
            state,
        };
        let members = vec![member.clone(), member];
        // The self-referential weight: summed over the duplicated member
        // list exactly as the server's structural check sums it, so only
        // the strict-slot-order rule can reject this push at admission.
        let weight: f64 = members.iter().map(|m| m.update.n_samples).sum();
        proto::write_msg(
            &mut stream,
            &Msg::FoldedPush(FoldedPush {
                session: ack.session,
                round: assign.round,
                weight,
                mean: vec![0.0; assign.global.len()],
                members,
            }),
            false,
        )?;
        loop {
            if matches!(proto::read_msg(&mut stream)?, Msg::Shutdown) {
                return Ok(());
            }
        }
    });

    let records = server
        .run()
        .expect("a malformed folded push must cut the slice, never kill the run");
    rogue.join().unwrap().unwrap();
    assert_eq!(records.len(), 1, "the round must still commit");
    assert_eq!(records[0].participated, 0, "the whole slice must be cut");
    assert_eq!(server.cuts.len(), 1, "one realized cut round: {:?}", server.cuts);
    assert_eq!(server.cuts[0].0, 0);
    assert_eq!(server.cuts[0].1.len(), 1, "the one sampled client is cut");
    // Budget 0 forced assign-time spills; shutdown must have removed them.
    assert!(server.state_store().spill_count() > 0, "budget 0 must spill");
    assert!(
        !server.state_store().spill_dir().exists(),
        "shutdown must remove the spill directory"
    );
}

#[test]
fn underprovisioned_tree_fleet_fails_fast() {
    // Review fix: tiers = 3 with only two sub-aggregators used to hang
    // out the root's full join timeout every round (`tier_slices` makes
    // min(tiers, K) groups and the tree round waits for that many live
    // peers). The harness must refuse the shape up front instead.
    let mut cfg = tree_cfg();
    cfg.tiers = 3;
    let err = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 2, subaggs: 2, compress: true, ..FleetOpts::default() },
    )
    .unwrap_err()
    .to_string();
    assert!(
        err.contains("sub-aggregator per tier group"),
        "must fail fast with the group arithmetic, not hang: {err}"
    );
}

/// Resident-set size in KiB via `/proc/self/status` (`None` off-Linux).
fn resident_kib() -> Option<u64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = text.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// The scale soak (ISSUE 9 satellite): a 100 000-client federation serves
/// a sampled round through the nonblocking accept/read path with the
/// client-state store pinned to a tiny resident budget, so the cohort's
/// post-round states *must* spill to disk — and the process RSS must stay
/// bounded (no O(n_clients · n_params) resident blow-up). Run via
/// `cargo test -q -- --ignored` (the CI `soak` job budget covers it).
#[test]
#[ignore = "soak: 100k-client round, ~minutes of wall-clock; run with -- --ignored"]
fn soak_100k_client_round_stays_within_state_budget() {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 100_000;
    cfg.clients_per_round = 256;
    cfg.rounds = 1;
    cfg.local_steps = 1;
    cfg.eval_batches = 1;
    cfg.seed = 17;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 2, 1);
    cfg.faults = FaultPlan::none();

    // The soak writes a structured event log (`PHOTON_OBS_LOG` overrides
    // the path): CI schema-checks it with `photon evck` and uploads it as
    // a triage artifact when the soak fails.
    let obs_log = std::env::var("PHOTON_OBS_LOG")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/soak_events.jsonl"));
    let report = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 2,
            compress: true,
            // 8 KiB resident: ~256 stateless client states per round is a
            // couple dozen KiB, so the LRU must spill under this budget.
            state_budget: Some(8 * 1024),
            watchdog_secs: Some(1200.0),
            obs_log: Some(obs_log.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 1, "the sampled round must commit");
    assert_eq!(
        report.records[0].participated, 256,
        "every sampled client must fold"
    );
    assert!(
        report.store_spills > 0,
        "a 8 KiB budget over a 256-client cohort must spill ({} spills)",
        report.store_spills
    );
    assert!(
        report.store_resident_peak > 0 && report.store_resident_peak <= 8 * 1024,
        "the resident high-water mark must witness an active but bounded \
         cache ({} bytes over the 8192-byte budget)",
        report.store_resident_peak
    );
    let text = std::fs::read_to_string(&obs_log).unwrap();
    let n = obs::validate_log_text(&text).expect("soak event log must validate");
    assert!(n > 0, "the soak must emit events");
    if let Some(kib) = resident_kib() {
        // Generous absolute ceiling: the run holds one model runtime and
        // 100k lightweight client nodes, not 100k resident states. A
        // resident-state leak (the regression this soak pins) would blow
        // past this by an order of magnitude.
        assert!(
            kib < 4 * 1024 * 1024,
            "100k-client round used {kib} KiB resident — state budget leak?"
        );
    }
}
