// lint-fixture: net/subagg.rs
// Positive corpus: the sub-aggregator's downstream collection path is
// wire scope — panics and raw indexing on decoded frames must be flagged
// exactly as they are in net/proto.rs.

fn collect(stream: &mut TcpStream) -> Result<()> {
    let frame = read_frame(stream)?;
    let tag = frame[0]; //~ wire-panic
    let msg = Msg::decode(&frame).unwrap(); //~ wire-panic
    let push = msg.push.expect("push"); //~ wire-panic
    if tag == 0 {
        unreachable!("joins are handled by the poller"); //~ wire-panic
    }
    fold(push)
}
