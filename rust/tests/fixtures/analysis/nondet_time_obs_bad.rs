// lint-fixture: obs/event.rs
// Positive corpus for nondet-time: everywhere in obs/ except clock.rs is
// determinism-scoped and clock-free — replay and `photon top --replay`
// must be pure functions of the log bytes.

fn stamp_record() -> u64 {
    let ts = SystemTime::now(); //~ nondet-time
    ts.elapsed().as_micros() as u64
}
