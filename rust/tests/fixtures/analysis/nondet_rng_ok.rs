// lint-fixture: data/corpus.rs
// Negative corpus for nondet-rng: seeded util::rng streams are the
// sanctioned source of randomness.
use crate::util::rng::Rng;

fn sample(rng: &mut Rng) -> u64 {
    rng.next_u64()
}

fn client_stream(root: &Rng, client: u64) -> Rng {
    root.derive("corpus", client)
}
