// lint-fixture: obs/clock.rs
// Negative corpus for nondet-time: obs/clock.rs is the observability
// plane's ONE allowlisted wall-clock site (event `ts_us` timestamps are
// display metadata, never an ordering key).

pub fn wall_ts_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}
