// lint-fixture: net/server.rs
// Lock-order positive corpus (fed to locks::analyze): submit and drain
// take the same two locks in opposite orders — the graph must cycle.

fn submit(&self) {
    let q = self.queue.lock();
    let s = self.slots.lock();
    q.push(s.take());
}

fn drain(&self) {
    let s = self.slots.lock();
    let q = self.queue.lock();
    s.push(q.take());
}
