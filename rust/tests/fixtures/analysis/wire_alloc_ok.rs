// lint-fixture: net/proto.rs
// Negative corpus for wire-alloc: capacity_hint, a cited hard bound, and
// sizing from bytes that actually arrived.

fn dec_tasks(d: &mut Dec) -> Result<Vec<Task>> {
    let n = d.u64()? as usize;
    let mut tasks = Vec::with_capacity(d.capacity_hint(n, 88));
    for _ in 0..n {
        tasks.push(dec_task(d)?);
    }
    Ok(tasks)
}

fn read_frame(head: [u8; 4], r: &mut impl Read) -> Result<Vec<u8>> {
    let len = u32::from_le_bytes(head) as usize;
    ensure!(len <= MAX_FRAME_BYTES, "implausible frame length {len}");
    // lint:allow(wire-alloc): len is ensure-bounded to MAX_FRAME_BYTES above
    let mut frame = vec![0u8; len];
    r.read_exact(&mut frame)?;
    Ok(frame)
}

fn copy_received(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len());
    out.extend_from_slice(payload);
    out
}
