// lint-fixture: metrics/mod.rs
// Positive corpus for allow-policy: every malformed suppression is itself
// a violation, and a reason-less allow does not suppress.

fn f() {
    let m = HashMap::new(); // lint:allow(nondet-map) //~ allow-policy nondet-map
}

// lint:allow(not-a-rule): misspelled rule name //~ allow-policy
// lint:allow(lock-order): structural findings have no single line //~ allow-policy
// lint:allow(allow-policy): cannot suppress the suppressor //~ allow-policy
