// lint-fixture: coordinator/federation.rs
// A reasoned allow for a reporting-only read in a scoped file passes.

fn wall_secs(started: Instant) -> f64 {
    // lint:allow(nondet-time): wall_secs is reporting-only; parity ignores it
    let now = Instant::now();
    (now - started).as_secs_f64()
}
