// lint-fixture: net/server.rs
// Lock-order negative corpus: a consistent global order, plus
// statement-scoped temporaries that never overlap.

fn submit(&self) {
    let q = self.queue.lock();
    let s = self.slots.lock();
    q.push(s.take());
}

fn tick(&self) {
    *self.queue.lock() += 1;
    *self.slots.lock() += 1;
}
