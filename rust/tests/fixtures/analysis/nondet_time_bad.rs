// lint-fixture: coordinator/federation.rs
// Positive corpus for nondet-time: clock reads in round math.

fn round_timing() -> (Instant, u64) {
    let t0 = Instant::now(); //~ nondet-time
    let stamp = std::time::SystemTime::now(); //~ nondet-time
    (t0, stamp.elapsed().as_secs())
}
