// lint-fixture: data/corpus.rs
// Positive corpus for nondet-rng: ambient entropy sources. Lines with two
// foreign identifiers produce two diagnostics.

fn sample() -> u64 {
    let mut r = rand::thread_rng(); //~ nondet-rng nondet-rng
    let s = StdRng::from_entropy(); //~ nondet-rng nondet-rng
    let state = RandomState::new(); //~ nondet-rng
    r.gen::<u64>() ^ s.gen::<u64>()
}
