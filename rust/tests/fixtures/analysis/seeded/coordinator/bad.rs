// A seeded violation tree for the CI negative gate: running
// `photon lint --src tests/fixtures/analysis/seeded` must exit non-zero.
use std::collections::HashMap;

pub fn tally(xs: &HashMap<u32, f32>) -> f32 {
    xs.values().sum()
}
