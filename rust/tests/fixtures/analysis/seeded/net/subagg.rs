// Seeded wire-panic violation: the sub-aggregator is wire scope, so an
// `.unwrap()` on a decoded frame must make the CI lint gate exit non-zero.

pub fn peek_round(frame: &[u8]) -> u64 {
    let msg = Msg::decode(frame).unwrap();
    msg.round
}
