// A seeded wall-clock violation in the obs plane (outside clock.rs):
// the CI negative gate must flag this as nondet-time.

pub fn sneak_a_clock() -> u64 {
    let t = std::time::SystemTime::now();
    t.elapsed().map(|d| d.as_micros() as u64).unwrap_or(0)
}
