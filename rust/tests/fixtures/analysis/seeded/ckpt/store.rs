// Seeded wire-alloc violation: the state store's spill decoder is wire
// scope, so an allocation sized by a decoded integer must make the CI
// lint gate exit non-zero.

pub fn load(d: &mut Dec) -> Result<Vec<u8>> {
    let n = d.u64()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(d.u8()?);
    }
    Ok(out)
}
