// lint-fixture: metrics/mod.rs
// Positive corpus for nondet-map: each marked line must be flagged.
use std::collections::HashMap; //~ nondet-map
use std::collections::HashSet; //~ nondet-map

fn tally(xs: &[(u32, f32)]) -> f32 {
    let by_key: HashMap<u32, f32> = xs.iter().copied().collect(); //~ nondet-map
    by_key.values().sum()
}

fn dedup(xs: &[u32]) -> usize {
    let seen: HashSet<u32> = xs.iter().copied().collect(); //~ nondet-map
    seen.len()
}
