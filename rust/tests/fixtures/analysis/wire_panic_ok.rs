// lint-fixture: net/proto.rs
// Negative corpus for wire-panic: robust handling, a reasoned allow for a
// provably infallible conversion, and #[cfg(test)] exemption.

fn handle(frame: &[u8]) -> Result<()> {
    let msg = Msg::decode(frame)?;
    let head = msg.first().ok_or_else(|| anyhow!("empty payload"))?;
    // lint:allow(wire-panic): try_into on a fixed 2-byte slice of a length-checked header is infallible
    let tag = u16::from_le_bytes(head[..2].try_into().unwrap());
    bail!("kind {tag} not recognized")
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_may_unwrap_and_index() {
        let v = decode_fixture().unwrap();
        assert_eq!(v[0], 1);
    }
}
