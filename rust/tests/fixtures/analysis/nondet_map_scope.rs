// lint-fixture: util/json.rs
// Scope check: util/ is outside the determinism scope, so hash containers
// are fine here (nothing in util/ feeds round math or the wire).
use std::collections::HashMap;

fn intern(m: &mut HashMap<String, u32>, s: &str) -> u32 {
    let next = m.len() as u32;
    *m.entry(s.to_string()).or_insert(next)
}
