// lint-fixture: net/harness.rs
// Negative corpus for nondet-time: the harness is on the wall-clock
// allowlist (process liveness, kill schedules, deadlines).

fn deadline(secs: f64) -> Instant {
    Instant::now() + Duration::from_secs_f64(secs)
}
