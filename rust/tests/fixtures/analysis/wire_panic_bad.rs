// lint-fixture: net/proto.rs
// Positive corpus for wire-panic: panics and raw indexing on decoded data.

fn handle(frame: &[u8]) -> Result<()> {
    let msg = Msg::decode(frame)?;
    let head = msg[0]; //~ wire-panic
    let tag = msg.kind.unwrap(); //~ wire-panic
    let body = msg.body.expect("body"); //~ wire-panic
    if head == 0 {
        panic!("zero head"); //~ wire-panic
    }
    match tag {
        0 => todo!(), //~ wire-panic
        _ => unreachable!(), //~ wire-panic
    }
}
