// lint-fixture: metrics/mod.rs
// Negative corpus for nondet-map: ordered containers pass, and a
// reasoned lint:allow covers the one legitimate exemption shape.
use std::collections::BTreeMap;

fn tally(xs: &[(u32, f32)]) -> f32 {
    let by_key: BTreeMap<u32, f32> = xs.iter().copied().collect();
    by_key.values().sum()
}

// lint:allow(nondet-map): point lookups only, never iterated
fn lookup(m: &HashMap<u32, f32>, k: u32) -> Option<f32> {
    m.get(&k).copied()
}
