// lint-fixture: net/proto.rs
// Positive corpus for wire-alloc: allocations sized by decoded integers.

fn dec_tasks(d: &mut Dec) -> Result<Vec<Task>> {
    let n = d.u64()? as usize;
    let mut tasks = Vec::with_capacity(n); //~ wire-alloc
    for _ in 0..n {
        tasks.push(dec_task(d)?);
    }
    Ok(tasks)
}

fn read_body(head: &[u8; 8]) -> Result<Vec<u8>> {
    let len = u32::from_le_bytes([head[0], head[1], head[2], head[3]]) as usize;
    let buf = vec![0u8; len]; //~ wire-alloc
    Ok(buf)
}

fn grow(d: &mut Dec, out: &mut Vec<u8>) -> Result<()> {
    let extra = d.u32()? as usize;
    out.reserve(extra); //~ wire-alloc
    Ok(())
}
