// lint-fixture: ckpt/store.rs
// Positive corpus: the state store's spill-file decoder is wire scope —
// a torn write reaches it exactly like a hostile frame reaches the link
// layer, so allocations sized by decoded integers must be flagged.

fn load_spill(d: &mut Dec) -> Result<Vec<u8>> {
    let n = d.u64()? as usize;
    let mut bytes = Vec::with_capacity(n); //~ wire-alloc
    for _ in 0..n {
        bytes.push(d.u8()?);
    }
    Ok(bytes)
}

fn read_trailer(head: &[u8; 8]) -> Result<Vec<u8>> {
    let len = u32::from_le_bytes([head[0], head[1], head[2], head[3]]) as usize;
    let buf = vec![0u8; len]; //~ wire-alloc
    Ok(buf)
}
