//! Property tests for the aggregation-tree plane (ISSUE 9).
//!
//! Three contracts are pinned here:
//!
//! * **Folded-push associativity** — the two-stage fold a sub-aggregator
//!   tree computes (per-group `weighted_mean_into`, then a root
//!   `streaming_fold` over the group means with carried weights) is
//!   bit-identical to `tiered_fold` over the same partition, and the
//!   single-group partition is bit-identical to the flat fold. The
//!   partition is *config* (`tier_slices`), never arrival order.
//! * **StateStore budget** — under arbitrary put/get traces the resident
//!   encoded bytes never exceed the configured budget, spilled states
//!   reload byte-identically, and generations are strictly monotonic.
//! * **Proto v4 wire surface** — `SubJoin` / `FoldedPush` / `RoundAssign`
//!   (with `Ref` states) round-trip exactly; every truncation and every
//!   seeded link-level flake of their frames fails decode loudly instead
//!   of misdecoding or panicking.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use photon::chaos::flake_frame;
use photon::ckpt::{ClientCkpt, StateStore};
use photon::coordinator::federation::tier_slices;
use photon::coordinator::ClientUpdate;
use photon::data::stream::StreamCursor;
use photon::model::vecmath::{streaming_fold, tiered_fold, weighted_mean_into, AggScratch};
use photon::net::proto::{
    AssignState, AssignTask, FoldedMember, FoldedPush, Join, Msg, RoundAssign, PROTO_VERSION,
};
use photon::testkit::{
    alloc_counter::{self, CountingAlloc},
    check, check_cases, rand_vec,
};
use photon::util::rng::Rng;

// Counting allocator for the resident-ceiling assertion below; pure
// delegation to the system allocator everywhere else in this binary.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

// ---------------------------------------------------------------------------
// Folded-push associativity
// ---------------------------------------------------------------------------

/// One fold instance: K rows of N params, positive FedAvg weights, and a
/// partition of the rows into contiguous group sizes.
#[derive(Clone, Debug)]
struct FoldCase {
    global: Vec<f32>,
    rows: Vec<Vec<f32>>,
    weights: Vec<f64>,
    sizes: Vec<usize>,
}

impl FoldCase {
    fn groups(&self) -> Vec<std::ops::Range<usize>> {
        let mut out = Vec::new();
        let mut lo = 0;
        for &s in &self.sizes {
            out.push(lo..lo + s);
            lo += s;
        }
        out
    }
}

fn gen_fold_case(rng: &mut Rng) -> FoldCase {
    let n = 1 + rng.usize_below(96);
    let k = 1 + rng.usize_below(10);
    let global = rand_vec(rng, n, 1.0);
    let rows: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(rng, n, 1.0)).collect();
    let weights: Vec<f64> = (0..k).map(|_| 0.25 + rng.f64() * 8.0).collect();
    let mut sizes = Vec::new();
    let mut left = k;
    while left > 0 {
        let s = 1 + rng.usize_below(left);
        sizes.push(s);
        left -= s;
    }
    FoldCase { global, rows, weights, sizes }
}

/// Shrink toward fewer rows, one group, and shorter vectors.
fn shrink_fold_case(c: &FoldCase) -> Vec<FoldCase> {
    let mut out = Vec::new();
    let k = c.rows.len();
    if c.sizes.len() > 1 {
        let mut one = c.clone();
        one.sizes = vec![k];
        out.push(one);
    }
    if k > 1 {
        let half = k / 2;
        out.push(FoldCase {
            global: c.global.clone(),
            rows: c.rows[..half].to_vec(),
            weights: c.weights[..half].to_vec(),
            sizes: vec![half],
        });
    }
    if c.global.len() > 1 {
        let n = c.global.len() / 2;
        out.push(FoldCase {
            global: c.global[..n].to_vec(),
            rows: c.rows.iter().map(|r| r[..n].to_vec()).collect(),
            weights: c.weights.clone(),
            sizes: c.sizes.clone(),
        });
    }
    out
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn prop_tiered_fold_matches_the_distributed_two_stage_fold() {
    check_cases(
        "tiered_fold_associativity",
        0x7EE5_0009,
        80,
        gen_fold_case,
        shrink_fold_case,
        |c| {
            let n = c.global.len();
            let k = c.rows.len();
            let rows: Vec<&[f32]> = c.rows.iter().map(|r| r.as_slice()).collect();
            let mut scratch = AggScratch::new();

            // Flat reference.
            let (mut mean_flat, mut pg_flat) = (vec![0.0f32; n], vec![0.0f32; n]);
            streaming_fold(
                &rows, &c.weights, &c.global, &mut mean_flat, &mut pg_flat, &mut scratch,
            );

            // tiers = 1 is bit-free: the single-group partition must equal
            // the flat fold exactly.
            let (mut mean_one, mut pg_one) = (vec![0.0f32; n], vec![0.0f32; n]);
            tiered_fold(
                &rows,
                &c.weights,
                &[0..k],
                &c.global,
                &mut mean_one,
                &mut pg_one,
                &mut scratch,
            );
            if bits(&mean_one) != bits(&mean_flat) || bits(&pg_one) != bits(&pg_flat) {
                return Err("single-group tiered_fold diverged from the flat fold".into());
            }

            // The canonical partitioned fold.
            let groups = c.groups();
            let (mut mean_t, mut pg_t) = (vec![0.0f32; n], vec![0.0f32; n]);
            tiered_fold(
                &rows, &c.weights, &groups, &c.global, &mut mean_t, &mut pg_t, &mut scratch,
            );

            // What the tree actually computes: each sub-aggregator folds its
            // slice in slot order and pushes (W_g, mean_g); the root folds
            // the pushed pairs. Must be bit-identical to tiered_fold.
            let mut sub_means: Vec<Vec<f32>> = Vec::new();
            let mut sub_weights: Vec<f64> = Vec::new();
            for g in &groups {
                let mut m = vec![0.0f32; n];
                weighted_mean_into(&rows[g.clone()], &c.weights[g.clone()], &mut m);
                sub_means.push(m);
                sub_weights.push(c.weights[g.clone()].iter().sum());
            }
            let sub_rows: Vec<&[f32]> = sub_means.iter().map(|m| m.as_slice()).collect();
            let (mut mean_d, mut pg_d) = (vec![0.0f32; n], vec![0.0f32; n]);
            streaming_fold(
                &sub_rows, &sub_weights, &c.global, &mut mean_d, &mut pg_d, &mut scratch,
            );
            if bits(&mean_d) != bits(&mean_t) || bits(&pg_d) != bits(&pg_t) {
                return Err(format!(
                    "distributed two-stage fold diverged from tiered_fold over {groups:?}"
                ));
            }

            // Determinism: a second evaluation reproduces the same bits.
            let (mut mean_r, mut pg_r) = (vec![0.0f32; n], vec![0.0f32; n]);
            tiered_fold(
                &rows, &c.weights, &groups, &c.global, &mut mean_r, &mut pg_r, &mut scratch,
            );
            if bits(&mean_r) != bits(&mean_t) || bits(&pg_r) != bits(&pg_t) {
                return Err("tiered_fold is not deterministic".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tier_slices_partition_contiguously_and_balanced() {
    check("tier_slices_partition", 0x511C_E5, 200, |rng| {
        let k = rng.usize_below(200);
        let tiers = 1 + rng.usize_below(12);
        let slices = tier_slices(k, tiers);
        if k == 0 {
            return if slices.is_empty() {
                Ok(())
            } else {
                Err("k=0 must produce no groups".into())
            };
        }
        if slices.len() != tiers.min(k) {
            return Err(format!("{} groups for k={k}, tiers={tiers}", slices.len()));
        }
        let mut cursor = 0;
        let mut sizes = Vec::new();
        for s in &slices {
            if s.start != cursor || s.end <= s.start {
                return Err(format!("non-contiguous or empty slice {s:?}"));
            }
            sizes.push(s.end - s.start);
            cursor = s.end;
        }
        if cursor != k {
            return Err(format!("slices cover {cursor} of {k}"));
        }
        let (lo, hi) = (sizes.iter().min().copied(), sizes.iter().max().copied());
        if let (Some(lo), Some(hi)) = (lo, hi) {
            if hi - lo > 1 {
                return Err(format!("unbalanced slice sizes {sizes:?}"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// StateStore: budget, round-trip, generations
// ---------------------------------------------------------------------------

static STORE_DIR_SALT: AtomicU64 = AtomicU64::new(0);

fn store_dir(tag: &str) -> PathBuf {
    let salt = STORE_DIR_SALT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "photon_props_tree_{tag}_{}_{salt}",
        std::process::id()
    ))
}

fn rand_state(rng: &mut Rng) -> ClientCkpt {
    let n = 1 + rng.usize_below(64);
    let n_residual = rng.usize_below(16);
    ClientCkpt {
        opt_m: rand_vec(rng, n, 1.0),
        opt_v: rand_vec(rng, n, 0.5),
        local_step: rng.below(1 << 20) as i64,
        cursors: vec![StreamCursor {
            mix_state: [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
            bucket_states: vec![(
                [rng.next_u64(), rng.next_u64(), rng.next_u64(), rng.next_u64()],
                rng.below(1000),
            )],
        }],
        residual: rand_vec(rng, n_residual, 0.25),
    }
}

#[test]
fn prop_state_store_honors_the_budget_under_random_traces() {
    check("store_budget_trace", 0x57A7_E570, 40, |rng| {
        let budget = rng.below(4096);
        let dir = store_dir("trace");
        let mut st = StateStore::new(budget, &dir);
        let mut model: BTreeMap<usize, ClientCkpt> = BTreeMap::new();
        let mut gens: BTreeMap<usize, u64> = BTreeMap::new();
        let ops = 1 + rng.usize_below(60);
        for _ in 0..ops {
            let client = rng.usize_below(8);
            if rng.bool(0.6) {
                let s = rand_state(rng);
                let gen = st.put(client, &s).map_err(|e| format!("put: {e:#}"))?;
                let want = gens.get(&client).copied().unwrap_or(0) + 1;
                if gen != want {
                    return Err(format!(
                        "client {client}: put returned gen {gen}, expected {want}"
                    ));
                }
                gens.insert(client, gen);
                model.insert(client, s);
            } else {
                let got = st.get(client).map_err(|e| format!("get: {e:#}"))?;
                if got.as_ref() != model.get(&client) {
                    return Err(format!("client {client}: get diverged from the model"));
                }
            }
            // The invariant under test: the resident set never exceeds the
            // budget, no matter the trace.
            if st.resident_bytes() > st.budget() {
                return Err(format!(
                    "resident {} exceeds budget {}",
                    st.resident_bytes(),
                    st.budget()
                ));
            }
        }
        // Nothing is ever lost: every state the model holds reloads equal
        // (resident hit or checksummed spill reload).
        for (client, want) in &model {
            match st.get(*client).map_err(|e| format!("final get: {e:#}"))? {
                Some(got) if got == *want => {}
                other => {
                    return Err(format!(
                        "client {client}: final reload mismatch (got {:?})",
                        other.map(|s| s.local_step)
                    ))
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

#[test]
fn prop_zero_budget_spills_everything_and_round_trips_byte_identically() {
    check("store_zero_budget", 0x57A7_0000, 30, |rng| {
        let dir = store_dir("zero");
        let mut st = StateStore::new(0, &dir);
        let n_clients = 1 + rng.usize_below(6);
        let states: Vec<ClientCkpt> = (0..n_clients).map(|_| rand_state(rng)).collect();
        for (c, s) in states.iter().enumerate() {
            st.put(c, s).map_err(|e| format!("put: {e:#}"))?;
            if st.resident_bytes() != 0 {
                return Err("zero budget must keep nothing resident".into());
            }
        }
        if st.spill_count() < n_clients as u64 {
            return Err(format!(
                "{} puts produced only {} spills",
                n_clients,
                st.spill_count()
            ));
        }
        for (c, want) in states.iter().enumerate() {
            let got = st
                .get(c)
                .map_err(|e| format!("get: {e:#}"))?
                .ok_or_else(|| format!("client {c} lost"))?;
            if got != *want {
                return Err(format!("client {c}: spill round-trip not identical"));
            }
        }
        if st.load_count() < n_clients as u64 {
            return Err("every zero-budget get must reload from disk".into());
        }
        std::fs::remove_dir_all(&dir).ok();
        Ok(())
    });
}

/// The resident ceiling is real memory, not bookkeeping: with a budget
/// sized for a handful of entries, a resident-hit `get` performs a small
/// bounded number of heap allocations (decode of one state), independent
/// of how many clients the store tracks in total.
#[test]
fn state_store_resident_get_allocates_a_bounded_amount() {
    let mut rng = Rng::new(0xA110_C8);
    let dir = store_dir("alloc");
    let probe = rand_state(&mut rng);
    // Budget for roughly two copies of the probe state; the other 63
    // clients must spill rather than grow the resident set.
    let mut sized = StateStore::new(u64::MAX, store_dir("sizing"));
    sized.put(0, &probe).unwrap();
    let one = sized.resident_bytes();
    let mut st = StateStore::new(2 * one + one / 2, &dir);
    for c in 0..64 {
        st.put(c, &rand_state(&mut rng)).unwrap();
    }
    st.put(99, &probe).unwrap();
    assert!(st.resident_bytes() <= st.budget(), "ceiling violated");
    assert!(st.spill_count() > 0, "the budget never bit");
    // Warm call first (pulls nothing from disk: 99 was just put).
    let (first, _) = alloc_counter::count(|| st.get(99).unwrap().unwrap());
    assert_eq!(first, probe);
    let (got, allocs) = alloc_counter::count(|| st.get(99).unwrap().unwrap());
    assert_eq!(got, probe);
    assert!(
        allocs < 512,
        "resident-hit get performed {allocs} allocations — decode of one \
         state should be O(state), not O(population)"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// FoldedPush member-order rule (LeaseBook::slots_strictly_increasing)
// ---------------------------------------------------------------------------

/// The admission-side half of the weight-carry rule: any slot-ordered,
/// duplicate-free subset of the sampled cohort passes; any duplicate, any
/// swap, and any unsampled member is refused. This is what lets the root
/// reject a malformed `FoldedPush` at admission (cut) instead of tripping
/// the commit-time bit-exact weight re-derivation (crash).
#[test]
fn prop_member_slot_order_accepts_exactly_the_ordered_subsets() {
    check("folded_member_order", 0x510_7012, 150, |rng| {
        let k = 1 + rng.usize_below(12);
        // Sampled clients with non-contiguous ids so slot != client id.
        let runnable: Vec<(usize, u64)> =
            (0..k).map(|s| (s * 3 + rng.usize_below(2), 4)).collect();
        let book = photon::chaos::LeaseBook::new(&runnable);

        // A random slot-ordered subset must pass.
        let subset: Vec<usize> = runnable
            .iter()
            .map(|&(c, _)| c)
            .filter(|_| rng.bool(0.6))
            .collect();
        if !subset.is_empty() && !book.slots_strictly_increasing(&subset) {
            return Err(format!("ordered subset {subset:?} was refused"));
        }
        if !book.slots_strictly_increasing(&[]) {
            return Err("the empty list is vacuously ordered".into());
        }

        // Duplicating any element must fail.
        if !subset.is_empty() {
            let mut dup = subset.clone();
            let at = rng.usize_below(dup.len());
            dup.insert(at, dup[at]);
            if book.slots_strictly_increasing(&dup) {
                return Err(format!("duplicate member {dup:?} was accepted"));
            }
        }

        // Swapping two distinct elements must fail.
        if subset.len() >= 2 {
            let mut swapped = subset.clone();
            let i = rng.usize_below(swapped.len() - 1);
            swapped.swap(i, i + 1);
            if book.slots_strictly_increasing(&swapped) {
                return Err(format!("out-of-order members {swapped:?} were accepted"));
            }
        }

        // An unsampled client must fail wherever it appears.
        let stranger = runnable.iter().map(|&(c, _)| c).max().unwrap() + 1;
        let mut with_stranger = subset.clone();
        with_stranger.push(stranger);
        if book.slots_strictly_increasing(&with_stranger) {
            return Err(format!("unsampled member in {with_stranger:?} was accepted"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Proto v4 corruption / truncation corpus
// ---------------------------------------------------------------------------

fn rand_update(rng: &mut Rng, client: usize) -> ClientUpdate {
    ClientUpdate {
        client_id: client,
        // Members of a FoldedPush travel with params cleared (the mean
        // carries the mass); the codec must round-trip that shape.
        params: Vec::new(),
        n_samples: 1.0 + rng.f64() * 32.0,
        loss_mean: rng.f64() * 8.0,
        loss_last: rng.f64() * 8.0,
        step_grad_norm_mean: rng.f64(),
        applied_update_norm_mean: rng.f64(),
        act_norm_mean: rng.f64(),
        model_norm: rng.f64() * 10.0,
        steps_done: rng.below(64),
        wire_bytes: rng.below(1 << 20),
    }
}

fn rand_msg(rng: &mut Rng) -> Msg {
    match rng.below(3) {
        0 => Msg::SubJoin(Join {
            proto: PROTO_VERSION,
            name: format!("subagg-{}", rng.below(16)),
            identity: rng.next_u64(),
        }),
        1 => {
            let k = 1 + rng.usize_below(4);
            let members: Vec<FoldedMember> = (0..k)
                .map(|c| FoldedMember { update: rand_update(rng, c), state: rand_state(rng) })
                .collect();
            let weight: f64 = members.iter().map(|m| m.update.n_samples).sum();
            let n = 1 + rng.usize_below(48);
            Msg::FoldedPush(FoldedPush {
                session: rng.next_u64(),
                round: rng.below(100),
                weight,
                mean: rand_vec(rng, n, 1.0),
                members,
            })
        }
        _ => {
            let n = 1 + rng.usize_below(48);
            Msg::RoundAssign(RoundAssign {
                session: rng.next_u64(),
                round: rng.below(100),
                seq_base: rng.below(1000),
                lease_epoch: rng.below(100),
                tasks: vec![
                    AssignTask {
                        client: rng.below(32),
                        steps: 1 + rng.below(40),
                        state: AssignState::Full(rand_state(rng)),
                    },
                    AssignTask {
                        client: 32 + rng.below(32),
                        steps: 1 + rng.below(40),
                        state: AssignState::Ref(rng.next_u64()),
                    },
                ],
                global: rand_vec(rng, n, 1.0),
            })
        }
    }
}

#[test]
fn prop_proto_v4_frames_roundtrip_and_reject_every_corruption() {
    check("proto_v4_corpus", 0x4C0D_EC04, 120, |rng| {
        let msg = rand_msg(rng);
        let compress = rng.bool(0.5);
        let clean = msg.encode(compress).map_err(|e| format!("encode: {e:#}"))?;
        let back = Msg::decode(&clean).map_err(|e| format!("clean decode: {e:#}"))?;
        // Canonical-bytes equality: decode must be lossless for the whole
        // v4 surface (Ref tags, folded members, carried states).
        let canon_a = msg.encode(false).map_err(|e| e.to_string())?;
        let canon_b = back.encode(false).map_err(|e| e.to_string())?;
        if canon_a != canon_b {
            return Err("decode(encode(msg)) is not the identity".into());
        }

        // Every truncation must fail decode (the link layer's declared
        // lengths + FNV-1a checksum make prefixes undecodable) — and must
        // fail as an Err, never a panic (wire-panic lint territory).
        let cuts: Vec<usize> = if clean.len() <= 40 {
            (0..clean.len()).collect()
        } else {
            let mut c: Vec<usize> = (0..16).collect();
            c.extend((0..24).map(|_| rng.usize_below(clean.len())));
            c
        };
        for cut in cuts {
            if Msg::decode(&clean[..cut]).is_ok() {
                return Err(format!(
                    "truncation to {cut} of {} bytes decoded",
                    clean.len()
                ));
            }
        }

        // Seeded link-level flakes (bit flips, length lies, checksum
        // corruption) must be rejected, never misdecoded.
        for _ in 0..4 {
            let mut bad = clean.clone();
            flake_frame(&mut bad, rng.next_u64());
            if Msg::decode(&bad).is_ok() {
                return Err("flaked frame decoded instead of being rejected".into());
            }
        }
        Ok(())
    });
}
