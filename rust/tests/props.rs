//! Property tests over coordinator invariants (testkit harness; DESIGN.md
//! §5). Each property runs many seeded random cases; failures report a
//! replay seed.

use photon::ckpt::{Checkpoint, ClientCkpt};
use photon::cluster::batchsize::find_micro_batch_with;
use photon::compress::UpdateCodec;
use photon::cluster::island::partial_aggregate;
use photon::coordinator::{ClientSampler, RoundExec};
use photon::data::corpus::SyntheticCorpus;
use photon::data::partition::Partition;
use photon::data::stream::{StreamCursor, TokenStream};
use photon::link::{decode_model, encode_model, MsgKind};
use photon::metrics::{mean_pairwise_cosine, mean_pairwise_cosine_from_gram};
use photon::model::vecmath::{
    l2_norm, mean_into, streaming_aggregate, sub_into, weighted_mean_into, AggScratch,
};
use photon::optim::outer::{OuterHyper, OuterOpt, OuterOptKind};
use photon::optim::schedule::CosineSchedule;
use photon::testkit::{assert_close, check, rand_vec};
use photon::util::rng::Rng;

#[test]
fn prop_partition_invariants() {
    check("partition_invariants", 0xA1, 60, |rng| {
        let vocab = 64 + rng.usize_below(64);
        let corpus = SyntheticCorpus::pile(vocab);
        let n_clients = 1 + rng.usize_below(64);
        let j = 1 + rng.usize_below(4.min(corpus.categories.len()));
        let p = Partition::heterogeneous(&corpus, n_clients, j);
        p.check_invariants().map_err(|e| e)?;
        // Every client owns exactly j buckets; owners resolve correctly.
        for (c, bs) in p.assignment.iter().enumerate() {
            if bs.len() != j {
                return Err(format!("client {c} owns {} buckets, want {j}", bs.len()));
            }
            for b in bs {
                if p.owner(b) != Some(c) {
                    return Err(format!("owner({b:?}) != {c}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_iid_partition_invariants() {
    check("iid_partition", 0xA2, 40, |rng| {
        let corpus = SyntheticCorpus::c4(32 + rng.usize_below(128));
        let n = 1 + rng.usize_below(64);
        let p = Partition::iid(&corpus, n);
        p.check_invariants().map_err(|e| e)
    });
}

#[test]
fn prop_fedavg_lr1_returns_client_mean() {
    check("fedavg_recovers_mean", 0xB1, 40, |rng| {
        let n = 1 + rng.usize_below(200);
        let k = 1 + rng.usize_below(8);
        let mut global = rand_vec(rng, n, 2.0);
        let clients: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(rng, n, 2.0)).collect();
        let rows: Vec<&[f32]> = clients.iter().map(|c| c.as_slice()).collect();
        let mut mean = vec![0.0f32; n];
        mean_into(&rows, &mut mean);
        let pg: Vec<f32> = global.iter().zip(&mean).map(|(g, m)| g - m).collect();
        let mut opt = OuterOpt::new(
            OuterOptKind::FedAvg,
            OuterHyper { lr: 1.0, ..OuterHyper::default() },
            n,
        );
        opt.step(&mut global, &pg);
        assert_close(&global, &mean, 1e-5)
    });
}

#[test]
fn prop_hierarchy_flattening() {
    // Aggregating island results with equal weights == aggregating all the
    // underlying vectors directly (islands=1 ⇔ flat federation).
    check("hierarchy_flattening", 0xB2, 40, |rng| {
        let n = 1 + rng.usize_below(100);
        let islands = 1 + rng.usize_below(5);
        let per = 1 + rng.usize_below(4);
        let all: Vec<Vec<f32>> =
            (0..islands * per).map(|_| rand_vec(rng, n, 1.0)).collect();
        // Per-island means, then weighted partial aggregate.
        let island_means: Vec<Vec<f32>> = (0..islands)
            .map(|i| {
                let rows: Vec<&[f32]> =
                    all[i * per..(i + 1) * per].iter().map(|v| v.as_slice()).collect();
                let mut m = vec![0.0f32; n];
                mean_into(&rows, &mut m);
                m
            })
            .collect();
        let flat_of_islands =
            partial_aggregate(&island_means, &vec![per as f64; islands]);
        // Direct global mean.
        let rows: Vec<&[f32]> = all.iter().map(|v| v.as_slice()).collect();
        let mut direct = vec![0.0f32; n];
        mean_into(&rows, &mut direct);
        assert_close(&flat_of_islands, &direct, 1e-5)
    });
}

#[test]
fn prop_weighted_mean_scale_invariant() {
    check("weighted_mean_scale_invariance", 0xB3, 40, |rng| {
        let n = 1 + rng.usize_below(64);
        let k = 1 + rng.usize_below(6);
        let rowsv: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(rng, n, 3.0)).collect();
        let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
        let w: Vec<f64> = (0..k).map(|_| 0.1 + rng.f64()).collect();
        let scale = 0.5 + 10.0 * rng.f64();
        let w2: Vec<f64> = w.iter().map(|x| x * scale).collect();
        let mut a = vec![0.0f32; n];
        let mut b = vec![0.0f32; n];
        weighted_mean_into(&rows, &w, &mut a);
        weighted_mean_into(&rows, &w2, &mut b);
        assert_close(&a, &b, 1e-5)
    });
}

#[test]
fn prop_sampler_without_replacement_and_deterministic() {
    check("sampler", 0xC1, 60, |rng| {
        let p = 1 + rng.usize_below(128);
        let k = 1 + rng.usize_below(p);
        let seed = rng.next_u64();
        let round = rng.usize_below(1000);
        let s = ClientSampler::new(seed);
        let a = s.sample(round, p, k);
        let b = s.sample(round, p, k);
        if a != b {
            return Err("not deterministic".into());
        }
        let mut sorted = a.clone();
        sorted.dedup();
        if sorted.len() != k {
            return Err(format!("duplicates in sample: {a:?}"));
        }
        if a.iter().any(|&c| c >= p) {
            return Err(format!("out of range: {a:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_link_roundtrip() {
    check("link_roundtrip", 0xD1, 40, |rng| {
        let n = 1 + rng.usize_below(5000);
        let payload = rand_vec(rng, n, 10.0);
        for compress in [false, true] {
            let frame = encode_model(MsgKind::ClientUpdate, &payload, compress)
                .map_err(|e| e.to_string())?;
            let (kind, back) = decode_model(&frame).map_err(|e| e.to_string())?;
            if kind != MsgKind::ClientUpdate {
                return Err("kind mismatch".into());
            }
            if back != payload {
                return Err("payload mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_link_detects_any_single_byte_corruption_of_payload() {
    check("link_corruption", 0xD2, 30, |rng| {
        let n = 64 + rng.usize_below(256);
        let payload = rand_vec(rng, n, 1.0);
        let mut frame = encode_model(MsgKind::GlobalModel, &payload, false)
            .map_err(|e| e.to_string())?;
        let idx = 28 + rng.usize_below(frame.len() - 28);
        let bit = 1u8 << rng.usize_below(8);
        frame[idx] ^= bit;
        match decode_model(&frame) {
            Err(_) => Ok(()),
            Ok((_, back)) if back != payload => {
                Err("corruption passed checksum".into())
            }
            Ok(_) => Err("corrupted frame decoded to original?!".into()),
        }
    });
}

#[test]
fn prop_link_detects_any_single_byte_corruption_of_header() {
    // Satellite of the payload-corruption property: flip one bit anywhere
    // in the 28-byte header. The decode must either fail or yield a
    // *different* message (a kind-field flip can land on another valid
    // kind — the payload checksum still holds, so the caller's kind
    // dispatch catches it); silently returning the original message is the
    // only unacceptable outcome.
    check("link_header_corruption", 0xD3, 60, |rng| {
        let n = 8 + rng.usize_below(128);
        let payload = rand_vec(rng, n, 1.0);
        let compress = rng.bool(0.5);
        let frame = encode_model(MsgKind::GlobalModel, &payload, compress)
            .map_err(|e| e.to_string())?;
        let idx = rng.usize_below(photon::link::HEADER_BYTES);
        let bit = 1u8 << rng.usize_below(8);
        let mut bad = frame.clone();
        bad[idx] ^= bit;
        match decode_model(&bad) {
            Err(_) => Ok(()),
            Ok((kind, back)) if kind != MsgKind::GlobalModel || back != payload => Ok(()),
            Ok(_) => Err(format!(
                "header byte {idx} bit-flip went unnoticed (compress={compress})"
            )),
        }
    });
}

#[test]
fn prop_link_rejects_newer_versions_with_clear_error() {
    check("link_version_gate", 0xD4, 20, |rng| {
        let payload = rand_vec(rng, 1 + rng.usize_below(64), 1.0);
        let mut frame = encode_model(MsgKind::ClientUpdate, &payload, false)
            .map_err(|e| e.to_string())?;
        // Any version above the supported one must be refused with an
        // error that names the upgrade path, never a decode attempt.
        let newer = (photon::link::VERSION + 1).wrapping_add(rng.below(1000) as u16);
        frame[4..6].copy_from_slice(&newer.to_le_bytes());
        match decode_model(&frame) {
            Ok(_) => Err(format!("version {newer} frame decoded")),
            Err(e) if e.to_string().contains("newer") => Ok(()),
            Err(e) => Err(format!("wrong error for newer version: {e}")),
        }
    });
}

#[test]
fn prop_checkpoint_roundtrip() {
    check("ckpt_roundtrip", 0xE1, 30, |rng| {
        let n = 1 + rng.usize_below(512);
        let clients = (0..rng.usize_below(8))
            .map(|_| {
                if rng.bool(0.3) {
                    None
                } else {
                    Some(ClientCkpt {
                        opt_m: rand_vec(rng, n, 1.0),
                        opt_v: rand_vec(rng, n, 1.0),
                        local_step: rng.below(1000) as i64,
                        // Error-feedback residual: empty (no lossy codec)
                        // or one entry per model param.
                        residual: if rng.bool(0.5) {
                            Vec::new()
                        } else {
                            rand_vec(rng, n, 0.5)
                        },
                        // 1–3 cursors: multi-island clients checkpoint one
                        // per island.
                        cursors: (0..1 + rng.usize_below(3))
                            .map(|_| StreamCursor {
                                mix_state: [rng.next_u64(); 4],
                                bucket_states: (0..1 + rng.usize_below(3))
                                    .map(|_| {
                                        (
                                            [
                                                rng.next_u64(),
                                                rng.next_u64(),
                                                rng.next_u64(),
                                                rng.next_u64(),
                                            ],
                                            rng.below(100),
                                        )
                                    })
                                    .collect(),
                            })
                            .collect(),
                    })
                }
            })
            .collect();
        let ck = Checkpoint {
            round: rng.below(100),
            seq_step: rng.below(100_000),
            global: rand_vec(rng, n, 0.1),
            outer_t: rng.below(100),
            outer_m: (0..n).map(|_| rng.f64() - 0.5).collect(),
            outer_v: (0..n).map(|_| rng.f64()).collect(),
            clients,
            timestamp: rng.next_u64() >> 32,
            elapsed_secs: rng.f64() * 1e5,
        };
        let back = Checkpoint::decode(&ck.encode()).map_err(|e| e.to_string())?;
        if back != ck {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_batch_search_optimality() {
    check("batch_search", 0xF1, 60, |rng| {
        let threshold = 1 + rng.usize_below(3000);
        let cap = 4096;
        match find_micro_batch_with(|b| b <= threshold, cap) {
            None => Err("threshold >= 1 must fit".into()),
            Some(b) => {
                if !b.is_power_of_two() {
                    return Err(format!("{b} not a power of two"));
                }
                if b > threshold {
                    return Err(format!("{b} exceeds threshold {threshold}"));
                }
                if 2 * b <= threshold && 2 * b <= cap {
                    return Err(format!("{b} not maximal for {threshold}"));
                }
                Ok(())
            }
        }
    });
}

#[test]
fn prop_schedule_bounds() {
    check("schedule_bounds", 0xF2, 40, |rng| {
        let eta = 1e-4 + rng.f64() * 1e-2;
        let alpha = rng.f64() * 0.5;
        let total = 10 + rng.below(10_000);
        let warmup = rng.below(total.min(total / 2 + 1));
        let s = CosineSchedule::new(eta, alpha, total, warmup);
        for _ in 0..50 {
            let t = rng.below(2 * total) + 1;
            let lr = s.lr(t);
            if !(0.0..=eta + 1e-12).contains(&lr) {
                return Err(format!("lr({t}) = {lr} outside [0, {eta}]"));
            }
            if t >= total && (lr - s.eta_min()).abs() > 1e-15 {
                return Err(format!("lr({t}) != eta_min after T"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stream_cursor_resume_equivalence() {
    check("stream_resume", 0xF3, 25, |rng| {
        let corpus = SyntheticCorpus::pile(64);
        let p = Partition::heterogeneous(&corpus, 8, 1 + rng.usize_below(2));
        let c = rng.usize_below(8);
        let seed = rng.next_u64();
        let mut s = TokenStream::bind(&p.assignment[c], &corpus.categories, 9, seed)
            .map_err(|e| e.to_string())?;
        for _ in 0..rng.usize_below(10) {
            s.next_batch(2);
        }
        let cur = s.cursor();
        let expect = s.next_batch(3);
        s.restore(&cur);
        if s.next_batch(3) != expect {
            return Err("cursor resume diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_outer_optimizers_finite_and_descending_direction() {
    check("outer_finite", 0xF4, 30, |rng| {
        let n = 1 + rng.usize_below(128);
        let kinds = [
            OuterOptKind::FedAvg,
            OuterOptKind::FedMomentum { nesterov: false },
            OuterOptKind::FedMomentum { nesterov: true },
            OuterOptKind::FedAdam,
            OuterOptKind::FedYogi,
            OuterOptKind::FedAdagrad,
        ];
        let kind = kinds[rng.usize_below(kinds.len())];
        let mut opt = OuterOpt::new(
            kind,
            OuterHyper { lr: 0.1 + rng.f64(), ..OuterHyper::default() },
            n,
        );
        let mut global = rand_vec(rng, n, 1.0);
        for _ in 0..5 {
            let pg = rand_vec(rng, n, 0.5);
            let before = global.clone();
            opt.step(&mut global, &pg);
            if global.iter().any(|v| !v.is_finite()) {
                return Err(format!("{kind:?} produced non-finite params"));
            }
            // Direction sanity: a pure-positive pseudo-grad must not raise
            // any coordinate on the first step.
            let _ = before;
        }
        Ok(())
    });
}

#[test]
fn prop_round_exec_parallel_matches_sequential_bit_exact() {
    // The round engine's contract (coordinator module docs): for work that
    // depends only on the task's own state, any worker count produces the
    // same results *and* the same final task states as the sequential path.
    // Tasks here mimic a client local round: a seeded RNG stream is
    // advanced a task-specific number of steps and folded into a vector.
    #[derive(Clone, PartialEq, Debug)]
    struct FakeNode {
        rng_seed: u64,
        steps: u64,
        out: Vec<f32>,
    }
    check("round_exec_bit_exact", 0xA7, 30, |rng| {
        let n_tasks = rng.usize_below(12); // includes the empty round
        let base: Vec<FakeNode> = (0..n_tasks)
            .map(|_| FakeNode {
                rng_seed: rng.next_u64(),
                steps: 1 + rng.below(50),
                out: Vec::new(),
            })
            .collect();
        let work = |t: &mut FakeNode| -> anyhow::Result<f64> {
            let mut r = Rng::new(t.rng_seed);
            let mut acc = 0.0f64;
            for _ in 0..t.steps {
                let v = r.f32();
                t.out.push(v);
                acc += v as f64;
            }
            Ok(acc)
        };
        let mut seq_tasks = base.clone();
        let seq: Vec<f64> = RoundExec::new(1)
            .run(&mut seq_tasks, work)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        for workers in [2, 3, 7, 0] {
            let mut par_tasks = base.clone();
            let par: Vec<f64> = RoundExec::new(workers)
                .run(&mut par_tasks, work)
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            if par != seq {
                return Err(format!("results diverged at workers={workers}"));
            }
            if par_tasks != seq_tasks {
                return Err(format!("task states diverged at workers={workers}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_streaming_aggregate_matches_materialized_path() {
    // The streaming accumulator must reproduce the former multi-pass
    // aggregation: weighted mean and pseudo-gradient bit-exactly, delta
    // norms and pairwise cosines to f64 round-off.
    check("streaming_aggregate", 0xA8, 30, |rng| {
        let n = 1 + rng.usize_below(5000);
        let k = 1 + rng.usize_below(8);
        let rowsv: Vec<Vec<f32>> = (0..k).map(|_| rand_vec(rng, n, 2.0)).collect();
        let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
        let weights: Vec<f64> = (0..k).map(|_| 0.1 + rng.f64()).collect();
        let global = rand_vec(rng, n, 2.0);

        let mut ref_mean = vec![0.0f32; n];
        weighted_mean_into(&rows, &weights, &mut ref_mean);
        let mut ref_pg = vec![0.0f32; n];
        sub_into(&global, &ref_mean, &mut ref_pg);
        let deltas: Vec<Vec<f32>> = rowsv
            .iter()
            .map(|r| {
                let mut d = vec![0.0f32; n];
                sub_into(r, &ref_mean, &mut d);
                d
            })
            .collect();

        let mut mean = vec![0.0f32; n];
        let mut pg = vec![0.0f32; n];
        let mut scratch = AggScratch::new();
        let stats =
            streaming_aggregate(&rows, &weights, &global, &mut mean, &mut pg, &mut scratch);
        if mean != ref_mean {
            return Err("mean not bit-identical".into());
        }
        if pg != ref_pg {
            return Err("pseudo-gradient not bit-identical".into());
        }
        for (i, d) in deltas.iter().enumerate() {
            let want = l2_norm(d);
            let got = stats.delta_norm(i);
            if (got - want).abs() > 1e-9 * want.max(1.0) {
                return Err(format!("delta norm {i}: {got} vs {want}"));
            }
        }
        let want_cos = mean_pairwise_cosine(&deltas);
        let got_cos = mean_pairwise_cosine_from_gram(stats.k, &stats.gram);
        if (got_cos - want_cos).abs() > 1e-6 {
            return Err(format!("pairwise cosine: {got_cos} vs {want_cos}"));
        }
        Ok(())
    });
}

#[test]
fn prop_momentum_free_outer_opts_report_zero_momentum() {
    // Regression (fig11 CSV): FedAdagrad used to mirror the pseudo-gradient
    // into buf_m, so momentum_norm() reported a gradient norm.
    check("momentum_free_norm", 0xA9, 20, |rng| {
        let n = 1 + rng.usize_below(64);
        for kind in [OuterOptKind::FedAvg, OuterOptKind::FedAdagrad] {
            let mut opt = OuterOpt::new(kind, OuterHyper::default(), n);
            let mut g = rand_vec(rng, n, 1.0);
            for _ in 0..3 {
                let pg = rand_vec(rng, n, 1.0);
                opt.step(&mut g, &pg);
            }
            if opt.momentum_norm() != 0.0 {
                return Err(format!("{kind:?} reported nonzero momentum norm"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_choose_k_uniformity() {
    // Every index appears with roughly equal frequency across samples.
    check("choose_k_uniform", 0xF5, 5, |rng| {
        let p = 16;
        let k = 4;
        let mut counts = vec![0usize; p];
        let trials = 4000;
        for _ in 0..trials {
            let mut r = Rng::new(rng.next_u64());
            for c in r.choose_k(p, k) {
                counts[c] += 1;
            }
        }
        let expected = trials * k / p;
        for (i, &c) in counts.iter().enumerate() {
            let rel = (c as f64 - expected as f64).abs() / expected as f64;
            if rel > 0.15 {
                return Err(format!("index {i}: count {c} vs expected {expected}"));
            }
        }
        Ok(())
    });
}

// --- update-codec properties (compress module) -----------------------------

#[test]
fn prop_quant_roundtrip_error_bounded_per_block() {
    // q8/q4 satellite: for every block, the per-element reconstruction
    // error is bounded by that block's quantization step (max|x|/levels),
    // regardless of block size, payload shape, or rounding seed.
    check("quant_error_bound", 0xC8, 40, |rng| {
        let n = 1 + rng.usize_below(3000);
        let block = 1 + rng.usize_below(512);
        let scale = 0.01 + rng.f32() * 10.0;
        let delta = rand_vec(rng, n, scale);
        let seed = rng.next_u64();
        for (codec, levels) in [
            (UpdateCodec::Q8 { block: block as u32 }, 127.0f64),
            (UpdateCodec::Q4 { block: block as u32 }, 7.0f64),
        ] {
            let mut residual = Vec::new();
            let body = codec
                .encode_delta(&delta, seed, &mut residual)
                .map_err(|e| e.to_string())?
                .ok_or("lossy codec must produce a body")?;
            if body.len() as u64 != codec.encoded_body_bytes(n) {
                return Err(format!("{}: body size drifted", codec.label()));
            }
            let back = codec.decode_delta(&body, n).map_err(|e| e.to_string())?;
            if back.len() != n {
                return Err(format!("{}: wrong length", codec.label()));
            }
            for (bi, (dc, bc)) in delta.chunks(block).zip(back.chunks(block)).enumerate()
            {
                let max = dc.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let step = max as f64 / levels;
                for (a, b) in dc.iter().zip(bc) {
                    let err = (*a as f64 - *b as f64).abs();
                    // 1.001: the f32-rounded scale can undershoot the exact
                    // max/levels by one ulp.
                    if err > step * 1.001 + 1e-12 {
                        return Err(format!(
                            "{} block {bi}: error {err} > step {step}",
                            codec.label()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topk_error_feedback_reconstructs_dense_sum() {
    // topk satellite: over T rounds, the transmitted (sparse) stream plus
    // the final residual reconstructs the dense sum of all deltas — error
    // feedback loses nothing, it only defers.
    check("topk_error_feedback", 0xC9, 30, |rng| {
        let n = 8 + rng.usize_below(500);
        let codec = UpdateCodec::TopK { keep_permille: 1 + rng.below(400) as u32 };
        let rounds = 2 + rng.usize_below(10);
        let mut residual: Vec<f32> = Vec::new();
        let mut sum_delta = vec![0.0f64; n];
        let mut sum_sent = vec![0.0f64; n];
        for _ in 0..rounds {
            let delta = rand_vec(rng, n, 1.0);
            let body = codec
                .encode_delta(&delta, 0, &mut residual)
                .map_err(|e| e.to_string())?
                .ok_or("topk must produce a body")?;
            let sent = codec.decode_delta(&body, n).map_err(|e| e.to_string())?;
            for i in 0..n {
                sum_delta[i] += delta[i] as f64;
                sum_sent[i] += sent[i] as f64;
            }
        }
        if residual.len() != n {
            return Err("residual must be dense after first encode".into());
        }
        for i in 0..n {
            // sent-so-far + withheld == sum of deltas, up to the f32
            // rounding of the per-round `delta + residual` addition.
            let err = (sum_sent[i] + residual[i] as f64 - sum_delta[i]).abs();
            let tol = 1e-5 * rounds as f64 * (1.0 + sum_delta[i].abs());
            if err > tol {
                return Err(format!("coord {i}: |sent+residual-sum| = {err} > {tol}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_corrupted_codec_id_rejected_never_misdecoded() {
    // Codec-id satellite: flip the codec-id byte anywhere it lives — the
    // head of the coded body or the link frame's flags field — and the
    // decode must fail; silently returning a different vector is the one
    // unacceptable outcome.
    check("codec_id_corruption", 0xCA, 40, |rng| {
        let n = 1 + rng.usize_below(800);
        let codecs = [
            UpdateCodec::Q8 { block: 1 + rng.below(300) as u32 },
            UpdateCodec::Q4 { block: 1 + rng.below(300) as u32 },
            UpdateCodec::TopK { keep_permille: 1 + rng.below(1000) as u32 },
        ];
        let codec = codecs[rng.usize_below(codecs.len())];
        let delta = rand_vec(rng, n, 2.0);
        let mut residual = Vec::new();
        let seed = rng.next_u64();
        let body = codec
            .encode_delta(&delta, seed, &mut residual)
            .map_err(|e| e.to_string())?
            .ok_or("lossy codec must produce a body")?;
        // Body-level id byte.
        let mut bad = body.clone();
        let flip = 1 + rng.below(255) as u8;
        bad[0] ^= flip;
        if codec.decode_delta(&bad, n).is_ok() {
            return Err(format!(
                "{}: body id byte ^ {flip:#x} decoded anyway",
                codec.label()
            ));
        }
        // Frame-level codec field (flags bits 8–15, header byte 9): the
        // frame checksum covers only the payload, so this corruption
        // reaches the codec check — which must refuse it.
        let mut residual2 = Vec::new();
        let frame = photon::link::encode_update(
            photon::link::MsgKind::ClientUpdate,
            &delta,
            &codec,
            seed,
            &mut residual2,
            rng.bool(0.5),
        )
        .map_err(|e| e.to_string())?;
        let mut bad_frame = frame.clone();
        bad_frame[9] ^= flip;
        if photon::link::decode_update(&bad_frame, &codec, n).is_ok() {
            return Err(format!(
                "{}: frame codec field ^ {flip:#x} decoded anyway",
                codec.label()
            ));
        }
        // And the intact frame still decodes.
        photon::link::decode_update(&frame, &codec, n).map_err(|e| e.to_string())?;
        Ok(())
    });
}

#[test]
fn prop_codec_transit_is_deterministic_and_parity_safe() {
    // The deployment-plane parity prerequisite: encode_transit is a pure
    // function of (codec, global, params, seed, residual) — two sides
    // starting from identical state produce byte-identical bodies and
    // identical post-encode residuals.
    check("codec_transit_determinism", 0xCB, 30, |rng| {
        let n = 1 + rng.usize_below(1000);
        let global = rand_vec(rng, n, 1.0);
        let params = rand_vec(rng, n, 1.0);
        let seed = rng.next_u64();
        let codecs = [
            UpdateCodec::None,
            UpdateCodec::Deflate,
            UpdateCodec::Q8 { block: 64 },
            UpdateCodec::Q4 { block: 64 },
            UpdateCodec::TopK { keep_permille: 100 },
        ];
        let codec = codecs[rng.usize_below(codecs.len())];
        let start: Vec<f32> = if rng.bool(0.5) { Vec::new() } else { rand_vec(rng, n, 0.2) };
        let mut res_a = start.clone();
        let mut res_b = start;
        let a = photon::compress::encode_transit(&codec, &global, &params, seed, &mut res_a)
            .map_err(|e| e.to_string())?;
        let b = photon::compress::encode_transit(&codec, &global, &params, seed, &mut res_b)
            .map_err(|e| e.to_string())?;
        if a.body != b.body || a.wire_bytes != b.wire_bytes || res_a != res_b {
            return Err(format!("{}: transit not deterministic", codec.label()));
        }
        if let Some(body) = &a.body {
            let rebuilt = photon::compress::decode_transit(&codec, &global, body)
                .map_err(|e| e.to_string())?;
            if rebuilt.len() != n {
                return Err("decode_transit length mismatch".into());
            }
        }
        Ok(())
    });
}

// --- wall-clock simulator properties (sim module) --------------------------

#[test]
fn prop_sim_same_config_same_timeline() {
    // Tentpole determinism contract: same seed + config → bit-identical
    // per-round timeline, across fresh simulator instances.
    use photon::cluster::faults::FaultPlan;
    use photon::config::ExperimentConfig;
    use photon::netsim::CLOUD_WAN;
    use photon::sim::{
        fleet_profiles, AggregationPolicy, RoundPlan, SimConfig, Simulator, DEFAULT_MFU,
    };
    check("sim_deterministic", 0xE1, 25, |rng| {
        let p = 1 + rng.usize_below(12);
        let k = 1 + rng.usize_below(p);
        let rounds = 1 + rng.usize_below(6);
        let tau = 1 + rng.below(50);
        let mut cfg = ExperimentConfig::wallclock(p, k, rounds, tau, rng.next_u64());
        cfg.faults = FaultPlan::new(rng.f64() * 0.5, rng.f64() * 0.5, rng.next_u64());
        let plan = RoundPlan::from_config(&cfg);
        let profiles =
            fleet_profiles(cfg.fleet.as_ref().unwrap(), 58_540_000, 1024 * 256, DEFAULT_MFU);
        let policy = match rng.usize_below(3) {
            0 => AggregationPolicy::Sync,
            1 => AggregationPolicy::SemiSync { deadline_factor: 1.0 + rng.f64() * 2.0 },
            _ => AggregationPolicy::Overlap,
        };
        let sim_cfg = SimConfig::new(58_540_000 * 4, CLOUD_WAN, policy);
        let a = Simulator::new(plan.clone(), profiles.clone(), sim_cfg).run();
        let b = Simulator::new(plan, profiles, sim_cfg).run();
        if a.rows != b.rows {
            return Err("timelines differ across identical runs".into());
        }
        if a.total_secs != b.total_secs {
            return Err(format!("totals differ: {} vs {}", a.total_secs, b.total_secs));
        }
        Ok(())
    });
}

#[test]
fn prop_sim_policy_ordering_and_accounting() {
    // Semi-sync and overlap can never be slower than sync on the same
    // schedule, and every round's participation partitions K exactly.
    use photon::cluster::faults::FaultPlan;
    use photon::config::ExperimentConfig;
    use photon::netsim::Link;
    use photon::sim::{
        fleet_profiles, AggregationPolicy, RoundPlan, SimConfig, Simulator, DEFAULT_MFU,
    };
    check("sim_policy_ordering", 0xE2, 25, |rng| {
        let p = 1 + rng.usize_below(10);
        let k = 1 + rng.usize_below(p);
        let rounds = 1 + rng.usize_below(5);
        let tau = 1 + rng.below(40);
        let mut cfg = ExperimentConfig::wallclock(p, k, rounds, tau, rng.next_u64());
        cfg.faults = FaultPlan::new(rng.f64() * 0.4, rng.f64() * 0.6, rng.next_u64());
        let plan = RoundPlan::from_config(&cfg);
        let profiles =
            fleet_profiles(cfg.fleet.as_ref().unwrap(), 58_540_000, 1024 * 256, DEFAULT_MFU);
        let link = Link { gbps: 0.01 + rng.f64() * 0.5, latency_s: rng.f64() * 0.1 };
        let payload = 1 + rng.below(1_000_000_000);
        let deadline_factor = 1.0 + rng.f64() * 2.0;
        let run = |policy| {
            let mut sc = SimConfig::new(payload, link, policy);
            sc.straggler_slowdown = 4.0;
            Simulator::new(plan.clone(), profiles.clone(), sc).run()
        };
        let sync = run(AggregationPolicy::Sync);
        let semi = run(AggregationPolicy::SemiSync { deadline_factor });
        let over = run(AggregationPolicy::Overlap);
        if semi.total_secs > sync.total_secs + 1e-6 {
            return Err(format!("semi {} > sync {}", semi.total_secs, sync.total_secs));
        }
        if over.total_secs > sync.total_secs + 1e-6 {
            return Err(format!("overlap {} > sync {}", over.total_secs, sync.total_secs));
        }
        for rep in [&sync, &semi, &over] {
            let mut prev_end = 0.0f64;
            for row in &rep.rows {
                if row.n_arrived + row.n_late + row.n_dropped != k {
                    return Err(format!(
                        "round {}: {}+{}+{} != K={k}",
                        row.round, row.n_arrived, row.n_late, row.n_dropped
                    ));
                }
                if row.t_start_secs != prev_end {
                    return Err(format!("round {} does not abut previous", row.round));
                }
                if row.bytes_down
                    != payload * (row.n_arrived + row.n_late) as u64
                {
                    return Err("broadcast byte accounting broken".into());
                }
                if row.bytes_up != payload * row.n_arrived as u64 {
                    return Err("upload byte accounting broken".into());
                }
                prev_end = row.t_end_secs;
            }
        }
        Ok(())
    });
}
