//! Integration: the chaos plane against the TCP deployment plane.
//! Requires `make artifacts`.
//!
//! The contract under test (ISSUE 5 acceptance): for any seeded
//! `chaos::Schedule`, the loopback fleet's final global model and round
//! records bit-equal the in-process `Federation` replay of the realized
//! trace (cuts + migrations + rejoins), and every round preserves
//! exactly-once client execution (participated + cut = runnable). The
//! `#[ignore]`d soak drives 50 rounds of mixed churn — run it with
//! `cargo test -q -- --ignored` (the CI `soak` job) and see
//! `docs/TESTING.md` for how to read a failure.

// Test-only wall-clock use (soak timing); the analysis pass exempts
// #[cfg(test)] code and clippy gets the file-level allow.
#![allow(clippy::disallowed_methods)]

use std::sync::Arc;

use photon::chaos::{ChaosConfig, Schedule};
use photon::cluster::faults::FaultPlan;
use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::metrics::RoundRecord;
use photon::net::{run_loopback, FleetOpts};
use photon::obs;
use photon::optim::schedule::CosineSchedule;
use photon::runtime::{ModelRuntime, Runtime};

fn model() -> Arc<ModelRuntime> {
    // Per-thread cache (same rationale as integration_fed.rs).
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

/// Full participation (K=P=6), no client-level faults: every cut and
/// migration in these tests is attributable to the injected worker chaos.
fn base_cfg(rounds: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 6;
    cfg.rounds = rounds;
    cfg.local_steps = 4;
    cfg.eval_batches = 2;
    cfg.seed = seed;
    let total = rounds as u64 * 4;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), 2);
    cfg.faults = FaultPlan::none();
    cfg
}

fn assert_parity(reference: &[RoundRecord], live: &[RoundRecord], what: &str) {
    assert_eq!(reference.len(), live.len(), "{what}: round count");
    for (r, n) in reference.iter().zip(live) {
        assert!(
            r.agrees_with(n),
            "{what}: round {} diverged\n  replay: {r:?}\n  fleet:  {n:?}",
            r.round
        );
    }
}

/// participated + cut must equal the runnable sample every round — the
/// exactly-once accounting (no client folded twice, none lost).
fn assert_exactly_once(report: &photon::net::FleetReport, k: usize, what: &str) {
    for rec in &report.records {
        let cut = report.trace.cut_for(rec.round).len();
        assert_eq!(
            rec.participated + cut,
            k,
            "{what}: round {} folded {} + cut {cut} != K={k}",
            rec.round,
            rec.participated
        );
    }
}

#[test]
fn chaotic_fleet_bit_equals_its_trace_replay() {
    // Mixed faults at a hefty rate, migration off: hangs and flakes
    // resolve through the deadline cut, crashes through disconnect (with
    // rejoin reclaiming leases when the schedule says so).
    let cfg = base_cfg(4, 31);
    let schedule = Schedule::generate(0xC4A0_5001, 4, 4, ChaosConfig::at_rate(0.45));
    assert!(!schedule.is_quiet(), "seed must actually inject faults");
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(8.0),
            chaos: Some(schedule),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 4, "every round must commit under churn");
    assert_exactly_once(&report, 6, "chaotic fleet");

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_trace(&report.trace).unwrap();
    assert_parity(&replayed, &report.records, "chaotic fleet vs trace replay");
    assert_eq!(replay.global, report.global, "global model must be bit-identical");
}

#[test]
fn rejoining_worker_reclaims_slot_and_leases_mid_round() {
    // Crash-only schedule with guaranteed rejoin: a crashed worker comes
    // back with its identity inside the same round, gets its pending
    // leases re-dispatched, and finishes them — so nothing is cut and the
    // run bit-equals a *clean* in-process run.
    let cfg = base_cfg(3, 47);
    let ccfg = ChaosConfig {
        crash_prob: 0.8,
        rejoin_prob: 1.0,
        ..ChaosConfig::none()
    };
    let schedule = Schedule::generate(0xC4A0_5002, 4, 3, ccfg);
    assert!(!schedule.is_quiet(), "seed must inject crashes");
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(20.0),
            chaos: Some(schedule),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(
        report.trace.total_rejoined() > 0,
        "crashed workers must have rejoined: {:?}",
        report.trace
    );
    assert_eq!(
        report.trace.total_cut(),
        0,
        "every lease must be reclaimed and served: {:?}",
        report.trace
    );
    for rec in &report.records {
        assert_eq!(rec.participated, 6, "round {}: full participation", rec.round);
    }

    // With zero cuts the chaotic run must equal the clean run bit-for-bit
    // — rejoins never touch the math.
    let mut clean = Federation::with_model(cfg, model()).unwrap();
    let reference = clean.run().unwrap();
    assert_parity(&reference, &report.records, "rejoin fleet vs clean run");
    assert_eq!(clean.global, report.global);
}

#[test]
fn hung_workers_leases_migrate_and_every_client_folds_once() {
    // Hang-heavy schedule with migration on: silent workers' unstarted
    // clients move to live peers at the halfway mark and still fold, so
    // participation stays full despite the hangs — and the stale owners'
    // (hypothetical) late pushes can never double-fold (exactly-once).
    let cfg = base_cfg(4, 53);
    let ccfg = ChaosConfig { hang_prob: 0.6, ..ChaosConfig::none() };
    let schedule = Schedule::generate(0xC4A0_5003, 4, 4, ccfg);
    assert!(!schedule.is_quiet(), "seed must inject hangs");
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(12.0),
            chaos: Some(schedule),
            migrate: true,
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(
        report.trace.total_migrated() > 0,
        "hung workers must have had leases migrated: {:?}",
        report.trace
    );
    assert_exactly_once(&report, 6, "migration fleet");
    // Migrated clients were computed by their new owner: they must have
    // folded, not been cut (no crashes in this schedule, so every
    // migration target stayed alive).
    for t in &report.trace.rounds {
        for m in &t.migrations {
            assert!(
                !t.cut.contains(&m.client),
                "round {}: migrated client {} was cut anyway",
                t.round,
                m.client
            );
        }
    }

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_trace(&report.trace).unwrap();
    assert_parity(&replayed, &report.records, "migration fleet vs trace replay");
    assert_eq!(replay.global, report.global);
}

/// The ISSUE 8 keystone: a chaotic fleet's JSONL event log, folded back
/// through `obs::to_trace`, must bit-equal the `Server::trace()` the
/// harness returned — the observability stream carries the *same*
/// realized history the replay-parity machinery runs on, so a saved log
/// is enough to reproduce a run. The commits in the log must also carry
/// the exact per-round loss the record stream reports.
#[test]
fn chaotic_fleet_event_log_reconstructs_the_trace_bit_exactly() {
    let dir = std::env::temp_dir().join(format!("photon_obs_fleet_{}", std::process::id()));
    let log = dir.join("events.jsonl");
    let cfg = base_cfg(4, 61);
    let schedule = Schedule::generate(0xC4A0_5008, 4, 4, ChaosConfig::at_rate(0.4));
    assert!(!schedule.is_quiet(), "seed must actually inject faults");
    let report = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(8.0),
            chaos: Some(schedule),
            migrate: true,
            obs_log: Some(log.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);

    // The log passes the `photon evck` schema gate wholesale...
    let text = std::fs::read_to_string(&log).unwrap();
    let n = obs::validate_log_text(&text).expect("fleet log must validate");
    assert!(n > 0, "the fleet must have emitted events");
    let (records, skipped) = obs::read_log(&log).unwrap();
    assert_eq!(skipped, 0, "a cleanly shut down log has no garbage");
    assert_eq!(records.len(), n);

    // ...and folds back into the exact realized trace.
    assert_eq!(
        obs::to_trace(&records),
        report.trace,
        "event log must reconstruct Server::trace() bit-exactly"
    );

    // Commits mirror the round records: same count, same order, and the
    // nll is the bit-identical server loss (not a re-derivation).
    let commits: Vec<(u64, u64, f64)> = records
        .iter()
        .filter_map(|r| match &r.event {
            obs::Event::RoundCommit { round, participated, nll, .. } => {
                Some((*round, *participated, *nll))
            }
            _ => None,
        })
        .collect();
    assert_eq!(commits.len(), report.records.len());
    for (rec, (round, participated, nll)) in report.records.iter().zip(&commits) {
        assert_eq!(rec.round as u64, *round);
        assert_eq!(rec.participated as u64, *participated);
        assert_eq!(rec.server_nll.to_bits(), nll.to_bits(), "round {round} nll");
    }

    // The reduced view agrees with the fleet report's own accounting.
    let mut view = obs::ViewState::default();
    view.apply_all(&records);
    assert!(view.shutdown, "a clean run ends in a shutdown event");
    assert_eq!(view.committed_rounds() as usize, report.records.len());
    assert_eq!(view.total_cut() as usize, report.trace.total_cut());
    assert_eq!(view.total_migrated() as usize, report.trace.total_migrated());
    assert_eq!(view.total_rejoined() as usize, report.trace.total_rejoined());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn watchdog_diagnoses_a_wedged_fleet_instead_of_hanging() {
    // A fleet asked to wait for more workers than will ever join: the
    // server blocks in its admission barrier past the watchdog, and the
    // harness must fail with a diagnosis instead of wedging the suite.
    // (Workers finish fine — the server thread is the stuck one.)
    let cfg = base_cfg(1, 7);
    let t0 = std::time::Instant::now();
    let err = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 0, // nobody joins; server waits for min_workers=0...
            deadline_secs: None,
            compress: true,
            watchdog_secs: Some(3.0),
            ..FleetOpts::default()
        },
    );
    // With zero workers the server either errors quickly (no live workers
    // at round 0 after its join window) or the watchdog fires first —
    // both are failures-with-diagnosis, never a hang.
    assert!(err.is_err(), "a worker-less fleet cannot succeed");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(60),
        "failure must be prompt, not a wedged join"
    );
    let msg = format!("{:#}", err.unwrap_err());
    assert!(
        msg.contains("watchdog") || msg.contains("workers"),
        "diagnosis must name the cause: {msg}"
    );
}

/// The churn soak (ISSUE 5 satellite): 50 rounds of mixed crash / hang /
/// slow / flake with rejoins and lease migration, asserting fleet-vs-
/// replay bit parity and exactly-once accounting for every round. Run via
/// `cargo test -q -- --ignored` (the CI `soak` job budget covers it).
#[test]
#[ignore = "soak: ~minutes of wall-clock; run with -- --ignored"]
fn soak_50_round_churn_stays_bit_reproducible() {
    let rounds = 50;
    let cfg = base_cfg(rounds, 101);
    let schedule =
        Schedule::generate(0xC4A0_50CA, 4, rounds, ChaosConfig::at_rate(0.35));
    assert!(!schedule.is_quiet());
    // The soak writes a structured event log (`PHOTON_OBS_LOG` overrides
    // the path): CI schema-checks it with `photon evck` and uploads it as
    // a triage artifact when the soak fails.
    let obs_log = std::env::var("PHOTON_OBS_LOG")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("target/soak_events.jsonl"));
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(6.0),
            chaos: Some(schedule),
            migrate: true,
            watchdog_secs: Some(1200.0),
            obs_log: Some(obs_log.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), rounds, "all {rounds} rounds must commit");
    assert_exactly_once(&report, 6, "soak fleet");
    let (records, _) = obs::read_log(&obs_log).unwrap();
    assert_eq!(
        obs::to_trace(&records),
        report.trace,
        "soak event log must reconstruct the realized trace"
    );
    assert!(
        report.trace.total_cut() > 0,
        "a 50-round churn soak should realize some cuts: {:?}",
        report.trace
    );

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_trace(&report.trace).unwrap();
    assert_parity(&replayed, &report.records, "soak fleet vs trace replay");
    assert_eq!(
        replay.global, report.global,
        "50 rounds of churn must stay bit-reproducible from the trace"
    );
}
