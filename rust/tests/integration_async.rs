//! Integration: the buffered-async aggregation plane against the TCP
//! deployment plane (ISSUE 10). Requires `make artifacts`.
//!
//! The keystone contract: for any realized async fleet — quiet or under
//! seeded chaos — the grant/fold/cut ledger ([`photon::chaos::AsyncTrace`])
//! replays bit-exactly in-process via `Federation::run_async_trace`:
//! identical epoch records, identical global parameter bits, identical
//! (wall-clock-canonicalized) checkpoint bytes. Exactly-once lease
//! accounting holds across worker crashes and identity rejoins. The
//! `#[ignore]`d soak drives a longer churned run whose JSONL event log
//! passes the `photon evck` schema gate — run it with
//! `cargo test -q -- --ignored` (the CI `soak` job).

// Test-only wall-clock use (soak timing); the analysis pass exempts
// #[cfg(test)] code and clippy gets the file-level allow.
#![allow(clippy::disallowed_methods)]

use std::sync::Arc;

use photon::chaos::{ChaosConfig, Schedule};
use photon::ckpt::{self, Checkpoint};
use photon::cluster::faults::FaultPlan;
use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::metrics::RoundRecord;
use photon::net::{run_loopback, FleetOpts};
use photon::obs;
use photon::optim::schedule::CosineSchedule;
use photon::runtime::{ModelRuntime, Runtime};

fn model() -> Arc<ModelRuntime> {
    // Per-thread cache (same rationale as integration_fed.rs).
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

/// Flat async base config: P=6 clients, folds of K (clients_per_round is
/// set to K for the comm accounting; the async server never consults the
/// per-round sampler), no client-level faults.
fn base_cfg(epochs: usize, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 3;
    cfg.rounds = epochs;
    cfg.local_steps = 4;
    cfg.eval_batches = 2;
    cfg.seed = seed;
    let total = epochs as u64 * 4;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), 2);
    cfg.faults = FaultPlan::none();
    cfg
}

fn assert_parity(reference: &[RoundRecord], live: &[RoundRecord], what: &str) {
    assert_eq!(reference.len(), live.len(), "{what}: epoch count");
    for (r, n) in reference.iter().zip(live) {
        assert!(
            r.agrees_with(n),
            "{what}: epoch {} diverged\n  replay: {r:?}\n  fleet:  {n:?}",
            r.round
        );
    }
}

/// Checkpoint with the wall-clock bookkeeping zeroed: the remaining bytes
/// are exactly the replay-relevant state.
fn canonical_bytes(mut ck: Checkpoint) -> Vec<u8> {
    ck.timestamp = 0;
    ck.elapsed_secs = 0.0;
    ck.encode()
}

#[test]
fn async_fleet_bit_equals_its_ledger_replay() {
    // Quiet 4-worker fleet, K=3 folds over 6 clients, 3 epochs. The
    // server checkpoints every epoch; the latest checkpoint's bytes must
    // equal the replay federation's own.
    let dir =
        std::env::temp_dir().join(format!("photon_async_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = base_cfg(3, 71);
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            async_agg: Some((3, 0.5)),
            ckpt_dir: Some(dir.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), 3, "every epoch must commit");
    let trace = report.async_trace.clone().expect("async fleet returns a ledger");
    trace.check_exactly_once().unwrap();
    assert_eq!(trace.k, 3);
    assert_eq!(trace.total_folded(), 9, "3 epochs × K=3 arrivals");
    // A quiet fleet still cuts the grants left in flight at shutdown —
    // the ledger accounts for every grant either way.
    assert_eq!(trace.total_folded() + trace.total_cut(), trace.grants.len());

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_async_trace(&trace).unwrap();
    assert_parity(&replayed, &report.records, "async fleet vs ledger replay");
    assert_eq!(replay.global, report.global, "global model must be bit-identical");

    // Checkpoint bytes: the fleet's last on-disk epoch checkpoint equals
    // the replay federation's state, wall clocks aside.
    let (round, path) = ckpt::latest_in(&dir).unwrap().expect("server checkpointed");
    assert_eq!(round, 3, "latest checkpoint is the final epoch's");
    let fleet_ck = Checkpoint::load(&path).unwrap();
    assert_eq!(
        canonical_bytes(fleet_ck),
        canonical_bytes(replay.checkpoint()),
        "fleet checkpoint bytes must equal the replay's"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crash_rejoin_fleet_preserves_exactly_once_lease_accounting() {
    // Crash-heavy schedule (keyed by grant id — generate well past the
    // epoch count) with guaranteed rejoin: grants die with their workers,
    // are cut exactly once, and their clients re-grant fresh. The ledger
    // replay must still be bit-exact.
    let epochs = 3;
    let cfg = base_cfg(epochs, 83);
    let ccfg = ChaosConfig { crash_prob: 0.35, rejoin_prob: 1.0, ..ChaosConfig::none() };
    let schedule = Schedule::generate(0xA51C_1002, 4, epochs * 24, ccfg);
    assert!(!schedule.is_quiet(), "seed must inject crashes");
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(10.0),
            chaos: Some(schedule),
            async_agg: Some((3, 0.5)),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), epochs, "every epoch must commit under churn");
    let trace = report.async_trace.clone().expect("async fleet returns a ledger");
    // The exactly-once contract across crash/rejoin epochs: every grant
    // id resolves into exactly one fold XOR one cut — never both, never
    // twice, none lost.
    trace.check_exactly_once().unwrap();
    assert_eq!(trace.total_folded(), epochs * 3, "K arrivals per epoch");
    assert_eq!(trace.total_folded() + trace.total_cut(), trace.grants.len());

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_async_trace(&trace).unwrap();
    assert_parity(&replayed, &report.records, "crash/rejoin fleet vs ledger replay");
    assert_eq!(replay.global, report.global, "global model must be bit-identical");
}

#[test]
fn async_trace_survives_staleness_and_discounts_it() {
    // With K=2 folds over 6 clients and 4 workers, up to max(K, live)=4
    // grants are in flight — arrivals born before an earlier fold commit
    // land with staleness ≥ 1 and a discounted weight. The ledger records
    // it and the replay agrees bit-for-bit.
    let cfg = base_cfg(4, 97);
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            async_agg: Some((2, 0.5)),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    let trace = report.async_trace.clone().expect("async fleet returns a ledger");
    trace.check_exactly_once().unwrap();
    // Every arrival's recorded weight is positive and each fold's weights
    // normalize to 1 (the discount invariant, as realized on the wire).
    for f in &trace.folds {
        let sum: f64 = f.arrivals.iter().map(|a| a.weight).sum();
        assert!((sum - 1.0).abs() < 1e-12, "epoch {}: weights sum {sum}", f.epoch);
        for a in &f.arrivals {
            assert!(a.weight > 0.0, "epoch {}: weight {}", f.epoch, a.weight);
        }
    }
    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_async_trace(&trace).unwrap();
    assert_parity(&replayed, &report.records, "staleness fleet vs ledger replay");
    assert_eq!(replay.global, report.global);
}

/// The async soak (ISSUE 10 satellite): a longer churned async run whose
/// structured event log passes the `photon evck` schema gate and whose
/// reduced view agrees with the ledger. Run via
/// `cargo test -q -- --ignored` (the CI `soak` job budget covers it).
#[test]
#[ignore = "soak: ~minutes of wall-clock; run with -- --ignored"]
fn soak_async_churn_stays_bit_reproducible_and_log_validates() {
    let epochs = 12;
    let cfg = base_cfg(epochs, 113);
    let schedule =
        Schedule::generate(0xA51C_10CA, 4, epochs * 24, ChaosConfig::at_rate(0.25));
    assert!(!schedule.is_quiet());
    let obs_log = std::env::var("PHOTON_OBS_LOG")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("target/soak_async_events.jsonl"));
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            deadline_secs: Some(8.0),
            chaos: Some(schedule),
            async_agg: Some((3, 0.7)),
            watchdog_secs: Some(1200.0),
            obs_log: Some(obs_log.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert_eq!(report.records.len(), epochs, "all {epochs} epochs must commit");
    let trace = report.async_trace.clone().expect("async fleet returns a ledger");
    trace.check_exactly_once().unwrap();

    // The event log passes the schema gate wholesale and folds into a
    // view that matches the ledger's accounting.
    let text = std::fs::read_to_string(&obs_log).unwrap();
    let n = obs::validate_log_text(&text).expect("async fleet log must validate");
    assert!(n > 0);
    let (records, skipped) = obs::read_log(&obs_log).unwrap();
    assert_eq!(skipped, 0, "a cleanly shut down log has no garbage");
    // `to_trace` folds the async log without error (async cut events
    // accumulate per epoch; grants/folds live in the async ledger).
    let _ = obs::to_trace(&records);
    let mut view = obs::ViewState::default();
    view.apply_all(&records);
    assert!(view.shutdown, "a clean run ends in a shutdown event");
    assert_eq!(view.committed_rounds() as usize, report.records.len());
    assert_eq!(view.total_folded() as usize, trace.total_folded());
    assert_eq!(
        view.rounds.values().map(|r| r.staleness_max).max().unwrap_or(0),
        trace.staleness_max(),
        "view staleness agrees with the ledger"
    );

    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let replayed = replay.run_async_trace(&trace).unwrap();
    assert_parity(&replayed, &report.records, "async soak vs ledger replay");
    assert_eq!(
        replay.global, report.global,
        "{epochs} churned epochs must stay bit-reproducible from the ledger"
    );
}
