//! Integration: checkpoint/resume over real federated training.
//! The paper's requirement (§4.1/§6.2): resumption from the most recent
//! round must be exact — global model, outer-optimizer state, schedule
//! position, and every client's stream cursor.

use std::sync::Arc;

use photon::cluster::faults::FaultPlan;
use photon::cluster::hardware::{ClientHardware, FleetSpec, NodeSpec, A40};
use photon::config::{ExperimentConfig, OptStatePolicy};
use photon::coordinator::Federation;
use photon::optim::outer::{OuterHyper, OuterOptKind};
use photon::runtime::{ModelRuntime, Runtime};

fn model() -> Arc<ModelRuntime> {
    let rt = Runtime::cpu().unwrap();
    Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
}

fn cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.rounds = 4;
    cfg.local_steps = 6;
    cfg.eval_batches = 2;
    // A stateful outer optimizer + KeepOpt clients: the hardest resume case.
    cfg.outer = OuterOptKind::FedMomentum { nesterov: true };
    cfg.outer_hyper = OuterHyper { lr: 0.7, momentum: 0.9, ..OuterHyper::default() };
    cfg.opt_state = OptStatePolicy::KeepOpt;
    cfg
}

#[test]
fn resume_is_bit_exact() {
    let m = model();
    // Uninterrupted reference run.
    let mut full = Federation::with_model(cfg(), m.clone()).unwrap();
    full.run().unwrap();

    // Interrupted run: 2 rounds, checkpoint, fresh federation, resume, 2 more.
    let dir = std::env::temp_dir().join(format!("photon_it_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut first = Federation::with_model(cfg(), m.clone()).unwrap();
    first.run_round().unwrap();
    first.run_round().unwrap();
    let path = dir.join("ckpt_round_2.bin");
    first.checkpoint().save(&path).unwrap();
    drop(first);

    let mut resumed = Federation::with_model(cfg(), m).unwrap();
    assert!(resumed.try_resume_from(&dir).unwrap());
    assert_eq!(resumed.next_round, 2);
    resumed.run().unwrap();

    assert_eq!(resumed.global, full.global, "resume must be bit-exact");
    assert_eq!(
        resumed.log.rounds.last().unwrap().server_ppl,
        full.log.rounds.last().unwrap().server_ppl
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn auto_checkpointing_during_run() {
    let m = model();
    let dir = std::env::temp_dir().join(format!("photon_it_auto_{}", std::process::id()));
    let mut fed = Federation::with_model(cfg(), m).unwrap();
    fed.ckpt_dir = Some(dir.clone());
    fed.run().unwrap();
    let (round, path) = photon::ckpt::latest_in(&dir).unwrap().unwrap();
    assert_eq!(round, 4);
    let ck = photon::ckpt::Checkpoint::load(&path).unwrap();
    assert_eq!(ck.global, fed.global);
    assert_eq!(ck.seq_step, 24);
    assert!(ck.clients.iter().all(|c| c.is_some()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn multi_island_resume_is_sample_exact() {
    // Regression: v1 checkpoints saved only streams[0]'s cursor, so a
    // multi-island (hetero-fleet) client resumed islands 1.. from their
    // *initial* stream state — resume was not sample-exact. Every island
    // cursor must now survive the roundtrip.
    let m = model();
    let mut c = cfg();
    c.n_clients = 2;
    c.clients_per_round = 2;
    let wan_client = ClientHardware {
        nodes: vec![NodeSpec { gpu: A40, n_gpus: 1, intra_gbps: 600.0 }; 2],
        inter_gbps: 0.1, // two poorly-connected nodes → two islands
    };
    c.fleet = Some(FleetSpec { clients: vec![wan_client.clone(), wan_client] });

    // Uninterrupted reference run.
    let mut full = Federation::with_model(c.clone(), m.clone()).unwrap();
    full.run().unwrap();

    // Interrupted + resumed run.
    let mut first = Federation::with_model(c.clone(), m.clone()).unwrap();
    first.run_round().unwrap();
    first.run_round().unwrap();
    let ck = first.checkpoint();
    assert!(
        ck.clients.iter().all(|cl| cl.as_ref().unwrap().cursors.len() == 2),
        "each 2-island client must checkpoint 2 cursors"
    );
    drop(first);
    let mut resumed = Federation::with_model(c, m).unwrap();
    resumed.restore(&ck).unwrap();
    resumed.run().unwrap();

    assert_eq!(resumed.global, full.global, "hetero-fleet resume must be bit-exact");
}

#[test]
fn all_dropped_round_still_writes_checkpoint() {
    // Regression: a round where every sampled client dropped returned
    // before the checkpoint block, so ckpt_dir silently skipped a round
    // file and resume replayed the round.
    let m = model();
    let dir = std::env::temp_dir().join(format!("photon_it_drop_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut c = cfg();
    c.rounds = 2;
    c.faults = FaultPlan { dropout_prob: 1.0, straggler_prob: 0.0, straggler_fraction: 0.5, seed: 1 };
    let mut fed = Federation::with_model(c.clone(), m.clone()).unwrap();
    fed.ckpt_dir = Some(dir.clone());
    fed.run().unwrap();
    for round in [1u64, 2] {
        assert!(
            dir.join(format!("ckpt_round_{round}.bin")).is_file(),
            "round {round} checkpoint missing despite ckpt_dir being set"
        );
    }
    let mut resumed = Federation::with_model(c, m).unwrap();
    assert!(resumed.try_resume_from(&dir).unwrap());
    assert_eq!(resumed.next_round, 2, "resume must not replay the dropped round");
    assert_eq!(resumed.seq_step, fed.seq_step, "schedule position must survive");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_mismatched_config() {
    let m = model();
    let fed = Federation::with_model(cfg(), m.clone()).unwrap();
    let mut ck = fed.checkpoint();
    ck.global.pop(); // wrong model size
    let mut other = Federation::with_model(cfg(), m).unwrap();
    assert!(other.restore(&ck).is_err());
}

#[test]
fn no_checkpoint_dir_resumes_nothing() {
    let m = model();
    let mut fed = Federation::with_model(cfg(), m).unwrap();
    let empty = std::env::temp_dir().join("photon_definitely_missing_xyz");
    assert!(!fed.try_resume_from(&empty).unwrap());
}
