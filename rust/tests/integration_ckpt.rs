//! Integration: checkpoint/resume over real federated training.
//! The paper's requirement (§4.1/§6.2): resumption from the most recent
//! round must be exact — global model, outer-optimizer state, schedule
//! position, and every client's stream cursor.

use std::rc::Rc;

use photon::config::{ExperimentConfig, OptStatePolicy};
use photon::coordinator::Federation;
use photon::optim::outer::{OuterHyper, OuterOptKind};
use photon::runtime::{ModelRuntime, Runtime};

fn model() -> Rc<ModelRuntime> {
    let rt = Runtime::cpu().unwrap();
    Rc::new(rt.load_model("m75a").expect("run `make artifacts`"))
}

fn cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.rounds = 4;
    cfg.local_steps = 6;
    cfg.eval_batches = 2;
    // A stateful outer optimizer + KeepOpt clients: the hardest resume case.
    cfg.outer = OuterOptKind::FedMomentum { nesterov: true };
    cfg.outer_hyper = OuterHyper { lr: 0.7, momentum: 0.9, ..OuterHyper::default() };
    cfg.opt_state = OptStatePolicy::KeepOpt;
    cfg
}

#[test]
fn resume_is_bit_exact() {
    let m = model();
    // Uninterrupted reference run.
    let mut full = Federation::with_model(cfg(), m.clone()).unwrap();
    full.run().unwrap();

    // Interrupted run: 2 rounds, checkpoint, fresh federation, resume, 2 more.
    let dir = std::env::temp_dir().join(format!("photon_it_ck_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut first = Federation::with_model(cfg(), m.clone()).unwrap();
    first.run_round().unwrap();
    first.run_round().unwrap();
    let path = dir.join("ckpt_round_2.bin");
    first.checkpoint().save(&path).unwrap();
    drop(first);

    let mut resumed = Federation::with_model(cfg(), m).unwrap();
    assert!(resumed.try_resume_from(&dir).unwrap());
    assert_eq!(resumed.next_round, 2);
    resumed.run().unwrap();

    assert_eq!(resumed.global, full.global, "resume must be bit-exact");
    assert_eq!(
        resumed.log.rounds.last().unwrap().server_ppl,
        full.log.rounds.last().unwrap().server_ppl
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn auto_checkpointing_during_run() {
    let m = model();
    let dir = std::env::temp_dir().join(format!("photon_it_auto_{}", std::process::id()));
    let mut fed = Federation::with_model(cfg(), m).unwrap();
    fed.ckpt_dir = Some(dir.clone());
    fed.run().unwrap();
    let (round, path) = photon::ckpt::latest_in(&dir).unwrap().unwrap();
    assert_eq!(round, 4);
    let ck = photon::ckpt::Checkpoint::load(&path).unwrap();
    assert_eq!(ck.global, fed.global);
    assert_eq!(ck.seq_step, 24);
    assert!(ck.clients.iter().all(|c| c.is_some()));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_rejects_mismatched_config() {
    let m = model();
    let fed = Federation::with_model(cfg(), m.clone()).unwrap();
    let mut ck = fed.checkpoint();
    ck.global.pop(); // wrong model size
    let mut other = Federation::with_model(cfg(), m).unwrap();
    assert!(other.restore(&ck).is_err());
}

#[test]
fn no_checkpoint_dir_resumes_nothing() {
    let m = model();
    let mut fed = Federation::with_model(cfg(), m).unwrap();
    let empty = std::env::temp_dir().join("photon_definitely_missing_xyz");
    assert!(!fed.try_resume_from(&empty).unwrap());
}
