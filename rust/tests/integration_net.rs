//! Integration: the TCP deployment plane (`net`) against the in-process
//! federation. Requires `make artifacts`.
//!
//! The contract under test (ISSUE 3 acceptance): a localhost fleet of K
//! workers reproduces `Federation::run` bit-for-bit — global model and
//! round-record stream — including rounds where a worker is cut (crash or
//! deadline) through the dropped-client path, and across a server restart
//! resumed from the latest checkpoint.

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::Arc;

use photon::cluster::faults::FaultPlan;
use photon::compress::UpdateCodec;
use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::metrics::RoundRecord;
use photon::net::proto::{self, Join, Msg, PROTO_VERSION};
use photon::net::{run_loopback, run_worker, FleetOpts, ServeOpts, Server, WorkerOpts};
use photon::optim::schedule::CosineSchedule;
use photon::runtime::{ModelRuntime, Runtime};
use photon::sim::RoundPlan;

fn model() -> Arc<ModelRuntime> {
    // Per-thread cache (same rationale as integration_fed.rs).
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

/// K=5 of P=6 clients, 3 rounds, dropouts + stragglers in the plan.
fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 6;
    cfg.clients_per_round = 5;
    cfg.rounds = 3;
    cfg.local_steps = 6;
    cfg.eval_batches = 2;
    cfg.seed = 11;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 18, 2);
    cfg.faults = FaultPlan::new(0.3, 0.3, 11);
    cfg
}

fn assert_parity(reference: &[RoundRecord], live: &[RoundRecord], what: &str) {
    assert_eq!(reference.len(), live.len(), "{what}: round count");
    for (r, n) in reference.iter().zip(live) {
        assert!(
            r.agrees_with(n),
            "{what}: round {} diverged\n  in-process: {r:?}\n  deployment: {n:?}",
            r.round
        );
    }
}

#[test]
fn plan_round_replays_the_sim_round_plan() {
    let cfg = base_cfg();
    let fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let plan = RoundPlan::from_config(&cfg);
    let d = fed.plan_round();
    let spec = &plan.rounds[0];
    let from_plan: Vec<(usize, u64)> =
        spec.participants.iter().map(|p| (p.client, p.steps)).collect();
    assert_eq!(d.runnable, from_plan, "dispatch must equal the replayed plan");
    assert_eq!(d.dropped, spec.dropped);
    assert_eq!(d.round, 0);
}

#[test]
fn loopback_fleet_of_4_matches_in_process_bitwise() {
    let cfg = base_cfg();
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 4, compress: true, ..FleetOpts::default() },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "no faults beyond the plan: {:?}", report.cuts);
    assert_parity(&reference, &report.records, "healthy fleet");
    assert_eq!(fed.global, report.global, "global model must be bit-identical");
    // Every worker served every round it was alive for.
    assert_eq!(report.workers.len(), 4);
    let pushed: u64 = report.workers.iter().map(|w| w.updates_pushed).sum();
    let expected: usize = reference.iter().map(|r| r.participated).sum();
    assert_eq!(pushed as usize, expected);
}

#[test]
fn loopback_fleet_with_q8_codec_negotiated_matches_in_process() {
    // ISSUE 4 acceptance: the distributed parity contract survives a lossy
    // update codec. Workers encode each pseudo-delta (stochastic rounding
    // seeded per (round, client) from the task spec), the server
    // decodes-then-folds; the in-process run replays the identical
    // transform, so records (incl. the new wire-byte accounting) and the
    // global model must stay bit-equal.
    let mut cfg = base_cfg();
    cfg.codec = UpdateCodec::Q8 { block: 64 };
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    let report = run_loopback(
        cfg,
        model(),
        FleetOpts { workers: 3, compress: true, ..FleetOpts::default() },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    assert!(report.cuts.is_empty(), "no faults beyond the plan: {:?}", report.cuts);
    assert_parity(&reference, &report.records, "q8 fleet");
    assert_eq!(fed.global, report.global, "global model must be bit-identical");
    // The codec actually shrank the wire: coded update frames are ~4×
    // smaller than dense, so the measured accounting must sit well below
    // the dense estimate on every participating round.
    for r in &reference {
        if r.participated > 0 {
            assert!(
                r.comm_bytes_wire < r.comm_bytes,
                "round {}: wire {} !< dense {}",
                r.round,
                r.comm_bytes_wire,
                r.comm_bytes
            );
        }
    }
}

#[test]
fn topk_codec_residual_survives_checkpoint_resume() {
    // Error-feedback state is client state: a run interrupted mid-stream
    // must resume with its residuals intact, sample- and codec-exact.
    let dir =
        std::env::temp_dir().join(format!("photon_net_topk_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut cfg = base_cfg();
    cfg.rounds = 4;
    cfg.codec = UpdateCodec::TopK { keep_permille: 100 };

    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    // Run 2 rounds, checkpointing; then resume a fresh federation.
    let mut half_cfg = cfg.clone();
    half_cfg.rounds = 2;
    let mut half = Federation::with_model(half_cfg, model()).unwrap();
    half.ckpt_dir = Some(dir.clone());
    half.run().unwrap();

    let mut resumed = Federation::with_model(cfg, model()).unwrap();
    resumed.ckpt_dir = Some(dir.clone());
    assert!(resumed.try_resume_from(&dir).unwrap(), "checkpoint must exist");
    assert_eq!(resumed.next_round, 2);
    let tail = resumed.run().unwrap();
    assert_parity(&reference[2..], &tail, "topk resume");
    assert_eq!(fed.global, resumed.global, "resume must be codec-state-exact");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn worker_killed_mid_round_is_cut_and_the_round_still_commits() {
    let mut cfg = base_cfg();
    // Full participation, no planned faults: every one of the 4 workers is
    // guaranteed an assignment each round, so the rigged worker receives
    // round 1 and "crashes" deterministically.
    cfg.faults = FaultPlan::none();
    let crashed = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 4,
            compress: true,
            die_at_round: BTreeMap::from([(0usize, 1u64)]),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    // The dead worker's clients were cut, yet every round committed.
    assert_eq!(crashed.records.len(), 3, "all rounds must commit");
    assert!(
        !crashed.cuts.is_empty(),
        "killing a worker mid-round must cut its pending clients"
    );
    for (round, clients) in &crashed.cuts {
        assert!(*round >= 1, "cuts can only start at the crash round");
        assert!(!clients.is_empty());
    }

    // Replaying the realized cut schedule in-process reproduces the run
    // bit-for-bit — the cut goes through the dropped-client path.
    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let mut replayed = Vec::new();
    for round in 0..3usize {
        let cut = crashed
            .cuts
            .iter()
            .find(|(r, _)| *r == round)
            .map(|(_, c)| c.clone())
            .unwrap_or_default();
        replayed.push(replay.run_round_cut(&cut).unwrap());
    }
    assert_parity(&replayed, &crashed.records, "crash-cut fleet");
    assert_eq!(replay.global, crashed.global);
}

#[test]
fn server_restart_resumes_sample_exact_from_latest_checkpoint() {
    let dir = std::env::temp_dir().join(format!("photon_net_restart_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut cfg = base_cfg();
    cfg.rounds = 4;
    // Uninterrupted reference.
    let mut fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let reference = fed.run().unwrap();

    // Phase 1: serve two rounds, checkpointing each, then shut down (the
    // state a crash would leave behind is the same file).
    let mut phase1_cfg = cfg.clone();
    phase1_cfg.rounds = 2;
    let phase1 = run_loopback(
        phase1_cfg,
        model(),
        FleetOpts {
            workers: 3,
            compress: true,
            ckpt_dir: Some(dir.clone()),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert_parity(&reference[..2], &phase1.records, "pre-restart rounds");

    // Phase 2: a fresh server resumes from the latest checkpoint; fresh
    // (stateless!) workers reconnect and finish the run.
    let phase2 = run_loopback(
        cfg,
        model(),
        FleetOpts {
            workers: 3,
            compress: true,
            ckpt_dir: Some(dir.clone()),
            resume: true,
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert_eq!(phase2.records.len(), 2, "resume must skip the two done rounds");
    assert_parity(&reference[2..], &phase2.records, "post-restart rounds");
    assert_eq!(fed.global, phase2.global, "restart must be sample-exact");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn silent_worker_is_deadline_cut_through_the_dropped_client_path() {
    // One round; two real workers plus one admitted peer that heartbeats
    // its Join but never pushes an update. The deadline must cut exactly
    // its clients and the round must commit with everyone else folded in.
    let mut cfg = base_cfg();
    cfg.rounds = 1;
    cfg.local_steps = 3;
    cfg.faults = FaultPlan::none();
    let fed = Federation::with_model(cfg.clone(), model()).unwrap();
    let serve = ServeOpts {
        bind: "127.0.0.1:0".into(),
        min_workers: 3,
        deadline_secs: Some(8.0),
        compress: true,
        ..ServeOpts::default()
    };
    let mut server = Server::with_federation(fed, serve).unwrap();
    let addr = server.local_addr().to_string();
    let server_handle = std::thread::spawn(move || {
        let result = server.run();
        (server, result)
    });

    // The silent peer: joins, drains every frame, never replies.
    let silent_addr = addr.clone();
    let silent = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(&silent_addr).unwrap();
        proto::write_msg(
            &mut stream,
            &Msg::Join(Join { proto: PROTO_VERSION, name: "silent".into(), identity: 0 }),
            false,
        )
        .unwrap();
        let mut assigned: Vec<usize> = Vec::new();
        loop {
            match proto::read_msg(&mut stream) {
                Ok(Msg::RoundAssign(a)) => {
                    assigned.extend(a.tasks.iter().map(|t| t.client as usize))
                }
                Ok(Msg::Shutdown) | Err(_) => return assigned,
                Ok(_) => {}
            }
        }
    });
    let shared = model();
    let workers: Vec<_> = (0..2)
        .map(|i| {
            let addr = addr.clone();
            let shared = shared.clone();
            std::thread::spawn(move || {
                run_worker(
                    &addr,
                    WorkerOpts {
                        name: format!("real-{i}"),
                        model: Some(shared),
                        ..WorkerOpts::default()
                    },
                )
            })
        })
        .collect();

    for w in workers {
        w.join().unwrap().unwrap();
    }
    let mut assigned = silent.join().unwrap();
    assigned.sort_unstable();
    let (server, result) = server_handle.join().unwrap();
    let records = result.unwrap();
    assert_eq!(records.len(), 1);
    assert!(!assigned.is_empty(), "the silent peer must have been assigned work");
    assert_eq!(
        server.cuts,
        vec![(0usize, assigned.clone())],
        "the deadline must cut exactly the silent peer's clients"
    );
    assert_eq!(records[0].participated, 5 - assigned.len());

    // Bit-exact in-process replay of the realized cut.
    let mut replay = Federation::with_model(cfg, model()).unwrap();
    let rec = replay.run_round_cut(&assigned).unwrap();
    assert!(rec.agrees_with(&records[0]), "{rec:?} vs {:?}", records[0]);
    assert_eq!(replay.global, server.federation().global.as_slice());
}
