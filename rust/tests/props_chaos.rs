//! Property tests for the chaos plane (artifact-free): schedule
//! determinism, lease-ledger exactly-once under random event orders, and
//! the link-flake rejection guarantee. Uses the seeded `testkit` harness
//! — every failure reports a replay seed (`TESTKIT_REPLAY=<seed>`), and
//! the lease property shrinks to a minimal failing op sequence.

use photon::chaos::{flake_frame, ChaosConfig, Fault, LeaseBook, Schedule};
use photon::link::{self, MsgKind};
use photon::sim::{Participant, RoundPlan, RoundSpec};
use photon::testkit::{check, check_cases, shrink_vec};
use photon::util::rng::Rng;

#[test]
fn prop_schedule_is_deterministic_and_extent_stable() {
    check("chaos_schedule_determinism", 0xC0FFEE, 40, |rng| {
        let seed = rng.next_u64();
        let workers = 1 + rng.usize_below(8);
        let rounds = 1 + rng.usize_below(40);
        let cfg = ChaosConfig::at_rate(rng.f64());
        let a = Schedule::generate(seed, workers, rounds, cfg);
        let b = Schedule::generate(seed, workers, rounds, cfg);
        // A wider/longer schedule must agree on every shared cell.
        let wide = Schedule::generate(seed, workers + 3, rounds + 17, cfg);
        for r in 0..rounds {
            for w in 0..workers {
                if a.fault(w, r) != b.fault(w, r) {
                    return Err(format!("cell ({w},{r}) differs across builds"));
                }
                if a.fault(w, r) != wide.fault(w, r) {
                    return Err(format!("cell ({w},{r}) changed when extended"));
                }
            }
        }
        // Out-of-extent cells are quiet, never a panic.
        if a.fault(workers + 1, 0) != Fault::None || a.fault(0, rounds) != Fault::None {
            return Err("out-of-extent cell not quiet".into());
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_rates_are_plausible() {
    check("chaos_schedule_rates", 0xBEEF, 8, |rng| {
        let rate = 0.2 + rng.f64() * 0.5;
        let s = Schedule::generate(rng.next_u64(), 6, 120, ChaosConfig::at_rate(rate));
        let mut faulty = 0usize;
        let mut cells = 0usize;
        // Worker 0 is protected (crash/hang downgraded); count the rest.
        for w in 1..6 {
            for r in 0..120 {
                cells += 1;
                if s.fault(w, r) != Fault::None {
                    faulty += 1;
                }
            }
        }
        let observed = faulty as f64 / cells as f64;
        if (observed - rate).abs() > 0.1 {
            return Err(format!("rate {rate:.3} realized as {observed:.3}"));
        }
        Ok(())
    });
}

/// One randomized lease-ledger operation (the shrink target: dropping ops
/// from a failing sequence must keep it valid, which `LeaseBook` allows —
/// every op is total).
#[derive(Clone, Copy, Debug)]
enum Op {
    /// Push for client (c % leased) claimed by worker (w % workers).
    Push { c: usize, w: usize },
    /// Migrate all pending leases of worker (w % workers) to the others.
    Migrate { w: usize },
    /// Cut one client.
    Cut { c: usize },
    /// Deadline: cut everything pending.
    CutAll,
}

#[test]
fn prop_lease_book_exactly_once_under_any_event_order() {
    const WORKERS: usize = 4;
    let gen = |rng: &mut Rng| {
        let n = 2 + rng.usize_below(10);
        let ops: Vec<Op> = (0..(1 + rng.usize_below(40)))
            .map(|_| match rng.below(10) {
                0..=5 => Op::Push { c: rng.usize_below(n), w: rng.usize_below(WORKERS) },
                6..=7 => Op::Migrate { w: rng.usize_below(WORKERS) },
                8 => Op::Cut { c: rng.usize_below(n) },
                _ => Op::CutAll,
            })
            .collect();
        (n, ops)
    };
    let shrink = |case: &(usize, Vec<Op>)| {
        let (n, ops) = case;
        shrink_vec(ops).into_iter().map(|o| (*n, o)).collect::<Vec<_>>()
    };
    check_cases("lease_exactly_once", 0x1EA5E, 300, gen, shrink, |case| {
        let (n, ops) = case;
        let runnable: Vec<(usize, u64)> = (0..*n).map(|c| (c, 5)).collect();
        let mut book = LeaseBook::new(&runnable);
        // Mirror model: owner + accepted set, maintained independently.
        let mut owner: Vec<usize> = (0..*n).map(|c| c % WORKERS).collect();
        for (c, _) in &runnable {
            book.lease(*c, *c % WORKERS);
        }
        let mut accepted: Vec<usize> = Vec::new();
        for op in ops {
            match *op {
                Op::Push { c, w } => {
                    let was_pending = !accepted.contains(&c) && book.cuts().binary_search(&c).is_err();
                    let ok = book.accept(c, w);
                    if ok {
                        if owner[c] != w {
                            return Err(format!("client {c} folded from non-owner {w}"));
                        }
                        if accepted.contains(&c) {
                            return Err(format!("client {c} folded twice"));
                        }
                        if !was_pending {
                            return Err(format!("client {c} folded after leaving pending"));
                        }
                        accepted.push(c);
                    }
                }
                Op::Migrate { w } => {
                    let targets: Vec<usize> =
                        (0..WORKERS).filter(|&t| t != w).collect();
                    for m in book.migrate_from(w, &targets) {
                        if owner[m.client] != w {
                            return Err(format!(
                                "migrated client {} off worker {w}, owner was {}",
                                m.client, owner[m.client]
                            ));
                        }
                        owner[m.client] = m.to;
                    }
                }
                Op::Cut { c } => {
                    book.cut(c);
                }
                Op::CutAll => {
                    book.cut_all_pending();
                }
            }
            book.check_invariants()?;
        }
        if book.arrived_count() != accepted.len() {
            return Err(format!(
                "ledger arrived {} vs model {}",
                book.arrived_count(),
                accepted.len()
            ));
        }
        // Conservation: every leased client is in exactly one bucket.
        let done = book.arrived_count() + book.cuts().len() + book.pending_count();
        if done != *n {
            return Err(format!("{done} of {n} clients accounted for"));
        }
        Ok(())
    });
}

#[test]
fn prop_flaked_frames_are_rejected_never_misdecoded() {
    check("flake_rejection", 0xF1A4E, 200, |rng| {
        let n = rng.usize_below(600);
        let payload: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let compress = rng.bool(0.5);
        let kind = if rng.bool(0.5) { MsgKind::UpdatePush } else { MsgKind::GlobalModel };
        let clean = link::encode_bytes(kind, &payload, compress)
            .map_err(|e| format!("encode: {e}"))?;
        let (k, back) = link::decode_bytes(&clean).map_err(|e| format!("decode: {e}"))?;
        if k != kind || back != payload {
            return Err("clean frame must round-trip".into());
        }
        let mut bad = clean.clone();
        flake_frame(&mut bad, rng.next_u64());
        match link::decode_bytes(&bad) {
            Err(_) => Ok(()),
            Ok((_, got)) => Err(format!(
                "flaked frame decoded ({} bytes{}) instead of being rejected",
                got.len(),
                if got == payload { ", bit-identical!" } else { "" }
            )),
        }
    });
}

#[test]
fn prop_chaos_plan_pricing_conserves_the_sample() {
    check("chaos_plan_conservation", 0x51A4, 60, |rng| {
        let n_clients = 2 + rng.usize_below(12);
        let rounds = 1 + rng.usize_below(25);
        let plan = RoundPlan {
            n_clients,
            tau: 1 + rng.below(50),
            rounds: (0..rounds)
                .map(|round| RoundSpec {
                    round,
                    participants: (0..n_clients)
                        .filter(|_| rng.bool(0.8))
                        .map(|client| Participant {
                            client,
                            steps: 5,
                            straggler: false,
                        })
                        .collect(),
                    dropped: vec![],
                })
                .collect(),
        };
        let s = Schedule::generate(
            rng.next_u64(),
            1 + rng.usize_below(5),
            rounds,
            ChaosConfig::at_rate(rng.f64() * 0.8),
        );
        for migrate in [false, true] {
            let churned = s.apply_to_plan(&plan, migrate);
            if churned.rounds.len() != plan.rounds.len() {
                return Err("round count changed".into());
            }
            for (orig, got) in plan.rounds.iter().zip(&churned.rounds) {
                let before = orig.participants.len() + orig.dropped.len();
                let after = got.participants.len() + got.dropped.len();
                if before != after {
                    return Err(format!(
                        "round {}: {before} sampled became {after}",
                        orig.round
                    ));
                }
            }
            if s.apply_to_plan(&plan, migrate) != churned {
                return Err("pricing must be deterministic".into());
            }
        }
        Ok(())
    });
}
