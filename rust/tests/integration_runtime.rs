//! Integration: AOT artifacts → PJRT runtime. Requires `make artifacts`.
//!
//! These tests prove the three-layer composition: the JAX/Pallas-authored
//! HLO executes under the Rust runtime with the numerics the python tests
//! established (loss ≈ ln V at init, loss decreases, pallas ≡ jnp).

use photon::data::corpus::SyntheticCorpus;
use photon::data::partition::Partition;
use photon::data::stream::TokenStream;
use photon::model::init::init_params;
use photon::runtime::{ModelRuntime, Runtime, TrainState};

fn load(name: &str) -> ModelRuntime {
    // PJRT handles are not Sync; each test gets its own client (cheap).
    let rt = Runtime::cpu().expect("pjrt cpu client");
    rt.load_model(name).expect("artifacts missing — run `make artifacts`")
}

fn tokens_for(model: &ModelRuntime, seed: u64) -> Vec<i32> {
    let corpus = SyntheticCorpus::c4(model.manifest.config.vocab);
    let partition = Partition::iid(&corpus, 1);
    let mut s = TokenStream::bind(
        &partition.assignment[0],
        &corpus.categories,
        model.seq_width(),
        seed,
    )
    .unwrap();
    s.next_batch(model.batch_size())
}

#[test]
fn initial_loss_is_near_uniform() {
    let m = load("m75a");
    let params = init_params(&m.manifest, 0);
    let toks = tokens_for(&m, 1);
    let (nll, ppl) = m.eval_nll(&params, &[toks]).unwrap();
    let uniform = (m.manifest.config.vocab as f64).ln();
    assert!((nll - uniform).abs() < 0.5, "nll {nll} vs ln V {uniform}");
    assert!((ppl - nll.exp()).abs() < 1e-9);
}

#[test]
fn train_step_decreases_loss_and_reports_metrics() {
    let m = load("m75a");
    let mut st = TrainState::new(init_params(&m.manifest, 0));
    let toks = tokens_for(&m, 2);
    let first = m.train_step(&mut st, 3e-3, &toks).unwrap();
    assert!(first.loss > 0.0 && first.grad_norm > 0.0);
    assert!(first.update_norm > 0.0 && first.act_norm > 0.0);
    let mut last = first;
    for _ in 0..30 {
        last = m.train_step(&mut st, 3e-3, &toks).unwrap();
    }
    assert!(
        (last.loss as f64) < first.loss as f64 - 1.0,
        "loss {} -> {}",
        first.loss,
        last.loss
    );
    assert_eq!(st.step, 31);
}

#[test]
fn zero_lr_is_identity() {
    let m = load("m75a");
    let params = init_params(&m.manifest, 3);
    let mut st = TrainState::new(params.clone());
    let toks = tokens_for(&m, 3);
    m.train_step(&mut st, 0.0, &toks).unwrap();
    assert_eq!(st.params, params);
}

#[test]
fn runtime_is_deterministic() {
    let m = load("m75a");
    let toks = tokens_for(&m, 4);
    let run = || {
        let mut st = TrainState::new(init_params(&m.manifest, 4));
        let mut stats = photon::runtime::StepStats::default();
        for _ in 0..3 {
            stats = m.train_step(&mut st, 1e-3, &toks).unwrap();
        }
        (st.params, stats.loss)
    };
    let (p1, l1) = run();
    let (p2, l2) = run();
    assert_eq!(p1, p2);
    assert_eq!(l1, l2);
}

#[test]
fn eval_matches_train_loss_scale() {
    let m = load("m75a");
    let params = init_params(&m.manifest, 5);
    let toks = tokens_for(&m, 5);
    let (sum, count) = m.eval_batch(&params, &toks).unwrap();
    assert_eq!(
        count as usize,
        m.batch_size() * m.seq_len(),
        "token accounting"
    );
    let mut st = TrainState::new(params);
    let stats = m.train_step(&mut st, 0.0, &toks).unwrap();
    // Same batch, same params (lr=0): train loss == eval mean NLL.
    assert!(
        ((sum / count) - stats.loss as f64).abs() < 1e-4,
        "{} vs {}",
        sum / count,
        stats.loss
    );
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    // The L1 kernel lowered through interpret mode must produce the same
    // training trajectory as the fused-jnp lowering — through Rust.
    let jnp = load("m75a");
    let pal = load("tiny_pallas");
    assert_eq!(jnp.n_params(), pal.n_params());
    let toks = tokens_for(&jnp, 6);
    let mut sj = TrainState::new(init_params(&jnp.manifest, 6));
    let mut sp = TrainState::new(init_params(&pal.manifest, 6));
    for _ in 0..5 {
        let a = jnp.train_step(&mut sj, 2e-3, &toks).unwrap();
        let b = pal.train_step(&mut sp, 2e-3, &toks).unwrap();
        assert!(
            (a.loss - b.loss).abs() < 1e-3,
            "loss diverged: {} vs {}",
            a.loss,
            b.loss
        );
    }
    for (x, y) in sj.params.iter().zip(&sp.params) {
        assert!((x - y).abs() < 1e-3, "params diverged: {x} vs {y}");
    }
}

#[test]
fn score_step_shapes_and_finiteness() {
    let m = load("m75a");
    let params = init_params(&m.manifest, 7);
    let toks = tokens_for(&m, 7);
    let mask = vec![1.0f32; m.batch_size() * m.seq_len()];
    let (ll, len) = m.score_batch(&params, &toks, &mask).unwrap();
    assert_eq!(ll.len(), m.batch_size());
    assert_eq!(len.len(), m.batch_size());
    assert!(len.iter().all(|&l| l == m.seq_len() as f32));
    assert!(ll.iter().all(|&x| x.is_finite() && x < 0.0));
}

#[test]
fn manifest_signature_is_enforced() {
    let m = load("m75a");
    // Wrong token arity must fail loudly, not crash.
    let bad = vec![0i32; 3];
    let params = init_params(&m.manifest, 8);
    assert!(m.eval_batch(&params, &bad).is_err());
}

#[test]
fn every_ladder_artifact_loads() {
    for name in photon::config::MODEL_LADDER {
        let m = load(name);
        assert_eq!(m.manifest.config.name, name);
        assert!(m.n_params() > 0);
    }
}

#[test]
fn chunked_training_matches_single_steps() {
    // The perf-pass artifact (train_chunk, EXPERIMENTS.md §Perf) must follow
    // exactly the same trajectory as the single-step artifact.
    let m = load("m75a");
    let k = m.chunk_size();
    let corpus = SyntheticCorpus::c4(m.manifest.config.vocab);
    let partition = Partition::iid(&corpus, 1);
    let mut stream = TokenStream::bind(
        &partition.assignment[0],
        &corpus.categories,
        m.seq_width(),
        9,
    )
    .unwrap();
    let block: Vec<Vec<i32>> = (0..k).map(|_| stream.next_batch(m.batch_size())).collect();
    let lrs: Vec<f32> = (0..k).map(|i| 1e-3 * (1.0 + i as f32 * 0.1)).collect();

    // Single-step reference.
    let mut s_ref = TrainState::new(init_params(&m.manifest, 9));
    let mut ref_losses = Vec::new();
    for i in 0..k {
        let stats = m.train_step(&mut s_ref, lrs[i], &block[i]).unwrap();
        ref_losses.push(stats.loss);
    }

    // One chunked dispatch.
    let mut s_chunk = TrainState::new(init_params(&m.manifest, 9));
    let flat_tokens: Vec<i32> = block.iter().flatten().copied().collect();
    let stats = m.train_chunk(&mut s_chunk, &lrs, &flat_tokens).unwrap();
    assert_eq!(stats.len(), k);
    assert_eq!(s_chunk.step, k as i64);
    for (a, b) in stats.iter().map(|s| s.loss).zip(&ref_losses) {
        assert!((a - b).abs() < 2e-5, "loss diverged: {a} vs {b}");
    }
    for (i, (a, b)) in s_chunk.params.iter().zip(&s_ref.params).enumerate() {
        assert!(
            (a - b).abs() <= 1e-5 * b.abs().max(1e-3),
            "params diverged at {i}: {a} vs {b}"
        );
    }
}

#[test]
fn chunk_arity_is_enforced() {
    let m = load("m75a");
    let mut st = TrainState::new(init_params(&m.manifest, 1));
    let bad_lrs = vec![1e-3f32; m.chunk_size() + 1];
    let toks = vec![0i32; m.chunk_size() * m.batch_size() * m.seq_width()];
    assert!(m.train_chunk(&mut st, &bad_lrs, &toks).is_err());
}
