//! Property tests for the buffered-async aggregation plane (ISSUE 10).
//!
//! Three contracts, shrunk to minimal counterexamples by the testkit
//! harness (see docs/TESTING.md for the replay workflow):
//!
//! 1. `chaos::discounted_weights` is a well-formed weighting: outputs are
//!    positive, normalize to 1, are monotone non-increasing in staleness
//!    at equal base weight, and at γ=1 degrade bitwise to plain
//!    normalized sample weighting.
//! 2. The server's buffered fold is arrival-order invariant: folding K
//!    arrivals drained from the grant-keyed buffer bit-equals the
//!    sequential fold in canonical (ascending grant) order, no matter
//!    the insertion order — the BTreeMap *is* the canonicalizer.
//! 3. `Federation::run_async_trace` is a pure function of the trace: two
//!    fresh federations replaying the same realized ledger produce
//!    bit-identical records, globals, and (wall-clock-canonicalized)
//!    checkpoint bytes. (This leg realizes one tiny loopback fleet and
//!    needs `make artifacts`, like the integration suites.)

use std::collections::BTreeMap;
use std::sync::Arc;

use photon::chaos::discounted_weights;
use photon::cluster::faults::FaultPlan;
use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::model::vecmath::weighted_mean_into;
use photon::net::{run_loopback, FleetOpts};
use photon::optim::schedule::CosineSchedule;
use photon::runtime::{ModelRuntime, Runtime};
use photon::testkit::{check, check_cases, shrink_vec};

#[test]
fn discounted_weights_are_positive_and_normalize_to_one() {
    check("discount_normalized", 0xA51C_0001, 200, |rng| {
        let n = 1 + rng.usize_below(8);
        let base: Vec<f64> = (0..n).map(|_| 0.1 + rng.f64() * 10.0).collect();
        let staleness: Vec<u64> = (0..n).map(|_| rng.usize_below(12) as u64).collect();
        let gamma = 0.05 + rng.f64() * 0.95;
        let w = discounted_weights(&base, &staleness, gamma);
        if w.len() != n {
            return Err(format!("length {} != {n}", w.len()));
        }
        if let Some(bad) = w.iter().find(|&&x| !(x > 0.0)) {
            return Err(format!("non-positive weight {bad} (base {base:?})"));
        }
        let sum: f64 = w.iter().sum();
        if (sum - 1.0).abs() > 1e-12 {
            return Err(format!("weights sum to {sum}, not 1"));
        }
        Ok(())
    });
}

#[test]
fn discounted_weights_monotone_non_increasing_in_staleness() {
    check("discount_monotone", 0xA51C_0002, 200, |rng| {
        let n = 2 + rng.usize_below(6);
        // Equal base weights so the discount is the only differentiator.
        let base = vec![1.0 + rng.f64() * 5.0; n];
        let mut staleness: Vec<u64> =
            (0..n).map(|_| rng.usize_below(10) as u64).collect();
        staleness.sort_unstable();
        let gamma = 0.05 + rng.f64() * 0.9; // strictly below 1
        let w = discounted_weights(&base, &staleness, gamma);
        for i in 1..n {
            if w[i] > w[i - 1] + 1e-15 {
                return Err(format!(
                    "weight rose with staleness: w[{i}]={} > w[{}]={} \
                     (staleness {staleness:?})",
                    w[i],
                    i - 1,
                    w[i - 1]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn gamma_one_is_plain_sample_weighting_bitwise() {
    check("discount_gamma_one", 0xA51C_0003, 200, |rng| {
        let n = 1 + rng.usize_below(8);
        let base: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64() * 20.0).collect();
        let staleness: Vec<u64> = (0..n).map(|_| rng.usize_below(50) as u64).collect();
        let w = discounted_weights(&base, &staleness, 1.0);
        // γ=1 ⇒ the discount is exactly 1.0 for every staleness, so the
        // output must bit-equal the undiscounted normalization computed
        // the same sequential way.
        let total: f64 = base.iter().sum();
        for (i, (&got, &b)) in w.iter().zip(&base).enumerate() {
            let want = b / total;
            if got.to_bits() != want.to_bits() {
                return Err(format!(
                    "index {i}: γ=1 weight {got} != plain normalized {want}"
                ));
            }
        }
        Ok(())
    });
}

/// One buffered arrival: grant id, update row, discounted weight.
type Arrival = (u64, Vec<f32>, f64);

#[test]
fn buffered_fold_is_arrival_order_invariant() {
    // Case: arrivals listed in *insertion* order (random grant ids, so
    // insertion order ≠ canonical order). The server-side fold drains a
    // grant-keyed BTreeMap; the reference fold sorts explicitly. Both
    // must produce bit-identical means.
    check_cases(
        "buffered_fold_canonical",
        0xA51C_0004,
        60,
        |rng| {
            let n = 1 + rng.usize_below(24); // model dim
            let k = 1 + rng.usize_below(6);
            let mut used = std::collections::BTreeSet::new();
            let mut arrivals: Vec<Arrival> = Vec::with_capacity(k);
            for _ in 0..k {
                let mut grant = rng.next_u64() % 1000;
                while !used.insert(grant) {
                    grant = rng.next_u64() % 1000;
                }
                let row: Vec<f32> =
                    (0..n).map(|_| (rng.f32() * 2.0 - 1.0) * 3.0).collect();
                let weight = 0.1 + rng.f64();
                arrivals.push((grant, row, weight));
            }
            arrivals
        },
        |case: &Vec<Arrival>| shrink_vec(case.as_slice()),
        |arrivals| {
            if arrivals.is_empty() {
                return Ok(()); // shrinker floor
            }
            let n = arrivals[0].1.len();
            // Server path: insert in arrival order, drain in key order.
            let mut buffer: BTreeMap<u64, (&[f32], f64)> = BTreeMap::new();
            for (g, row, w) in arrivals {
                buffer.insert(*g, (row.as_slice(), *w));
            }
            let rows: Vec<&[f32]> = buffer.values().map(|(r, _)| *r).collect();
            let weights: Vec<f64> = buffer.values().map(|(_, w)| *w).collect();
            let mut folded = vec![0.0f32; n];
            weighted_mean_into(&rows, &weights, &mut folded);
            // Reference path: sort the same arrivals by grant explicitly.
            let mut canonical: Vec<&Arrival> = arrivals.iter().collect();
            canonical.sort_by_key(|(g, _, _)| *g);
            let c_rows: Vec<&[f32]> =
                canonical.iter().map(|(_, r, _)| r.as_slice()).collect();
            let c_weights: Vec<f64> = canonical.iter().map(|(_, _, w)| *w).collect();
            let mut reference = vec![0.0f32; n];
            weighted_mean_into(&c_rows, &c_weights, &mut reference);
            for i in 0..n {
                if folded[i].to_bits() != reference[i].to_bits() {
                    return Err(format!(
                        "element {i}: buffered {} != canonical {}",
                        folded[i], reference[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

// --- replay purity (needs `make artifacts`) -------------------------------

fn model() -> Arc<ModelRuntime> {
    thread_local! {
        static CACHED: std::cell::OnceCell<Arc<ModelRuntime>> =
            const { std::cell::OnceCell::new() };
    }
    CACHED.with(|c| {
        c.get_or_init(|| {
            let rt = Runtime::cpu().unwrap();
            Arc::new(rt.load_model("m75a").expect("run `make artifacts`"))
        })
        .clone()
    })
}

fn tiny_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quickstart("m75a");
    cfg.n_clients = 4;
    cfg.clients_per_round = 2;
    cfg.rounds = 2;
    cfg.local_steps = 2;
    cfg.eval_batches = 1;
    cfg.seed = 0xA51C;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, 4, 2);
    cfg.faults = FaultPlan::none();
    cfg
}

/// Checkpoint bytes with the wall-clock bookkeeping zeroed — everything
/// left is replay-relevant state, so byte equality means state equality.
fn canonical_ckpt_bytes(fed: &Federation) -> Vec<u8> {
    let mut ck = fed.checkpoint();
    ck.timestamp = 0;
    ck.elapsed_secs = 0.0;
    ck.encode()
}

#[test]
fn async_replay_is_a_pure_function_of_the_trace() {
    // Realize one quiet async ledger over a real loopback fleet...
    let cfg = tiny_cfg();
    let report = run_loopback(
        cfg.clone(),
        model(),
        FleetOpts {
            workers: 2,
            compress: true,
            async_agg: Some((2, 0.5)),
            ..FleetOpts::default()
        },
    )
    .unwrap();
    assert!(report.worker_errors.is_empty(), "{:?}", report.worker_errors);
    let trace = report.async_trace.expect("async fleet must return a ledger");
    trace.check_exactly_once().unwrap();

    // ...then replay it twice from fresh federations: identical records
    // (modulo wall time), identical global bits, identical canonicalized
    // checkpoint bytes. The trace bytes fully determine the run.
    let mut a = Federation::with_model(cfg.clone(), model()).unwrap();
    let rec_a = a.run_async_trace(&trace).unwrap();
    let mut b = Federation::with_model(cfg, model()).unwrap();
    let rec_b = b.run_async_trace(&trace).unwrap();
    assert_eq!(rec_a.len(), rec_b.len());
    for (x, y) in rec_a.iter().zip(&rec_b) {
        assert!(x.agrees_with(y), "replay divergence at epoch {}", x.round);
    }
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
    assert_eq!(bits(&a.global), bits(&b.global), "global model bits");
    assert_eq!(
        canonical_ckpt_bytes(&a),
        canonical_ckpt_bytes(&b),
        "checkpoint bytes must be a pure function of the trace"
    );
    // And both reproduce the fleet itself.
    assert_eq!(bits(&a.global), bits(&report.global), "replay vs fleet");
}
