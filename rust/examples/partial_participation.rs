//! Partial participation (paper §7.4): a 64-organization federation where
//! only 4 clients (6.25%) train each round — the same convergence as full
//! participation at a fraction of the parallel compute, enabling several
//! concurrent federated workloads over one population.
//!
//! Run: `cargo run --release --example partial_participation`

use std::sync::Arc;

use photon::config::{CorpusKind, ExperimentConfig};
use photon::coordinator::Federation;
use photon::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let model = Arc::new(rt.load_model("m75a")?);

    let mut partial = ExperimentConfig::quickstart("m75a");
    partial.label = "64x4".into();
    partial.n_clients = 64;
    partial.clients_per_round = 4;
    partial.rounds = 8;
    partial.local_steps = 15;

    let mut full = partial.clone();
    full.label = "8x8".into();
    full.n_clients = 8;
    full.clients_per_round = 8;

    println!("partial participation (4/64 = 6.25%) vs full participation (8/8)\n");
    let mut fed_p = Federation::with_model(partial, model.clone())?;
    let mut fed_f = Federation::with_model(full, model)?;
    println!("round | partial ppl | full ppl | partial client-steps | full client-steps");
    let mut steps_p = 0u64;
    let mut steps_f = 0u64;
    for _ in 0..fed_p.cfg.rounds {
        let rp = fed_p.run_round()?;
        let rf = fed_f.run_round()?;
        steps_p += rp.participated as u64 * fed_p.cfg.local_steps;
        steps_f += rf.participated as u64 * fed_f.cfg.local_steps;
        println!(
            "{:>5} | {:>11.2} | {:>8.2} | {:>20} | {:>17}",
            rp.round, rp.server_ppl, rf.server_ppl, steps_p, steps_f
        );
    }
    let pp = fed_p.log.last().unwrap().server_ppl;
    let fp = fed_f.log.last().unwrap().server_ppl;
    println!(
        "\nfinal: partial {pp:.2} vs full {fp:.2} ({:+.1}%) using {:.0}% of the parallel compute",
        100.0 * (pp - fp) / fp,
        100.0 * steps_p as f64 / steps_f as f64
    );
    Ok(())
}
