//! Heterogeneous federation: the paper's motivating scenario — eight
//! organizations (publishers) each holding one genre of the Pile-analogue
//! corpus, running on a *heterogeneous fleet* (A40/A100/H100 mixes, one
//! poorly-connected multi-node client that trains as an island
//! sub-federation), with stragglers and dropouts injected.
//!
//! Demonstrates: natural data heterogeneity, hardware strategy selection
//! (Algorithm 1 L.14–24), micro-batch search, fault tolerance, and
//! per-client personalized evaluation (§4.2).
//!
//! Run: `cargo run --release --example heterogeneous_federation`

use photon::cluster::batchsize::find_micro_batch;
use photon::cluster::faults::FaultPlan;
use photon::cluster::hardware::{
    training_footprint_bytes, ClientHardware, FleetSpec, NodeSpec, A100, A40, H100,
};
use photon::config::{CorpusKind, ExperimentConfig};
use photon::coordinator::Federation;

fn main() -> anyhow::Result<()> {
    // --- the fleet: 8 clients with unequal hardware ----------------------
    let mut clients: Vec<ClientHardware> = (0..7)
        .map(|i| {
            let gpu = [A40, A100, H100][i % 3];
            ClientHardware::single(gpu, 1 + i % 4)
        })
        .collect();
    // Client 7: two machines linked over WAN → island sub-federation.
    clients.push(ClientHardware {
        nodes: vec![NodeSpec { gpu: A40, n_gpus: 2, intra_gbps: 600.0 }; 2],
        inter_gbps: 0.1,
    });
    let fleet = FleetSpec { clients };

    let mut cfg = ExperimentConfig::quickstart("m125a");
    cfg.label = "heterogeneous-pile".into();
    cfg.corpus = CorpusKind::PileHetero { j: 1 };
    cfg.n_clients = 8;
    cfg.clients_per_round = 8;
    cfg.rounds = 6;
    cfg.local_steps = 15;
    cfg.faults = FaultPlan::new(0.05, 0.15, 7);
    cfg.fleet = Some(fleet.clone());

    let mut fed = Federation::new(cfg)?;

    // --- hardware report: strategy + micro-batch per client --------------
    println!("client hardware and chosen local strategy (paper §5.1):");
    let paper_7b_params = 6_920_000_000usize;
    for (c, hw) in fleet.clients.iter().enumerate() {
        let genre = &fed.data.partition.assignment[c][0].category;
        let strategy = hw.choose_strategy(training_footprint_bytes(paper_7b_params));
        let micro = find_micro_batch(&hw.nodes[0].gpu, paper_7b_params, 2048, 4096, 32);
        println!(
            "  client {c}: {} node(s) of {}x{}  genre={genre:<13} \
             7B-strategy={strategy:?} micro-batch={micro:?}",
            hw.nodes.len(),
            hw.nodes[0].n_gpus,
            hw.nodes[0].gpu.name,
        );
    }

    // --- federated training ----------------------------------------------
    println!("\ntraining (dropout 5%, stragglers 15%):");
    while fed.next_round < fed.cfg.rounds {
        let r = fed.run_round()?;
        println!(
            "round {}  server ppl {:>8.2}  client loss {:.3}±{:.3}  \
             participated {}/8",
            r.round, r.server_ppl, r.client_loss_mean, r.client_loss_std, r.participated
        );
    }

    // --- personalized evaluation (§4.2) -----------------------------------
    println!("\nper-client (personalized) perplexity of the global model:");
    for c in 0..fed.cfg.n_clients {
        let batches = fed.data.client_validation_batches(
            c,
            2,
            fed.model.batch_size(),
            fed.model.seq_width(),
        )?;
        let (_, ppl) = fed.model.eval_nll(&fed.global, &batches)?;
        let genre = &fed.data.partition.assignment[c][0].category;
        println!("  client {c} ({genre:<13}) ppl {ppl:>8.2}");
    }
    Ok(())
}
