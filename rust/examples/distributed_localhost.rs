//! Distributed federation on localhost: a Photon Aggregator service plus a
//! fleet of four TCP workers (the deployment plane, paper §4.1), proving
//! on the spot that the networked run is bit-identical to the in-process
//! one — same global model, same round records.
//!
//! The same topology runs across machines with the CLI:
//!
//! ```text
//! host A$ photon serve --config m75a --clients 8 --rounds 5 --min-workers 4
//! host B$ photon worker --connect hostA:7070
//! ```
//!
//! Run: `cargo run --release --example distributed_localhost`
//! (requires `make artifacts` first)

use std::sync::Arc;

use photon::config::ExperimentConfig;
use photon::coordinator::Federation;
use photon::net::{run_loopback, FleetOpts};
use photon::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::quickstart("m75a");
    println!(
        "deployment plane: {} clients, {} rounds of τ={} — in-process vs 4 TCP workers",
        cfg.n_clients, cfg.rounds, cfg.local_steps
    );

    let rt = Runtime::cpu()?;
    let model = Arc::new(rt.load_model(&cfg.model)?);

    let mut fed = Federation::with_model(cfg.clone(), model.clone())?;
    let reference = fed.run()?;

    let fleet = run_loopback(
        cfg,
        model,
        FleetOpts { workers: 4, compress: true, ..FleetOpts::default() },
    )?;

    println!("\nround | in-process ppl | tcp-fleet ppl | bit-equal");
    for (r, n) in reference.iter().zip(&fleet.records) {
        println!(
            "{:>5} | {:>14.6} | {:>13.6} | {}",
            r.round,
            r.server_ppl,
            n.server_ppl,
            if r.agrees_with(n) { "yes" } else { "NO" }
        );
    }
    assert_eq!(fed.global, fleet.global, "global models must be bit-identical");
    println!("\nglobal model bit-identical across {} workers ✔", fleet.workers.len());
    Ok(())
}
