//! End-to-end driver (DESIGN.md / EXPERIMENTS.md §E2E): federally pre-train
//! the `e2e` transformer (~6.6M parameters — the CPU-budget analogue of the
//! paper's billion-scale runs; see DESIGN.md §1) for a few hundred steps on
//! the synthetic C4-analogue corpus, proving every layer composes:
//!
//!   Pallas/JAX-authored HLO → PJRT runtime → Photon LLM Nodes →
//!   Photon Aggregator (FedAvg) → checkpointing → downstream ICL scoring.
//!
//! Logs the loss curve to results/e2e/ and prints the summary recorded in
//! EXPERIMENTS.md. `--fast` shrinks the run for smoke testing.
//!
//! Run: `cargo run --release --example e2e_pretrain [-- --fast]`

// Example binary: wall-clock timing is reporting-only.
#![allow(clippy::disallowed_methods)]

use photon::config::{CorpusKind, ExperimentConfig};
use photon::coordinator::Federation;
use photon::data::corpus::SyntheticCorpus;
use photon::evalharness::{task_accuracy, TaskFamily};
use photon::optim::schedule::CosineSchedule;

fn main() -> anyhow::Result<()> {
    let fast = std::env::args().any(|a| a == "--fast");
    let (rounds, tau) = if fast { (3, 10) } else { (10, 30) };
    let total = (rounds * tau) as u64;

    let mut cfg = ExperimentConfig::quickstart("e2e");
    cfg.label = "e2e-pretrain".into();
    cfg.corpus = CorpusKind::C4Iid;
    cfg.n_clients = 4;
    cfg.clients_per_round = 2; // partial participation, paper-style
    cfg.rounds = rounds;
    cfg.local_steps = tau as u64;
    cfg.eval_batches = 2;
    cfg.schedule = CosineSchedule::new(1e-3, 0.1, total, total / 10);

    println!(
        "e2e pre-train: {} params, P={} K={} rounds={rounds} τ={tau} \
         ({} total client steps)",
        "~6.6M", cfg.n_clients, cfg.clients_per_round,
        rounds * tau * cfg.clients_per_round,
    );

    let t0 = std::time::Instant::now();
    let mut fed = Federation::new(cfg)?;
    fed.ckpt_dir = Some(photon::util::results_dir("e2e").join("ckpt"));
    let (_, ppl0) = fed.eval_global()?;
    println!("init: server perplexity {ppl0:.2} (uniform = vocab = 1024)");

    while fed.next_round < fed.cfg.rounds {
        let r = fed.run_round()?;
        println!(
            "round {:>2}  server ppl {:>8.2}  client loss {:.4}  \
             |pseudo-grad| {:.3}  {:>5.1}s",
            r.round, r.server_ppl, r.client_loss_mean, r.pseudo_grad_norm, r.wall_secs
        );
    }
    let csv = photon::util::results_dir("e2e").join("loss_curve.csv");
    fed.log.write_csv(&csv)?;

    // Downstream sanity: the trained model must beat chance on the ICL
    // suite's easiest family (the full suite is `photon exp table56`).
    let corpus = SyntheticCorpus::pile(fed.model.manifest.config.vocab);
    let fams = TaskFamily::suite(&corpus, fed.model.manifest.config.seq_len);
    let fam = &fams[0];
    let n_items = if fast { 10 } else { 30 };
    let acc = task_accuracy(&fed.model, &fed.global, &corpus, fam, n_items, 11)?;
    let chance = 1.0 / fam.n_options as f64;

    let last = fed.log.last().unwrap();
    println!("\n=== E2E SUMMARY ===");
    println!("wall-clock: {:.1}s on 1 CPU core", t0.elapsed().as_secs_f64());
    println!("perplexity: {ppl0:.2} → {:.2}", last.server_ppl);
    println!(
        "communication: {:.1} MB total ({} rounds × {} clients × 2 payloads)",
        fed.log.rounds.iter().map(|r| r.comm_bytes as f64).sum::<f64>() / 1e6,
        fed.cfg.rounds, fed.cfg.clients_per_round
    );
    println!("checkpoints: {:?}", fed.ckpt_dir.as_ref().unwrap());
    println!("loss curve: {}", csv.display());
    println!("ICL ({}): accuracy {acc:.3} vs chance {chance:.3}", fam.name);
    assert!(last.server_ppl < ppl0 / 2.0, "e2e training must at least halve ppl");
    Ok(())
}
