//! Quickstart: the smallest end-to-end federated pre-training run.
//!
//! Four organizations, IID shards of the C4-analogue corpus, five FedAvg
//! rounds of 20 local AdamW steps on the 75M-analogue model — the whole
//! Photon pipeline (sample → broadcast → local train → aggregate → eval)
//! in under a minute on one CPU.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use photon::config::ExperimentConfig;
use photon::coordinator::Federation;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::quickstart("m75a");
    println!(
        "quickstart: model={} P={} K={} rounds={} τ={}",
        cfg.model, cfg.n_clients, cfg.clients_per_round, cfg.rounds, cfg.local_steps
    );

    let mut fed = Federation::new(cfg)?;
    let (nll0, ppl0) = fed.eval_global()?;
    println!("before training: server nll {nll0:.4}, perplexity {ppl0:.2}");

    while fed.next_round < fed.cfg.rounds {
        let r = fed.run_round()?;
        println!(
            "round {}  server ppl {:>8.2}  client loss {:.4}±{:.4}  \
             pseudo-grad |Δ| {:.4}  comm {} KB",
            r.round,
            r.server_ppl,
            r.client_loss_mean,
            r.client_loss_std,
            r.pseudo_grad_norm,
            r.comm_bytes / 1024,
        );
    }

    let last = fed.log.last().unwrap();
    println!(
        "\ndone: perplexity {:.2} → {:.2} over {} rounds \
         ({} model payloads exchanged)",
        ppl0,
        last.server_ppl,
        fed.cfg.rounds,
        2 * fed.cfg.rounds * fed.cfg.clients_per_round,
    );
    assert!(last.server_ppl < ppl0, "training must reduce perplexity");
    Ok(())
}
