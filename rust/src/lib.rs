//! # Photon-RS
//!
//! A Rust + JAX + Pallas reproduction of **Photon**, the system from
//! *"The Future of Large Language Model Pre-training is Federated"*
//! (CS.LG 2024): federated generative pre-training of LLMs across
//! organizations holding private data and heterogeneous hardware.
//!
//! Layering (see DESIGN.md):
//! * **L3 (this crate)** — the Photon Aggregator / LLM Node / Data Source
//!   runtime: round orchestration, client sampling, outer optimizers,
//!   hierarchical island aggregation, streaming synthetic corpora, the
//!   Photon-Link transport, checkpointing, network cost modeling, and the
//!   experiment harness that regenerates every table/figure of the paper.
//! * **L2/L1 (build-time python)** — the MPT-style transformer train step
//!   (JAX) with a Pallas flash-attention kernel, AOT-lowered to HLO text in
//!   `artifacts/` and executed here through PJRT (`runtime` module).
//!
//! Quick start:
//! ```no_run
//! use photon::config::ExperimentConfig;
//! use photon::coordinator::Federation;
//!
//! let cfg = ExperimentConfig::quickstart("m75a");
//! let mut fed = Federation::new(cfg).unwrap();
//! let history = fed.run().unwrap();
//! println!("final server perplexity: {:.2}", history.last().unwrap().server_ppl);
//! ```

pub mod benchkit;
pub mod ckpt;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod evalharness;
pub mod exp;
pub mod link;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod optim;
pub mod runtime;
pub mod testkit;
pub mod util;
