//! # Photon-RS
//!
//! A Rust + JAX + Pallas reproduction of **Photon**, the system from
//! *"The Future of Large Language Model Pre-training is Federated"*
//! (CS.LG 2024): federated generative pre-training of LLMs across
//! organizations holding private data and heterogeneous hardware.
//!
//! Layering (see `docs/ARCHITECTURE.md` for the full module → paper map):
//! * **L3 (this crate)** — the Photon Aggregator / LLM Node / Data Source
//!   runtime: round orchestration ([`coordinator`]), client sampling,
//!   outer optimizers ([`optim`]), hierarchical island aggregation
//!   ([`cluster`]), streaming synthetic corpora ([`data`]), the
//!   Photon-Link transport ([`link`]) with its lossy update-codec registry
//!   ([`compress`]: q8/q4 stochastic quantization, top-k + error
//!   feedback), the TCP deployment plane ([`net`]:
//!   real Aggregator/worker federation with straggler cuts, worker
//!   rejoin, client-lease migration, and restart recovery), the seeded
//!   chaos-injection plane ([`chaos`]: deterministic fault schedules,
//!   realized-trace replay), the structured JSONL observability plane
//!   ([`obs`]: typed event bus + `photon top` cockpit, with
//!   `obs::to_trace` tying event logs back to replay parity),
//!   checkpointing ([`ckpt`]), network cost modeling
//!   ([`netsim`]), the event-driven wall-clock simulator ([`sim`]), and
//!   the experiment harness ([`exp`]) that regenerates every table/figure
//!   of the paper.
//! * **L2/L1 (build-time python)** — the MPT-style transformer train step
//!   (JAX) with a Pallas flash-attention kernel, AOT-lowered to HLO text in
//!   `artifacts/` and executed here through PJRT (the [`runtime`] module).
//!
//! ## Quick start: train a federation
//!
//! Requires compiled artifacts (`make artifacts`):
//!
//! ```no_run
//! use photon::config::ExperimentConfig;
//! use photon::coordinator::Federation;
//!
//! let cfg = ExperimentConfig::quickstart("m75a");
//! let mut fed = Federation::new(cfg).unwrap();
//! let history = fed.run().unwrap();
//! println!("final server perplexity: {:.2}", history.last().unwrap().server_ppl);
//! ```
//!
//! ## Quick start: simulate wall-clock (artifact-free)
//!
//! The [`sim`] module replays the same round schedule through an
//! event-driven time model — no artifacts or PJRT needed:
//!
//! ```
//! use photon::config::ExperimentConfig;
//! use photon::netsim::BROADBAND;
//! use photon::sim::{AggregationPolicy, RoundPlan, SimConfig, Simulator};
//!
//! let cfg = ExperimentConfig::wallclock(8, 8, 5, 500, 42);
//! let plan = RoundPlan::from_config(&cfg);
//! let payload = 443_560_000; // 125M params × 4 B
//! let sim = SimConfig::new(payload, BROADBAND, AggregationPolicy::Sync);
//! let report = Simulator::uniform(&plan, 2.8, sim).run();
//! assert!(report.comm_fraction() < 0.05, "WAN hidden behind τ=500 local steps");
//! ```

pub mod analysis;
pub mod benchkit;
pub mod chaos;
pub mod ckpt;
pub mod cluster;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod evalharness;
pub mod exp;
pub mod link;
pub mod metrics;
pub mod model;
pub mod net;
pub mod netsim;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod testkit;
pub mod util;
