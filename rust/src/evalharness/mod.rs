//! Downstream in-context-learning evaluation harness (paper §7.9, Tables
//! 5–6): multiple-choice tasks scored by length-normalized per-option
//! log-likelihood, exactly the ICL scoring path the paper's suite uses.
//!
//! The 13 task families are synthetic analogues named after the paper's
//! benchmarks. Each item is a *continuation-selection* problem over the
//! synthetic corpus: given a context sampled from a task-specific category,
//! the correct option is the generator's true continuation and the
//! distractors are continuations from foreign categories / perturbed paths.
//! A model that has learned the corpus statistics assigns the true
//! continuation a higher log-likelihood — so accuracy scales with model
//! quality, which is what Tables 5–6 assert across the ladder.
//!
//! Entry points: [`TaskFamily::suite`] derives the 13 families over a
//! corpus, and [`task_accuracy`] scores one family on a model + params
//! (used by `photon eval` and the `table56` experiment driver). Scoring
//! is deterministic given the item seed, so suite accuracies are exactly
//! reproducible across runs and worker counts.

use anyhow::Result;

use crate::data::corpus::{Category, CategorySampler, SyntheticCorpus};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// The paper's benchmark names (Tables 5 and 6), reused as task-family
/// labels for the synthetic analogues.
pub const TASKS_TABLE5: [&str; 7] = [
    "ARC-Challenge", "BigBench-QA-Wikidata", "HellaSwag", "PIQA",
    "Winogrande", "ARC-Easy", "BoolQ",
];
pub const TASKS_TABLE6: [&str; 6] = [
    "OpenbookQA", "Winograd", "LAMBADA", "BigBench-StrategyQA", "COPA", "MMLU",
];

/// One multiple-choice item: shared context, N options, gold index.
#[derive(Clone, Debug)]
pub struct McItem {
    pub context: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub gold: usize,
}

/// A task family = generator of MC items with its own difficulty knobs.
pub struct TaskFamily {
    pub name: String,
    pub n_options: usize,
    pub context_len: usize,
    pub option_len: usize,
    /// Index of the "home" category within the corpus.
    pub category: usize,
}

impl TaskFamily {
    /// Derive the 13 families over a corpus, cycling categories and varying
    /// context/option lengths so families differ in difficulty.
    pub fn suite(corpus: &SyntheticCorpus, seq_len: usize) -> Vec<TaskFamily> {
        let names: Vec<&str> = TASKS_TABLE5.iter().chain(TASKS_TABLE6.iter()).copied().collect();
        names
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let option_len = 3 + i % 4;
                TaskFamily {
                    name: name.to_string(),
                    n_options: 2 + i % 3,
                    context_len: (seq_len - option_len).min(seq_len * 3 / 4),
                    option_len,
                    category: i % corpus.categories.len(),
                }
            })
            .collect()
    }

    /// Generate `n` items. The gold option is the true continuation of the
    /// context under the home category's sampler; distractors continue from
    /// a *different* starting token (perturbed path) or a foreign category.
    pub fn items(
        &self,
        corpus: &SyntheticCorpus,
        n: usize,
        seed: u64,
    ) -> Vec<McItem> {
        let home = CategorySampler::new(&corpus.categories[self.category]);
        let foreign_cat: &Category =
            &corpus.categories[(self.category + 1) % corpus.categories.len()];
        let foreign = CategorySampler::new(foreign_cat);
        let mut rng = Rng::new(seed ^ 0xe4a1);
        (0..n)
            .map(|_| {
                let context = home.sequence(self.context_len, &mut rng);
                let last = *context.last().unwrap() as u32;
                // Gold: continue the home chain from the true last token.
                let gold_opt = continue_from(&home, last, self.option_len, &mut rng);
                let mut options = vec![gold_opt];
                for d in 1..self.n_options {
                    let opt = if d % 2 == 1 && corpus.categories.len() > 1 {
                        continue_from(&foreign, last, self.option_len, &mut rng)
                    } else {
                        // Perturbed path: continue from a random token.
                        let start = rng.usize_below(corpus.vocab) as u32;
                        continue_from(&home, start, self.option_len, &mut rng)
                    };
                    options.push(opt);
                }
                // Shuffle options, track gold.
                let mut order: Vec<usize> = (0..options.len()).collect();
                rng.shuffle(&mut order);
                let gold = order.iter().position(|&o| o == 0).unwrap();
                let options = order.into_iter().map(|o| options[o].clone()).collect();
                McItem { context, options, gold }
            })
            .collect()
    }
}

fn continue_from(s: &CategorySampler, start: u32, len: usize, rng: &mut Rng) -> Vec<i32> {
    let mut out = Vec::with_capacity(len);
    let mut cur = start;
    for _ in 0..len {
        cur = s.next_token(cur, rng);
        out.push(cur as i32);
    }
    out
}

/// Score one item: argmax over options of length-normalized log-likelihood,
/// computed through the AOT `score_step` artifact. Each option is laid out
/// as `[context | option | pad]` with the mask selecting option positions.
pub fn score_item(model: &ModelRuntime, params: &[f32], item: &McItem) -> Result<usize> {
    let b = model.batch_size();
    let width = model.seq_width();
    let seq_len = model.seq_len();
    let mut best = (f64::NEG_INFINITY, 0usize);
    // Options are scored in batches of `b` (artifact shape is fixed).
    for (chunk_start, chunk) in item.options.chunks(b).enumerate() {
        let mut tokens = vec![0i32; b * width];
        let mut mask = vec![0.0f32; b * seq_len];
        for (row, opt) in chunk.iter().enumerate() {
            let ctx_take = item.context.len().min(width - opt.len());
            let seq: Vec<i32> = item.context[item.context.len() - ctx_take..]
                .iter()
                .chain(opt.iter())
                .copied()
                .collect();
            debug_assert!(seq.len() <= width);
            tokens[row * width..row * width + seq.len()].copy_from_slice(&seq);
            // Targets are tokens[1..]; option tokens occupy target positions
            // [ctx_take-1, ctx_take-1+len(opt)).
            let start = ctx_take - 1;
            for p in start..start + opt.len() {
                mask[row * seq_len + p] = 1.0;
            }
        }
        let (ll, len) = model.score_batch(params, &tokens, &mask)?;
        for (row, _opt) in chunk.iter().enumerate() {
            let norm = ll[row] as f64 / (len[row] as f64).max(1.0);
            let opt_idx = chunk_start * b + row;
            if norm > best.0 {
                best = (norm, opt_idx);
            }
        }
    }
    Ok(best.1)
}

/// Accuracy of `params` on a task family.
pub fn task_accuracy(
    model: &ModelRuntime,
    params: &[f32],
    corpus: &SyntheticCorpus,
    family: &TaskFamily,
    n_items: usize,
    seed: u64,
) -> Result<f64> {
    let items = family.items(corpus, n_items, seed);
    let mut correct = 0usize;
    for item in &items {
        if score_item(model, params, item)? == item.gold {
            correct += 1;
        }
    }
    Ok(correct as f64 / n_items as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> SyntheticCorpus {
        SyntheticCorpus::pile(64)
    }

    #[test]
    fn suite_has_13_families() {
        let s = TaskFamily::suite(&corpus(), 32);
        assert_eq!(s.len(), 13);
        for f in &s {
            assert!(f.n_options >= 2);
            assert!(f.context_len + f.option_len <= 32 + 32 / 4);
        }
    }

    #[test]
    fn items_are_well_formed() {
        let s = TaskFamily::suite(&corpus(), 32);
        let items = s[0].items(&corpus(), 10, 3);
        assert_eq!(items.len(), 10);
        for it in &items {
            assert_eq!(it.options.len(), s[0].n_options);
            assert!(it.gold < it.options.len());
            assert_eq!(it.context.len(), s[0].context_len);
            assert!(it.options.iter().all(|o| o.len() == s[0].option_len));
        }
    }

    #[test]
    fn items_deterministic_per_seed() {
        let s = TaskFamily::suite(&corpus(), 32);
        let a = s[2].items(&corpus(), 5, 9);
        let b = s[2].items(&corpus(), 5, 9);
        assert_eq!(a[0].context, b[0].context);
        assert_eq!(a[0].gold, b[0].gold);
    }

    #[test]
    fn gold_position_is_uniformish() {
        let s = TaskFamily::suite(&corpus(), 32);
        let items = s[0].items(&corpus(), 200, 1);
        let mut counts = vec![0usize; s[0].n_options];
        for it in &items {
            counts[it.gold] += 1;
        }
        for &c in &counts {
            assert!(c > 200 / s[0].n_options / 3, "gold position biased: {counts:?}");
        }
    }
}
