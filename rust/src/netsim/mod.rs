//! Network/communication cost model (paper §4.3 + headline claim 1:
//! federated pre-training needs orders-of-magnitude less communication than
//! data-parallel training).
//!
//! Analytic, deterministic model:
//! * **DDP / Ring-AllReduce** (the centralized baseline): every optimizer
//!   step moves `2·(n−1)/n · payload` per worker over the slowest link and
//!   costs one allreduce latency round (§2.1.1).
//! * **Federated round** (Photon): per sampled client, one model broadcast
//!   down + one update up per τ local steps (§4.3).
//!
//! `comm_ratio` — how many times more bytes DDP moves than FL for the same
//! number of sequential steps — is ≈ τ·(n−1)/n, i.e. ~500× at the paper's
//! τ = 500. The `comm` experiment sweeps the ladder and bandwidths.

/// A network link.
///
/// # Example
///
/// ```
/// use photon::netsim::{Link, BROADBAND};
///
/// // 125 MB over 100 Mbit/s: ~10 s of bandwidth + 30 ms latency.
/// let secs = BROADBAND.transfer_secs(125_000_000);
/// assert!((secs - 10.03).abs() < 1e-9);
///
/// // A zero-byte transfer still pays one latency.
/// let rtt_half = Link { gbps: 25.0, latency_s: 10e-6 }.transfer_secs(0);
/// assert_eq!(rtt_half, 10e-6);
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Bandwidth in gigaBYTES per second.
    pub gbps: f64,
    /// One-way latency in seconds.
    pub latency_s: f64,
}

pub const DATACENTER: Link = Link { gbps: 25.0, latency_s: 10e-6 };
pub const CLOUD_WAN: Link = Link { gbps: 0.125, latency_s: 50e-3 }; // 1 Gbit/s
pub const BROADBAND: Link = Link { gbps: 0.0125, latency_s: 30e-3 }; // 100 Mbit/s

impl Link {
    /// Seconds to move `bytes` once over this link.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / (self.gbps * 1e9)
    }
}

/// Bytes per worker per optimizer step under Ring-AllReduce over `n`
/// workers with a `payload` of gradient bytes (2(n−1)/n · payload).
pub fn ring_allreduce_bytes_per_step(payload: u64, n_workers: usize) -> u64 {
    if n_workers <= 1 {
        return 0;
    }
    (2 * payload * (n_workers as u64 - 1)) / n_workers as u64
}

/// Total DDP bytes per worker to run `steps` sequential steps.
pub fn ddp_total_bytes(payload: u64, n_workers: usize, steps: u64) -> u64 {
    ring_allreduce_bytes_per_step(payload, n_workers) * steps
}

/// Total federated bytes per participating client for `rounds` rounds
/// (down + up each round).
pub fn fed_total_bytes(payload: u64, rounds: u64) -> u64 {
    2 * payload * rounds
}

/// Communication ratio DDP/FL for the same sequential-step count
/// (`steps = rounds·τ`), per worker. Degenerate inputs (zero payload, a
/// single worker, or zero rounds) move zero federated bytes; the ratio is
/// defined as 0 there rather than NaN.
pub fn comm_ratio(payload: u64, n_workers: usize, rounds: u64, tau: u64) -> f64 {
    let ddp = ddp_total_bytes(payload, n_workers, rounds * tau) as f64;
    let fed = fed_total_bytes(payload, rounds) as f64;
    if fed == 0.0 {
        return 0.0;
    }
    ddp / fed
}

/// Wall-clock of one federated round for one client:
/// broadcast + τ·compute + upload (compute given per-step seconds).
pub fn fed_round_secs(payload: u64, link: &Link, tau: u64, step_secs: f64) -> f64 {
    link.transfer_secs(payload) + tau as f64 * step_secs + link.transfer_secs(payload)
}

/// Wall-clock of τ DDP steps: each step pays compute + allreduce over the
/// slowest link.
pub fn ddp_steps_secs(
    payload: u64,
    n_workers: usize,
    link: &Link,
    tau: u64,
    step_secs: f64,
) -> f64 {
    let per_step = step_secs + link.transfer_secs(ring_allreduce_bytes_per_step(payload, n_workers));
    tau as f64 * per_step
}

/// Communication fraction of a federated round's wall-clock (§4.3 argues
/// this is negligible for compute-intensive LLM training).
pub fn fed_comm_fraction(payload: u64, link: &Link, tau: u64, step_secs: f64) -> f64 {
    let comm = 2.0 * link.transfer_secs(payload);
    comm / fed_round_secs(payload, link, tau, step_secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_formula() {
        // 8 workers, 1 GB payload: 2*7/8 GB = 1.75 GB per step.
        assert_eq!(
            ring_allreduce_bytes_per_step(1_000_000_000, 8),
            1_750_000_000
        );
        assert_eq!(ring_allreduce_bytes_per_step(1_000_000_000, 1), 0);
    }

    #[test]
    fn comm_ratio_is_about_tau() {
        // The headline: ratio ≈ τ·(n−1)/n.
        let r = comm_ratio(4_000_000, 8, 10, 500);
        assert!((r - 500.0 * 7.0 / 8.0).abs() < 1e-6, "{r}");
        // At paper τ=500 that is ~437×; "orders of magnitude".
        assert!(r > 100.0);
    }

    #[test]
    fn single_worker_moves_no_ddp_bytes() {
        // n ≤ 1: there is nobody to allreduce with (and no divide-by-zero).
        assert_eq!(ring_allreduce_bytes_per_step(1 << 30, 0), 0);
        assert_eq!(ring_allreduce_bytes_per_step(1 << 30, 1), 0);
        assert_eq!(ddp_total_bytes(1 << 30, 1, 1_000), 0);
        assert_eq!(ddp_total_bytes(1 << 30, 0, 1_000), 0);
        // The ratio degenerates to 0/positive = 0, not NaN.
        let r = comm_ratio(1 << 30, 1, 10, 500);
        assert_eq!(r, 0.0);
        // DDP per-step wall-clock collapses to pure compute.
        let t = ddp_steps_secs(1 << 30, 1, &CLOUD_WAN, 10, 0.5);
        assert!((t - 10.0 * (0.5 + CLOUD_WAN.latency_s)).abs() < 1e-9, "{t}");
    }

    #[test]
    fn zero_byte_payload_edges() {
        assert_eq!(fed_total_bytes(0, 100), 0);
        assert_eq!(ring_allreduce_bytes_per_step(0, 8), 0);
        assert!(comm_ratio(0, 8, 10, 500).abs() < 1e-12, "0/0 defined as 0");
        // Zero-byte transfers cost exactly one latency.
        assert_eq!(DATACENTER.transfer_secs(0), DATACENTER.latency_s);
        // A zero-byte round is all latency + compute; fraction is finite.
        let f = fed_comm_fraction(0, &CLOUD_WAN, 10, 1.0);
        assert!(f > 0.0 && f < 0.011, "{f}");
    }

    #[test]
    fn zero_rounds_ratio_defined() {
        assert_eq!(comm_ratio(1 << 20, 8, 0, 500), 0.0);
    }

    #[test]
    fn transfer_time_includes_latency() {
        let t = CLOUD_WAN.transfer_secs(125_000_000); // 1 Gbit/s, 125 MB → 1 s
        assert!((t - 1.05).abs() < 0.01, "{t}");
    }

    #[test]
    fn fed_round_dominated_by_compute_when_tau_large() {
        // 28 MB model (7M params), WAN, τ=500, 1 s/step.
        let frac = fed_comm_fraction(28_000_000, &CLOUD_WAN, 500, 1.0);
        assert!(frac < 0.01, "comm fraction {frac} should be negligible");
    }

    #[test]
    fn ddp_slower_than_fed_on_wan() {
        let payload = 28_000_000u64;
        let fed = fed_round_secs(payload, &CLOUD_WAN, 500, 0.1);
        let ddp = ddp_steps_secs(payload, 8, &CLOUD_WAN, 500, 0.1);
        assert!(ddp > 2.0 * fed, "ddp {ddp} vs fed {fed}");
    }

    #[test]
    fn ddp_fine_in_datacenter() {
        // §4.3: the datacenter interconnect makes DDP's per-step allreduce
        // cheap relative to compute.
        let payload = 28_000_000u64;
        let per_step_comm =
            DATACENTER.transfer_secs(ring_allreduce_bytes_per_step(payload, 8));
        assert!(per_step_comm < 0.01);
    }
}
