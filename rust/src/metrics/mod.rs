//! Federated metrics (paper §6.2 experimental tools): per-round records of
//! everything the paper's figures plot — server/client perplexities, model
//! and pseudo-gradient L2 norms, activation norms, momentum norms, pairwise
//! client-model cosine similarity — plus CSV emission for the figure
//! drivers.
//!
//! Also home to the wall-clock simulator's per-round [`TimelineRow`]
//! (`sim` module, `wallclock` experiment), so every CSV schema the repo
//! emits lives in one place.

use std::path::Path;

use anyhow::Result;

use crate::model::vecmath;
use crate::util::csv::CsvWriter;

/// Everything measured in one federated round (or one centralized
/// round-equivalent of τ steps).
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Server model perplexity on the centralized validation set.
    pub server_ppl: f64,
    pub server_nll: f64,
    /// Mean/std of client *training* loss over the round (paper plots the
    /// averaged client train perplexity).
    pub client_loss_mean: f64,
    pub client_loss_std: f64,
    pub client_ppl_mean: f64,
    /// L2 norms (fig7/fig8/fig11/fig12..15).
    pub global_model_norm: f64,
    pub client_model_norm_mean: f64,
    pub client_avg_norm: f64,
    pub pseudo_grad_norm: f64,
    pub step_grad_norm_mean: f64,
    pub applied_update_norm_mean: f64,
    pub act_norm_mean: f64,
    pub momentum_norm: f64,
    /// Mean pairwise cosine similarity between client deltas (consensus
    /// diagnostic, §7.3).
    pub client_cosine_mean: f64,
    /// Clients that actually contributed (after faults).
    pub participated: usize,
    /// Dense-estimate Photon-Link bytes this round (downlink + uplink,
    /// raw f32 accounting — the paper's Table-style comm numbers).
    pub comm_bytes: u64,
    /// Actual framed transit bytes this round under the active update
    /// codec (`compress`): per participating client, one dense broadcast
    /// frame down plus the measured encoded update frame up (pre-deflate).
    /// Equals `comm_bytes` plus two frame headers per client when
    /// `codec = none`; shrinks with lossy codecs.
    pub comm_bytes_wire: u64,
    pub wall_secs: f64,
}

impl RoundRecord {
    /// True when every *deterministic* field matches `other` exactly —
    /// everything except `wall_secs`, which measures real time. This is
    /// the deployment plane's parity check: a localhost TCP fleet must
    /// produce a record stream that `agrees_with` the in-process
    /// `Federation::run` bit for bit.
    pub fn agrees_with(&self, other: &RoundRecord) -> bool {
        // Exhaustive destructuring, no `..` rest pattern: adding a field
        // to RoundRecord is a compile error here, forcing the parity
        // check to account for it (either compared or explicitly waived
        // like `wall_secs`).
        let RoundRecord {
            round,
            server_ppl,
            server_nll,
            client_loss_mean,
            client_loss_std,
            client_ppl_mean,
            global_model_norm,
            client_model_norm_mean,
            client_avg_norm,
            pseudo_grad_norm,
            step_grad_norm_mean,
            applied_update_norm_mean,
            act_norm_mean,
            momentum_norm,
            client_cosine_mean,
            participated,
            comm_bytes,
            comm_bytes_wire,
            wall_secs: _,
        } = self;
        *round == other.round
            && server_ppl.to_bits() == other.server_ppl.to_bits()
            && server_nll.to_bits() == other.server_nll.to_bits()
            && client_loss_mean.to_bits() == other.client_loss_mean.to_bits()
            && client_loss_std.to_bits() == other.client_loss_std.to_bits()
            && client_ppl_mean.to_bits() == other.client_ppl_mean.to_bits()
            && global_model_norm.to_bits() == other.global_model_norm.to_bits()
            && client_model_norm_mean.to_bits() == other.client_model_norm_mean.to_bits()
            && client_avg_norm.to_bits() == other.client_avg_norm.to_bits()
            && pseudo_grad_norm.to_bits() == other.pseudo_grad_norm.to_bits()
            && step_grad_norm_mean.to_bits() == other.step_grad_norm_mean.to_bits()
            && applied_update_norm_mean.to_bits()
                == other.applied_update_norm_mean.to_bits()
            && act_norm_mean.to_bits() == other.act_norm_mean.to_bits()
            && momentum_norm.to_bits() == other.momentum_norm.to_bits()
            && client_cosine_mean.to_bits() == other.client_cosine_mean.to_bits()
            && *participated == other.participated
            && *comm_bytes == other.comm_bytes
            && *comm_bytes_wire == other.comm_bytes_wire
    }
}

/// Rolling per-round log with CSV export.
#[derive(Default)]
pub struct MetricsLog {
    pub rounds: Vec<RoundRecord>,
}

pub const CSV_HEADER: [&str; 19] = [
    "round", "server_ppl", "server_nll", "client_loss_mean", "client_loss_std",
    "client_ppl_mean", "global_model_norm", "client_model_norm_mean",
    "client_avg_norm", "pseudo_grad_norm", "step_grad_norm_mean",
    "applied_update_norm_mean", "act_norm_mean", "momentum_norm",
    "client_cosine_mean", "participated", "comm_bytes", "comm_bytes_wire",
    "wall_secs",
];

impl MetricsLog {
    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.rounds.last()
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(path, &CSV_HEADER)?;
        for r in &self.rounds {
            w.row(&[
                r.round as f64, r.server_ppl, r.server_nll, r.client_loss_mean,
                r.client_loss_std, r.client_ppl_mean, r.global_model_norm,
                r.client_model_norm_mean, r.client_avg_norm, r.pseudo_grad_norm,
                r.step_grad_norm_mean, r.applied_update_norm_mean,
                r.act_norm_mean, r.momentum_norm, r.client_cosine_mean,
                r.participated as f64, r.comm_bytes as f64,
                r.comm_bytes_wire as f64, r.wall_secs,
            ])?;
        }
        w.finish()
    }
}

/// One simulated round of the event-driven wall-clock simulator
/// (`sim::Simulator`): when the round ran, what gated it, who made it.
#[derive(Clone, Debug, PartialEq)]
pub struct TimelineRow {
    pub round: usize,
    /// Simulated wall-clock at round start / end (seconds since t=0).
    pub t_start_secs: f64,
    pub t_end_secs: f64,
    pub round_secs: f64,
    /// One broadcast / upload transfer time on the configured link.
    pub broadcast_secs: f64,
    pub upload_secs: f64,
    /// Longest scheduled client compute span this round (straggler
    /// slowdown and overlap tail credit included).
    pub compute_secs: f64,
    /// Clients whose upload arrived in time to be aggregated.
    pub n_arrived: usize,
    /// Clients cut by a semi-sync deadline.
    pub n_late: usize,
    /// Sampled clients that dropped before doing any work.
    pub n_dropped: usize,
    pub bytes_down: u64,
    pub bytes_up: u64,
    /// Client id of the last arrival (-1 if nobody arrived).
    pub slowest_client: i64,
}

pub const TIMELINE_CSV_HEADER: [&str; 14] = [
    "round", "t_start_secs", "t_end_secs", "round_secs", "broadcast_secs",
    "upload_secs", "compute_secs", "n_arrived", "n_late", "n_dropped",
    "bytes_down", "bytes_up", "slowest_client", "comm_frac",
];

/// A simulated timeline with CSV export (`results/wallclock/…`).
pub struct TimelineLog {
    pub rows: Vec<TimelineRow>,
}

impl TimelineLog {
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        let mut w = CsvWriter::create(path, &TIMELINE_CSV_HEADER)?;
        for r in &self.rows {
            let comm_frac = if r.round_secs > 0.0 {
                ((r.broadcast_secs + r.upload_secs) / r.round_secs).min(1.0)
            } else {
                0.0
            };
            w.row(&[
                r.round as f64, r.t_start_secs, r.t_end_secs, r.round_secs,
                r.broadcast_secs, r.upload_secs, r.compute_secs,
                r.n_arrived as f64, r.n_late as f64, r.n_dropped as f64,
                r.bytes_down as f64, r.bytes_up as f64,
                r.slowest_client as f64, comm_frac,
            ])?;
        }
        w.finish()
    }
}

/// One cell of the `photon exp chaos` resilience sweep: a chaotic
/// loopback fleet at one fault rate × migration setting, its realized
/// damage, the bit-parity verdict of the in-process trace replay, and the
/// wall-clock the simulator prices for the same churned schedule under
/// one aggregation policy.
#[derive(Clone, Debug, PartialEq)]
pub struct ResilienceRow {
    /// Aggregate per-(worker, round) fault probability, in percent.
    pub fault_pct: f64,
    pub migrate: bool,
    /// Simulator aggregation policy label (`sync`/`semisync`/`overlap`).
    pub policy: String,
    pub final_ppl: f64,
    pub final_nll: f64,
    /// Mean fraction of the sampled clients that made each aggregation.
    pub participation: f64,
    pub cuts: usize,
    pub migrations: usize,
    pub rejoins: usize,
    /// 1 when the fleet's records + global model bit-equal the in-process
    /// replay of its realized trace (`Federation::run_trace`).
    pub replay_agree: bool,
    /// Simulated wall-clock of the churned schedule under `policy`.
    pub sim_secs: f64,
    pub sim_dropped: usize,
}

pub const RESILIENCE_CSV_HEADER: [&str; 12] = [
    "fault_pct", "migrate", "policy", "final_ppl", "final_nll", "participation",
    "cuts", "migrations", "rejoins", "replay_agree", "sim_secs", "sim_dropped",
];

/// Write the resilience sweep CSV (`results/chaos/resilience.csv`).
pub fn write_resilience_csv(path: &Path, rows: &[ResilienceRow]) -> Result<()> {
    let mut w = CsvWriter::create(path, &RESILIENCE_CSV_HEADER)?;
    for r in rows {
        w.row_mixed(&[
            format!("{:.1}", r.fault_pct),
            (r.migrate as u8).to_string(),
            r.policy.clone(),
            format!("{:.6}", r.final_ppl),
            format!("{:.6}", r.final_nll),
            format!("{:.4}", r.participation),
            r.cuts.to_string(),
            r.migrations.to_string(),
            r.rejoins.to_string(),
            (r.replay_agree as u8).to_string(),
            format!("{:.3}", r.sim_secs),
            r.sim_dropped.to_string(),
        ])?;
    }
    w.finish()
}

/// One cell of the `photon exp async` staleness sweep: an asynchronous
/// loopback fleet at one (γ, fault-rate, τ) setting, its realized
/// staleness profile, the bit-parity verdict of the in-process
/// `Federation::run_async_trace` replay, and the wall-clock the
/// simulator prices for the same schedule under the async vs semi-sync
/// policies.
#[derive(Clone, Debug, PartialEq)]
pub struct AsyncRow {
    /// Staleness discount base (fold weight `w·γ^staleness`).
    pub gamma: f64,
    /// Aggregate per-(worker, round) fault probability, in percent.
    pub fault_pct: f64,
    /// Local steps per grant (τ).
    pub tau: u64,
    /// Arrivals folded per epoch (the async K).
    pub k: usize,
    pub final_ppl: f64,
    pub final_nll: f64,
    /// Committed epochs (= folds) and grants cut over the run.
    pub folds: usize,
    pub cuts: usize,
    /// Realized staleness profile across every folded arrival.
    pub staleness_max: u64,
    pub staleness_mean: f64,
    /// 1 when the fleet's records + global model bit-equal the in-process
    /// replay of its realized trace (`Federation::run_async_trace`).
    pub replay_agree: bool,
    /// Simulated wall-clock of the same schedule: async vs semi-sync.
    pub sim_async_secs: f64,
    pub sim_semisync_secs: f64,
}

pub const ASYNC_CSV_HEADER: [&str; 13] = [
    "gamma", "fault_pct", "tau", "k", "final_ppl", "final_nll", "folds", "cuts",
    "staleness_max", "staleness_mean", "replay_agree", "sim_async_secs",
    "sim_semisync_secs",
];

/// Write the async staleness sweep CSV (`results/async/staleness.csv`).
pub fn write_async_csv(path: &Path, rows: &[AsyncRow]) -> Result<()> {
    let mut w = CsvWriter::create(path, &ASYNC_CSV_HEADER)?;
    for r in rows {
        w.row_mixed(&[
            format!("{:.3}", r.gamma),
            format!("{:.1}", r.fault_pct),
            r.tau.to_string(),
            r.k.to_string(),
            format!("{:.6}", r.final_ppl),
            format!("{:.6}", r.final_nll),
            r.folds.to_string(),
            r.cuts.to_string(),
            r.staleness_max.to_string(),
            format!("{:.4}", r.staleness_mean),
            (r.replay_agree as u8).to_string(),
            format!("{:.3}", r.sim_async_secs),
            format!("{:.3}", r.sim_semisync_secs),
        ])?;
    }
    w.finish()
}

/// Mean + population std of a slice.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
    (mean, var.sqrt())
}

/// Mean pairwise cosine similarity from a precomputed K×K delta Gram
/// matrix (`vecmath::streaming_aggregate`): cos(i,j) = G_ij/√(G_ii·G_jj),
/// zero-norm pairs contribute 0 (matching `vecmath::cosine`). This is the
/// streaming-aggregation replacement for `mean_pairwise_cosine` — same
/// metric, no materialized delta vectors.
pub fn mean_pairwise_cosine_from_gram(k: usize, gram: &[f64]) -> f64 {
    debug_assert_eq!(gram.len(), k * k);
    if k < 2 {
        return 1.0;
    }
    let mut sum = 0.0;
    let mut n = 0usize;
    for i in 0..k {
        for j in (i + 1)..k {
            let (gii, gjj) = (gram[i * k + i], gram[j * k + j]);
            if gii > 0.0 && gjj > 0.0 {
                sum += gram[i * k + j] / (gii.sqrt() * gjj.sqrt());
            }
            n += 1;
        }
    }
    sum / n as f64
}

/// Mean pairwise cosine similarity among client delta vectors (the paper's
/// federated consensus metric). O(K²·N) — K is small (≤ 64).
pub fn mean_pairwise_cosine(deltas: &[Vec<f32>]) -> f64 {
    if deltas.len() < 2 {
        return 1.0;
    }
    let mut sum = 0.0;
    let mut n = 0usize;
    for i in 0..deltas.len() {
        for j in (i + 1)..deltas.len() {
            sum += vecmath::cosine(&deltas[i], &deltas[j]);
            n += 1;
        }
    }
    sum / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agrees_with_ignores_wall_clock_only() {
        let a = RoundRecord { round: 2, server_ppl: 41.5, wall_secs: 1.0, ..Default::default() };
        let mut b = a.clone();
        b.wall_secs = 99.0;
        assert!(a.agrees_with(&b), "wall_secs must not affect parity");
        b.server_ppl = 41.5000001;
        assert!(!a.agrees_with(&b), "any deterministic field mismatch fails parity");
        let mut c = a.clone();
        c.participated = 7;
        assert!(!a.agrees_with(&c));
    }

    #[test]
    fn mean_std_basic() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m, 2.5);
        assert!((s - 1.118033988749895).abs() < 1e-12);
        assert_eq!(mean_std(&[]), (0.0, 0.0));
    }

    #[test]
    fn pairwise_cosine() {
        let a = vec![1.0f32, 0.0];
        let b = vec![1.0f32, 0.0];
        let c = vec![0.0f32, 1.0];
        assert!((mean_pairwise_cosine(&[a.clone(), b.clone()]) - 1.0).abs() < 1e-9);
        // (1 + 0 + 0) / 3 pairs.
        let m = mean_pairwise_cosine(&[a, b, c]);
        assert!((m - 1.0 / 3.0).abs() < 1e-9);
        assert_eq!(mean_pairwise_cosine(&[vec![1.0]]), 1.0);
    }

    #[test]
    fn gram_cosine_matches_materialized() {
        let deltas = vec![
            vec![1.0f32, 0.5, -0.25],
            vec![-0.5f32, 1.0, 0.75],
            vec![0.0f32, -1.0, 0.5],
        ];
        let k = deltas.len();
        let mut gram = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                gram[i * k + j] = deltas[i]
                    .iter()
                    .zip(&deltas[j])
                    .map(|(&x, &y)| x as f64 * y as f64)
                    .sum();
            }
        }
        let a = mean_pairwise_cosine(&deltas);
        let b = mean_pairwise_cosine_from_gram(k, &gram);
        assert!((a - b).abs() < 1e-12, "{a} vs {b}");
        // Zero-norm client contributes 0 to its pairs, matching cosine().
        let with_zero = vec![vec![1.0f32, 0.0], vec![0.0f32, 0.0]];
        let mut g2 = vec![0.0f64; 4];
        g2[0] = 1.0; // only the non-zero diagonal entry
        assert_eq!(mean_pairwise_cosine(&with_zero), 0.0);
        assert_eq!(mean_pairwise_cosine_from_gram(2, &g2), 0.0);
        assert_eq!(mean_pairwise_cosine_from_gram(1, &[4.0]), 1.0);
    }

    #[test]
    fn timeline_csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("photon_tl_{}", std::process::id()));
        let log = TimelineLog {
            rows: vec![
                TimelineRow {
                    round: 0,
                    t_start_secs: 0.0,
                    t_end_secs: 12.5,
                    round_secs: 12.5,
                    broadcast_secs: 1.0,
                    upload_secs: 1.5,
                    compute_secs: 10.0,
                    n_arrived: 7,
                    n_late: 1,
                    n_dropped: 0,
                    bytes_down: 800,
                    bytes_up: 700,
                    slowest_client: 3,
                },
                TimelineRow {
                    round: 1,
                    t_start_secs: 12.5,
                    t_end_secs: 12.5,
                    round_secs: 0.0,
                    broadcast_secs: 1.0,
                    upload_secs: 1.5,
                    compute_secs: 0.0,
                    n_arrived: 0,
                    n_late: 0,
                    n_dropped: 8,
                    bytes_down: 0,
                    bytes_up: 0,
                    slowest_client: -1,
                },
            ],
        };
        let p = dir.join("timeline.csv");
        log.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("round,t_start_secs"));
        assert!(text.lines().nth(1).unwrap().starts_with("0,0,12.5"));
        // Zero-duration all-dropped round reports comm_frac 0, slowest -1.
        let dropped_row = text.lines().nth(2).unwrap();
        assert!(dropped_row.contains(",-1,"), "{dropped_row}");
        assert!(dropped_row.ends_with(",0"), "{dropped_row}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resilience_csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("photon_rz_{}", std::process::id()));
        let rows = vec![ResilienceRow {
            fault_pct: 25.0,
            migrate: true,
            policy: "semisync".into(),
            final_ppl: 41.25,
            final_nll: 3.72,
            participation: 0.8125,
            cuts: 7,
            migrations: 3,
            rejoins: 2,
            replay_agree: true,
            sim_secs: 123.456,
            sim_dropped: 9,
        }];
        let p = dir.join("resilience.csv");
        write_resilience_csv(&p, &rows).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("fault_pct,migrate,policy"));
        let row = text.lines().nth(1).unwrap();
        assert!(row.starts_with("25.0,1,semisync,41.25"), "{row}");
        assert!(row.contains(",7,3,2,1,"), "{row}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn async_csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("photon_as_{}", std::process::id()));
        let rows = vec![AsyncRow {
            gamma: 0.5,
            fault_pct: 15.0,
            tau: 6,
            k: 3,
            final_ppl: 39.5,
            final_nll: 3.676,
            folds: 5,
            cuts: 2,
            staleness_max: 3,
            staleness_mean: 0.8,
            replay_agree: true,
            sim_async_secs: 45.5,
            sim_semisync_secs: 61.25,
        }];
        let p = dir.join("staleness.csv");
        write_async_csv(&p, &rows).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("gamma,fault_pct,tau,k"), "{text}");
        let row = text.lines().nth(1).unwrap();
        assert!(row.starts_with("0.500,15.0,6,3,39.5"), "{row}");
        assert!(row.contains(",5,2,3,0.8000,1,"), "{row}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join(format!("photon_m_{}", std::process::id()));
        let mut log = MetricsLog::default();
        log.push(RoundRecord { round: 1, server_ppl: 42.5, ..Default::default() });
        log.push(RoundRecord { round: 2, server_ppl: 40.0, ..Default::default() });
        let p = dir.join("log.csv");
        log.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.lines().nth(1).unwrap().starts_with("1,42.5"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
