//! Typed experiment configuration (the paper's Hydra-style schemas, §6.2)
//! plus the paper's own hyperparameter tables (Tables 1–4) as data, so the
//! table drivers can reprint them next to the analogue values.

use anyhow::Result;

use crate::cluster::faults::FaultPlan;
use crate::cluster::hardware::FleetSpec;
use crate::compress::UpdateCodec;
use crate::optim::outer::{OuterHyper, OuterOptKind};
use crate::optim::schedule::CosineSchedule;

/// Which corpus + partition shape a federation trains on (paper §6.3).
#[derive(Clone, Debug, PartialEq)]
pub enum CorpusKind {
    /// IID shards of the homogeneous C4 stand-in.
    C4Iid,
    /// Natural heterogeneous Pile stand-in; `j` categories per client.
    PileHetero { j: usize },
    /// Disjoint-vocabulary language partition (mC4 stand-in).
    Mc4 { n_langs: usize },
}

/// Round-engine execution knobs (coordinator::round_exec): how many worker
/// threads run sampled clients' local rounds concurrently, and whether the
/// PJRT dispatch itself is serialized (see runtime::DispatchPolicy). The
/// engine is bit-exact across any worker count under a fixed seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecConfig {
    /// Worker threads for client local rounds. 0 = auto (one per available
    /// CPU, capped at the number of runnable clients); 1 = sequential.
    pub workers: usize,
    /// Serialize XLA executable dispatch behind a per-model mutex (default
    /// true — host-side work still overlaps). False opts into PJRT's
    /// thread-safe concurrent `Execute`.
    pub serialize_dispatch: bool,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { workers: 1, serialize_dispatch: true }
    }
}

/// Local optimizer-state policy between rounds (paper §7.8).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptStatePolicy {
    /// Reset AdamW moments each round — the paper's recommended stateless
    /// clients.
    Stateless,
    /// FedAvg-KeepOpt: clients carry their AdamW state across rounds.
    KeepOpt,
}

/// Full federated-experiment schema.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub label: String,
    /// Artifact/model config name (see python/compile/configs.py).
    pub model: String,
    pub corpus: CorpusKind,
    /// P: federation size.
    pub n_clients: usize,
    /// K: clients sampled per round.
    pub clients_per_round: usize,
    pub rounds: usize,
    /// τ: local steps per round (paper: 500).
    pub local_steps: u64,
    pub seed: u64,
    pub outer: OuterOptKind,
    pub outer_hyper: OuterHyper,
    pub schedule: CosineSchedule,
    pub opt_state: OptStatePolicy,
    /// Validation batches for server-side perplexity.
    pub eval_batches: usize,
    pub faults: FaultPlan,
    /// Per-client hardware (None = uniform single-GPU clients).
    pub fleet: Option<FleetSpec>,
    /// Round-engine parallelism (workers, dispatch serialization).
    pub exec: ExecConfig,
    /// Pseudo-gradient update codec applied in transit (`compress`
    /// module). `None` is the pre-codec lossless path and leaves every
    /// record bit-identical to builds without the codec plane.
    pub codec: UpdateCodec,
    /// Aggregation-tree fan-in: how many sub-aggregator groups the sampled
    /// clients are partitioned into each round (contiguous slices in
    /// sampled order — see `coordinator::federation::tier_slices`). The
    /// partition is part of the round *plan*, so the in-process fold and a
    /// deployed aggregation tree compute the identical group-structured
    /// fold and stay bit-equal. `1` (the default) is the flat fold,
    /// bit-identical to builds without the tier plane.
    pub tiers: usize,
}

impl ExperimentConfig {
    /// Small, fast federated run used by the quickstart example and tests.
    pub fn quickstart(model: &str) -> ExperimentConfig {
        ExperimentConfig {
            label: format!("quickstart-{model}"),
            model: model.to_string(),
            corpus: CorpusKind::C4Iid,
            n_clients: 4,
            clients_per_round: 4,
            rounds: 5,
            local_steps: 20,
            seed: 42,
            outer: OuterOptKind::FedAvg,
            outer_hyper: OuterHyper { lr: 1.0, ..OuterHyper::default() },
            schedule: CosineSchedule::new(3e-3, 0.1, 2_000, 20),
            opt_state: OptStatePolicy::Stateless,
            eval_batches: 4,
            faults: FaultPlan::none(),
            fleet: None,
            exec: ExecConfig::default(),
            codec: UpdateCodec::None,
            tiers: 1,
        }
    }

    /// The figure-experiment default: paper recipe scaled to CPU budget
    /// (DESIGN.md §1). `--paper-scale` multiplies these back up.
    pub fn figure_default(model: &str, corpus: CorpusKind) -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart(model);
        c.label = format!("fig-{model}");
        c.corpus = corpus;
        c.n_clients = 8;
        c.clients_per_round = 8;
        c.rounds = 15;
        c.local_steps = 40;
        c.schedule = CosineSchedule::new(3e-3, 0.1, 15 * 40, 30);
        c
    }

    /// Schedule-only config for the wall-clock simulator (`sim` module /
    /// `wallclock` experiment): the model artifact is never loaded — only
    /// the sampler/fault schedule and the heterogeneous fleet matter.
    pub fn wallclock(p: usize, k: usize, rounds: usize, tau: u64, seed: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart("m75a");
        c.label = format!("wallclock-{p}x{k}");
        c.n_clients = p;
        c.clients_per_round = k;
        c.rounds = rounds;
        c.local_steps = tau;
        c.seed = seed;
        c.fleet = Some(FleetSpec::heterogeneous(p));
        c
    }

    /// Total sequential optimizer steps a client will have taken by the end.
    pub fn total_sequential_steps(&self) -> u64 {
        self.rounds as u64 * self.local_steps
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.n_clients >= 1, "need at least one client");
        anyhow::ensure!(
            self.clients_per_round >= 1 && self.clients_per_round <= self.n_clients,
            "K must be in [1, P]"
        );
        anyhow::ensure!(self.local_steps >= 1, "τ must be >= 1");
        anyhow::ensure!(self.rounds >= 1, "need at least one round");
        anyhow::ensure!(
            self.tiers >= 1 && self.tiers <= self.clients_per_round,
            "tiers must be in [1, K]: every sub-aggregator group needs at \
             least one sampled client"
        );
        self.codec.validate()?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Paper tables as data (reprinted by `photon exp table1..4`)
// ---------------------------------------------------------------------------

/// One row of the paper's Table 2 (architecture ladder) + our analogue.
pub struct PaperModelRow {
    pub size: &'static str,
    pub blocks: usize,
    pub d: usize,
    pub heads: usize,
    pub vocab: usize,
    pub seq: usize,
    /// Our artifact config implementing this row's analogue.
    pub analog: &'static str,
}

pub const PAPER_TABLE2: [PaperModelRow; 6] = [
    PaperModelRow { size: "75M", blocks: 3, d: 896, heads: 16, vocab: 50368, seq: 1024, analog: "m75a" },
    PaperModelRow { size: "125M", blocks: 12, d: 768, heads: 12, vocab: 50368, seq: 2048, analog: "m125a" },
    PaperModelRow { size: "350M", blocks: 24, d: 1024, heads: 16, vocab: 50368, seq: 2048, analog: "m350a" },
    PaperModelRow { size: "1.3B", blocks: 24, d: 2048, heads: 16, vocab: 50368, seq: 2048, analog: "m1ba" },
    PaperModelRow { size: "3B", blocks: 32, d: 2560, heads: 20, vocab: 50368, seq: 2048, analog: "m3ba" },
    PaperModelRow { size: "7B", blocks: 32, d: 4096, heads: 32, vocab: 50368, seq: 2048, analog: "m7ba" },
];

/// One row of the paper's Table 3 (hyperparameters).
pub struct PaperHyperRow {
    pub size: &'static str,
    pub eta_s: f64,
    pub mu_s: f64,
    pub alpha: f64,
    pub eta_max: f64,
    pub t_steps: u64,
    pub batch: usize,
}

pub const PAPER_TABLE3: [PaperHyperRow; 6] = [
    PaperHyperRow { size: "75M", eta_s: 0.7, mu_s: 0.9, alpha: 0.1, eta_max: 4e-4, t_steps: 88_000, batch: 256 },
    PaperHyperRow { size: "125M", eta_s: 0.5, mu_s: 0.9, alpha: 0.1, eta_max: 6e-4, t_steps: 15_000, batch: 256 },
    PaperHyperRow { size: "350M", eta_s: 0.1, mu_s: 0.9, alpha: 0.1, eta_max: 3e-4, t_steps: 13_400, batch: 256 },
    PaperHyperRow { size: "1.3B", eta_s: 0.7, mu_s: 0.9, alpha: 0.1, eta_max: 2e-4, t_steps: 24_800, batch: 512 },
    PaperHyperRow { size: "3B", eta_s: 0.7, mu_s: 0.9, alpha: 0.1, eta_max: 1.6e-4, t_steps: 51_500, batch: 512 },
    PaperHyperRow { size: "7B", eta_s: 0.7, mu_s: 0.9, alpha: 0.1, eta_max: 1.2e-4, t_steps: 63_900, batch: 1024 },
];

/// One row of the paper's Table 4 (federated settings).
pub struct PaperFedRow {
    pub size: &'static str,
    pub rounds: &'static str,
    pub p: &'static str,
    pub k: &'static str,
    pub dataset: &'static str,
    pub tau: &'static str,
}

pub const PAPER_TABLE4: [PaperFedRow; 6] = [
    PaperFedRow { size: "75M", rounds: "40", p: "8,64", k: "8,4", dataset: "C4, The Pile", tau: "500" },
    PaperFedRow { size: "125M", rounds: "10,25", p: "8,64", k: "8,4", dataset: "C4, The Pile", tau: "250,500" },
    PaperFedRow { size: "350M", rounds: "40", p: "8", k: "8", dataset: "C4", tau: "500" },
    PaperFedRow { size: "1.3B", rounds: "14", p: "8", k: "8", dataset: "C4", tau: "500" },
    PaperFedRow { size: "3B", rounds: "21", p: "64", k: "4", dataset: "C4", tau: "500" },
    PaperFedRow { size: "7B", rounds: "21", p: "64", k: "4", dataset: "C4", tau: "500" },
];

/// Paper Table 1 parameters: (size label, params, chinchilla tokens, mpt
/// tokens, seq tokens, par tokens, l, B).
pub struct PaperTokenRow {
    pub size: &'static str,
    pub params: f64,
    pub chinchilla_tokens: f64,
    pub mpt_tokens: f64,
    pub seq_tokens: f64,
    pub par_tokens: f64,
    pub l: u64,
    pub b: u64,
}

pub const PAPER_TABLE1: [PaperTokenRow; 6] = [
    PaperTokenRow { size: "75M", params: 58.54e6, chinchilla_tokens: 1.17e9, mpt_tokens: f64::NAN, seq_tokens: 5.2e9, par_tokens: 41.9e9, l: 1024, b: 256 },
    PaperTokenRow { size: "125M", params: 110.89e6, chinchilla_tokens: 2.22e9, mpt_tokens: 2.5e9, seq_tokens: 6.6e9, par_tokens: 52.4e9, l: 2048, b: 256 },
    PaperTokenRow { size: "350M", params: 331.19e6, chinchilla_tokens: 6.62e9, mpt_tokens: 8.0e9, seq_tokens: 10.5e9, par_tokens: 83.9e9, l: 2048, b: 256 },
    PaperTokenRow { size: "1.3B", params: 1.26e9, chinchilla_tokens: 25.2e9, mpt_tokens: 26.0e9, seq_tokens: 7.35e9, par_tokens: 58.8e9, l: 2048, b: 512 },
    PaperTokenRow { size: "3B", params: 2.96e9, chinchilla_tokens: 59.2e9, mpt_tokens: 54.0e9, seq_tokens: 13.1e9, par_tokens: 52.4e9, l: 2048, b: 512 },
    PaperTokenRow { size: "7B", params: 6.92e9, chinchilla_tokens: 138e9, mpt_tokens: 134.0e9, seq_tokens: 22.0e9, par_tokens: 88.1e9, l: 2048, b: 1024 },
];

/// The model ladder available as artifacts, ordered by size.
pub const MODEL_LADDER: [&str; 6] = ["m75a", "m125a", "m350a", "m1ba", "m3ba", "m7ba"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quickstart_validates() {
        ExperimentConfig::quickstart("m75a").validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_k() {
        let mut c = ExperimentConfig::quickstart("m75a");
        c.clients_per_round = 10; // > P=4
        assert!(c.validate().is_err());
        c.clients_per_round = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn wallclock_config_validates_with_fleet() {
        let c = ExperimentConfig::wallclock(16, 4, 10, 500, 7);
        c.validate().unwrap();
        assert_eq!(c.fleet.as_ref().unwrap().clients.len(), 16);
        assert_eq!((c.rounds, c.local_steps), (10, 500));
    }

    #[test]
    fn validation_catches_bad_tiers() {
        let mut c = ExperimentConfig::quickstart("m75a");
        assert_eq!(c.tiers, 1, "flat fold is the default");
        c.tiers = 0;
        assert!(c.validate().is_err());
        c.tiers = c.clients_per_round + 1; // more groups than sampled clients
        assert!(c.validate().is_err());
        c.tiers = c.clients_per_round;
        c.validate().unwrap();
    }

    #[test]
    fn sequential_steps() {
        let c = ExperimentConfig::figure_default("m75a", CorpusKind::C4Iid);
        assert_eq!(c.total_sequential_steps(), 15 * 40);
    }

    #[test]
    fn table_data_is_consistent() {
        assert_eq!(PAPER_TABLE2.len(), PAPER_TABLE3.len());
        for (t2, t3) in PAPER_TABLE2.iter().zip(&PAPER_TABLE3) {
            assert_eq!(t2.size, t3.size);
        }
        // Chinchilla ratio ≈ 20 tokens/param.
        for r in &PAPER_TABLE1 {
            let ratio = r.chinchilla_tokens / r.params;
            assert!((ratio - 20.0).abs() < 0.2, "{}: {ratio}", r.size);
        }
    }
}
