//! Client sampler (paper §4.1): "the client sampler assesses how many
//! Photon LLM Nodes are available and selects a number of them depending on
//! the requirements of the optimization algorithm". Sampling is uniform
//! without replacement (Algorithm 1 L.4, `C ~ U(P, K)`) and seeded per
//! round for exact reproducibility (§6.1 "reproducible sampling").

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ClientSampler {
    seed: u64,
}

impl ClientSampler {
    pub fn new(seed: u64) -> ClientSampler {
        ClientSampler { seed }
    }

    /// Sample `k` distinct clients from `0..p` for `round`. Deterministic in
    /// (seed, round); independent across rounds.
    pub fn sample(&self, round: usize, p: usize, k: usize) -> Vec<usize> {
        assert!(k <= p, "cannot sample {k} of {p} clients");
        let mut rng =
            Rng::new(self.seed).derive("client_sampler", round as u64);
        let mut picks = rng.choose_k(p, k);
        picks.sort_unstable(); // stable iteration order for reproducibility
        picks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_round() {
        let s = ClientSampler::new(42);
        assert_eq!(s.sample(3, 64, 4), s.sample(3, 64, 4));
    }

    #[test]
    fn rounds_differ() {
        let s = ClientSampler::new(42);
        assert_ne!(s.sample(1, 64, 8), s.sample(2, 64, 8));
    }

    #[test]
    fn without_replacement_and_sorted() {
        let s = ClientSampler::new(7);
        let picks = s.sample(5, 64, 16);
        assert_eq!(picks.len(), 16);
        let mut d = picks.clone();
        d.dedup();
        assert_eq!(d, picks, "sorted + distinct");
        assert!(picks.iter().all(|&c| c < 64));
    }

    #[test]
    fn full_participation_is_everyone() {
        let s = ClientSampler::new(1);
        assert_eq!(s.sample(0, 8, 8), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn coverage_over_many_rounds() {
        // Unbiased sampling: over many rounds every client appears.
        let s = ClientSampler::new(9);
        let mut seen = vec![false; 64];
        for round in 0..200 {
            for c in s.sample(round, 64, 4) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "some client never sampled");
    }
}
