//! The Photon coordinator — the paper's L3 system contribution.
//!
//! * `sampler`     — reproducible client sampling (Algorithm 1 L.4)
//! * `client`      — the Photon LLM Node: local training pipeline, island
//!                   sub-federation, optimizer-state policy (L.12–27)
//! * `round_exec`  — the round execution engine: sampled clients' local
//!                   rounds run on a worker pool (Photon runs LLM Nodes
//!                   concurrently)
//! * `federation`  — the Photon Aggregator: round orchestration, streaming
//!                   aggregation, outer optimization, metrics,
//!                   checkpointing (L.1–11)
//! * `centralized` — the centralized baseline every figure compares against
//!
//! ## Parallelism & determinism
//!
//! `ExperimentConfig::exec.workers` (CLI `--workers N|auto`, default 1)
//! sets how many clients train concurrently per round. Under a fixed seed
//! the produced `RoundRecord` stream and the global model are bit-identical
//! for every worker count: client sampling happens before execution,
//! every client's local round depends only on its own state, and the
//! aggregator folds updates in sampled order regardless of completion
//! order (see `round_exec` for the mechanism, `rust/tests/props.rs` for
//! the property test). PJRT dispatch stays mutex-serialized unless
//! `exec.serialize_dispatch` is turned off (`--parallel-dispatch`), so the
//! default concurrency is in the host-side work: batch assembly, literal
//! construction, partial aggregation, and metrics.

pub mod centralized;
pub mod client;
pub mod federation;
pub mod round_exec;
pub mod sampler;

pub use centralized::run_centralized;
pub use client::{ClientNode, ClientUpdate};
pub use federation::{bind_client_streams, build_data, Federation, RoundDispatch};
pub use round_exec::{ClientTask, RoundExec};
pub use sampler::ClientSampler;
