//! The Photon coordinator — the paper's L3 system contribution.
//!
//! * `sampler`     — reproducible client sampling (Algorithm 1 L.4)
//! * `client`      — the Photon LLM Node: local training pipeline, island
//!                   sub-federation, optimizer-state policy (L.12–27)
//! * `federation`  — the Photon Aggregator: round orchestration, outer
//!                   optimization, metrics, checkpointing (L.1–11)
//! * `centralized` — the centralized baseline every figure compares against

pub mod centralized;
pub mod client;
pub mod federation;
pub mod sampler;

pub use centralized::run_centralized;
pub use client::{ClientNode, ClientUpdate};
pub use federation::Federation;
pub use sampler::ClientSampler;
