//! The Photon Aggregator: owns the global model, orchestrates rounds
//! (Algorithm 1 L.1–11) through the parallel round engine
//! (`round_exec`), applies the outer optimizer via one-pass streaming
//! aggregation, tracks federated metrics, and checkpoints the full
//! training state. See `coordinator` module docs for the worker-count
//! knob and the cross-worker determinism guarantee.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::ckpt::{Checkpoint, ClientCkpt};
use crate::cluster::island::group_islands;
use crate::config::{CorpusKind, ExperimentConfig};
use crate::coordinator::client::{ClientNode, ClientUpdate};
use crate::coordinator::round_exec::{ClientTask, RoundExec};
use crate::coordinator::sampler::ClientSampler;
use crate::data::corpus::SyntheticCorpus;
use crate::data::partition::Partition;
use crate::data::source::DataSource;
use crate::data::stream::TokenStream;
use crate::link;
use crate::metrics::{mean_pairwise_cosine_from_gram, mean_std, MetricsLog, RoundRecord};
use crate::model::init::init_params;
use crate::model::vecmath::{l2_norm, streaming_aggregate, AggScratch};
use crate::optim::outer::OuterOpt;
use crate::runtime::{DispatchPolicy, ModelRuntime, Runtime};

/// A running federation (Aggregator + nodes + data plane).
pub struct Federation {
    pub cfg: ExperimentConfig,
    pub model: Arc<ModelRuntime>,
    pub data: DataSource,
    pub global: Vec<f32>,
    pub outer: OuterOpt,
    sampler: ClientSampler,
    nodes: Vec<ClientNode>,
    val_batches: Vec<Vec<i32>>,
    pub log: MetricsLog,
    /// Cumulative sequential steps (drives the shared LR schedule).
    pub seq_step: u64,
    pub next_round: usize,
    /// Where to drop `ckpt_round_<n>.bin` (None = no checkpointing).
    pub ckpt_dir: Option<PathBuf>,
    started: Instant,
    elapsed_offset: f64,
    // Scratch buffers reused across rounds (aggregation hot path).
    scratch_mean: Vec<f32>,
    scratch_pg: Vec<f32>,
    scratch_agg: AggScratch,
}

/// Build the corpus + partition for a config.
pub fn build_data(cfg: &ExperimentConfig, vocab: usize) -> DataSource {
    let (corpus, partition) = match &cfg.corpus {
        CorpusKind::C4Iid => {
            let c = SyntheticCorpus::c4(vocab);
            let p = Partition::iid(&c, cfg.n_clients);
            (c, p)
        }
        CorpusKind::PileHetero { j } => {
            let c = SyntheticCorpus::pile(vocab);
            let p = Partition::heterogeneous(&c, cfg.n_clients, *j);
            (c, p)
        }
        CorpusKind::Mc4 { n_langs } => {
            let c = SyntheticCorpus::mc4(vocab, *n_langs);
            let p = Partition::heterogeneous(&c, cfg.n_clients, 1);
            (c, p)
        }
    };
    DataSource::new(corpus, partition, cfg.seed)
}

impl Federation {
    /// Load artifacts and build the federation (compiles the model's HLO —
    /// reuse `with_model` when running several variants of one config).
    pub fn new(cfg: ExperimentConfig) -> Result<Federation> {
        let rt = Runtime::cpu()?;
        let model = Arc::new(rt.load_model(&cfg.model)?);
        Federation::with_model(cfg, model)
    }

    pub fn with_model(cfg: ExperimentConfig, model: Arc<ModelRuntime>) -> Result<Federation> {
        cfg.validate()?;
        // The dispatch policy is per-model process state (the gate lives on
        // the shared ModelRuntime); building a federation resets it, so
        // federations sharing one model must agree on the policy if they
        // ever run rounds concurrently (see ModelRuntime::set_dispatch_policy).
        model.set_dispatch_policy(if cfg.exec.serialize_dispatch {
            DispatchPolicy::Serialized
        } else {
            DispatchPolicy::Concurrent
        });
        if let Some(fleet) = &cfg.fleet {
            anyhow::ensure!(
                fleet.clients.len() == cfg.n_clients,
                "fleet size {} != P {}",
                fleet.clients.len(),
                cfg.n_clients
            );
        }
        let vocab = model.manifest.config.vocab;
        let data = build_data(&cfg, vocab);
        let seq_width = model.seq_width();

        // Bind each node's streams; poorly-connected multi-node clients get
        // one stream per island (disjoint sample paths = PartitionStream).
        let mut nodes = Vec::with_capacity(cfg.n_clients);
        for c in 0..cfg.n_clients {
            let n_islands = cfg
                .fleet
                .as_ref()
                .map(|f| group_islands(&f.clients[c]).len())
                .unwrap_or(1);
            let streams: Vec<TokenStream> = (0..n_islands)
                .map(|isl| {
                    TokenStream::bind(
                        &data.partition.assignment[c],
                        &data.corpus.categories,
                        seq_width,
                        cfg.seed ^ ((isl as u64) << 32),
                    )
                })
                .collect();
            nodes.push(ClientNode::new(c, streams));
        }

        let global = init_params(&model.manifest, cfg.seed);
        let outer = OuterOpt::new(cfg.outer, cfg.outer_hyper, model.n_params());
        let val_batches =
            data.validation_batches(cfg.eval_batches, model.batch_size(), seq_width);
        let n = model.n_params();
        Ok(Federation {
            sampler: ClientSampler::new(cfg.seed),
            cfg,
            model,
            data,
            global,
            outer,
            nodes,
            val_batches,
            log: MetricsLog::default(),
            seq_step: 0,
            next_round: 0,
            ckpt_dir: None,
            started: Instant::now(),
            elapsed_offset: 0.0,
            scratch_mean: vec![0.0; n],
            scratch_pg: vec![0.0; n],
            scratch_agg: AggScratch::new(),
        })
    }

    /// Server-side validation perplexity of the current global model.
    pub fn eval_global(&self) -> Result<(f64, f64)> {
        self.model.eval_nll(&self.global, &self.val_batches)
    }

    /// Execute one federated round (Algorithm 1 L.3–11). Returns the round
    /// record (also appended to `self.log`).
    ///
    /// Sampled clients run through the round engine (`cfg.exec.workers`
    /// concurrent local rounds); updates are folded in sampled order, so
    /// the record stream is bit-identical across worker counts.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        let round = self.next_round;
        let t0 = Instant::now();
        let k = self.cfg.clients_per_round;
        let sampled = self.sampler.sample(round, self.cfg.n_clients, k);
        let faults = self.cfg.faults.for_round(round, &sampled);

        let schedule = self.cfg.schedule;
        let lr_at = move |t: u64| schedule.lr(t);

        // One slot per runnable client, in sampled order — the slot is the
        // deterministic reduction position, independent of which worker
        // finishes first.
        let mut slot_of = vec![usize::MAX; self.cfg.n_clients];
        let mut n_runnable = 0usize;
        for &c in &sampled {
            if !faults.is_dropped(c) {
                slot_of[c] = n_runnable;
                n_runnable += 1;
            }
        }
        let local_steps = self.cfg.local_steps;
        let seq_base = self.seq_step;
        let policy = self.cfg.opt_state;
        let engine = RoundExec::new(self.cfg.exec.workers);
        let model = &self.model;
        let global = &self.global;
        let mut tasks: Vec<ClientTask> = self
            .nodes
            .iter_mut()
            .enumerate()
            .filter(|(c, _)| slot_of[*c] != usize::MAX)
            .map(|(c, node)| ClientTask {
                client_id: c,
                steps: faults.effective_steps(c, local_steps),
                node,
            })
            .collect();
        tasks.sort_by_key(|t| slot_of[t.client_id]);
        let results = engine.run(&mut tasks, |task| {
            task.node
                .run_local_round(model, global, task.steps, seq_base, &lr_at, policy)
                .with_context(|| format!("client {} round {round}", task.client_id))
        });
        drop(tasks);
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(results.len());
        for r in results {
            updates.push(r?);
        }

        // Schedule advances by the nominal τ regardless of faults (the
        // paper's schedule is synchronized across sequential steps).
        self.seq_step += self.cfg.local_steps;
        self.next_round += 1;

        if updates.is_empty() {
            // Every sampled client dropped: global model unchanged. Still a
            // completed round — it must produce its checkpoint file, or a
            // resume would silently replay it (and re-advance the schedule
            // against a stale round counter).
            let (nll, ppl) = self.eval_global()?;
            let rec = RoundRecord {
                round,
                server_ppl: ppl,
                server_nll: nll,
                global_model_norm: l2_norm(&self.global),
                wall_secs: t0.elapsed().as_secs_f64(),
                ..Default::default()
            };
            self.log.push(rec.clone());
            self.write_round_checkpoint()?;
            return Ok(rec);
        }

        // --- Aggregation (L.8–9): one streaming pass over the K client
        // vectors produces the weighted mean, the pseudo-gradient, and the
        // delta Gram matrix (norms + pairwise cosines) with no per-round
        // O(K·N) allocation.
        let rows: Vec<&[f32]> = updates.iter().map(|u| u.params.as_slice()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.n_samples).collect();
        let agg = streaming_aggregate(
            &rows,
            &weights,
            &self.global,
            &mut self.scratch_mean,
            &mut self.scratch_pg,
            &mut self.scratch_agg,
        );
        drop(rows);
        let pseudo_grad_norm = l2_norm(&self.scratch_pg);
        self.outer.step(&mut self.global, &self.scratch_pg);

        // --- Metrics -------------------------------------------------------
        let losses: Vec<f64> = updates.iter().map(|u| u.loss_mean).collect();
        let (loss_mean, loss_std) = mean_std(&losses);
        let (nll, ppl) = self.eval_global()?;
        let rec = RoundRecord {
            round,
            server_ppl: ppl,
            server_nll: nll,
            client_loss_mean: loss_mean,
            client_loss_std: loss_std,
            client_ppl_mean: loss_mean.exp(),
            global_model_norm: l2_norm(&self.global),
            client_model_norm_mean: mean_std(
                &updates.iter().map(|u| u.model_norm).collect::<Vec<_>>(),
            )
            .0,
            client_avg_norm: l2_norm(&self.scratch_mean),
            pseudo_grad_norm,
            step_grad_norm_mean: mean_std(
                &updates.iter().map(|u| u.step_grad_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            applied_update_norm_mean: mean_std(
                &updates
                    .iter()
                    .map(|u| u.applied_update_norm_mean)
                    .collect::<Vec<_>>(),
            )
            .0,
            act_norm_mean: mean_std(
                &updates.iter().map(|u| u.act_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            momentum_norm: self.outer.momentum_norm(),
            client_cosine_mean: mean_pairwise_cosine_from_gram(agg.k, &agg.gram),
            participated: updates.len(),
            comm_bytes: link::round_bytes(self.model.n_params(), updates.len()),
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        self.log.push(rec.clone());
        self.write_round_checkpoint()?;
        Ok(rec)
    }

    /// Drop `ckpt_round_<next_round>.bin` if checkpointing is configured.
    /// Called on every round completion path — including rounds where all
    /// sampled clients dropped — so the checkpoint sequence has no holes.
    fn write_round_checkpoint(&self) -> Result<()> {
        if let Some(dir) = &self.ckpt_dir {
            self.checkpoint()
                .save(&dir.join(format!("ckpt_round_{}.bin", self.next_round)))?;
        }
        Ok(())
    }

    /// Run all configured rounds (resuming from `next_round`).
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        while self.next_round < self.cfg.rounds {
            self.run_round()?;
        }
        Ok(self.log.rounds.clone())
    }

    /// Snapshot the full federation state. Every stream cursor of every
    /// client is captured — multi-island clients have one per island, and
    /// all of them must survive a resume for the fleet to stay
    /// sample-exact.
    pub fn checkpoint(&self) -> Checkpoint {
        let clients = self
            .nodes
            .iter()
            .map(|n| {
                let cursors = n.streams.iter().map(|s| s.cursor()).collect();
                let (m, v, st) = match &n.saved_opt {
                    Some((m, v, st)) => (m.clone(), v.clone(), *st),
                    None => (Vec::new(), Vec::new(), 0),
                };
                Some(ClientCkpt { opt_m: m, opt_v: v, local_step: st, cursors })
            })
            .collect();
        let (t, m, v) = self.outer.state();
        Checkpoint {
            round: self.next_round as u64,
            seq_step: self.seq_step,
            global: self.global.clone(),
            outer_t: t,
            outer_m: m.to_vec(),
            outer_v: v.to_vec(),
            clients,
            timestamp: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            elapsed_secs: self.elapsed_offset + self.started.elapsed().as_secs_f64(),
        }
    }

    /// Restore a federation from a checkpoint (config must match the one
    /// that produced it). Stream cursors, optimizer state, and the global
    /// model resume bit-exactly (integration_ckpt.rs asserts equality).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        if ck.global.len() != self.global.len() {
            bail!(
                "checkpoint model size {} != config model size {}",
                ck.global.len(),
                self.global.len()
            );
        }
        if ck.clients.len() != self.nodes.len() {
            bail!("checkpoint has {} clients, config {}", ck.clients.len(), self.nodes.len());
        }
        // Validate cursor arity before mutating anything so a fleet
        // mismatch cannot leave the federation half-restored.
        for (id, (node, c)) in self.nodes.iter().zip(&ck.clients).enumerate() {
            if let Some(c) = c {
                if c.cursors.len() != node.streams.len() {
                    bail!(
                        "checkpoint client {id} carries {} stream cursors, \
                         config builds {} islands (fleet mismatch?)",
                        c.cursors.len(),
                        node.streams.len()
                    );
                }
            }
        }
        self.global.copy_from_slice(&ck.global);
        self.outer.restore(ck.outer_t, ck.outer_m.clone(), ck.outer_v.clone());
        self.seq_step = ck.seq_step;
        self.next_round = ck.round as usize;
        self.elapsed_offset = ck.elapsed_secs;
        for (node, c) in self.nodes.iter_mut().zip(&ck.clients) {
            if let Some(c) = c {
                for (stream, cur) in node.streams.iter_mut().zip(&c.cursors) {
                    stream.restore(cur);
                }
                node.saved_opt = if c.opt_m.is_empty() {
                    None
                } else {
                    Some((c.opt_m.clone(), c.opt_v.clone(), c.local_step))
                };
            }
        }
        Ok(())
    }

    /// The exact round schedule this federation executes (same sampler
    /// draws, same fault realizations), replayable through the wall-clock
    /// simulator without touching the model runtime.
    pub fn round_plan(&self) -> crate::sim::RoundPlan {
        crate::sim::RoundPlan::from_config(&self.cfg)
    }

    /// Replay this federation's schedule through the event-driven
    /// wall-clock simulator (`sim` module): per-client compute time comes
    /// from the configured fleet (uniform single-A100 clients when no
    /// fleet is set), payload bytes from the loaded model, transfer time
    /// from `link`.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use photon::config::ExperimentConfig;
    /// use photon::coordinator::Federation;
    /// use photon::netsim::BROADBAND;
    /// use photon::sim::AggregationPolicy;
    ///
    /// let fed = Federation::new(ExperimentConfig::quickstart("m75a")).unwrap();
    /// let report = fed.simulate_wallclock(BROADBAND, AggregationPolicy::Sync);
    /// println!("simulated run: {:.1} s over 100 Mbit/s", report.total_secs);
    /// ```
    pub fn simulate_wallclock(
        &self,
        link: crate::netsim::Link,
        policy: crate::sim::AggregationPolicy,
    ) -> crate::sim::SimReport {
        use crate::cluster::hardware::{FleetSpec, A100};
        let n_params = self.model.n_params() as u64;
        let tokens = (self.model.batch_size() * self.model.seq_width()) as u64;
        let uniform;
        let fleet = match &self.cfg.fleet {
            Some(f) => f,
            None => {
                uniform = FleetSpec::uniform(self.cfg.n_clients, A100, 1);
                &uniform
            }
        };
        let profiles =
            crate::sim::fleet_profiles(fleet, n_params, tokens, crate::sim::DEFAULT_MFU);
        let sim_cfg = crate::sim::SimConfig::new(n_params * 4, link, policy);
        crate::sim::Simulator::new(self.round_plan(), profiles, sim_cfg).run()
    }

    /// Resume from the latest checkpoint in `dir` if one exists.
    pub fn try_resume_from(&mut self, dir: &std::path::Path) -> Result<bool> {
        match crate::ckpt::latest_in(dir)? {
            None => Ok(false),
            Some((_, path)) => {
                let ck = Checkpoint::load(&path)?;
                self.restore(&ck)?;
                Ok(true)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn build_data_shapes() {
        let mut cfg = ExperimentConfig::quickstart("m75a");
        cfg.n_clients = 8;
        cfg.corpus = CorpusKind::PileHetero { j: 1 };
        let ds = build_data(&cfg, 64);
        assert_eq!(ds.n_clients(), 8);
        assert_eq!(ds.corpus.categories.len(), 8);
        cfg.corpus = CorpusKind::C4Iid;
        assert_eq!(build_data(&cfg, 64).corpus.categories.len(), 1);
        cfg.corpus = CorpusKind::Mc4 { n_langs: 4 };
        assert_eq!(build_data(&cfg, 64).corpus.categories.len(), 4);
    }
}
