//! The Photon Aggregator: owns the global model, orchestrates rounds
//! (Algorithm 1 L.1–11) through the parallel round engine
//! (`round_exec`), applies the outer optimizer via one-pass streaming
//! aggregation, tracks federated metrics, and checkpoints the full
//! training state. See `coordinator` module docs for the worker-count
//! knob and the cross-worker determinism guarantee.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::ckpt::{Checkpoint, ClientCkpt};
use crate::cluster::island::island_counts;
use crate::compress;
use crate::config::{CorpusKind, ExperimentConfig};
use crate::coordinator::client::{ClientNode, ClientUpdate};
use crate::coordinator::round_exec::{ClientTask, RoundExec};
use crate::coordinator::sampler::ClientSampler;
use crate::data::corpus::SyntheticCorpus;
use crate::data::partition::Partition;
use crate::data::source::DataSource;
use crate::data::stream::TokenStream;
use crate::link;
use crate::metrics::{mean_pairwise_cosine_from_gram, mean_std, MetricsLog, RoundRecord};
use crate::model::init::init_params;
use crate::model::vecmath::{
    l2_norm, streaming_aggregate, streaming_fold, tiered_fold, AggScratch,
};
use crate::obs::{Event as ObsEvent, EventSink};
use crate::optim::outer::OuterOpt;
use crate::runtime::{DispatchPolicy, ModelRuntime, Runtime};

/// A running federation (Aggregator + nodes + data plane).
pub struct Federation {
    pub cfg: ExperimentConfig,
    pub model: Arc<ModelRuntime>,
    pub data: DataSource,
    pub global: Vec<f32>,
    pub outer: OuterOpt,
    sampler: ClientSampler,
    nodes: Vec<ClientNode>,
    val_batches: Vec<Vec<i32>>,
    pub log: MetricsLog,
    /// Cumulative sequential steps (drives the shared LR schedule).
    pub seq_step: u64,
    pub next_round: usize,
    /// Where to drop `ckpt_round_<n>.bin` (None = no checkpointing).
    pub ckpt_dir: Option<PathBuf>,
    /// Optional observability event sink (`obs` plane). Emission is
    /// fire-and-forget and never feeds back into round math; the
    /// deployment plane shares this sink so in-process and TCP runs of
    /// one config produce structurally comparable streams.
    pub obs: Option<EventSink>,
    started: Instant,
    elapsed_offset: f64,
    // Scratch buffers reused across rounds (aggregation hot path).
    scratch_mean: Vec<f32>,
    scratch_pg: Vec<f32>,
    scratch_agg: AggScratch,
}

/// Build the corpus + partition for a corpus kind. Takes the pieces rather
/// than a full [`ExperimentConfig`] so remote workers (`net::worker`), which
/// only receive a task spec over the wire, build the *identical* data plane
/// the Aggregator does.
pub fn build_data(corpus: &CorpusKind, n_clients: usize, seed: u64, vocab: usize) -> DataSource {
    let (corpus, partition) = match corpus {
        CorpusKind::C4Iid => {
            let c = SyntheticCorpus::c4(vocab);
            let p = Partition::iid(&c, n_clients);
            (c, p)
        }
        CorpusKind::PileHetero { j } => {
            let c = SyntheticCorpus::pile(vocab);
            let p = Partition::heterogeneous(&c, n_clients, *j);
            (c, p)
        }
        CorpusKind::Mc4 { n_langs } => {
            let c = SyntheticCorpus::mc4(vocab, *n_langs);
            let p = Partition::heterogeneous(&c, n_clients, 1);
            (c, p)
        }
    };
    DataSource::new(corpus, partition, seed)
}

/// Bind client `c`'s training streams: one per connectivity island, each on
/// a disjoint seed path. Shared by the in-process Aggregator and remote
/// workers — both sides must bind bit-identically for the deployment plane
/// to reproduce `Federation::run` exactly.
pub fn bind_client_streams(
    data: &DataSource,
    client: usize,
    n_islands: usize,
    seq_width: usize,
    seed: u64,
) -> Result<Vec<TokenStream>> {
    (0..n_islands)
        .map(|isl| {
            TokenStream::bind(
                &data.partition.assignment[client],
                &data.corpus.categories,
                seq_width,
                seed ^ ((isl as u64) << 32),
            )
        })
        .collect()
}

/// Contiguous tier partition of `k` round slots into at most `tiers`
/// non-empty groups in slot (= sampled) order, first `k mod g` groups one
/// larger. This is the canonical sub-aggregator assignment: the root
/// server leases `runnable[slice]` to sub-aggregator `i`, and the
/// in-process fold groups the same slices — the partition is *planned*,
/// never emergent from arrival order, which is what keeps the two planes
/// bit-equal (f64 folds are only order-stable under a fixed grouping).
pub fn tier_slices(k: usize, tiers: usize) -> Vec<std::ops::Range<usize>> {
    let g = tiers.max(1).min(k);
    let mut out = Vec::with_capacity(g);
    let base = k / g.max(1);
    let extra = k % g.max(1);
    let mut lo = 0;
    for i in 0..g {
        let len = base + usize::from(i < extra);
        out.push(lo..lo + len);
        lo += len;
    }
    out
}

/// One planned round before execution: who was sampled, who is runnable
/// (with their effective step counts, in sampled order), and who dropped —
/// exactly the realization `run_round` executes and `sim::RoundPlan`
/// replays. The deployment plane (`net::server`) dispatches from this.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundDispatch {
    pub round: usize,
    /// Cumulative sequential steps at round start (LR-schedule base).
    pub seq_base: u64,
    pub sampled: Vec<usize>,
    /// `(client, effective_steps)` in sampled order — the deterministic
    /// reduction order for aggregation.
    pub runnable: Vec<(usize, u64)>,
    pub dropped: Vec<usize>,
}

impl Federation {
    /// Load artifacts and build the federation (compiles the model's HLO —
    /// reuse `with_model` when running several variants of one config).
    pub fn new(cfg: ExperimentConfig) -> Result<Federation> {
        let rt = Runtime::cpu()?;
        let model = Arc::new(rt.load_model(&cfg.model)?);
        Federation::with_model(cfg, model)
    }

    #[allow(clippy::disallowed_methods)] // wall-clock start is reporting-only
    pub fn with_model(cfg: ExperimentConfig, model: Arc<ModelRuntime>) -> Result<Federation> {
        cfg.validate()?;
        // The dispatch policy is per-model process state (the gate lives on
        // the shared ModelRuntime); building a federation resets it, so
        // federations sharing one model must agree on the policy if they
        // ever run rounds concurrently (see ModelRuntime::set_dispatch_policy).
        model.set_dispatch_policy(if cfg.exec.serialize_dispatch {
            DispatchPolicy::Serialized
        } else {
            DispatchPolicy::Concurrent
        });
        if let Some(fleet) = &cfg.fleet {
            anyhow::ensure!(
                fleet.clients.len() == cfg.n_clients,
                "fleet size {} != P {}",
                fleet.clients.len(),
                cfg.n_clients
            );
        }
        let vocab = model.manifest.config.vocab;
        let data = build_data(&cfg.corpus, cfg.n_clients, cfg.seed, vocab);
        let seq_width = model.seq_width();

        // Bind each node's streams; poorly-connected multi-node clients get
        // one stream per island (disjoint sample paths = PartitionStream).
        let islands = island_counts(cfg.fleet.as_ref(), cfg.n_clients);
        let mut nodes = Vec::with_capacity(cfg.n_clients);
        for c in 0..cfg.n_clients {
            let streams = bind_client_streams(&data, c, islands[c], seq_width, cfg.seed)?;
            nodes.push(ClientNode::new(c, streams));
        }

        let global = init_params(&model.manifest, cfg.seed);
        let outer = OuterOpt::new(cfg.outer, cfg.outer_hyper, model.n_params());
        let val_batches =
            data.validation_batches(cfg.eval_batches, model.batch_size(), seq_width)?;
        let n = model.n_params();
        Ok(Federation {
            sampler: ClientSampler::new(cfg.seed),
            cfg,
            model,
            data,
            global,
            outer,
            nodes,
            val_batches,
            log: MetricsLog::default(),
            seq_step: 0,
            next_round: 0,
            ckpt_dir: None,
            obs: None,
            // lint:allow(nondet-time): wall_secs reporting only; parity ignores it
            started: Instant::now(),
            elapsed_offset: 0.0,
            scratch_mean: vec![0.0; n],
            scratch_pg: vec![0.0; n],
            scratch_agg: AggScratch::new(),
        })
    }

    /// Server-side validation perplexity of the current global model.
    pub fn eval_global(&self) -> Result<(f64, f64)> {
        self.model.eval_nll(&self.global, &self.val_batches)
    }

    fn emit(&self, ev: ObsEvent) {
        if let Some(sink) = &self.obs {
            sink.emit(ev);
        }
    }

    /// Run-start marker for the whole-run drivers (`run`, `run_trace`).
    /// In-process runs have no serve session id; the config seed (hex,
    /// like the server's session token) identifies the stream.
    fn emit_run_start(&self) {
        if self.obs.is_none() {
            return;
        }
        self.emit(ObsEvent::ServerStart {
            session: format!("{:#x}", self.cfg.seed),
            rounds: self.cfg.rounds as u64,
            n_clients: self.cfg.n_clients as u64,
            clients_per_round: self.cfg.clients_per_round as u64,
        });
    }

    /// Plan the next round without executing it: replay the sampler and
    /// fault draws exactly as `run_round` will (Algorithm 1 L.3–7). The
    /// deployment plane dispatches remote work from this plan; `sim`'s
    /// `RoundPlan::from_config` is the whole-run analogue.
    pub fn plan_round(&self) -> RoundDispatch {
        let round = self.next_round;
        let sampled =
            self.sampler.sample(round, self.cfg.n_clients, self.cfg.clients_per_round);
        let faults = self.cfg.faults.for_round(round, &sampled);
        let runnable = sampled
            .iter()
            .filter(|c| !faults.is_dropped(**c))
            .map(|&c| (c, faults.effective_steps(c, self.cfg.local_steps)))
            .collect();
        RoundDispatch {
            round,
            seq_base: self.seq_step,
            sampled,
            runnable,
            dropped: faults.dropped,
        }
    }

    /// Execute one federated round (Algorithm 1 L.3–11). Returns the round
    /// record (also appended to `self.log`).
    ///
    /// Sampled clients run through the round engine (`cfg.exec.workers`
    /// concurrent local rounds); updates are folded in sampled order, so
    /// the record stream is bit-identical across worker counts.
    pub fn run_round(&mut self) -> Result<RoundRecord> {
        self.run_round_cut(&[])
    }

    /// Like [`run_round`](Federation::run_round), but additionally treats
    /// the clients in `cut` exactly as dropped: they do not run, their
    /// state does not advance, and they contribute nothing to aggregation.
    /// This is the in-process replay of a deployment-plane deadline cut
    /// (`net::server` cuts stragglers and dead workers through this same
    /// dropped-client path), so a live run with realized cuts is
    /// bit-reproducible here from its cut schedule.
    #[allow(clippy::disallowed_methods)] // round timing is reporting-only
    pub fn run_round_cut(&mut self, cut: &[usize]) -> Result<RoundRecord> {
        // lint:allow(nondet-time): t0 only feeds the wall_secs report column
        let t0 = Instant::now();
        let d = self.plan_round();
        let round = d.round;
        if self.obs.is_some() {
            // In-process runs have no worker slots; lane 0 keeps the
            // stream structurally comparable to a TCP run's.
            for &(c, _) in &d.runnable {
                if !cut.contains(&c) {
                    self.emit(ObsEvent::LeaseGrant {
                        round: round as u64,
                        client: c as u64,
                        worker: 0,
                    });
                }
            }
        }

        let schedule = self.cfg.schedule;
        let lr_at = move |t: u64| schedule.lr(t);

        // One slot per surviving runnable client, in sampled order — the
        // slot is the deterministic reduction position, independent of
        // which worker finishes first.
        let mut slot_of = vec![usize::MAX; self.cfg.n_clients];
        let mut steps_of = vec![0u64; self.cfg.n_clients];
        let mut n_runnable = 0usize;
        for &(c, steps) in &d.runnable {
            if !cut.contains(&c) {
                slot_of[c] = n_runnable;
                steps_of[c] = steps;
                n_runnable += 1;
            }
        }
        let seq_base = d.seq_base;
        let policy = self.cfg.opt_state;
        let engine = RoundExec::new(self.cfg.exec.workers);
        let model = &self.model;
        let global = &self.global;
        let mut tasks: Vec<ClientTask> = self
            .nodes
            .iter_mut()
            .enumerate()
            .filter(|(c, _)| slot_of[*c] != usize::MAX)
            .map(|(c, node)| ClientTask { client_id: c, steps: steps_of[c], node })
            .collect();
        tasks.sort_by_key(|t| slot_of[t.client_id]);
        let results = engine.run(&mut tasks, |task| {
            task.node
                .run_local_round(model, global, task.steps, seq_base, &lr_at, policy)
                .with_context(|| format!("client {} round {round}", task.client_id))
        });
        drop(tasks);
        let mut updates: Vec<ClientUpdate> = Vec::with_capacity(results.len());
        for r in results {
            updates.push(r?);
        }

        // --- Update-codec transit (lossy path only): apply the exact
        // encode→decode transform the deployment plane's wire applies, so
        // the folded parameters — and therefore every record — are
        // bit-identical whether the update crossed a socket or not. The
        // lossless path skips this entirely and stays byte-for-byte the
        // pre-codec behavior.
        if self.cfg.codec.is_lossy() {
            for u in &mut updates {
                let node = &mut self.nodes[u.client_id];
                let seed =
                    compress::transit_seed(self.cfg.seed, round as u64, u.client_id as u64);
                let transit = compress::encode_transit(
                    &self.cfg.codec,
                    &self.global,
                    &u.params,
                    seed,
                    &mut node.residual,
                )?;
                if let Some(body) = &transit.body {
                    u.params = compress::decode_transit(&self.cfg.codec, &self.global, body)?;
                }
                u.wire_bytes = transit.wire_bytes;
            }
        }
        if self.obs.is_some() {
            for u in &updates {
                self.emit(ObsEvent::LeaseFold {
                    round: round as u64,
                    client: u.client_id as u64,
                    worker: 0,
                });
            }
            let mut realized: Vec<u64> = d
                .runnable
                .iter()
                .map(|&(c, _)| c)
                .filter(|c| cut.contains(c))
                .map(|c| c as u64)
                .collect();
            realized.sort_unstable();
            if !realized.is_empty() {
                self.emit(ObsEvent::Cut { round: round as u64, clients: realized });
            }
        }
        self.commit_round(round, updates, t0)
    }

    /// Replay one round of a realized chaos trace (`net::Server::trace`)
    /// in-process. Lease migrations and worker rejoins never touch the
    /// math — *which* worker computes a client's round is invisible to the
    /// fold, since all state travels with the lease — so the replay
    /// reduces to the realized cut schedule: exactly
    /// [`run_round_cut`](Federation::run_round_cut) over `trace.cut`. The
    /// trace's round index is validated so a misaligned replay fails
    /// loudly instead of silently diverging.
    pub fn run_round_trace(&mut self, trace: &crate::chaos::RoundTrace) -> Result<RoundRecord> {
        anyhow::ensure!(
            trace.round == self.next_round,
            "trace names round {}, federation is at round {}",
            trace.round,
            self.next_round
        );
        self.run_round_cut(&trace.cut)
    }

    /// Replay a whole realized chaos trace: every remaining round runs
    /// in-process, applying the trace's cut schedule where the trace has
    /// an entry and running clean otherwise. A chaotic deployment-plane
    /// run (`net::harness::run_loopback` + `FleetReport::trace`) replayed
    /// here reproduces its records and final global model **bit for bit**
    /// — the ISSUE 5 acceptance invariant, exercised by
    /// `tests/integration_chaos.rs` and the `photon exp chaos` sweep.
    pub fn run_trace(&mut self, trace: &crate::chaos::Trace) -> Result<Vec<RoundRecord>> {
        self.emit_run_start();
        while self.next_round < self.cfg.rounds {
            match trace.for_round(self.next_round) {
                Some(t) => self.run_round_trace(t)?,
                None => self.run_round()?,
            };
        }
        self.emit(ObsEvent::Shutdown { rounds: self.next_round as u64 });
        Ok(self.log.rounds.clone())
    }

    /// Replay a realized **asynchronous** run (`net::Server::async_trace`)
    /// in-process, bit for bit — the async analogue of
    /// [`run_trace`](Federation::run_trace) and the keystone of the async
    /// plane's determinism contract. The trace is a pure function of the
    /// realized fleet: which grants were dispatched (with their frozen
    /// `seq_base` and birth epoch), which arrivals each epoch folded (in
    /// canonical ascending-grant order, with realized staleness and
    /// discounted weight), and which grants were cut. Replay is then a
    /// pure function of the trace bytes:
    ///
    /// 1. **Compute phase** — every grant *born* at the current epoch
    ///    that the fleet eventually folded runs now, against exactly the
    ///    global model it was dispatched with (the global only advances
    ///    at fold commits). Cut grants are skipped entirely: their client
    ///    state never advanced on the server. Advancing the node state
    ///    here — possibly epochs before this grant folds — is invisible,
    ///    because per-client serialization (a client stays leased until
    ///    its arrival folds) means no other grant for the same client can
    ///    intervene.
    /// 2. **Fold phase** — the epoch's recorded arrivals are assembled in
    ///    trace order and committed through
    ///    [`commit_async_fold`](Federation::commit_async_fold), which
    ///    re-derives and bitwise-verifies the discounted weights.
    pub fn run_async_trace(
        &mut self,
        trace: &crate::chaos::AsyncTrace,
    ) -> Result<Vec<RoundRecord>> {
        anyhow::ensure!(
            self.cfg.tiers == 1,
            "async replay needs a flat (tiers = 1) config"
        );
        anyhow::ensure!(
            self.next_round == 0,
            "async replay must start from a fresh federation (next_round = {})",
            self.next_round
        );
        trace.check_exactly_once()?;
        self.emit_run_start();
        let folded: std::collections::BTreeSet<u64> = trace
            .folds
            .iter()
            .flat_map(|f| f.arrivals.iter().map(|a| a.grant))
            .collect();
        let schedule = self.cfg.schedule;
        let lr_at = move |t: u64| schedule.lr(t);
        let policy = self.cfg.opt_state;
        let mut stash: std::collections::BTreeMap<u64, ClientUpdate> =
            std::collections::BTreeMap::new();
        for fold in &trace.folds {
            let epoch = fold.epoch;
            anyhow::ensure!(
                epoch == self.next_round as u64,
                "trace fold names epoch {epoch}, federation is at epoch {}",
                self.next_round
            );
            // lint:allow(nondet-time): t0 only feeds the wall_secs column
            #[allow(clippy::disallowed_methods)]
            let t0 = Instant::now();
            for g in trace.grants.iter().filter(|g| g.born_epoch == epoch) {
                if !folded.contains(&g.grant) {
                    continue;
                }
                self.emit(ObsEvent::LeaseGrant {
                    round: g.grant,
                    client: g.client as u64,
                    worker: 0,
                });
                let node = &mut self.nodes[g.client];
                let mut update = node
                    .run_local_round(
                        &self.model,
                        &self.global,
                        g.steps,
                        g.seq_base,
                        &lr_at,
                        policy,
                    )
                    .with_context(|| {
                        format!("client {} grant {} (async replay)", g.client, g.grant)
                    })?;
                if self.cfg.codec.is_lossy() {
                    // The wire keys transit noise by the grant id (the v5
                    // `round` field carries it), never the epoch.
                    let seed =
                        compress::transit_seed(self.cfg.seed, g.grant, g.client as u64);
                    let transit = compress::encode_transit(
                        &self.cfg.codec,
                        &self.global,
                        &update.params,
                        seed,
                        &mut node.residual,
                    )?;
                    if let Some(body) = &transit.body {
                        update.params =
                            compress::decode_transit(&self.cfg.codec, &self.global, body)?;
                    }
                    update.wire_bytes = transit.wire_bytes;
                }
                stash.insert(g.grant, update);
            }
            let mut updates = Vec::with_capacity(fold.arrivals.len());
            let mut staleness = Vec::with_capacity(fold.arrivals.len());
            let mut weights = Vec::with_capacity(fold.arrivals.len());
            for a in &fold.arrivals {
                let u = stash.remove(&a.grant).with_context(|| {
                    format!("fold {epoch} names grant {} with no computed update", a.grant)
                })?;
                anyhow::ensure!(
                    u.client_id == a.client,
                    "grant {} computed client {}, trace says client {}",
                    a.grant,
                    u.client_id,
                    a.client
                );
                self.emit(ObsEvent::LeaseFold {
                    round: a.grant,
                    client: a.client as u64,
                    worker: 0,
                });
                updates.push(u);
                staleness.push(a.staleness);
                weights.push(a.weight);
            }
            self.emit(ObsEvent::AsyncFold {
                epoch,
                k: fold.arrivals.len() as u64,
                clients: fold.arrivals.iter().map(|a| a.client as u64).collect(),
                staleness_max: fold
                    .arrivals
                    .iter()
                    .map(|a| a.staleness)
                    .max()
                    .unwrap_or(0),
            });
            self.commit_async_fold(
                epoch as usize,
                updates,
                &staleness,
                &weights,
                trace.gamma,
                t0,
            )?;
        }
        self.emit(ObsEvent::Shutdown { rounds: self.next_round as u64 });
        Ok(self.log.rounds.clone())
    }

    /// Fold a round's client updates into the global model (Algorithm 1
    /// L.8–11): streaming aggregation, outer-optimizer step, metrics
    /// record, checkpoint. `updates` must be in sampled order and `round`
    /// must be the current `next_round` — both the in-process path
    /// (`run_round`) and the deployment plane (`net::server`) commit
    /// through here, which is what makes their record streams comparable
    /// bit-for-bit. When a lossy codec is active, the caller has already
    /// decoded each update back to dense params (decode-then-fold) and
    /// stamped `ClientUpdate::wire_bytes` with its framed transit size;
    /// updates with `wire_bytes == 0` are accounted at the dense frame
    /// size, so the `codec = none` path needs no transit pass.
    pub fn commit_round(
        &mut self,
        round: usize,
        updates: Vec<ClientUpdate>,
        t0: Instant,
    ) -> Result<RoundRecord> {
        anyhow::ensure!(
            round == self.next_round,
            "commit_round({round}) out of order: federation is at round {}",
            self.next_round
        );
        // The tier grouping is a function of this round's *plan*, so it
        // must be derived before the round counter advances below.
        let tier_groups = if self.cfg.tiers > 1 && !updates.is_empty() {
            Some(self.commit_groups(&updates)?)
        } else {
            None
        };
        // Schedule advances by the nominal τ regardless of faults (the
        // paper's schedule is synchronized across sequential steps).
        self.seq_step += self.cfg.local_steps;
        self.next_round += 1;

        if updates.is_empty() {
            // Every sampled client dropped: global model unchanged. Still a
            // completed round — it must produce its checkpoint file, or a
            // resume would silently replay it (and re-advance the schedule
            // against a stale round counter).
            let (nll, ppl) = self.eval_global()?;
            let rec = RoundRecord {
                round,
                server_ppl: ppl,
                server_nll: nll,
                global_model_norm: l2_norm(&self.global),
                wall_secs: t0.elapsed().as_secs_f64(),
                ..Default::default()
            };
            self.emit_commit(&rec);
            self.log.push(rec.clone());
            self.write_round_checkpoint()?;
            return Ok(rec);
        }

        // --- Aggregation (L.8–9): one streaming pass over the K client
        // vectors produces the weighted mean, the pseudo-gradient, and the
        // delta Gram matrix (norms + pairwise cosines) with no per-round
        // O(K·N) allocation. With `cfg.tiers > 1` the fold is instead the
        // group-structured `tiered_fold` over the planned tier partition —
        // the identical computation a deployed aggregation tree performs
        // (sub-aggregators fold their slice, the root folds the carried
        // `(weight, mean)` pairs) — and is Gram-free: pairwise cosines
        // would need every full client row at the root, defeating the
        // tree, so both planes record `client_cosine_mean = 0.0`.
        let rows: Vec<&[f32]> = updates.iter().map(|u| u.params.as_slice()).collect();
        let weights: Vec<f64> = updates.iter().map(|u| u.n_samples).collect();
        let client_cosine_mean;
        if let Some(groups) = &tier_groups {
            tiered_fold(
                &rows,
                &weights,
                groups,
                &self.global,
                &mut self.scratch_mean,
                &mut self.scratch_pg,
                &mut self.scratch_agg,
            );
            client_cosine_mean = 0.0;
        } else {
            let agg = streaming_aggregate(
                &rows,
                &weights,
                &self.global,
                &mut self.scratch_mean,
                &mut self.scratch_pg,
                &mut self.scratch_agg,
            );
            client_cosine_mean = mean_pairwise_cosine_from_gram(agg.k, &agg.gram);
        }
        drop(rows);
        let pseudo_grad_norm = l2_norm(&self.scratch_pg);
        self.outer.step(&mut self.global, &self.scratch_pg);

        // --- Metrics -------------------------------------------------------
        let losses: Vec<f64> = updates.iter().map(|u| u.loss_mean).collect();
        let (loss_mean, loss_std) = mean_std(&losses);
        let (nll, ppl) = self.eval_global()?;
        let rec = RoundRecord {
            round,
            server_ppl: ppl,
            server_nll: nll,
            client_loss_mean: loss_mean,
            client_loss_std: loss_std,
            client_ppl_mean: loss_mean.exp(),
            global_model_norm: l2_norm(&self.global),
            client_model_norm_mean: mean_std(
                &updates.iter().map(|u| u.model_norm).collect::<Vec<_>>(),
            )
            .0,
            client_avg_norm: l2_norm(&self.scratch_mean),
            pseudo_grad_norm,
            step_grad_norm_mean: mean_std(
                &updates.iter().map(|u| u.step_grad_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            applied_update_norm_mean: mean_std(
                &updates
                    .iter()
                    .map(|u| u.applied_update_norm_mean)
                    .collect::<Vec<_>>(),
            )
            .0,
            act_norm_mean: mean_std(
                &updates.iter().map(|u| u.act_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            momentum_norm: self.outer.momentum_norm(),
            client_cosine_mean,
            participated: updates.len(),
            comm_bytes: link::round_bytes(self.model.n_params(), updates.len()),
            comm_bytes_wire: {
                // Actual framed transit bytes: one dense broadcast down per
                // participating client plus each update's measured size up.
                // Deterministic and computed identically by the deployment
                // plane, so it survives the bit-parity check.
                let dense_frame = link::dense_frame_bytes(self.model.n_params());
                let up: u64 = updates
                    .iter()
                    .map(|u| if u.wire_bytes > 0 { u.wire_bytes } else { dense_frame })
                    .sum();
                updates.len() as u64 * dense_frame + up
            },
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        self.emit_commit(&rec);
        self.log.push(rec.clone());
        self.write_round_checkpoint()?;
        Ok(rec)
    }

    /// Derive the tier grouping over the *arrived* updates: partition the
    /// planned runnable list (sampled order) into `cfg.tiers` contiguous
    /// slices via [`tier_slices`], then keep each update in its planned
    /// group. Cuts shrink a group — they never re-balance the partition —
    /// so a deployed tree (which leased the planned slices to its
    /// sub-aggregators before anyone crashed) and this in-process fold
    /// group identically and stay bit-equal.
    fn commit_groups(&self, updates: &[ClientUpdate]) -> Result<Vec<std::ops::Range<usize>>> {
        let d = self.plan_round();
        let mut group_of = vec![usize::MAX; self.cfg.n_clients];
        for (gid, slice) in tier_slices(d.runnable.len(), self.cfg.tiers).iter().enumerate() {
            for &(c, _) in &d.runnable[slice.clone()] {
                group_of[c] = gid;
            }
        }
        let mut groups: Vec<std::ops::Range<usize>> = Vec::new();
        let mut current: Option<usize> = None;
        for (i, u) in updates.iter().enumerate() {
            let gid = group_of.get(u.client_id).copied().unwrap_or(usize::MAX);
            anyhow::ensure!(
                gid != usize::MAX,
                "update from client {} outside the round plan",
                u.client_id
            );
            if current == Some(gid) {
                if let Some(last) = groups.last_mut() {
                    last.end = i + 1;
                }
            } else {
                anyhow::ensure!(
                    current.map_or(true, |c| gid > c),
                    "updates out of sampled order at client {}",
                    u.client_id
                );
                groups.push(i..i + 1);
                current = Some(gid);
            }
        }
        Ok(groups)
    }

    /// Commit a round from **pre-folded** tier pushes: the deployment
    /// plane's aggregation tree calls this where the flat server calls
    /// [`Self::commit_round`]. `updates` are the member metric rows
    /// (params empty — their pseudo-gradients only ever existed inside
    /// the sub-aggregators' folds) in sampled order; `folded` is one
    /// `(weight, mean)` pair per tier group in group order, exactly what
    /// each `FoldedPush` carried.
    ///
    /// Bit-parity contract with the in-process tiered fold: each group's
    /// `weight` must be the *sequential* sum of its members' `n_samples`
    /// in sampled order and its `mean` the `weighted_mean_into` of their
    /// rows in that order — both are re-derivable from the round plan, so
    /// the weight carry is verified here (bitwise) before anything folds.
    pub fn commit_round_folded(
        &mut self,
        round: usize,
        updates: Vec<ClientUpdate>,
        folded: Vec<(f64, Vec<f32>)>,
        t0: Instant,
    ) -> Result<RoundRecord> {
        anyhow::ensure!(
            round == self.next_round,
            "commit_round_folded({round}) out of order: federation is at round {}",
            self.next_round
        );
        anyhow::ensure!(
            self.cfg.tiers > 1,
            "commit_round_folded needs a tiered config (cfg.tiers > 1)"
        );
        if updates.is_empty() {
            anyhow::ensure!(
                folded.is_empty(),
                "folded groups without member updates"
            );
            // Delegate: the all-dropped path is fold-free and identical.
            return self.commit_round(round, updates, t0);
        }
        // Structural + weight-carry verification against this round's plan
        // (before the counter advances, like commit_round's tier_groups).
        let groups = self.commit_groups(&updates)?;
        anyhow::ensure!(
            folded.len() == groups.len(),
            "{} folded groups for {} planned (non-empty) tier groups",
            folded.len(),
            groups.len()
        );
        for (g, (w, mean)) in groups.iter().zip(&folded) {
            let want: f64 = updates[g.clone()].iter().map(|u| u.n_samples).sum();
            anyhow::ensure!(
                w.to_bits() == want.to_bits(),
                "folded group weight {w} != sequential member-weight sum {want}"
            );
            anyhow::ensure!(
                mean.len() == self.global.len(),
                "folded mean has {} params, model has {}",
                mean.len(),
                self.global.len()
            );
        }
        self.seq_step += self.cfg.local_steps;
        self.next_round += 1;

        // Second-stage fold: group means as rows with carried weights —
        // the same `streaming_fold` call `tiered_fold` ends with, so the
        // tree root and the in-process tiered commit are bit-identical.
        let mean_rows: Vec<&[f32]> = folded.iter().map(|(_, m)| m.as_slice()).collect();
        let group_weights: Vec<f64> = folded.iter().map(|(w, _)| *w).collect();
        streaming_fold(
            &mean_rows,
            &group_weights,
            &self.global,
            &mut self.scratch_mean,
            &mut self.scratch_pg,
            &mut self.scratch_agg,
        );
        drop(mean_rows);
        let pseudo_grad_norm = l2_norm(&self.scratch_pg);
        self.outer.step(&mut self.global, &self.scratch_pg);

        let losses: Vec<f64> = updates.iter().map(|u| u.loss_mean).collect();
        let (loss_mean, loss_std) = mean_std(&losses);
        let (nll, ppl) = self.eval_global()?;
        let rec = RoundRecord {
            round,
            server_ppl: ppl,
            server_nll: nll,
            client_loss_mean: loss_mean,
            client_loss_std: loss_std,
            client_ppl_mean: loss_mean.exp(),
            global_model_norm: l2_norm(&self.global),
            client_model_norm_mean: mean_std(
                &updates.iter().map(|u| u.model_norm).collect::<Vec<_>>(),
            )
            .0,
            client_avg_norm: l2_norm(&self.scratch_mean),
            pseudo_grad_norm,
            step_grad_norm_mean: mean_std(
                &updates.iter().map(|u| u.step_grad_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            applied_update_norm_mean: mean_std(
                &updates
                    .iter()
                    .map(|u| u.applied_update_norm_mean)
                    .collect::<Vec<_>>(),
            )
            .0,
            act_norm_mean: mean_std(
                &updates.iter().map(|u| u.act_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            momentum_norm: self.outer.momentum_norm(),
            // The tree fold is Gram-free on both planes (see commit_round).
            client_cosine_mean: 0.0,
            participated: updates.len(),
            comm_bytes: link::round_bytes(self.model.n_params(), updates.len()),
            comm_bytes_wire: {
                // Same flat accounting as commit_round: the tree changes
                // who folds, not what the federation's transit metric
                // means. Member `wire_bytes` carry the subagg-measured
                // worker→subagg leg.
                let dense_frame = link::dense_frame_bytes(self.model.n_params());
                let up: u64 = updates
                    .iter()
                    .map(|u| if u.wire_bytes > 0 { u.wire_bytes } else { dense_frame })
                    .sum();
                updates.len() as u64 * dense_frame + up
            },
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        self.emit_commit(&rec);
        self.log.push(rec.clone());
        self.write_round_checkpoint()?;
        Ok(rec)
    }

    /// Fold one **asynchronous epoch** into the global model: the async
    /// analogue of [`Self::commit_round`]. `updates` are the K buffered
    /// arrivals in canonical (ascending grant id) order; `staleness[i]`
    /// counts how many epochs arrival `i`'s dispatch model lags this
    /// commit; `weights` are the staleness-discounted fold weights the
    /// server realized (`w_i · γ^staleness`, normalized to sum 1). Like
    /// the tree plane's weight carry ([`Self::commit_round_folded`]),
    /// the weights are **re-derived** here from `n_samples`, `staleness`
    /// and `gamma` ([`crate::chaos::discounted_weights`]) and verified
    /// bitwise before anything folds — a server whose discounting drifts
    /// from the replay's fails loudly at commit, not silently at the
    /// parity check.
    ///
    /// An async epoch is a full schedule round: the LR clock advances by
    /// the nominal τ and the epoch counter by one, exactly as
    /// `commit_round` does — the async plane changes *which* updates fold
    /// and *how they are weighted*, never the outer-step bookkeeping.
    pub fn commit_async_fold(
        &mut self,
        epoch: usize,
        updates: Vec<ClientUpdate>,
        staleness: &[u64],
        weights: &[f64],
        gamma: f64,
        t0: Instant,
    ) -> Result<RoundRecord> {
        anyhow::ensure!(
            epoch == self.next_round,
            "commit_async_fold({epoch}) out of order: federation is at epoch {}",
            self.next_round
        );
        anyhow::ensure!(
            self.cfg.tiers == 1,
            "async folds need a flat (tiers = 1) config"
        );
        anyhow::ensure!(!updates.is_empty(), "async fold with no arrivals");
        anyhow::ensure!(
            updates.len() == staleness.len() && updates.len() == weights.len(),
            "{} updates, {} staleness entries, {} weights",
            updates.len(),
            staleness.len(),
            weights.len()
        );
        let base: Vec<f64> = updates.iter().map(|u| u.n_samples).collect();
        let want = crate::chaos::discounted_weights(&base, staleness, gamma);
        for (i, (w, want)) in weights.iter().zip(&want).enumerate() {
            anyhow::ensure!(
                w.to_bits() == want.to_bits(),
                "arrival {i}: carried discounted weight {w} != re-derived {want}"
            );
        }
        self.seq_step += self.cfg.local_steps;
        self.next_round += 1;

        // Same one-pass fold as the sync plane; the discounted weights are
        // already normalized, which `streaming_aggregate`'s internal
        // normalization leaves untouched up to the identical sequential
        // weight-sum division both planes perform.
        let rows: Vec<&[f32]> = updates.iter().map(|u| u.params.as_slice()).collect();
        let agg = streaming_aggregate(
            &rows,
            weights,
            &self.global,
            &mut self.scratch_mean,
            &mut self.scratch_pg,
            &mut self.scratch_agg,
        );
        let client_cosine_mean = mean_pairwise_cosine_from_gram(agg.k, &agg.gram);
        drop(rows);
        let pseudo_grad_norm = l2_norm(&self.scratch_pg);
        self.outer.step(&mut self.global, &self.scratch_pg);

        let losses: Vec<f64> = updates.iter().map(|u| u.loss_mean).collect();
        let (loss_mean, loss_std) = mean_std(&losses);
        let (nll, ppl) = self.eval_global()?;
        let rec = RoundRecord {
            round: epoch,
            server_ppl: ppl,
            server_nll: nll,
            client_loss_mean: loss_mean,
            client_loss_std: loss_std,
            client_ppl_mean: loss_mean.exp(),
            global_model_norm: l2_norm(&self.global),
            client_model_norm_mean: mean_std(
                &updates.iter().map(|u| u.model_norm).collect::<Vec<_>>(),
            )
            .0,
            client_avg_norm: l2_norm(&self.scratch_mean),
            pseudo_grad_norm,
            step_grad_norm_mean: mean_std(
                &updates.iter().map(|u| u.step_grad_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            applied_update_norm_mean: mean_std(
                &updates
                    .iter()
                    .map(|u| u.applied_update_norm_mean)
                    .collect::<Vec<_>>(),
            )
            .0,
            act_norm_mean: mean_std(
                &updates.iter().map(|u| u.act_norm_mean).collect::<Vec<_>>(),
            )
            .0,
            momentum_norm: self.outer.momentum_norm(),
            client_cosine_mean,
            participated: updates.len(),
            comm_bytes: link::round_bytes(self.model.n_params(), updates.len()),
            comm_bytes_wire: {
                // Same flat accounting as commit_round: one dense
                // broadcast down per folded arrival plus its measured
                // upload size.
                let dense_frame = link::dense_frame_bytes(self.model.n_params());
                let up: u64 = updates
                    .iter()
                    .map(|u| if u.wire_bytes > 0 { u.wire_bytes } else { dense_frame })
                    .sum();
                updates.len() as u64 * dense_frame + up
            },
            wall_secs: t0.elapsed().as_secs_f64(),
        };
        self.emit_commit(&rec);
        self.log.push(rec.clone());
        self.write_round_checkpoint()?;
        Ok(rec)
    }

    /// The one `RoundCommit` emission site — every commit path (clean,
    /// cut, all-dropped; in-process or deployment plane) funnels through
    /// `commit_round`, so TCP and in-process streams agree here.
    fn emit_commit(&self, rec: &RoundRecord) {
        self.emit(ObsEvent::RoundCommit {
            round: rec.round as u64,
            participated: rec.participated as u64,
            nll: rec.server_nll,
            comm_bytes_wire: rec.comm_bytes_wire,
            wall_us: (rec.wall_secs * 1e6) as u64,
        });
    }

    /// Drop `ckpt_round_<next_round>.bin` if checkpointing is configured.
    /// Called on every round completion path — including rounds where all
    /// sampled clients dropped — so the checkpoint sequence has no holes.
    fn write_round_checkpoint(&self) -> Result<()> {
        if let Some(dir) = &self.ckpt_dir {
            self.checkpoint()
                .save(&dir.join(format!("ckpt_round_{}.bin", self.next_round)))?;
        }
        Ok(())
    }

    /// Run all configured rounds (resuming from `next_round`).
    pub fn run(&mut self) -> Result<Vec<RoundRecord>> {
        self.emit_run_start();
        while self.next_round < self.cfg.rounds {
            self.run_round()?;
        }
        self.emit(ObsEvent::Shutdown { rounds: self.next_round as u64 });
        Ok(self.log.rounds.clone())
    }

    /// One client's full inter-round state (stream cursors + KeepOpt
    /// moments) in checkpoint form — the unit of state the deployment
    /// plane ships to stateless workers each round and takes back with
    /// their updates.
    pub fn client_state(&self, client: usize) -> ClientCkpt {
        self.nodes[client].state()
    }

    /// Validate a client state against this federation's structure without
    /// mutating anything — the deployment plane runs this on every arriving
    /// update so a malformed push can be cut instead of poisoning a commit.
    pub fn check_client_state(&self, client: usize, st: &ClientCkpt) -> Result<()> {
        anyhow::ensure!(client < self.nodes.len(), "client {client} out of range");
        anyhow::ensure!(
            st.residual.is_empty() || st.residual.len() == self.global.len(),
            "client {client} state carries a {}-element codec residual, model has {} \
             params",
            st.residual.len(),
            self.global.len()
        );
        self.nodes[client].check_state(st)
    }

    /// Install a client state returned by a worker (or a checkpoint
    /// fragment). Validates structure before mutating; a cut or crashed
    /// worker simply never gets here, leaving the client at its pre-round
    /// state — exactly the dropped-client semantics.
    pub fn restore_client_state(&mut self, client: usize, st: &ClientCkpt) -> Result<()> {
        anyhow::ensure!(client < self.nodes.len(), "client {client} out of range");
        self.nodes[client].restore_state(st)
    }

    /// Snapshot the full federation state. Every stream cursor of every
    /// client is captured — multi-island clients have one per island, and
    /// all of them must survive a resume for the fleet to stay
    /// sample-exact.
    #[allow(clippy::disallowed_methods)] // checkpoint timestamp is metadata
    pub fn checkpoint(&self) -> Checkpoint {
        let clients = self.nodes.iter().map(|n| Some(n.state())).collect();
        let (t, m, v) = self.outer.state();
        Checkpoint {
            round: self.next_round as u64,
            seq_step: self.seq_step,
            global: self.global.clone(),
            outer_t: t,
            outer_m: m.to_vec(),
            outer_v: v.to_vec(),
            clients,
            // lint:allow(nondet-time): checkpoint timestamp is metadata; resume never reads it
            timestamp: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            elapsed_secs: self.elapsed_offset + self.started.elapsed().as_secs_f64(),
        }
    }

    /// Restore a federation from a checkpoint (config must match the one
    /// that produced it). Stream cursors, optimizer state, and the global
    /// model resume bit-exactly (integration_ckpt.rs asserts equality).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        if ck.global.len() != self.global.len() {
            bail!(
                "checkpoint model size {} != config model size {}",
                ck.global.len(),
                self.global.len()
            );
        }
        if ck.clients.len() != self.nodes.len() {
            bail!("checkpoint has {} clients, config {}", ck.clients.len(), self.nodes.len());
        }
        // Validate cursor arity before mutating anything so a fleet
        // mismatch cannot leave the federation half-restored.
        for (node, c) in self.nodes.iter().zip(&ck.clients) {
            if let Some(c) = c {
                node.check_state(c).context("checkpoint does not fit this config")?;
            }
        }
        self.global.copy_from_slice(&ck.global);
        self.outer.restore(ck.outer_t, ck.outer_m.clone(), ck.outer_v.clone());
        self.seq_step = ck.seq_step;
        self.next_round = ck.round as usize;
        self.elapsed_offset = ck.elapsed_secs;
        for (node, c) in self.nodes.iter_mut().zip(&ck.clients) {
            if let Some(c) = c {
                node.restore_state(c)?;
            }
        }
        Ok(())
    }

    /// The exact round schedule this federation executes (same sampler
    /// draws, same fault realizations), replayable through the wall-clock
    /// simulator without touching the model runtime.
    pub fn round_plan(&self) -> crate::sim::RoundPlan {
        crate::sim::RoundPlan::from_config(&self.cfg)
    }

    /// Replay this federation's schedule through the event-driven
    /// wall-clock simulator (`sim` module): per-client compute time comes
    /// from the configured fleet (uniform single-A100 clients when no
    /// fleet is set), payload bytes from the loaded model, transfer time
    /// from `link`. Upload payloads are priced from the update codec's
    /// **actual encoded size** (`UpdateCodec::encoded_body_bytes`, exact
    /// for the quantizing/sparsifying codecs) rather than the dense
    /// estimate, so a `q8` federation simulates with `q8` wire bytes.
    ///
    /// # Example
    ///
    /// ```no_run
    /// use photon::config::ExperimentConfig;
    /// use photon::coordinator::Federation;
    /// use photon::netsim::BROADBAND;
    /// use photon::sim::AggregationPolicy;
    ///
    /// let fed = Federation::new(ExperimentConfig::quickstart("m75a")).unwrap();
    /// let report = fed.simulate_wallclock(BROADBAND, AggregationPolicy::Sync);
    /// println!("simulated run: {:.1} s over 100 Mbit/s", report.total_secs);
    /// ```
    pub fn simulate_wallclock(
        &self,
        link: crate::netsim::Link,
        policy: crate::sim::AggregationPolicy,
    ) -> crate::sim::SimReport {
        use crate::cluster::hardware::{FleetSpec, A100};
        let n_params = self.model.n_params() as u64;
        let tokens = (self.model.batch_size() * self.model.seq_width()) as u64;
        let uniform;
        let fleet = match &self.cfg.fleet {
            Some(f) => f,
            None => {
                uniform = FleetSpec::uniform(self.cfg.n_clients, A100, 1);
                &uniform
            }
        };
        let profiles =
            crate::sim::fleet_profiles(fleet, n_params, tokens, crate::sim::DEFAULT_MFU);
        let mut sim_cfg = crate::sim::SimConfig::asymmetric(
            n_params * 4,
            self.cfg.codec.encoded_body_bytes(n_params as usize),
            link,
            policy,
        );
        if self.cfg.tiers > 1 {
            // Tree topology: price the sub-aggregator → root hop. Folded
            // means are always dense (never re-coded), one per tier group.
            sim_cfg = sim_cfg
                .with_tiers(self.cfg.tiers, link::dense_frame_bytes(n_params as usize));
        }
        crate::sim::Simulator::new(self.round_plan(), profiles, sim_cfg).run()
    }

    /// Resume from the latest checkpoint in `dir` if one exists.
    pub fn try_resume_from(&mut self, dir: &std::path::Path) -> Result<bool> {
        match crate::ckpt::latest_in(dir)? {
            None => Ok(false),
            Some((_, path)) => {
                let ck = Checkpoint::load(&path)?;
                self.restore(&ck)?;
                Ok(true)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn build_data_shapes() {
        let ds = build_data(&CorpusKind::PileHetero { j: 1 }, 8, 42, 64);
        assert_eq!(ds.n_clients(), 8);
        assert_eq!(ds.corpus.categories.len(), 8);
        assert_eq!(build_data(&CorpusKind::C4Iid, 8, 42, 64).corpus.categories.len(), 1);
        assert_eq!(
            build_data(&CorpusKind::Mc4 { n_langs: 4 }, 8, 42, 64).corpus.categories.len(),
            4
        );
    }

    #[test]
    fn bind_client_streams_is_deterministic_and_island_aware() {
        let ds = build_data(&CorpusKind::PileHetero { j: 2 }, 4, 7, 64);
        let a = bind_client_streams(&ds, 0, 2, 9, 7).unwrap();
        let mut b = bind_client_streams(&ds, 0, 2, 9, 7).unwrap();
        assert_eq!(a.len(), 2);
        // Same binding → same cursors; islands differ from each other.
        assert_eq!(a[0].cursor(), b[0].cursor());
        assert_eq!(a[1].cursor(), b[1].cursor());
        let first_island = b[0].next_batch(2);
        let second_island = b[1].next_batch(2);
        assert_ne!(first_island, second_island);
    }
}
