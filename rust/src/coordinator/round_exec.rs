//! The round execution engine: runs the sampled clients' local rounds on a
//! worker pool (Photon runs many LLM Nodes concurrently; the paper's
//! Aggregator only ever sees completed updates).
//!
//! ## Determinism guarantee
//!
//! `RoundExec::run` is a *deterministic parallel map over mutable tasks*:
//! given tasks whose work function depends only on the task's own state
//! (each client owns its streams, RNGs, and optimizer moments), the result
//! vector and the final task states are bit-identical for every worker
//! count, including the sequential `workers = 1` path. Two mechanisms make
//! this hold:
//!
//! * results are written into the slot matching the task's input position,
//!   so downstream reduction (FedAvg weighted mean, metrics) always folds
//!   updates in sampled order regardless of completion order;
//! * tasks are handed to workers whole — a task never migrates mid-run, so
//!   its mutations happen on one thread with no interleaving.
//!
//! Shared-model access is governed separately by
//! `runtime::DispatchPolicy`: under the default `Serialized` policy the XLA
//! dispatch is mutex-gated while host-side batch assembly, literal
//! construction, and aggregation still overlap across workers.
//!
//! The worker count comes from `config::ExecConfig::workers`
//! (`--workers N|auto` on the CLI); `0` means one worker per available CPU,
//! capped at the number of runnable tasks. `rust/tests/props.rs` holds the
//! parallel-vs-sequential bit-exactness property test, and `bench_round`
//! tracks the speedup at K ≥ 8.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::coordinator::client::ClientNode;

/// One sampled client's work order for a round, in sampled order.
pub struct ClientTask<'a> {
    pub client_id: usize,
    /// Effective local steps after fault injection (stragglers run fewer).
    pub steps: u64,
    pub node: &'a mut ClientNode,
}

/// Worker-pool executor for one federated round (or any per-task
/// deterministic map).
pub struct RoundExec {
    workers: usize,
}

impl RoundExec {
    /// `workers = 0` means auto (available parallelism).
    pub fn new(workers: usize) -> RoundExec {
        RoundExec { workers }
    }

    /// Worker threads that will actually run for `n_tasks` runnable tasks.
    pub fn effective_workers(&self, n_tasks: usize) -> usize {
        let w = if self.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.workers
        };
        w.min(n_tasks).max(1)
    }

    /// Run `f` over every task, returning results in task order. With one
    /// effective worker this is a plain in-order loop; with more, tasks are
    /// claimed from a shared queue in task order and executed concurrently.
    /// `f` must depend only on the task it is given (plus immutable shared
    /// state) — that is what makes the parallel schedule bit-exact with the
    /// sequential one.
    pub fn run<T, R, F>(&self, tasks: &mut [T], f: F) -> Vec<Result<R>>
    where
        T: Send,
        R: Send,
        F: Fn(&mut T) -> Result<R> + Sync,
    {
        let n = tasks.len();
        let w = self.effective_workers(n);
        if w <= 1 {
            return tasks.iter_mut().map(|t| f(t)).collect();
        }

        // Slot-addressed handout: workers claim the next unclaimed task by
        // index and write its result into the matching slot.
        let queue: Vec<Mutex<Option<&mut T>>> =
            tasks.iter_mut().map(|t| Mutex::new(Some(t))).collect();
        let slots: Vec<Mutex<Option<Result<R>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..w {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task = queue[i]
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .take()
                        .expect("task claimed twice");
                    let r = f(task);
                    *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .unwrap_or_else(|p| p.into_inner())
                    .expect("worker exited without reporting a result")
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_and_parallel_agree_in_order() {
        let run = |workers: usize| {
            let mut tasks: Vec<u64> = (0..17).collect();
            let results: Vec<u64> = RoundExec::new(workers)
                .run(&mut tasks, |t| {
                    *t = t.wrapping_mul(0x9E3779B97F4A7C15);
                    Ok(*t >> 7)
                })
                .into_iter()
                .map(|r| r.unwrap())
                .collect();
            (tasks, results)
        };
        let (t1, r1) = run(1);
        for workers in [2, 3, 8, 0] {
            let (tw, rw) = run(workers);
            assert_eq!(t1, tw, "task states must match at workers={workers}");
            assert_eq!(r1, rw, "results must match at workers={workers}");
        }
    }

    #[test]
    fn errors_stay_in_their_slot() {
        let mut tasks: Vec<usize> = (0..6).collect();
        let results = RoundExec::new(3).run(&mut tasks, |t| {
            if *t % 2 == 1 {
                anyhow::bail!("odd task {t}")
            }
            Ok(*t)
        });
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.is_err(), i % 2 == 1, "slot {i}");
        }
    }

    #[test]
    fn empty_and_single_task() {
        let mut none: Vec<u32> = Vec::new();
        assert!(RoundExec::new(4).run(&mut none, |_| Ok(())).is_empty());
        let mut one = vec![5u32];
        let r = RoundExec::new(4).run(&mut one, |t| Ok(*t * 2));
        assert_eq!(r.into_iter().next().unwrap().unwrap(), 10);
    }

    #[test]
    fn effective_workers_clamps() {
        assert_eq!(RoundExec::new(8).effective_workers(3), 3);
        assert_eq!(RoundExec::new(2).effective_workers(10), 2);
        assert_eq!(RoundExec::new(5).effective_workers(0), 1);
        assert!(RoundExec::new(0).effective_workers(64) >= 1);
    }
}
