//! Centralized baseline: the non-federated training run every figure of the
//! paper compares against. Same model artifact, same initialization, same
//! cosine schedule, same total sequential step count — but one trainer
//! consuming the *union* of all client buckets, evaluated on the same
//! centralized validation set at every τ-step boundary so its curve aligns
//! with the federated rounds.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::federation::build_data;
use crate::data::stream::TokenStream;
use crate::metrics::{MetricsLog, RoundRecord};
use crate::model::init::init_params;
use crate::model::vecmath::l2_norm;
use crate::runtime::{ModelRuntime, TrainState};

/// Run the centralized counterpart of `cfg`: `rounds·τ` sequential steps on
/// the merged data, recording one RoundRecord per τ steps. The *effective
/// batch* is the same device batch as one client (the paper's "same batch
/// size locally as the centralized pre-training recipe" regime).
#[allow(clippy::disallowed_methods)] // round timing is reporting-only
pub fn run_centralized(
    cfg: &ExperimentConfig,
    model: &Arc<ModelRuntime>,
) -> Result<MetricsLog> {
    let data = build_data(&cfg.corpus, cfg.n_clients, cfg.seed, model.manifest.config.vocab);
    // Union of every client's buckets = the centralized dataset.
    let all_buckets: Vec<_> = data
        .partition
        .assignment
        .iter()
        .flatten()
        .cloned()
        .collect();
    let mut stream = TokenStream::bind(
        &all_buckets,
        &data.corpus.categories,
        model.seq_width(),
        cfg.seed ^ 0xce47a1_u64, // centralized-stream salt
    )?;
    let val = data.validation_batches(
        cfg.eval_batches,
        model.batch_size(),
        model.seq_width(),
    )?;

    let mut state = TrainState::new(init_params(&model.manifest, cfg.seed));
    let mut log = MetricsLog::default();
    let mut seq_step = 0u64;
    for round in 0..cfg.rounds {
        // lint:allow(nondet-time): t0 only feeds the wall_secs report column
        let t0 = std::time::Instant::now();
        let mut losses = Vec::with_capacity(cfg.local_steps as usize);
        let mut grad_norms = 0.0;
        let mut update_norms = 0.0;
        let mut act_norms = 0.0;
        for _ in 0..cfg.local_steps {
            seq_step += 1;
            let tokens = stream.next_batch(model.batch_size());
            let lr = cfg.schedule.lr(seq_step) as f32;
            let stats = model.train_step(&mut state, lr, &tokens)?;
            losses.push(stats.loss as f64);
            grad_norms += stats.grad_norm as f64;
            update_norms += stats.update_norm as f64;
            act_norms += stats.act_norm as f64;
        }
        let inv = 1.0 / cfg.local_steps as f64;
        let (nll, ppl) = model.eval_nll(&state.params, &val)?;
        let loss_mean = losses.iter().sum::<f64>() * inv;
        log.push(RoundRecord {
            round,
            server_ppl: ppl,
            server_nll: nll,
            client_loss_mean: loss_mean,
            client_loss_std: 0.0,
            client_ppl_mean: loss_mean.exp(),
            global_model_norm: l2_norm(&state.params),
            client_model_norm_mean: l2_norm(&state.params),
            client_avg_norm: l2_norm(&state.params),
            pseudo_grad_norm: 0.0,
            step_grad_norm_mean: grad_norms * inv,
            applied_update_norm_mean: update_norms * inv,
            act_norm_mean: act_norms * inv,
            momentum_norm: 0.0,
            client_cosine_mean: 1.0,
            participated: 1,
            comm_bytes: 0,
            comm_bytes_wire: 0,
            wall_secs: t0.elapsed().as_secs_f64(),
        });
    }
    Ok(log)
}
