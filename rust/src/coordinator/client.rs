//! The Photon LLM Node: executes the local training pipeline of one
//! federated client (Algorithm 1 L.12–27).
//!
//! Per round the node: receives the global model, binds its Photon Data
//! Source stream(s), picks an execution strategy from its hardware
//! (§5.1 — single island = one local trainer; poorly-connected nodes =
//! per-island sub-federation with partial aggregation, L.19–24), runs τ
//! fused AdamW steps through the AOT train-step artifact, and returns its
//! parameters + metrics. Optimizer-state policy implements §7.8
//! (stateless vs KeepOpt clients).

use anyhow::{ensure, Result};

use crate::ckpt::ClientCkpt;
use crate::cluster::island::partial_aggregate;
use crate::config::OptStatePolicy;
use crate::data::stream::TokenStream;
use crate::model::vecmath::l2_norm;
use crate::runtime::{ModelRuntime, TrainState};

/// Persistent client-side state living at the node between rounds.
pub struct ClientNode {
    pub id: usize,
    /// One stream per connectivity island (usually one).
    pub streams: Vec<TokenStream>,
    /// KeepOpt: AdamW state carried across rounds (None = stateless).
    pub saved_opt: Option<(Vec<f32>, Vec<f32>, i64)>,
    /// Error-feedback residual of the lossy update codec (`topk`): the
    /// gradient mass withheld so far. Empty means zero; updated by
    /// `compress::encode_transit` when the update leaves the node.
    pub residual: Vec<f32>,
}

/// What a node sends back through the Photon Link after a round.
#[derive(Clone, Debug)]
pub struct ClientUpdate {
    pub client_id: usize,
    pub params: Vec<f32>,
    /// Sequences consumed this round (FedAvg weighting under quantity skew).
    pub n_samples: f64,
    pub loss_mean: f64,
    pub loss_last: f64,
    pub step_grad_norm_mean: f64,
    pub applied_update_norm_mean: f64,
    pub act_norm_mean: f64,
    pub model_norm: f64,
    pub steps_done: u64,
    /// Framed Photon-Link bytes this update occupies in transit (coded
    /// body, or dense payload, plus one frame header). 0 = "not measured
    /// yet": `commit_round` substitutes the dense-frame size, so the
    /// lossless path needs no transit pass at all.
    pub wire_bytes: u64,
}

impl ClientNode {
    pub fn new(id: usize, streams: Vec<TokenStream>) -> ClientNode {
        assert!(!streams.is_empty());
        ClientNode { id, streams, saved_opt: None, residual: Vec::new() }
    }

    pub fn islands(&self) -> usize {
        self.streams.len()
    }

    /// Snapshot this node's full inter-round state — one stream cursor per
    /// island plus KeepOpt moments. The same [`ClientCkpt`] bytes serve the
    /// checkpoint file and the deployment plane's wire (`net::proto` ships
    /// it in `RoundAssign`/`UpdatePush`), which is what makes workers
    /// stateless: the Aggregator owns every client's state.
    pub fn state(&self) -> ClientCkpt {
        let cursors = self.streams.iter().map(|s| s.cursor()).collect();
        let (opt_m, opt_v, local_step) = match &self.saved_opt {
            Some((m, v, st)) => (m.clone(), v.clone(), *st),
            None => (Vec::new(), Vec::new(), 0),
        };
        ClientCkpt { opt_m, opt_v, local_step, cursors, residual: self.residual.clone() }
    }

    /// Validate that `st` structurally fits this node (island and bucket
    /// arity) without mutating anything, so a mismatched state — a fleet or
    /// corpus config drift — can never leave the node half-restored.
    pub fn check_state(&self, st: &ClientCkpt) -> Result<()> {
        ensure!(
            st.cursors.len() == self.streams.len(),
            "client {} state carries {} stream cursors, node has {} islands \
             (fleet mismatch?)",
            self.id,
            st.cursors.len(),
            self.streams.len()
        );
        for (isl, (stream, cur)) in self.streams.iter().zip(&st.cursors).enumerate() {
            ensure!(
                cur.bucket_states.len() == stream.buckets().len(),
                "client {} island {isl} cursor has {} bucket states, stream \
                 has {} buckets (partition mismatch?)",
                self.id,
                cur.bucket_states.len(),
                stream.buckets().len()
            );
        }
        Ok(())
    }

    /// Restore a state produced by [`ClientNode::state`] (possibly on
    /// another process — the deployment plane round-trips it over TCP).
    pub fn restore_state(&mut self, st: &ClientCkpt) -> Result<()> {
        self.check_state(st)?;
        for (stream, cur) in self.streams.iter_mut().zip(&st.cursors) {
            stream.restore(cur);
        }
        self.saved_opt = if st.opt_m.is_empty() {
            None
        } else {
            Some((st.opt_m.clone(), st.opt_v.clone(), st.local_step))
        };
        self.residual = st.residual.clone();
        Ok(())
    }

    /// Run one local round: `steps` fused train steps per island starting
    /// from `global`, LR driven by `lr_at(sequential_step)` with
    /// `seq_step_base` the federation's cumulative step count.
    ///
    /// Multi-island nodes run an inner sub-federation: each island trains
    /// independently on its disjoint stream and the node partially
    /// aggregates (simple average, Algorithm 1 L.23) before replying.
    ///
    /// Deterministic given the node's stream/optimizer state — the property
    /// the round engine (`round_exec`) relies on to be bit-exact across
    /// worker counts (`lr_at` is `Sync` so workers can share it).
    pub fn run_local_round(
        &mut self,
        model: &ModelRuntime,
        global: &[f32],
        steps: u64,
        seq_step_base: u64,
        lr_at: &(dyn Fn(u64) -> f64 + Sync),
        policy: OptStatePolicy,
    ) -> Result<ClientUpdate> {
        let batch = model.batch_size();
        let n_islands = self.streams.len();
        let mut island_params: Vec<Vec<f32>> = Vec::with_capacity(n_islands);
        let mut island_weights: Vec<f64> = Vec::with_capacity(n_islands);

        let mut losses: Vec<f64> = Vec::new();
        let mut grad_norms = 0.0f64;
        let mut update_norms = 0.0f64;
        let mut act_norms = 0.0f64;
        let mut total_steps = 0u64;
        let mut keep_state: Option<(Vec<f32>, Vec<f32>, i64)> = None;

        for (isl, stream) in self.streams.iter_mut().enumerate() {
            let mut state = TrainState::new(global.to_vec());
            if policy == OptStatePolicy::KeepOpt {
                if let Some((m, v, st)) = &self.saved_opt {
                    if isl == 0 && m.len() == state.m.len() {
                        state.m.copy_from_slice(m);
                        state.v.copy_from_slice(v);
                        state.step = *st;
                    }
                }
            }
            // Chunked hot path (EXPERIMENTS.md §Perf): full chunks go
            // through the fused scan artifact, the remainder through the
            // single-step artifact. Trajectories are identical either way.
            let k = model.chunk_size() as u64;
            let mut t = 0u64;
            let mut push = |stats: crate::runtime::StepStats| {
                losses.push(stats.loss as f64);
                grad_norms += stats.grad_norm as f64;
                update_norms += stats.update_norm as f64;
                act_norms += stats.act_norm as f64;
                total_steps += 1;
            };
            while t + k <= steps {
                let mut toks = Vec::with_capacity(
                    k as usize * batch * model.seq_width());
                let mut lrs = Vec::with_capacity(k as usize);
                for i in 0..k {
                    toks.extend(stream.next_batch(batch));
                    lrs.push(lr_at(seq_step_base + t + i + 1) as f32);
                }
                for stats in model.train_chunk(&mut state, &lrs, &toks)? {
                    push(stats);
                }
                t += k;
            }
            while t < steps {
                let tokens = stream.next_batch(batch);
                let lr = lr_at(seq_step_base + t + 1) as f32;
                push(model.train_step(&mut state, lr, &tokens)?);
                t += 1;
            }
            if isl == 0 && policy == OptStatePolicy::KeepOpt {
                keep_state = Some((state.m.clone(), state.v.clone(), state.step));
            }
            island_weights.push(steps as f64 * batch as f64);
            island_params.push(state.params);
        }

        self.saved_opt = match policy {
            OptStatePolicy::KeepOpt => keep_state,
            OptStatePolicy::Stateless => None,
        };

        let params = if n_islands == 1 {
            island_params.pop().unwrap()
        } else {
            partial_aggregate(&island_params, &island_weights)
        };

        let inv = 1.0 / total_steps.max(1) as f64;
        Ok(ClientUpdate {
            client_id: self.id,
            model_norm: l2_norm(&params),
            params,
            n_samples: total_steps as f64 * batch as f64,
            loss_mean: losses.iter().sum::<f64>() * inv,
            loss_last: losses.last().copied().unwrap_or(f64::NAN),
            step_grad_norm_mean: grad_norms * inv,
            applied_update_norm_mean: update_norms * inv,
            act_norm_mean: act_norms * inv,
            steps_done: total_steps,
            wire_bytes: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    // Needs compiled artifacts; exercised by rust/tests/integration_fed.rs.
    // The pure parts (island aggregation) are covered in cluster::island.
}
