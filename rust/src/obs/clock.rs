//! The observability plane's only wall-clock read.
//!
//! `obs/clock.rs` is the single sanctioned `obs/` entry on the lint's
//! wall-clock allowlist (`analysis::rules::WALL_CLOCK_FILES`); everything
//! else under `obs/` must stay off the host clock so that replay and
//! parity remain deterministic. The timestamp produced here is display
//! and log-merge metadata only — ordering, replay, and `to_trace` all key
//! on the sink's monotonic `seq` (see docs/OBSERVABILITY.md, "`ts_us`
//! vs `seq`").

// Mirrors the lint allowlist entry; clippy.toml disallows these methods
// everywhere else.
#![allow(clippy::disallowed_methods)]

use std::time::{SystemTime, UNIX_EPOCH};

/// Microseconds since the Unix epoch. Not monotonic (NTP can step the
/// host clock) — consumers must never order or validate by it.
pub fn wall_ts_us() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}
