//! Live fleet observability: structured JSONL event bus + cockpit.
//!
//! The deployment plane's failures — stragglers, crashes, rejoins,
//! migrations — play out over hours; this plane makes them visible as a
//! machine-readable stream instead of scattered stderr lines. Layers,
//! source → parser → view-state, each pure and testable on its own:
//!
//! - [`event`]: the typed [`Event`] enum, the [`EventSink`] writer
//!   (monotonic `seq`, wall-clock `ts_us`), the strict line codec, the
//!   [`validate_log_text`] schema gate (`photon evck`), and the keystone
//!   [`to_trace`] fold back into a `chaos::Trace`.
//! - [`clock`]: the plane's only sanctioned wall-clock read.
//! - [`tail`]: follow-mode reader tolerating truncated last lines and
//!   garbage (crash-torn logs must still triage).
//! - [`view`]: pure reducer into per-worker lanes, a round timeline,
//!   and cumulative aggregates.
//! - [`top`]: deterministic ANSI frame renderer behind `photon top`.
//!
//! Determinism contract: `seq` (assigned under the sink lock, so
//! sequence order is write order) is the only ordering key; `ts_us` is
//! display metadata and may step backwards with the host clock. Replay
//! never reads a clock — see docs/OBSERVABILITY.md.

pub mod clock;
pub mod event;
pub mod tail;
pub mod top;
pub mod view;

pub use event::{to_trace, validate_log_text, Event, EventRecord, EventSink, EVENT_KINDS};
pub use tail::{read_log, Tail};
pub use top::{render_frame, render_stats, sparkline, Mode, CLEAR};
pub use view::{RoundRow, ViewState, WorkerLane};

/// The one `[timing]` reporter (lint, benchck, evck, serve rounds,
/// harness watchdog all route through here), so wall-clock reports are
/// a single grep pattern: `[timing] <area> <what>: <secs>s`.
pub fn timing(area: &str, what: &str, secs: f64) {
    eprintln!("[timing] {area} {what}: {secs:.2}s");
}
