//! Frame renderer for `photon top` — raw ANSI, no terminal crates.
//!
//! Rendering is a pure function of [`ViewState`] + [`Mode`]: no clocks,
//! no environment probes, no color autodetection. That is what makes
//! `photon top --replay` byte-identical across runs (the acceptance
//! criterion pinned by `tests/fixtures/obs/golden_frame.txt`). Follow
//! mode prepends [`CLEAR`] per frame in `main.rs`; the frame itself is
//! identical between live and replay apart from the mode tag.

use super::view::ViewState;

/// Clear screen + home cursor — the follow-mode frame prefix.
pub const CLEAR: &str = "\x1b[2J\x1b[H";

/// Rounds shown in the timeline table (older rows scroll off).
const TIMELINE_ROWS: usize = 12;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Live,
    Replay,
}

const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Min-max scaled block sparkline; `"-"` when empty, mid-height when
/// the series is constant.
pub fn sparkline(values: &[f64]) -> String {
    if values.is_empty() {
        return "-".to_string();
    }
    let min = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = values.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = max - min;
    values
        .iter()
        .map(|&v| {
            let idx = if span > 0.0 && v.is_finite() {
                (((v - min) / span) * 7.0).round() as usize
            } else {
                3
            };
            BARS[idx.min(7)]
        })
        .collect()
}

/// Render one full cockpit frame (trailing newline included).
pub fn render_frame(v: &ViewState, mode: Mode) -> String {
    let mut out = String::new();
    let session = v.session.as_deref().unwrap_or("-");
    let seq = if v.applied > 0 { v.last_seq.to_string() } else { "-".to_string() };
    let mode_tag = match mode {
        Mode::Live => "live",
        Mode::Replay => "replay",
    };
    out.push_str(&format!(
        "\x1b[1mphoton top\x1b[0m — session {session}  [{mode_tag}]  seq {seq}\n"
    ));
    let total = v.rounds_total.map_or_else(|| "-".to_string(), |r| r.to_string());
    out.push_str(&format!(
        "rounds {}/{}  folded {}  cut {}  migrated {}  rejoined {}  malformed {}  \
         stalls {}  wire {} B\n",
        v.committed_rounds(),
        total,
        v.total_folded(),
        v.total_cut(),
        v.total_migrated(),
        v.total_rejoined(),
        v.malformed,
        v.stalls,
        v.total_wire_bytes,
    ));
    out.push_str(&format!("nll {}\n", sparkline(&v.nll_series())));
    out.push('\n');

    out.push_str("workers\n");
    out.push_str(&format!(
        "{:>5}  {:<16}  {:>7}  {:>6}  {:>7}  {:>9}  {:>8}\n",
        "slot", "name", "granted", "folded", "rejoins", "malformed", "last-seq"
    ));
    for (slot, lane) in &v.workers {
        out.push_str(&format!(
            "{:>5}  {:<16}  {:>7}  {:>6}  {:>7}  {:>9}  {:>8}\n",
            slot, lane.name, lane.granted, lane.folded, lane.rejoins, lane.malformed,
            lane.last_seq,
        ));
    }
    out.push('\n');

    out.push_str(&format!("rounds (last {TIMELINE_ROWS})\n"));
    out.push_str(&format!(
        "{:>6}  {:>7}  {:>6}  {:>4}  {:>8}  {:>10}  {:>12}  {:>9}\n",
        "round", "granted", "folded", "cut", "migrated", "nll", "wire B", "wall ms"
    ));
    let rows: Vec<_> = v.rounds.values().collect();
    let start = rows.len().saturating_sub(TIMELINE_ROWS);
    for row in &rows[start..] {
        let nll = if row.committed { format!("{:.4}", row.nll) } else { "-".to_string() };
        let wall = if row.committed {
            format!("{:.1}", row.wall_us as f64 / 1000.0)
        } else {
            "-".to_string()
        };
        out.push_str(&format!(
            "{:>6}  {:>7}  {:>6}  {:>4}  {:>8}  {:>10}  {:>12}  {:>9}\n",
            row.round, row.granted, row.folded, row.cut, row.migrated, nll, row.wire_bytes,
            wall,
        ));
    }
    if v.shutdown {
        out.push_str("\n-- shutdown: run complete --\n");
    }
    out
}

/// One-shot plain-text summary (`photon top --stats`): two `[obs]`
/// lines, grep-stable, no ANSI.
pub fn render_stats(v: &ViewState) -> String {
    let total = v.rounds_total.map_or_else(|| "-".to_string(), |r| r.to_string());
    let nll = v.final_nll().map_or_else(|| "-".to_string(), |n| format!("{n:.6}"));
    format!(
        "[obs] events {}  rounds {}/{}  granted {}  folded {}  cut {}  migrated {}  \
         rejoined {}  malformed {}  stalls {}\n\
         [obs] wire {} B  final nll {}  workers {}\n",
        v.applied,
        v.committed_rounds(),
        total,
        v.total_granted(),
        v.total_folded(),
        v.total_cut(),
        v.total_migrated(),
        v.total_rejoined(),
        v.malformed,
        v.stalls,
        v.total_wire_bytes,
        nll,
        v.workers.len(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparkline_scales_min_to_max() {
        assert_eq!(sparkline(&[]), "-");
        assert_eq!(sparkline(&[1.0]), "▄", "constant series sits mid-height");
        assert_eq!(sparkline(&[5.25, 4.5]), "█▁");
        assert_eq!(sparkline(&[0.0, 0.5, 1.0]), "▁▅█");
    }

    #[test]
    fn empty_state_renders_placeholders() {
        let v = ViewState::default();
        let frame = render_frame(&v, Mode::Replay);
        assert!(frame.contains("session -"));
        assert!(frame.contains("seq -"));
        assert!(frame.contains("nll -\n"));
        assert!(!frame.contains("shutdown"));
        assert_eq!(frame, render_frame(&v, Mode::Replay), "rendering is pure");
        let stats = render_stats(&v);
        assert!(stats.starts_with("[obs] events 0"));
        assert!(!stats.contains('\x1b'), "stats are plain text");
    }
}
