//! Follow-mode reader for JSONL event logs.
//!
//! The writer ([`super::event::EventSink`]) appends whole lines, but a
//! reader can race a write mid-line (or land on a log torn by a crash),
//! so the tail splits at the **last** newline it has seen: complete
//! lines parse now, an unterminated suffix stays buffered until its
//! newline arrives. Garbage lines are counted ([`Tail::skipped`]) and
//! skipped, never fatal — a cockpit must survive a dirty log.

use std::fs::File;
use std::io::Read;
use std::path::Path;

use anyhow::{anyhow, Result};

use super::event::EventRecord;

/// Incremental reader over a growing event log.
pub struct Tail {
    file: File,
    /// Bytes read but not yet terminated by a newline.
    buf: Vec<u8>,
    /// Undecodable complete lines seen so far (blank lines excluded).
    pub skipped: u64,
}

impl Tail {
    pub fn open(path: &Path) -> Result<Tail> {
        let file = File::open(path)
            .map_err(|e| anyhow!("opening event log {}: {e}", path.display()))?;
        Ok(Tail { file, buf: Vec::new(), skipped: 0 })
    }

    /// Read everything appended since the last poll and parse the
    /// complete lines, in order. A final line still missing its newline
    /// stays buffered and surfaces on a later poll.
    pub fn poll(&mut self) -> Result<Vec<EventRecord>> {
        let mut chunk = [0u8; 64 * 1024];
        loop {
            match self.file.read(&mut chunk)? {
                0 => break,
                n => self.buf.extend_from_slice(&chunk[..n]),
            }
        }
        let mut out = Vec::new();
        let Some(last_nl) = self.buf.iter().rposition(|&b| b == b'\n') else {
            return Ok(out);
        };
        let complete: Vec<u8> = self.buf.drain(..=last_nl).collect();
        for raw in complete.split(|&b| b == b'\n') {
            let line = match std::str::from_utf8(raw) {
                Ok(s) => s.trim(),
                Err(_) => {
                    self.skipped += 1;
                    continue;
                }
            };
            if line.is_empty() {
                continue;
            }
            match EventRecord::parse(line) {
                Ok(rec) => out.push(rec),
                Err(_) => self.skipped += 1,
            }
        }
        Ok(out)
    }

    /// Bytes still waiting for their newline (an in-flight write).
    pub fn pending_bytes(&self) -> usize {
        self.buf.len()
    }
}

/// One-shot read of a whole log. An unterminated final line that parses
/// cleanly still counts (the writer got the bytes out, not the newline);
/// an unparsable tail is treated as a truncated in-flight write and
/// ignored rather than counted as garbage.
pub fn read_log(path: &Path) -> Result<(Vec<EventRecord>, u64)> {
    let mut tail = Tail::open(path)?;
    let mut records = tail.poll()?;
    if !tail.buf.is_empty() {
        if let Ok(line) = std::str::from_utf8(&tail.buf) {
            if let Ok(rec) = EventRecord::parse(line.trim()) {
                records.push(rec);
            }
        }
    }
    Ok((records, tail.skipped))
}
