//! Typed fleet events, their JSONL codec, and the `EventSink`.
//!
//! One `EventRecord` per line: `{"seq":N,"ts_us":T,"ev":"...",...payload}`
//! serialized through `util::json` (BTreeMap ⇒ alphabetical keys ⇒ a
//! byte-stable encoding). `seq` is assigned under the sink's lock, so
//! sequence order IS write order — the determinism key. `ts_us` comes
//! from [`super::clock::wall_ts_us`] and is metadata only.
//!
//! The keystone correctness hook lives here too: [`to_trace`] folds a
//! server-emitted event stream back into a [`chaos::Trace`] that must
//! bit-equal `Server::trace()` (tested against a chaotic loopback fleet
//! in `tests/props_obs.rs`), tying the observability plane to the
//! existing replay-parity guarantees.

use std::collections::BTreeMap;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::chaos::{Migration, RoundTrace, Trace};
use crate::util::json::{self, Json};

/// One typed observability event. Worker indices are server slots;
/// in-process (`Federation::run`) streams use lane 0 for every grant and
/// fold so TCP and in-process runs stay structurally comparable.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// A run began. `session` is the hex session token (`{:#x}` of the
    /// serve session id, or of the config seed for in-process runs).
    ServerStart { session: String, rounds: u64, n_clients: u64, clients_per_round: u64 },
    /// A worker was admitted into a fresh slot.
    WorkerJoin { worker: u64, name: String },
    /// A crashed worker reclaimed its slot (server-authoritative; the
    /// worker's own log emits a plain `WorkerJoin` — it cannot know).
    WorkerRejoin { round: u64, worker: u64, name: String },
    /// A sub-aggregator was admitted into a fresh slot (tree mode).
    SubaggJoin { subagg: u64, name: String },
    /// A sub-aggregator's pre-folded slice was accepted into the round:
    /// `n_clients` member updates carrying `weight` total samples.
    FoldedPush { round: u64, subagg: u64, n_clients: u64, weight: f64 },
    /// A client lease was granted to a worker for this round.
    LeaseGrant { round: u64, client: u64, worker: u64 },
    /// The lease folded: the client's update was accepted exactly once.
    LeaseFold { round: u64, client: u64, worker: u64 },
    /// These clients were cut from the round (deadline or stall backstop).
    /// In async mode `round` is the epoch at cut time and one epoch may
    /// emit several `Cut` events (grants are cut individually as
    /// disconnects and deadlines land).
    Cut { round: u64, clients: Vec<u64> },
    /// An asynchronous epoch committed: `k` buffered arrivals from
    /// `clients` (canonical ascending-grant order) folded with
    /// staleness-discounted weights; `staleness_max` is the oldest
    /// arrival's epoch lag.
    AsyncFold { epoch: u64, k: u64, clients: Vec<u64>, staleness_max: u64 },
    /// A pending lease moved from a silent worker to a live one.
    Migration { round: u64, client: u64, from: u64, to: u64 },
    /// An undecodable frame arrived (`worker` is `None` when the sender
    /// could not be identified).
    Malformed { round: u64, worker: Option<u64> },
    /// The round committed into the global model.
    RoundCommit { round: u64, participated: u64, nll: f64, comm_bytes_wire: u64, wall_us: u64 },
    /// Liveness backstop fired (`round` is `None` for harness-level
    /// watchdog stalls that are not attributable to a round).
    Stall { round: Option<u64>, waited_us: u64, detail: String },
    /// The run ended after `rounds` rounds.
    Shutdown { rounds: u64 },
}

/// Every `ev` discriminator the schema knows, in emission-typical order.
pub const EVENT_KINDS: &[&str] = &[
    "server_start",
    "worker_join",
    "worker_rejoin",
    "subagg_join",
    "lease_grant",
    "lease_fold",
    "folded_push",
    "cut",
    "async_fold",
    "migration",
    "malformed",
    "round_commit",
    "stall",
    "shutdown",
];

impl Event {
    /// The wire discriminator stored under the `"ev"` key.
    pub fn name(&self) -> &'static str {
        match self {
            Event::ServerStart { .. } => "server_start",
            Event::WorkerJoin { .. } => "worker_join",
            Event::WorkerRejoin { .. } => "worker_rejoin",
            Event::SubaggJoin { .. } => "subagg_join",
            Event::LeaseGrant { .. } => "lease_grant",
            Event::LeaseFold { .. } => "lease_fold",
            Event::FoldedPush { .. } => "folded_push",
            Event::Cut { .. } => "cut",
            Event::AsyncFold { .. } => "async_fold",
            Event::Migration { .. } => "migration",
            Event::Malformed { .. } => "malformed",
            Event::RoundCommit { .. } => "round_commit",
            Event::Stall { .. } => "stall",
            Event::Shutdown { .. } => "shutdown",
        }
    }
}

/// One stamped line of the event log.
#[derive(Clone, Debug, PartialEq)]
pub struct EventRecord {
    /// Monotonic per-sink sequence number, consecutive from 0. The
    /// determinism key: sequence order is write order.
    pub seq: u64,
    /// Wall-clock microseconds since the epoch — metadata only, never
    /// ordered on (the host clock can step backwards).
    pub ts_us: u64,
    pub event: Event,
}

/// Integers in the log stay below 2^53, so `f64` carries them exactly.
fn uint(v: u64) -> Json {
    Json::Num(v as f64)
}

fn field_u64(v: &Json, key: &str) -> Result<u64> {
    let n = v.get(key)?.as_f64().with_context(|| format!("field {key:?}"))?;
    if n < 0.0 || n.fract() != 0.0 || n >= 9e15 {
        bail!("field {key:?} is not a small non-negative integer: {n}");
    }
    Ok(n as u64)
}

fn field_opt_u64(v: &Json, key: &str) -> Result<Option<u64>> {
    match v {
        Json::Obj(m) if !m.contains_key(key) => Ok(None),
        _ => field_u64(v, key).map(Some),
    }
}

fn field_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)?.as_str().with_context(|| format!("field {key:?}"))?.to_string())
}

fn field_arr_u64(v: &Json, key: &str) -> Result<Vec<u64>> {
    v.get(key)?
        .as_arr()
        .with_context(|| format!("field {key:?}"))?
        .iter()
        .map(|e| {
            let n = e.as_f64()?;
            if n < 0.0 || n.fract() != 0.0 || n >= 9e15 {
                bail!("field {key:?} holds a non-integer: {n}");
            }
            Ok(n as u64)
        })
        .collect()
}

impl EventRecord {
    pub fn to_json(&self) -> Json {
        let mut pairs: Vec<(&str, Json)> = vec![
            ("seq", uint(self.seq)),
            ("ts_us", uint(self.ts_us)),
            ("ev", json::s(self.event.name())),
        ];
        match &self.event {
            Event::ServerStart { session, rounds, n_clients, clients_per_round } => {
                pairs.push(("session", json::s(session)));
                pairs.push(("rounds", uint(*rounds)));
                pairs.push(("n_clients", uint(*n_clients)));
                pairs.push(("clients_per_round", uint(*clients_per_round)));
            }
            Event::WorkerJoin { worker, name } => {
                pairs.push(("worker", uint(*worker)));
                pairs.push(("name", json::s(name)));
            }
            Event::WorkerRejoin { round, worker, name } => {
                pairs.push(("round", uint(*round)));
                pairs.push(("worker", uint(*worker)));
                pairs.push(("name", json::s(name)));
            }
            Event::SubaggJoin { subagg, name } => {
                pairs.push(("subagg", uint(*subagg)));
                pairs.push(("name", json::s(name)));
            }
            Event::LeaseGrant { round, client, worker }
            | Event::LeaseFold { round, client, worker } => {
                pairs.push(("round", uint(*round)));
                pairs.push(("client", uint(*client)));
                pairs.push(("worker", uint(*worker)));
            }
            Event::FoldedPush { round, subagg, n_clients, weight } => {
                pairs.push(("round", uint(*round)));
                pairs.push(("subagg", uint(*subagg)));
                pairs.push(("n_clients", uint(*n_clients)));
                pairs.push(("weight", json::num(*weight)));
            }
            Event::Cut { round, clients } => {
                pairs.push(("round", uint(*round)));
                pairs.push(("clients", json::arr(clients.iter().map(|&c| uint(c)))));
            }
            Event::AsyncFold { epoch, k, clients, staleness_max } => {
                pairs.push(("epoch", uint(*epoch)));
                pairs.push(("k", uint(*k)));
                pairs.push(("clients", json::arr(clients.iter().map(|&c| uint(c)))));
                pairs.push(("staleness_max", uint(*staleness_max)));
            }
            Event::Migration { round, client, from, to } => {
                pairs.push(("round", uint(*round)));
                pairs.push(("client", uint(*client)));
                pairs.push(("from", uint(*from)));
                pairs.push(("to", uint(*to)));
            }
            Event::Malformed { round, worker } => {
                pairs.push(("round", uint(*round)));
                if let Some(w) = worker {
                    pairs.push(("worker", uint(*w)));
                }
            }
            Event::RoundCommit { round, participated, nll, comm_bytes_wire, wall_us } => {
                pairs.push(("round", uint(*round)));
                pairs.push(("participated", uint(*participated)));
                pairs.push(("nll", json::num(*nll)));
                pairs.push(("comm_bytes_wire", uint(*comm_bytes_wire)));
                pairs.push(("wall_us", uint(*wall_us)));
            }
            Event::Stall { round, waited_us, detail } => {
                if let Some(r) = round {
                    pairs.push(("round", uint(*r)));
                }
                pairs.push(("waited_us", uint(*waited_us)));
                pairs.push(("detail", json::s(detail)));
            }
            Event::Shutdown { rounds } => {
                pairs.push(("rounds", uint(*rounds)));
            }
        }
        json::obj(pairs)
    }

    /// The record as one JSONL line (no trailing newline). Byte-stable:
    /// keys are alphabetical, integers print without a decimal point,
    /// and `nll` round-trips via shortest-roundtrip f64 display.
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// Strict parse of one log line. Unknown `ev` kinds and malformed
    /// fields are errors; extra keys are ignored (forward compatibility).
    pub fn parse(line: &str) -> Result<EventRecord> {
        let v = Json::parse(line.trim())?;
        let seq = field_u64(&v, "seq")?;
        let ts_us = field_u64(&v, "ts_us")?;
        let ev = field_str(&v, "ev")?;
        let event = match ev.as_str() {
            "server_start" => Event::ServerStart {
                session: field_str(&v, "session")?,
                rounds: field_u64(&v, "rounds")?,
                n_clients: field_u64(&v, "n_clients")?,
                clients_per_round: field_u64(&v, "clients_per_round")?,
            },
            "worker_join" => Event::WorkerJoin {
                worker: field_u64(&v, "worker")?,
                name: field_str(&v, "name")?,
            },
            "worker_rejoin" => Event::WorkerRejoin {
                round: field_u64(&v, "round")?,
                worker: field_u64(&v, "worker")?,
                name: field_str(&v, "name")?,
            },
            "subagg_join" => Event::SubaggJoin {
                subagg: field_u64(&v, "subagg")?,
                name: field_str(&v, "name")?,
            },
            "folded_push" => Event::FoldedPush {
                round: field_u64(&v, "round")?,
                subagg: field_u64(&v, "subagg")?,
                n_clients: field_u64(&v, "n_clients")?,
                weight: v.get("weight")?.as_f64().context("field \"weight\"")?,
            },
            "lease_grant" => Event::LeaseGrant {
                round: field_u64(&v, "round")?,
                client: field_u64(&v, "client")?,
                worker: field_u64(&v, "worker")?,
            },
            "lease_fold" => Event::LeaseFold {
                round: field_u64(&v, "round")?,
                client: field_u64(&v, "client")?,
                worker: field_u64(&v, "worker")?,
            },
            "cut" => Event::Cut {
                round: field_u64(&v, "round")?,
                clients: field_arr_u64(&v, "clients")?,
            },
            "async_fold" => Event::AsyncFold {
                epoch: field_u64(&v, "epoch")?,
                k: field_u64(&v, "k")?,
                clients: field_arr_u64(&v, "clients")?,
                staleness_max: field_u64(&v, "staleness_max")?,
            },
            "migration" => Event::Migration {
                round: field_u64(&v, "round")?,
                client: field_u64(&v, "client")?,
                from: field_u64(&v, "from")?,
                to: field_u64(&v, "to")?,
            },
            "malformed" => Event::Malformed {
                round: field_u64(&v, "round")?,
                worker: field_opt_u64(&v, "worker")?,
            },
            "round_commit" => Event::RoundCommit {
                round: field_u64(&v, "round")?,
                participated: field_u64(&v, "participated")?,
                nll: v.get("nll")?.as_f64().context("field \"nll\"")?,
                comm_bytes_wire: field_u64(&v, "comm_bytes_wire")?,
                wall_us: field_u64(&v, "wall_us")?,
            },
            "stall" => Event::Stall {
                round: field_opt_u64(&v, "round")?,
                waited_us: field_u64(&v, "waited_us")?,
                detail: field_str(&v, "detail")?,
            },
            "shutdown" => Event::Shutdown { rounds: field_u64(&v, "rounds")? },
            other => bail!("unknown event kind {other:?}"),
        };
        Ok(EventRecord { seq, ts_us, event })
    }
}

enum TsSource {
    /// Stamp from the host clock ([`super::clock::wall_ts_us`]).
    Wall,
    /// Deterministic stamps for golden tests: `ts_us = base + seq·step`.
    Fixed { base_us: u64, step_us: u64 },
}

enum SinkOut {
    File(BufWriter<std::fs::File>),
    Memory(Vec<u8>),
}

struct SinkState {
    seq: u64,
    ts: TsSource,
    out: SinkOut,
}

/// Append-only JSONL event sink, cheap to clone and share across the
/// server, harness, and federation (`Arc<Mutex<..>>` inside). `seq` is
/// taken under the lock, so sequence order is exactly file order.
#[derive(Clone)]
pub struct EventSink {
    state: Arc<Mutex<SinkState>>,
}

impl std::fmt::Debug for EventSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "EventSink(seq={})", self.emitted())
    }
}

impl EventSink {
    /// Sink writing (and flushing per line, for `photon top --follow`)
    /// to a fresh file at `path`; parent directories are created.
    pub fn to_file(path: &Path) -> Result<EventSink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .map_err(|e| anyhow!("creating {}: {e}", dir.display()))?;
            }
        }
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow!("creating event log {}: {e}", path.display()))?;
        Ok(Self::with(SinkOut::File(BufWriter::new(f)), TsSource::Wall))
    }

    /// In-memory sink (wall-clock stamps); read back with [`Self::dump`].
    pub fn memory() -> EventSink {
        Self::with(SinkOut::Memory(Vec::new()), TsSource::Wall)
    }

    /// In-memory sink with deterministic stamps `base_us + seq·step_us`
    /// — the golden-fixture generator's clock.
    pub fn memory_fixed(base_us: u64, step_us: u64) -> EventSink {
        Self::with(SinkOut::Memory(Vec::new()), TsSource::Fixed { base_us, step_us })
    }

    fn with(out: SinkOut, ts: TsSource) -> EventSink {
        EventSink { state: Arc::new(Mutex::new(SinkState { seq: 0, ts, out })) }
    }

    /// Append one event. Best-effort by design: a poisoned lock or a
    /// full disk must never take the fleet down, so failures are
    /// swallowed (the validator's consecutive-`seq` check will surface
    /// a torn log at read time).
    pub fn emit(&self, event: Event) {
        let Ok(mut st) = self.state.lock() else { return };
        let seq = st.seq;
        st.seq += 1;
        let ts_us = match st.ts {
            TsSource::Wall => super::clock::wall_ts_us(),
            TsSource::Fixed { base_us, step_us } => base_us.wrapping_add(seq.wrapping_mul(step_us)),
        };
        let line = EventRecord { seq, ts_us, event }.to_line();
        match &mut st.out {
            SinkOut::File(w) => {
                let _ = w.write_all(line.as_bytes());
                let _ = w.write_all(b"\n");
                let _ = w.flush();
            }
            SinkOut::Memory(buf) => {
                buf.extend_from_slice(line.as_bytes());
                buf.push(b'\n');
            }
        }
    }

    /// Events emitted so far (equivalently: the next `seq`).
    pub fn emitted(&self) -> u64 {
        self.state.lock().map(|s| s.seq).unwrap_or(0)
    }

    /// The buffered JSONL text of a memory sink (`None` for file sinks).
    pub fn dump(&self) -> Option<String> {
        let st = self.state.lock().ok()?;
        match &st.out {
            SinkOut::Memory(buf) => Some(String::from_utf8_lossy(buf).into_owned()),
            SinkOut::File(_) => None,
        }
    }
}

/// Fold a server-emitted event stream back into the realized
/// [`chaos::Trace`]. Bit-equal to `Server::trace()` because the server
/// emits `Cut` / `Migration` / `WorkerRejoin` exactly where it pushes to
/// its own `cuts` / `migrations` / `rejoins` ledgers, in the same order
/// (cuts arrive sorted from the lease book's `BTreeSet`; migrations and
/// rejoins are chronological, which `seq` preserves).
pub fn to_trace(records: &[EventRecord]) -> Trace {
    let mut rounds: BTreeMap<usize, RoundTrace> = BTreeMap::new();
    let row = |m: &mut BTreeMap<usize, RoundTrace>, r: usize| -> &mut RoundTrace {
        m.entry(r).or_insert_with(|| RoundTrace { round: r, ..RoundTrace::default() })
    };
    for rec in records {
        match &rec.event {
            Event::Cut { round, clients } => {
                // Extend, don't assign: a sync round emits at most one
                // `Cut`, but an async epoch may emit several (grants are
                // cut one at a time) and all of them belong to the row.
                let t = row(&mut rounds, *round as usize);
                t.cut.extend(clients.iter().map(|&c| c as usize));
            }
            Event::Migration { round, client, from, to } => {
                row(&mut rounds, *round as usize).migrations.push(Migration {
                    client: *client as usize,
                    from: *from as usize,
                    to: *to as usize,
                });
            }
            Event::WorkerRejoin { round, worker, .. } => {
                row(&mut rounds, *round as usize).rejoined.push(*worker as usize);
            }
            _ => {}
        }
    }
    Trace { rounds: rounds.into_values().collect() }
}

/// Validate a whole log against the schema: every non-blank line parses
/// as a known event, and `seq` runs consecutively from 0 (this is the
/// whole ordering contract — `ts_us` is deliberately NOT checked for
/// monotonicity, because host clocks step). Returns the event count.
pub fn validate_log_text(text: &str) -> Result<usize> {
    let mut next_seq = 0u64;
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let rec = EventRecord::parse(line).map_err(|e| anyhow!("line {}: {e:#}", i + 1))?;
        if rec.seq != next_seq {
            bail!(
                "line {}: seq {} (expected {next_seq}; seq must be consecutive from 0)",
                i + 1,
                rec.seq
            );
        }
        next_seq += 1;
    }
    Ok(next_seq as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_round_trips_every_kind() {
        let samples = vec![
            Event::ServerStart {
                session: "0x2a".into(),
                rounds: 3,
                n_clients: 6,
                clients_per_round: 4,
            },
            Event::WorkerJoin { worker: 0, name: "loopback-0".into() },
            Event::WorkerRejoin { round: 1, worker: 2, name: "loopback-2".into() },
            Event::SubaggJoin { subagg: 1, name: "subagg-1".into() },
            Event::LeaseGrant { round: 0, client: 5, worker: 1 },
            Event::LeaseFold { round: 0, client: 5, worker: 1 },
            Event::FoldedPush { round: 1, subagg: 0, n_clients: 3, weight: 96.5 },
            Event::Cut { round: 2, clients: vec![1, 4] },
            Event::AsyncFold { epoch: 3, k: 2, clients: vec![0, 5], staleness_max: 1 },
            Event::Migration { round: 2, client: 4, from: 1, to: 0 },
            Event::Malformed { round: 0, worker: Some(1) },
            Event::Malformed { round: 0, worker: None },
            Event::RoundCommit {
                round: 2,
                participated: 4,
                nll: 5.0625,
                comm_bytes_wire: 1024,
                wall_us: 1500,
            },
            Event::Stall { round: Some(2), waited_us: 7, detail: "pending".into() },
            Event::Stall { round: None, waited_us: 7, detail: "watchdog".into() },
            Event::Shutdown { rounds: 3 },
        ];
        for (seq, event) in samples.into_iter().enumerate() {
            let rec = EventRecord { seq: seq as u64, ts_us: 10 + seq as u64, event };
            let line = rec.to_line();
            let back = EventRecord::parse(&line).unwrap();
            assert_eq!(back, rec, "{line}");
            assert_eq!(back.to_line(), line, "re-serialization must be byte-stable");
        }
    }

    #[test]
    fn parser_rejects_unknown_and_malformed() {
        assert!(EventRecord::parse("{}").is_err());
        assert!(EventRecord::parse(r#"{"seq":0,"ts_us":1,"ev":"mystery"}"#).is_err());
        assert!(
            EventRecord::parse(r#"{"seq":-1,"ts_us":1,"ev":"shutdown","rounds":1}"#).is_err(),
            "negative seq"
        );
        assert!(
            EventRecord::parse(r#"{"seq":0.5,"ts_us":1,"ev":"shutdown","rounds":1}"#).is_err(),
            "fractional seq"
        );
        assert!(EventRecord::parse("not json").is_err());
    }

    #[test]
    fn validator_wants_consecutive_seq_but_ignores_ts() {
        let sink = EventSink::memory_fixed(100, 0); // constant ts: still valid
        sink.emit(Event::Shutdown { rounds: 0 });
        sink.emit(Event::Shutdown { rounds: 1 });
        let text = sink.dump().unwrap();
        assert_eq!(validate_log_text(&text).unwrap(), 2);

        let gap = text.replace("\"seq\":1", "\"seq\":5");
        assert!(validate_log_text(&gap).is_err(), "seq gap must fail");
        assert_eq!(validate_log_text("\n  \n").unwrap(), 0, "blank lines are fine");
    }

    #[test]
    fn memory_sink_is_shared_through_clones() {
        let a = EventSink::memory_fixed(0, 1);
        let b = a.clone();
        a.emit(Event::Shutdown { rounds: 1 });
        b.emit(Event::Shutdown { rounds: 2 });
        assert_eq!(a.emitted(), 2);
        let text = b.dump().unwrap();
        assert_eq!(validate_log_text(&text).unwrap(), 2);
        assert!(text.contains("\"ts_us\":1"), "fixed clock: ts = base + seq*step\n{text}");
    }
}
