//! Pure view-state reducer over an event stream.
//!
//! [`ViewState::apply`] folds [`EventRecord`]s into per-worker lanes, a
//! round timeline, and cumulative aggregates — no I/O, no clocks, no
//! terminal, so the reducer is unit-testable and `photon top --replay`
//! is deterministic by construction. Stale records (`seq` at or below
//! the high-water mark) are dropped, not double-counted, which makes
//! re-polling and replay-from-scratch idempotent.

use std::collections::BTreeMap;

use super::event::{Event, EventRecord};

/// One worker slot's cumulative lane.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerLane {
    pub name: String,
    pub granted: u64,
    pub folded: u64,
    /// Pre-folded slice pushes accepted from this slot (tree mode; the
    /// member updates inside them count under `folded`).
    pub folded_pushes: u64,
    pub rejoins: u64,
    pub malformed: u64,
    /// `seq` of the last event that touched this lane.
    pub last_seq: u64,
}

/// One round's row in the timeline.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundRow {
    pub round: u64,
    pub granted: u64,
    pub folded: u64,
    /// Pre-folded slice pushes accepted this round (tree mode).
    pub folded_pushes: u64,
    pub cut: u64,
    pub migrated: u64,
    /// Largest arrival staleness folded into this round's async commit
    /// (always zero for sync/semi-sync rounds).
    pub staleness_max: u64,
    /// True once the `RoundCommit` arrived; the commit fields below are
    /// meaningless before then.
    pub committed: bool,
    pub participated: u64,
    pub nll: f64,
    pub wire_bytes: u64,
    pub wall_us: u64,
}

/// The whole cockpit state, reduced from a stream of records.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ViewState {
    pub session: Option<String>,
    pub rounds_total: Option<u64>,
    pub n_clients: Option<u64>,
    /// Records applied (stale drops excluded).
    pub applied: u64,
    /// High-water `seq` among applied records.
    pub last_seq: u64,
    /// `ts_us` of the last applied record (display metadata only).
    pub last_ts_us: u64,
    /// Records dropped for arriving at or below the high-water `seq`.
    pub dropped_stale: u64,
    pub workers: BTreeMap<u64, WorkerLane>,
    pub rounds: BTreeMap<u64, RoundRow>,
    pub total_wire_bytes: u64,
    pub stalls: u64,
    pub malformed: u64,
    pub shutdown: bool,
}

impl ViewState {
    fn lane(&mut self, worker: u64, seq: u64) -> &mut WorkerLane {
        let lane = self.workers.entry(worker).or_default();
        lane.last_seq = seq;
        lane
    }

    fn row(&mut self, round: u64) -> &mut RoundRow {
        self.rounds.entry(round).or_insert_with(|| RoundRow { round, ..RoundRow::default() })
    }

    /// Fold one record in. Returns false (and counts it) when the record
    /// is stale — `seq` at or below the high-water mark of an already
    /// applied record.
    pub fn apply(&mut self, rec: &EventRecord) -> bool {
        if self.applied > 0 && rec.seq <= self.last_seq {
            self.dropped_stale += 1;
            return false;
        }
        self.applied += 1;
        self.last_seq = rec.seq;
        self.last_ts_us = rec.ts_us;
        let seq = rec.seq;
        match &rec.event {
            Event::ServerStart { session, rounds, n_clients, .. } => {
                self.session = Some(session.clone());
                self.rounds_total = Some(*rounds);
                self.n_clients = Some(*n_clients);
            }
            Event::WorkerJoin { worker, name } => {
                let lane = self.lane(*worker, seq);
                lane.name = name.clone();
            }
            Event::WorkerRejoin { worker, name, .. } => {
                let lane = self.lane(*worker, seq);
                lane.name = name.clone();
                lane.rejoins += 1;
            }
            // A sub-aggregator occupies a worker slot at the root: its
            // lane carries the same grant/fold counters (the per-member
            // LeaseFold events keep `folded` accurate; the FoldedPush
            // only bumps the push counters).
            Event::SubaggJoin { subagg, name } => {
                let lane = self.lane(*subagg, seq);
                lane.name = name.clone();
            }
            Event::FoldedPush { round, subagg, .. } => {
                self.lane(*subagg, seq).folded_pushes += 1;
                self.row(*round).folded_pushes += 1;
            }
            Event::LeaseGrant { round, worker, .. } => {
                self.lane(*worker, seq).granted += 1;
                self.row(*round).granted += 1;
            }
            Event::LeaseFold { round, worker, .. } => {
                self.lane(*worker, seq).folded += 1;
                self.row(*round).folded += 1;
            }
            Event::Cut { round, clients } => {
                self.row(*round).cut += clients.len() as u64;
            }
            Event::AsyncFold { epoch, staleness_max, .. } => {
                let row = self.row(*epoch);
                row.staleness_max = row.staleness_max.max(*staleness_max);
            }
            Event::Migration { round, .. } => {
                self.row(*round).migrated += 1;
            }
            Event::Malformed { worker, .. } => {
                self.malformed += 1;
                if let Some(w) = worker {
                    self.lane(*w, seq).malformed += 1;
                }
            }
            Event::RoundCommit { round, participated, nll, comm_bytes_wire, wall_us } => {
                let row = self.row(*round);
                row.committed = true;
                row.participated = *participated;
                row.nll = *nll;
                row.wire_bytes = *comm_bytes_wire;
                row.wall_us = *wall_us;
                self.total_wire_bytes += *comm_bytes_wire;
            }
            Event::Stall { .. } => self.stalls += 1,
            Event::Shutdown { .. } => self.shutdown = true,
        }
        true
    }

    pub fn apply_all(&mut self, records: &[EventRecord]) {
        for rec in records {
            self.apply(rec);
        }
    }

    // -- aggregates ------------------------------------------------------

    pub fn committed_rounds(&self) -> u64 {
        self.rounds.values().filter(|r| r.committed).count() as u64
    }

    pub fn total_granted(&self) -> u64 {
        self.rounds.values().map(|r| r.granted).sum()
    }

    pub fn total_folded(&self) -> u64 {
        self.rounds.values().map(|r| r.folded).sum()
    }

    pub fn total_cut(&self) -> u64 {
        self.rounds.values().map(|r| r.cut).sum()
    }

    pub fn total_migrated(&self) -> u64 {
        self.rounds.values().map(|r| r.migrated).sum()
    }

    pub fn total_rejoined(&self) -> u64 {
        self.workers.values().map(|l| l.rejoins).sum()
    }

    /// Committed rounds' losses, in round order (the sparkline input).
    pub fn nll_series(&self) -> Vec<f64> {
        self.rounds.values().filter(|r| r.committed).map(|r| r.nll).collect()
    }

    pub fn final_nll(&self) -> Option<f64> {
        self.rounds.values().filter(|r| r.committed).next_back().map(|r| r.nll)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(seq: u64, event: Event) -> EventRecord {
        EventRecord { seq, ts_us: seq, event }
    }

    #[test]
    fn reducer_counts_and_drops_stale() {
        let mut v = ViewState::default();
        assert!(v.apply(&rec(0, Event::LeaseGrant { round: 0, client: 1, worker: 0 })));
        assert!(v.apply(&rec(1, Event::LeaseFold { round: 0, client: 1, worker: 0 })));
        assert!(
            !v.apply(&rec(1, Event::LeaseFold { round: 0, client: 1, worker: 0 })),
            "replayed seq must be dropped"
        );
        assert_eq!(v.dropped_stale, 1);
        assert_eq!(v.total_granted(), 1);
        assert_eq!(v.total_folded(), 1, "stale fold must not double-count");
        assert_eq!(v.workers.get(&0).map(|l| l.last_seq), Some(1));
    }

    #[test]
    fn commit_fills_the_row() {
        let mut v = ViewState::default();
        v.apply(&rec(
            0,
            Event::RoundCommit {
                round: 3,
                participated: 5,
                nll: 4.75,
                comm_bytes_wire: 2048,
                wall_us: 900,
            },
        ));
        let row = v.rounds.get(&3).unwrap();
        assert!(row.committed);
        assert_eq!((row.participated, row.wire_bytes, row.wall_us), (5, 2048, 900));
        assert_eq!(v.final_nll(), Some(4.75));
        assert_eq!(v.nll_series(), vec![4.75]);
        assert_eq!(v.total_wire_bytes, 2048);
    }
}
