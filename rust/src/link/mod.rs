//! Photon Link — the communication gateway between the Aggregator and the
//! LLM Nodes (paper §4.1): model-payload serialization, *lossless*
//! compression ("We do not prune the model by default and only use lossless
//! compression"), integrity checking, and — since wire v2 — the carrier
//! for the *opt-in* lossy update codecs of [`crate::compress`]
//! (q8/q4/topk), which trade pseudo-gradient precision for wire bytes.
//!
//! Wire format (little-endian, [`HEADER_BYTES`] = 28-byte header; the
//! byte-exact normative spec lives in `docs/PROTOCOL.md`):
//!   magic "PHLK" (4) | version u16 | kind u16
//!   | flags u32 (bit0 = deflate, bits 8–15 = update-codec id)
//!   | uncompressed_len u64 | checksum u64 (FNV-1a of raw payload) | payload
//!
//! Version 2 added the **codec id** field to the flags word: a nonzero id
//! means the payload is a lossy-coded pseudo-gradient body
//! ([`crate::compress`]) rather than raw f32s, and must be decoded with
//! [`decode_update`] against the negotiated codec. Version-1 frames (no
//! codec field, those bits were reserved-zero) still decode; id 0 frames
//! are byte-compatible with v1 apart from the version halfword.
//!
//! A frame with an empty payload is exactly 28 bytes and is valid — the
//! decoder accepts any frame of at least the header size. Frames written by
//! a *newer* peer (version > [`VERSION`]) are rejected with an explicit
//! upgrade error; flag bits this build does not understand are rejected the
//! same way, so header corruption cannot be silently ignored. The chaos
//! plane ([`crate::chaos::flake_frame`]) leans on exactly these checks:
//! a flaked (bit-flipped or truncated) frame is always *rejected* here,
//! never mis-decoded into different bytes — property-tested in
//! `tests/props_chaos.rs` and drilled live by the `Flake` fault.
//!
//! The frame path is **zero-copy** when the negotiated codec is `none`
//! and deflate is off: [`encode_coded`] writes the update slice straight
//! into the framed output (one exact-capacity allocation, no intermediate
//! body buffer), and the `_ref` decoders ([`decode_coded_ref`],
//! [`decode_bytes_ref`]) hand back a `Cow::Borrowed` view of the frame
//! after verifying the checksum in place — allocation-count-tested in
//! `tests/props_perf.rs` via the testkit counting allocator.
//!
//! Two payload shapes share the format: model payloads (f32 vectors, the
//! original `GlobalModel`/`ClientUpdate`/`Metrics` kinds) and the `net`
//! deployment plane's control messages (opaque byte bodies encoded by
//! `net::proto`). The netsim module prices these payloads, the wall-clock
//! simulator (`sim`) accepts measured frame sizes as its transfer payloads,
//! and the `net` runtime carries them over real TCP sockets.

use std::borrow::Cow;
use std::io::{Read, Write};

use anyhow::{bail, ensure, Result};

use crate::compress::UpdateCodec;

/// Message kinds exchanged during a round (Algorithm 1) plus the `net`
/// deployment plane's control messages (paper §4.1's Aggregator ↔ LLM Node
/// protocol; see `net::proto` for the bodies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Server → client: global model broadcast.
    GlobalModel = 1,
    /// Client → server: model update (pseudo-gradient source).
    ClientUpdate = 2,
    /// Client → server: metrics payload.
    Metrics = 3,
    /// Worker → server: session admission request (version handshake).
    Join = 4,
    /// Server → worker: admission granted + task spec.
    JoinAck = 5,
    /// Server → worker: one round's work order (global model + clients).
    RoundAssign = 6,
    /// Worker → server: one client's finished local round.
    UpdatePush = 7,
    /// Worker → server: assignment acknowledgement.
    Heartbeat = 8,
    /// Server → worker: round folded into the global model.
    RoundCommit = 9,
    /// Server → worker: training finished, disconnect cleanly.
    Shutdown = 10,
    /// Server → worker: admission refused (version mismatch etc.).
    Reject = 11,
    /// Sub-aggregator → server: tier admission request (proto v4 — the
    /// joiner leases a *slice* of each round's sampled clients and folds
    /// them locally; see `net::subagg`).
    SubJoin = 12,
    /// Sub-aggregator → server: one pre-folded `(weight, mean)` pair plus
    /// the member updates' metrics and advanced client states (proto v4).
    FoldedPush = 13,
}

impl MsgKind {
    fn from_u16(v: u16) -> Result<MsgKind> {
        Ok(match v {
            1 => MsgKind::GlobalModel,
            2 => MsgKind::ClientUpdate,
            3 => MsgKind::Metrics,
            4 => MsgKind::Join,
            5 => MsgKind::JoinAck,
            6 => MsgKind::RoundAssign,
            7 => MsgKind::UpdatePush,
            8 => MsgKind::Heartbeat,
            9 => MsgKind::RoundCommit,
            10 => MsgKind::Shutdown,
            11 => MsgKind::Reject,
            12 => MsgKind::SubJoin,
            13 => MsgKind::FoldedPush,
            _ => bail!("unknown message kind {v}"),
        })
    }
}

const MAGIC: &[u8; 4] = b"PHLK";
/// Current wire version (v2: update-codec id in flags bits 8–15). Peers
/// emitting a newer version are rejected with an upgrade error (see
/// [`decode_bytes`]).
pub const VERSION: u16 = 2;
/// Oldest wire version this build still decodes (v1 frames carry no codec
/// field and decode as codec id 0).
const MIN_VERSION: u16 = 1;
/// Flag bits with a defined meaning; anything else is rejected.
const FLAG_DEFLATE: u32 = 1;
/// Bit offset of the update-codec id inside the flags word (v2+).
const CODEC_SHIFT: u32 = 8;
/// Mask of the update-codec id field inside the flags word (v2+).
const CODEC_FLAG_MASK: u32 = 0xFF << CODEC_SHIFT;

/// Frame header size: magic (4) + version (2) + kind (2) + flags (4) +
/// uncompressed_len (8) + checksum (8).
pub const HEADER_BYTES: usize = 28;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_as_bytes(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("payload length {} not a multiple of 4", bytes.len());
    }
    let mut out = vec![0f32; bytes.len() / 4];
    for (i, ch) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
    }
    Ok(out)
}

/// Encode an arbitrary byte payload into a Photon-Link frame (the `net`
/// control plane's transport; model payloads go through [`encode_model`]).
pub fn encode_bytes(kind: MsgKind, raw: &[u8], compress: bool) -> Result<Vec<u8>> {
    encode_coded(kind, 0, raw, compress)
}

/// Encode a payload with an update-codec id in the frame flags (id 0 =
/// raw payload, identical to [`encode_bytes`]; nonzero ids mark a
/// [`crate::compress`] coded body and require [`decode_update`] /
/// [`decode_coded`] on the far side).
pub fn encode_coded(kind: MsgKind, codec_id: u8, raw: &[u8], compress: bool) -> Result<Vec<u8>> {
    let checksum = fnv1a(raw);
    let flags = (compress as u32) | ((codec_id as u32) << CODEC_SHIFT);
    // Header first, payload straight after: the uncompressed path writes
    // the update slice directly into the framed body — exactly one
    // allocation (the exact-capacity frame itself), no intermediate body
    // buffer. The deflate path streams the encoder into the same vec.
    let mut out = Vec::with_capacity(HEADER_BYTES + raw.len());
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(kind as u16).to_le_bytes());
    out.extend_from_slice(&flags.to_le_bytes());
    out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
    out.extend_from_slice(&checksum.to_le_bytes());
    if compress {
        let mut enc = flate2::write::DeflateEncoder::new(out, flate2::Compression::fast());
        enc.write_all(raw)?;
        out = enc.finish()?;
    } else {
        out.extend_from_slice(raw);
    }
    Ok(out)
}

/// Encode a model payload into a Photon-Link frame.
///
/// The payload is raw little-endian f32s (codec id 0 in the frame flags);
/// `compress` applies the frame's *lossless* deflate. Lossy-coded
/// pseudo-gradients go through [`encode_update`] instead, which stamps the
/// codec id into the header so decoders can never misread a coded body as
/// dense parameters.
///
/// # Example
///
/// ```
/// use photon::link::{decode_model, encode_model, MsgKind};
///
/// let params = vec![0.25f32, -1.0, 3.5];
/// let frame = encode_model(MsgKind::GlobalModel, &params, true).unwrap();
/// let (kind, back) = decode_model(&frame).unwrap();
/// assert_eq!(kind, MsgKind::GlobalModel);
/// assert_eq!(back, params, "deflate is lossless");
/// ```
pub fn encode_model(kind: MsgKind, params: &[f32], compress: bool) -> Result<Vec<u8>> {
    encode_bytes(kind, f32s_as_bytes(params), compress)
}

/// Borrowing decode + verify of a Photon-Link frame into
/// `(kind, codec_id, raw bytes)`. For **uncompressed** frames — the hot
/// path when the negotiated codec is `none` — the returned payload is a
/// `Cow::Borrowed` view into `frame`: the checksum is verified in place and
/// nothing is allocated or copied. Deflated frames still inflate into an
/// owned buffer. Every hardening check (magic, version window, unknown
/// flag bits, declared length, checksum — see `docs/PROTOCOL.md`) is
/// identical to [`decode_coded`], which delegates here; the zero-copy
/// property tests in `tests/props_perf.rs` hold both decoders to the same
/// corruption corpus and pin the allocation count.
pub fn decode_coded_ref(frame: &[u8]) -> Result<(MsgKind, u8, Cow<'_, [u8]>)> {
    // The header is 28 bytes; an empty payload is legal (e.g. a metrics
    // probe), so anything of at least HEADER_BYTES with the magic passes.
    if frame.len() < HEADER_BYTES || &frame[..4] != MAGIC {
        bail!("bad frame header");
    }
    let version = u16::from_le_bytes([frame[4], frame[5]]);
    if version > VERSION {
        bail!(
            "frame uses link version {version}, newer than this build \
             supports (≤ {VERSION}) — upgrade this node to talk to that peer"
        );
    }
    if version < MIN_VERSION {
        bail!("unsupported link version {version} (this build decodes {MIN_VERSION}..={VERSION})");
    }
    let kind = MsgKind::from_u16(u16::from_le_bytes([frame[6], frame[7]]))?;
    let flags = u32::from_le_bytes([frame[8], frame[9], frame[10], frame[11]]);
    // v1 frames predate the codec field: those bits were reserved-zero.
    let known = FLAG_DEFLATE | if version >= 2 { CODEC_FLAG_MASK } else { 0 };
    if flags & !known != 0 {
        bail!("frame carries unknown flag bits {flags:#x} — corrupted or newer peer");
    }
    let codec_id = ((flags & CODEC_FLAG_MASK) >> CODEC_SHIFT) as u8;
    // lint:allow(wire-panic): try_into on a fixed 8-byte slice of a length-checked header is infallible
    let raw_len = u64::from_le_bytes(frame[12..20].try_into().unwrap()) as usize;
    // lint:allow(wire-panic): try_into on a fixed 8-byte slice of a length-checked header is infallible
    let checksum = u64::from_le_bytes(frame[20..28].try_into().unwrap());
    let body = &frame[28..];
    let raw: Cow<'_, [u8]> = if flags & FLAG_DEFLATE != 0 {
        // `raw_len` is untrusted — never pre-allocate from it. Deflate
        // expands at most ~1032:1, so a declared length beyond that is
        // corrupt on its face, and `take` caps a decompression bomb at
        // one byte past the declared length (the mismatch check catches
        // it) instead of inflating the whole stream.
        if raw_len > body.len().saturating_mul(1032).saturating_add(64) {
            bail!("frame declares {raw_len} raw bytes from a {}-byte body", body.len());
        }
        let mut dec = flate2::read::DeflateDecoder::new(body).take(raw_len as u64 + 1);
        let mut out = Vec::new();
        dec.read_to_end(&mut out)?;
        Cow::Owned(out)
    } else {
        if raw_len != body.len() {
            bail!("frame declares {raw_len} raw bytes, got {}", body.len());
        }
        // Zero-copy: the payload is the frame's own body slice, verified
        // below without materializing a second buffer.
        Cow::Borrowed(body)
    };
    if raw.len() != raw_len {
        bail!("frame declares {raw_len} raw bytes, got {}", raw.len());
    }
    if fnv1a(&raw) != checksum {
        bail!("checksum mismatch — corrupted frame");
    }
    Ok((kind, codec_id, raw))
}

/// Decode + verify a Photon-Link frame into `(kind, codec_id, raw bytes)`.
/// The payload is checksum-verified and inflated but **not** codec-decoded
/// — pass a nonzero-id payload to [`crate::compress::UpdateCodec::decode_delta`]
/// (or use [`decode_update`], which does both and enforces the negotiated
/// codec). Owning wrapper over [`decode_coded_ref`]; callers that only
/// inspect the payload should use the `_ref` variant and skip the copy.
pub fn decode_coded(frame: &[u8]) -> Result<(MsgKind, u8, Vec<u8>)> {
    let (kind, codec_id, raw) = decode_coded_ref(frame)?;
    Ok((kind, codec_id, raw.into_owned()))
}

/// Borrowing variant of [`decode_bytes`]: uncompressed payloads come back
/// as a `Cow::Borrowed` view of the frame (no allocation on the hot path).
/// Refuses codec-coded frames (nonzero codec id) — those must go through
/// [`decode_update`] so the body is interpreted against the negotiated
/// codec, never as plain bytes.
pub fn decode_bytes_ref(frame: &[u8]) -> Result<(MsgKind, Cow<'_, [u8]>)> {
    let (kind, codec_id, raw) = decode_coded_ref(frame)?;
    ensure!(
        codec_id == 0,
        "frame carries a codec-coded payload (codec id {codec_id}) — decode \
         it with link::decode_update against the negotiated codec"
    );
    Ok((kind, raw))
}

/// Decode + verify a Photon-Link frame into its raw byte payload. Refuses
/// codec-coded frames (nonzero codec id) — those must go through
/// [`decode_update`] so the body is interpreted against the negotiated
/// codec, never as plain bytes. Owning wrapper over [`decode_bytes_ref`].
pub fn decode_bytes(frame: &[u8]) -> Result<(MsgKind, Vec<u8>)> {
    let (kind, raw) = decode_bytes_ref(frame)?;
    Ok((kind, raw.into_owned()))
}

/// Decode + verify a Photon-Link frame carrying a model payload.
///
/// Counterpart of [`encode_model`]: accepts only codec-id-0 frames (raw
/// f32 payloads, deflated or not) and rejects lossy-coded frames with an
/// explicit error — the codec-id header byte routes every frame to exactly
/// one decoder, so corruption flips are refused rather than mis-decoded.
pub fn decode_model(frame: &[u8]) -> Result<(MsgKind, Vec<f32>)> {
    // Borrowing decode: the f32 vector is parsed straight out of the frame
    // body, skipping the former byte-payload copy.
    let (kind, raw) = decode_bytes_ref(frame)?;
    Ok((kind, bytes_to_f32s(&raw)?))
}

/// Encode a pseudo-gradient (or any dense f32 update vector) through an
/// update codec into a Photon-Link frame. Lossless codecs emit a codec-id-0
/// frame bit-identical to [`encode_model`] (`deflate` forces the frame's
/// deflate flag); lossy codecs emit their coded body with the codec id
/// stamped into the frame flags. `seed` drives stochastic rounding and
/// `residual` is the caller's error-feedback state (see
/// [`crate::compress`]).
pub fn encode_update(
    kind: MsgKind,
    dense: &[f32],
    codec: &UpdateCodec,
    seed: u64,
    residual: &mut Vec<f32>,
    compress: bool,
) -> Result<Vec<u8>> {
    match codec.encode_delta(dense, seed, residual)? {
        None => encode_model(
            kind,
            dense,
            compress || matches!(codec, UpdateCodec::Deflate),
        ),
        Some(body) => encode_coded(kind, codec.wire_id(), &body, compress),
    }
}

/// Decode a frame produced by [`encode_update`] against the *negotiated*
/// codec. The frame's codec id must equal the negotiated codec's wire id
/// exactly — a dense frame where a coded one was negotiated (or vice
/// versa, or any corrupted codec-id byte) is an error, never a silent
/// mis-decode — and the decoded vector must have exactly `expect_len`
/// elements.
pub fn decode_update(
    frame: &[u8],
    codec: &UpdateCodec,
    expect_len: usize,
) -> Result<(MsgKind, Vec<f32>)> {
    // Borrowing decode: an uncompressed dense (codec-id-0) frame parses its
    // f32s straight out of the frame body, and a coded body feeds the codec
    // from the borrowed slice — the per-frame payload copy is gone.
    let (kind, codec_id, raw) = decode_coded_ref(frame)?;
    ensure!(
        codec_id == codec.wire_id(),
        "frame carries codec id {codec_id}, negotiated codec is {} (id {}) — \
         corrupted header or codec renegotiation drift",
        codec.label(),
        codec.wire_id()
    );
    if codec_id == 0 {
        let dense = bytes_to_f32s(&raw)?;
        ensure!(
            dense.len() == expect_len,
            "dense update has {} params, expected {expect_len}",
            dense.len()
        );
        Ok((kind, dense))
    } else {
        Ok((kind, codec.decode_delta(&raw, expect_len)?))
    }
}

/// Bytes one round moves through the link for `k` clients with an
/// `n_params` model: broadcast down + updates up (uncompressed accounting;
/// the paper's Table-style comm numbers use raw f32 payloads).
pub fn round_bytes(n_params: usize, k: usize) -> u64 {
    2 * (n_params as u64) * 4 * (k as u64)
}

/// Pre-deflate framed size of a payload body: body + one frame header.
/// The single source of truth for the transit accounting both federation
/// planes fold into `RoundRecord::comm_bytes_wire` — the in-process
/// transit pass, the server's decode-then-fold, and `commit_round`'s
/// dense-frame substitution all price frames through here, so the
/// bit-parity contract cannot drift between call sites.
pub fn framed_bytes(body_len: usize) -> u64 {
    (body_len + HEADER_BYTES) as u64
}

/// Framed size of one dense f32 payload of `n_params` values
/// (`4·n_params` + one header) — see [`framed_bytes`].
pub fn dense_frame_bytes(n_params: usize) -> u64 {
    framed_bytes(n_params * 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin() * 0.02).collect()
    }

    #[test]
    fn roundtrip_uncompressed() {
        let p = payload(1000);
        let f = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        let (kind, back) = decode_model(&f).unwrap();
        assert_eq!(kind, MsgKind::GlobalModel);
        assert_eq!(back, p);
    }

    #[test]
    fn roundtrip_compressed_lossless() {
        let p = payload(5000);
        let f = encode_model(MsgKind::ClientUpdate, &p, true).unwrap();
        let (kind, back) = decode_model(&f).unwrap();
        assert_eq!(kind, MsgKind::ClientUpdate);
        assert_eq!(back, p, "compression must be lossless");
    }

    #[test]
    fn compression_shrinks_structured_payloads() {
        // Many repeated values (LN gains etc.) compress well.
        let p = vec![1.0f32; 10_000];
        let c = encode_model(MsgKind::GlobalModel, &p, true).unwrap();
        let u = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        assert!(c.len() < u.len() / 4, "{} vs {}", c.len(), u.len());
    }

    #[test]
    fn zero_payload_frame_is_valid() {
        // A header-only frame (28 bytes) round-trips; the old decoder
        // rejected anything under 32 bytes and broke this case.
        for compress in [false, true] {
            let f = encode_model(MsgKind::Metrics, &[], compress).unwrap();
            if !compress {
                assert_eq!(f.len(), HEADER_BYTES);
            }
            let (kind, back) = decode_model(&f).unwrap();
            assert_eq!(kind, MsgKind::Metrics);
            assert!(back.is_empty());
        }
    }

    #[test]
    fn truncated_header_rejected() {
        let f = encode_model(MsgKind::Metrics, &[], false).unwrap();
        assert!(decode_model(&f[..HEADER_BYTES - 1]).is_err());
    }

    #[test]
    fn corruption_detected() {
        let p = payload(256);
        let mut f = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        let last = f.len() - 1;
        f[last] ^= 0xFF;
        assert!(decode_model(&f).is_err());
    }

    #[test]
    fn header_errors() {
        assert!(decode_model(b"nope").is_err());
        let p = payload(4);
        let mut f = encode_model(MsgKind::Metrics, &p, false).unwrap();
        f[4] = 9; // version
        assert!(decode_model(&f).is_err());
    }

    #[test]
    fn newer_version_rejected_with_upgrade_error() {
        let mut f = encode_model(MsgKind::GlobalModel, &payload(4), false).unwrap();
        let v = (VERSION + 1).to_le_bytes();
        f[4] = v[0];
        f[5] = v[1];
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("newer"), "error must name the cause: {err}");
        // Version 0 (older than MIN_VERSION) is a plain unsupported error.
        f[4] = 0;
        f[5] = 0;
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("unsupported"), "{err}");
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let mut f = encode_model(MsgKind::GlobalModel, &payload(4), false).unwrap();
        f[10] = 0x01; // flags bits 16–23: undefined in every version
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("flag"), "{err}");
        // In a v1 frame even the codec field (bits 8–15) is undefined.
        let mut old = encode_model(MsgKind::GlobalModel, &payload(4), false).unwrap();
        old[4] = 1;
        old[5] = 0;
        assert!(decode_model(&old).is_ok(), "v1 frames still decode");
        old[9] = 0x02;
        let err = decode_model(&old).unwrap_err().to_string();
        assert!(err.contains("flag"), "{err}");
    }

    #[test]
    fn codec_frames_are_refused_by_the_raw_decoders() {
        // A frame whose flags carry a codec id must never decode as plain
        // bytes/model params — the id routes it to decode_update.
        let f = encode_coded(MsgKind::ClientUpdate, 2, &[1, 2, 3, 4], false).unwrap();
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("codec"), "{err}");
        assert!(decode_bytes(&f).is_err());
        let (kind, id, raw) = decode_coded(&f).unwrap();
        assert_eq!((kind, id), (MsgKind::ClientUpdate, 2));
        assert_eq!(raw, vec![1, 2, 3, 4]);
    }

    #[test]
    fn encode_update_roundtrips_every_codec() {
        use crate::compress::UpdateCodec;
        let dense = payload(777);
        for codec in [
            UpdateCodec::None,
            UpdateCodec::Deflate,
            UpdateCodec::Q8 { block: 64 },
            UpdateCodec::Q4 { block: 64 },
            UpdateCodec::TopK { keep_permille: 100 },
        ] {
            let mut residual = Vec::new();
            let f =
                encode_update(MsgKind::ClientUpdate, &dense, &codec, 5, &mut residual, true)
                    .unwrap();
            let (kind, back) = decode_update(&f, &codec, dense.len()).unwrap();
            assert_eq!(kind, MsgKind::ClientUpdate);
            assert_eq!(back.len(), dense.len());
            if !codec.is_lossy() {
                assert_eq!(back, dense, "{} must be lossless", codec.label());
            }
            // Negotiation is strict: decoding against a different codec
            // fails even when the frame itself is intact.
            let other = if codec.is_lossy() {
                UpdateCodec::None
            } else {
                UpdateCodec::Q8 { block: 64 }
            };
            assert!(decode_update(&f, &other, dense.len()).is_err());
        }
    }

    #[test]
    fn corrupted_codec_id_byte_never_misdecodes() {
        use crate::compress::UpdateCodec;
        let codec = UpdateCodec::Q8 { block: 2 };
        // n = 15, block = 2 makes the q8 body exactly 4·n bytes — the one
        // shape where a flipped codec id *could* alias a dense f32 payload
        // of the right length if the id were not enforced.
        let dense = payload(15);
        let mut residual = Vec::new();
        let f = encode_update(MsgKind::ClientUpdate, &dense, &codec, 5, &mut residual, false)
            .unwrap();
        assert_eq!(f.len() - HEADER_BYTES, 60);
        for wrong in [0u8, 1, 3, 4, 0xFF] {
            let mut bad = f.clone();
            bad[9] = wrong; // flags bits 8–15 = the codec id
            assert!(
                decode_update(&bad, &codec, 15).is_err(),
                "codec id {wrong} must be rejected, not mis-decoded"
            );
        }
    }

    #[test]
    fn chaos_flaked_frames_are_rejected_across_kinds() {
        // The chaos plane's link-flake contract from the transport's side:
        // whatever the kind or compression, a flaked frame fails decode.
        let body: Vec<u8> = (0..257u16).map(|i| (i * 7 % 251) as u8).collect();
        for kind in [MsgKind::GlobalModel, MsgKind::UpdatePush, MsgKind::Heartbeat] {
            for compress in [false, true] {
                let clean = encode_bytes(kind, &body, compress).unwrap();
                for seed in 0..16u64 {
                    let mut bad = clean.clone();
                    crate::chaos::flake_frame(&mut bad, seed);
                    assert!(
                        decode_bytes(&bad).is_err(),
                        "{kind:?} compress={compress} seed={seed}"
                    );
                }
            }
        }
    }

    #[test]
    fn byte_payload_roundtrip_all_control_kinds() {
        let body: Vec<u8> = (0..200u16).map(|i| (i % 251) as u8).collect();
        for kind in [
            MsgKind::Join,
            MsgKind::JoinAck,
            MsgKind::RoundAssign,
            MsgKind::UpdatePush,
            MsgKind::Heartbeat,
            MsgKind::RoundCommit,
            MsgKind::Shutdown,
            MsgKind::Reject,
        ] {
            for compress in [false, true] {
                let f = encode_bytes(kind, &body, compress).unwrap();
                let (k, back) = decode_bytes(&f).unwrap();
                assert_eq!(k, kind);
                assert_eq!(back, body);
            }
        }
        // Byte payloads need not be f32-aligned — only decode_model cares.
        let f = encode_bytes(MsgKind::Heartbeat, &[1, 2, 3], false).unwrap();
        assert!(decode_model(&f).is_err());
        assert_eq!(decode_bytes(&f).unwrap().1, vec![1, 2, 3]);
    }

    #[test]
    fn ref_decode_agrees_with_owning_decode() {
        let p = payload(513);
        for compress in [false, true] {
            let f = encode_model(MsgKind::ClientUpdate, &p, compress).unwrap();
            let (k1, id1, raw1) = decode_coded(&f).unwrap();
            let (k2, id2, raw2) = decode_coded_ref(&f).unwrap();
            assert_eq!((k1, id1), (k2, id2));
            assert_eq!(raw1.as_slice(), raw2.as_ref());
            // Uncompressed payloads borrow the frame; deflated ones must
            // inflate into an owned buffer.
            assert_eq!(
                matches!(raw2, Cow::Borrowed(_)),
                !compress,
                "compress={compress}"
            );
        }
    }

    #[test]
    fn ref_decode_rejects_what_owning_decode_rejects() {
        let p = payload(64);
        let clean = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        for i in 0..clean.len() {
            for bit in [0x01u8, 0x80] {
                let mut bad = clean.clone();
                bad[i] ^= bit;
                let own = decode_bytes(&bad).map(|(k, r)| (k, r));
                let brw = decode_bytes_ref(&bad).map(|(k, r)| (k, r.into_owned()));
                match (own, brw) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "byte {i} bit {bit:#x}"),
                    (Err(_), Err(_)) => {}
                    (a, b) => panic!(
                        "decoders disagree at byte {i} bit {bit:#x}: {a:?} vs {b:?}"
                    ),
                }
            }
        }
    }

    #[test]
    fn round_bytes_formula() {
        // 8 clients, 1M params: 2 * 4MB * 8 = 64 MB.
        assert_eq!(round_bytes(1_000_000, 8), 64_000_000);
    }
}
