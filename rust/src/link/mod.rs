//! Photon Link — the communication gateway between the Aggregator and the
//! LLM Nodes (paper §4.1): model-payload serialization, *lossless*
//! compression ("We do not prune the model by default and only use lossless
//! compression"), and integrity checking.
//!
//! Wire format (little-endian, [`HEADER_BYTES`] = 28-byte header):
//!   magic "PHLK" (4) | version u16 | kind u16 | flags u32 (bit0 = deflate)
//!   | uncompressed_len u64 | checksum u64 (FNV-1a of raw payload) | payload
//!
//! A frame with an empty payload is exactly 28 bytes and is valid — the
//! decoder accepts any frame of at least the header size.
//!
//! The netsim module prices these payloads, and the wall-clock simulator
//! (`sim`) accepts measured frame sizes as its transfer payloads; the
//! `comm` and `wallclock` experiments use the measured compressed sizes.

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Message kinds exchanged during a round (Algorithm 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Server → client: global model broadcast.
    GlobalModel = 1,
    /// Client → server: model update (pseudo-gradient source).
    ClientUpdate = 2,
    /// Client → server: metrics payload.
    Metrics = 3,
}

impl MsgKind {
    fn from_u16(v: u16) -> Result<MsgKind> {
        Ok(match v {
            1 => MsgKind::GlobalModel,
            2 => MsgKind::ClientUpdate,
            3 => MsgKind::Metrics,
            _ => bail!("unknown message kind {v}"),
        })
    }
}

const MAGIC: &[u8; 4] = b"PHLK";
const VERSION: u16 = 1;

/// Frame header size: magic (4) + version (2) + kind (2) + flags (4) +
/// uncompressed_len (8) + checksum (8).
pub const HEADER_BYTES: usize = 28;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_as_bytes(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("payload length {} not a multiple of 4", bytes.len());
    }
    let mut out = vec![0f32; bytes.len() / 4];
    for (i, ch) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
    }
    Ok(out)
}

/// Encode a model payload into a Photon-Link frame.
pub fn encode_model(kind: MsgKind, params: &[f32], compress: bool) -> Result<Vec<u8>> {
    let raw = f32s_as_bytes(params);
    let checksum = fnv1a(raw);
    let body: Vec<u8> = if compress {
        let mut enc =
            flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::fast());
        enc.write_all(raw)?;
        enc.finish()?
    } else {
        raw.to_vec()
    };
    let mut out = Vec::with_capacity(body.len() + HEADER_BYTES);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(kind as u16).to_le_bytes());
    out.extend_from_slice(&(compress as u32).to_le_bytes());
    out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
    out.extend_from_slice(&checksum.to_le_bytes());
    out.extend_from_slice(&body);
    Ok(out)
}

/// Decode + verify a Photon-Link frame.
pub fn decode_model(frame: &[u8]) -> Result<(MsgKind, Vec<f32>)> {
    // The header is 28 bytes; an empty payload is legal (e.g. a metrics
    // probe), so anything of at least HEADER_BYTES with the magic passes.
    if frame.len() < HEADER_BYTES || &frame[..4] != MAGIC {
        bail!("bad frame header");
    }
    let version = u16::from_le_bytes([frame[4], frame[5]]);
    if version != VERSION {
        bail!("unsupported link version {version}");
    }
    let kind = MsgKind::from_u16(u16::from_le_bytes([frame[6], frame[7]]))?;
    let flags = u32::from_le_bytes([frame[8], frame[9], frame[10], frame[11]]);
    let raw_len = u64::from_le_bytes(frame[12..20].try_into().unwrap()) as usize;
    let checksum = u64::from_le_bytes(frame[20..28].try_into().unwrap());
    let body = &frame[28..];
    let raw: Vec<u8> = if flags & 1 != 0 {
        let mut dec = flate2::read::DeflateDecoder::new(body);
        let mut out = Vec::with_capacity(raw_len);
        dec.read_to_end(&mut out)?;
        out
    } else {
        body.to_vec()
    };
    if raw.len() != raw_len {
        bail!("frame declares {raw_len} raw bytes, got {}", raw.len());
    }
    if fnv1a(&raw) != checksum {
        bail!("checksum mismatch — corrupted frame");
    }
    Ok((kind, bytes_to_f32s(&raw)?))
}

/// Bytes one round moves through the link for `k` clients with an
/// `n_params` model: broadcast down + updates up (uncompressed accounting;
/// the paper's Table-style comm numbers use raw f32 payloads).
pub fn round_bytes(n_params: usize, k: usize) -> u64 {
    2 * (n_params as u64) * 4 * (k as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin() * 0.02).collect()
    }

    #[test]
    fn roundtrip_uncompressed() {
        let p = payload(1000);
        let f = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        let (kind, back) = decode_model(&f).unwrap();
        assert_eq!(kind, MsgKind::GlobalModel);
        assert_eq!(back, p);
    }

    #[test]
    fn roundtrip_compressed_lossless() {
        let p = payload(5000);
        let f = encode_model(MsgKind::ClientUpdate, &p, true).unwrap();
        let (kind, back) = decode_model(&f).unwrap();
        assert_eq!(kind, MsgKind::ClientUpdate);
        assert_eq!(back, p, "compression must be lossless");
    }

    #[test]
    fn compression_shrinks_structured_payloads() {
        // Many repeated values (LN gains etc.) compress well.
        let p = vec![1.0f32; 10_000];
        let c = encode_model(MsgKind::GlobalModel, &p, true).unwrap();
        let u = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        assert!(c.len() < u.len() / 4, "{} vs {}", c.len(), u.len());
    }

    #[test]
    fn zero_payload_frame_is_valid() {
        // A header-only frame (28 bytes) round-trips; the old decoder
        // rejected anything under 32 bytes and broke this case.
        for compress in [false, true] {
            let f = encode_model(MsgKind::Metrics, &[], compress).unwrap();
            if !compress {
                assert_eq!(f.len(), HEADER_BYTES);
            }
            let (kind, back) = decode_model(&f).unwrap();
            assert_eq!(kind, MsgKind::Metrics);
            assert!(back.is_empty());
        }
    }

    #[test]
    fn truncated_header_rejected() {
        let f = encode_model(MsgKind::Metrics, &[], false).unwrap();
        assert!(decode_model(&f[..HEADER_BYTES - 1]).is_err());
    }

    #[test]
    fn corruption_detected() {
        let p = payload(256);
        let mut f = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        let last = f.len() - 1;
        f[last] ^= 0xFF;
        assert!(decode_model(&f).is_err());
    }

    #[test]
    fn header_errors() {
        assert!(decode_model(b"nope").is_err());
        let p = payload(4);
        let mut f = encode_model(MsgKind::Metrics, &p, false).unwrap();
        f[4] = 9; // version
        assert!(decode_model(&f).is_err());
    }

    #[test]
    fn round_bytes_formula() {
        // 8 clients, 1M params: 2 * 4MB * 8 = 64 MB.
        assert_eq!(round_bytes(1_000_000, 8), 64_000_000);
    }
}
