//! Photon Link — the communication gateway between the Aggregator and the
//! LLM Nodes (paper §4.1): model-payload serialization, *lossless*
//! compression ("We do not prune the model by default and only use lossless
//! compression"), and integrity checking.
//!
//! Wire format (little-endian, [`HEADER_BYTES`] = 28-byte header):
//!   magic "PHLK" (4) | version u16 | kind u16 | flags u32 (bit0 = deflate)
//!   | uncompressed_len u64 | checksum u64 (FNV-1a of raw payload) | payload
//!
//! A frame with an empty payload is exactly 28 bytes and is valid — the
//! decoder accepts any frame of at least the header size. Frames written by
//! a *newer* peer (version > [`VERSION`]) are rejected with an explicit
//! upgrade error; flag bits this build does not understand are rejected the
//! same way, so header corruption cannot be silently ignored.
//!
//! Two payload shapes share the format: model payloads (f32 vectors, the
//! original `GlobalModel`/`ClientUpdate`/`Metrics` kinds) and the `net`
//! deployment plane's control messages (opaque byte bodies encoded by
//! `net::proto`). The netsim module prices these payloads, the wall-clock
//! simulator (`sim`) accepts measured frame sizes as its transfer payloads,
//! and the `net` runtime carries them over real TCP sockets.

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Message kinds exchanged during a round (Algorithm 1) plus the `net`
/// deployment plane's control messages (paper §4.1's Aggregator ↔ LLM Node
/// protocol; see `net::proto` for the bodies).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Server → client: global model broadcast.
    GlobalModel = 1,
    /// Client → server: model update (pseudo-gradient source).
    ClientUpdate = 2,
    /// Client → server: metrics payload.
    Metrics = 3,
    /// Worker → server: session admission request (version handshake).
    Join = 4,
    /// Server → worker: admission granted + task spec.
    JoinAck = 5,
    /// Server → worker: one round's work order (global model + clients).
    RoundAssign = 6,
    /// Worker → server: one client's finished local round.
    UpdatePush = 7,
    /// Worker → server: assignment acknowledgement.
    Heartbeat = 8,
    /// Server → worker: round folded into the global model.
    RoundCommit = 9,
    /// Server → worker: training finished, disconnect cleanly.
    Shutdown = 10,
    /// Server → worker: admission refused (version mismatch etc.).
    Reject = 11,
}

impl MsgKind {
    fn from_u16(v: u16) -> Result<MsgKind> {
        Ok(match v {
            1 => MsgKind::GlobalModel,
            2 => MsgKind::ClientUpdate,
            3 => MsgKind::Metrics,
            4 => MsgKind::Join,
            5 => MsgKind::JoinAck,
            6 => MsgKind::RoundAssign,
            7 => MsgKind::UpdatePush,
            8 => MsgKind::Heartbeat,
            9 => MsgKind::RoundCommit,
            10 => MsgKind::Shutdown,
            11 => MsgKind::Reject,
            _ => bail!("unknown message kind {v}"),
        })
    }
}

const MAGIC: &[u8; 4] = b"PHLK";
/// Current wire version. Peers emitting a newer version are rejected with
/// an upgrade error (see [`decode_bytes`]).
pub const VERSION: u16 = 1;
/// Oldest wire version this build still decodes.
const MIN_VERSION: u16 = 1;
/// Flag bits with a defined meaning; anything else is rejected.
const FLAG_DEFLATE: u32 = 1;

/// Frame header size: magic (4) + version (2) + kind (2) + flags (4) +
/// uncompressed_len (8) + checksum (8).
pub const HEADER_BYTES: usize = 28;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn f32s_as_bytes(data: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("payload length {} not a multiple of 4", bytes.len());
    }
    let mut out = vec![0f32; bytes.len() / 4];
    for (i, ch) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
    }
    Ok(out)
}

/// Encode an arbitrary byte payload into a Photon-Link frame (the `net`
/// control plane's transport; model payloads go through [`encode_model`]).
pub fn encode_bytes(kind: MsgKind, raw: &[u8], compress: bool) -> Result<Vec<u8>> {
    let checksum = fnv1a(raw);
    let body: Vec<u8> = if compress {
        let mut enc =
            flate2::write::DeflateEncoder::new(Vec::new(), flate2::Compression::fast());
        enc.write_all(raw)?;
        enc.finish()?
    } else {
        raw.to_vec()
    };
    let mut out = Vec::with_capacity(body.len() + HEADER_BYTES);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(kind as u16).to_le_bytes());
    out.extend_from_slice(&(compress as u32).to_le_bytes());
    out.extend_from_slice(&(raw.len() as u64).to_le_bytes());
    out.extend_from_slice(&checksum.to_le_bytes());
    out.extend_from_slice(&body);
    Ok(out)
}

/// Encode a model payload into a Photon-Link frame.
pub fn encode_model(kind: MsgKind, params: &[f32], compress: bool) -> Result<Vec<u8>> {
    encode_bytes(kind, f32s_as_bytes(params), compress)
}

/// Decode + verify a Photon-Link frame into its raw byte payload.
pub fn decode_bytes(frame: &[u8]) -> Result<(MsgKind, Vec<u8>)> {
    // The header is 28 bytes; an empty payload is legal (e.g. a metrics
    // probe), so anything of at least HEADER_BYTES with the magic passes.
    if frame.len() < HEADER_BYTES || &frame[..4] != MAGIC {
        bail!("bad frame header");
    }
    let version = u16::from_le_bytes([frame[4], frame[5]]);
    if version > VERSION {
        bail!(
            "frame uses link version {version}, newer than this build \
             supports (≤ {VERSION}) — upgrade this node to talk to that peer"
        );
    }
    if version < MIN_VERSION {
        bail!("unsupported link version {version} (this build decodes {MIN_VERSION}..={VERSION})");
    }
    let kind = MsgKind::from_u16(u16::from_le_bytes([frame[6], frame[7]]))?;
    let flags = u32::from_le_bytes([frame[8], frame[9], frame[10], frame[11]]);
    if flags & !FLAG_DEFLATE != 0 {
        bail!("frame carries unknown flag bits {flags:#x} — corrupted or newer peer");
    }
    let raw_len = u64::from_le_bytes(frame[12..20].try_into().unwrap()) as usize;
    let checksum = u64::from_le_bytes(frame[20..28].try_into().unwrap());
    let body = &frame[28..];
    let raw: Vec<u8> = if flags & FLAG_DEFLATE != 0 {
        // `raw_len` is untrusted — never pre-allocate from it. Deflate
        // expands at most ~1032:1, so a declared length beyond that is
        // corrupt on its face, and `take` caps a decompression bomb at
        // one byte past the declared length (the mismatch check catches
        // it) instead of inflating the whole stream.
        if raw_len > body.len().saturating_mul(1032).saturating_add(64) {
            bail!("frame declares {raw_len} raw bytes from a {}-byte body", body.len());
        }
        let mut dec = flate2::read::DeflateDecoder::new(body).take(raw_len as u64 + 1);
        let mut out = Vec::new();
        dec.read_to_end(&mut out)?;
        out
    } else {
        if raw_len != body.len() {
            bail!("frame declares {raw_len} raw bytes, got {}", body.len());
        }
        body.to_vec()
    };
    if raw.len() != raw_len {
        bail!("frame declares {raw_len} raw bytes, got {}", raw.len());
    }
    if fnv1a(&raw) != checksum {
        bail!("checksum mismatch — corrupted frame");
    }
    Ok((kind, raw))
}

/// Decode + verify a Photon-Link frame carrying a model payload.
pub fn decode_model(frame: &[u8]) -> Result<(MsgKind, Vec<f32>)> {
    let (kind, raw) = decode_bytes(frame)?;
    Ok((kind, bytes_to_f32s(&raw)?))
}

/// Bytes one round moves through the link for `k` clients with an
/// `n_params` model: broadcast down + updates up (uncompressed accounting;
/// the paper's Table-style comm numbers use raw f32 payloads).
pub fn round_bytes(n_params: usize, k: usize) -> u64 {
    2 * (n_params as u64) * 4 * (k as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin() * 0.02).collect()
    }

    #[test]
    fn roundtrip_uncompressed() {
        let p = payload(1000);
        let f = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        let (kind, back) = decode_model(&f).unwrap();
        assert_eq!(kind, MsgKind::GlobalModel);
        assert_eq!(back, p);
    }

    #[test]
    fn roundtrip_compressed_lossless() {
        let p = payload(5000);
        let f = encode_model(MsgKind::ClientUpdate, &p, true).unwrap();
        let (kind, back) = decode_model(&f).unwrap();
        assert_eq!(kind, MsgKind::ClientUpdate);
        assert_eq!(back, p, "compression must be lossless");
    }

    #[test]
    fn compression_shrinks_structured_payloads() {
        // Many repeated values (LN gains etc.) compress well.
        let p = vec![1.0f32; 10_000];
        let c = encode_model(MsgKind::GlobalModel, &p, true).unwrap();
        let u = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        assert!(c.len() < u.len() / 4, "{} vs {}", c.len(), u.len());
    }

    #[test]
    fn zero_payload_frame_is_valid() {
        // A header-only frame (28 bytes) round-trips; the old decoder
        // rejected anything under 32 bytes and broke this case.
        for compress in [false, true] {
            let f = encode_model(MsgKind::Metrics, &[], compress).unwrap();
            if !compress {
                assert_eq!(f.len(), HEADER_BYTES);
            }
            let (kind, back) = decode_model(&f).unwrap();
            assert_eq!(kind, MsgKind::Metrics);
            assert!(back.is_empty());
        }
    }

    #[test]
    fn truncated_header_rejected() {
        let f = encode_model(MsgKind::Metrics, &[], false).unwrap();
        assert!(decode_model(&f[..HEADER_BYTES - 1]).is_err());
    }

    #[test]
    fn corruption_detected() {
        let p = payload(256);
        let mut f = encode_model(MsgKind::GlobalModel, &p, false).unwrap();
        let last = f.len() - 1;
        f[last] ^= 0xFF;
        assert!(decode_model(&f).is_err());
    }

    #[test]
    fn header_errors() {
        assert!(decode_model(b"nope").is_err());
        let p = payload(4);
        let mut f = encode_model(MsgKind::Metrics, &p, false).unwrap();
        f[4] = 9; // version
        assert!(decode_model(&f).is_err());
    }

    #[test]
    fn newer_version_rejected_with_upgrade_error() {
        let mut f = encode_model(MsgKind::GlobalModel, &payload(4), false).unwrap();
        let v = (VERSION + 1).to_le_bytes();
        f[4] = v[0];
        f[5] = v[1];
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("newer"), "error must name the cause: {err}");
        // Version 0 (older than MIN_VERSION) is a plain unsupported error.
        f[4] = 0;
        f[5] = 0;
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("unsupported"), "{err}");
    }

    #[test]
    fn unknown_flag_bits_rejected() {
        let mut f = encode_model(MsgKind::GlobalModel, &payload(4), false).unwrap();
        f[9] = 0x80; // a flag bit this build does not define
        let err = decode_model(&f).unwrap_err().to_string();
        assert!(err.contains("flag"), "{err}");
    }

    #[test]
    fn byte_payload_roundtrip_all_control_kinds() {
        let body: Vec<u8> = (0..200u16).map(|i| (i % 251) as u8).collect();
        for kind in [
            MsgKind::Join,
            MsgKind::JoinAck,
            MsgKind::RoundAssign,
            MsgKind::UpdatePush,
            MsgKind::Heartbeat,
            MsgKind::RoundCommit,
            MsgKind::Shutdown,
            MsgKind::Reject,
        ] {
            for compress in [false, true] {
                let f = encode_bytes(kind, &body, compress).unwrap();
                let (k, back) = decode_bytes(&f).unwrap();
                assert_eq!(k, kind);
                assert_eq!(back, body);
            }
        }
        // Byte payloads need not be f32-aligned — only decode_model cares.
        let f = encode_bytes(MsgKind::Heartbeat, &[1, 2, 3], false).unwrap();
        assert!(decode_model(&f).is_err());
        assert_eq!(decode_bytes(&f).unwrap().1, vec![1, 2, 3]);
    }

    #[test]
    fn round_bytes_formula() {
        // 8 clients, 1M params: 2 * 4MB * 8 = 64 MB.
        assert_eq!(round_bytes(1_000_000, 8), 64_000_000);
    }
}
