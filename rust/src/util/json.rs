//! Minimal JSON parser/writer (serde is unavailable offline; see DESIGN.md).
//!
//! Used to read `artifacts/<cfg>/manifest.json` written by the python AOT
//! pipeline and to emit machine-readable experiment results. Supports the
//! full JSON grammar; numbers are f64 (the manifest's integers are exact
//! below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (want key {key:?})"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    // -- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for result emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(it: I) -> Json {
    Json::Arr(it.into_iter().collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected eof"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i);
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at byte {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // Surrogate pairs: enough for manifests (ASCII),
                            // handled for completeness.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if &self.b[self.i..self.i + 2] != b"\\u" {
                                    bail!("lone high surrogate");
                                }
                                self.i += 2;
                                let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                self.i += 4;
                                char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        e => bail!("bad escape \\{}", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Multi-byte UTF-8: copy raw bytes.
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\n""#).unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":false}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_usize().unwrap(), 2);
        assert!(!a[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,null,true],"s":"q\"uote","n":-7}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"schema_version":1,"n_params":32928,
            "params":[{"name":"wte","shape":[256,32],"offset":0,"size":8192,
                       "init":{"kind":"normal","std":0.02}}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("n_params").unwrap().as_usize().unwrap(), 32928);
        let p = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("init").unwrap().get("kind").unwrap().as_str().unwrap(), "normal");
    }
}
