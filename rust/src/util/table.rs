//! Fixed-width console tables — the experiment drivers print paper-style
//! rows with these.

pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_string(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:<w$} | ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

/// Shorthand for f64 cells.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(&["name", "ppl"]);
        t.row(vec!["m75a".into(), f(45.2511, 2)]);
        t.row(vec!["m125a_long".into(), "7".into()]);
        let s = t.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].contains("45.25"));
        // All rows same width.
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
