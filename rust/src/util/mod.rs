//! Small self-contained substrates (the offline image carries no serde /
//! clap / rand crates — these are the in-repo replacements, each unit
//! tested).

pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod table;

/// Root of the repository (artifacts/results are resolved relative to it).
/// Honors `PHOTON_ROOT`, else walks up from the current dir looking for
/// `Cargo.toml`, else falls back to `.`.
pub fn repo_root() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PHOTON_ROOT") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join("Cargo.toml").exists() || dir.join("artifacts").exists() {
            return dir;
        }
        if !dir.pop() {
            return ".".into();
        }
    }
}

/// `artifacts/` directory produced by `make artifacts`.
pub fn artifacts_dir() -> std::path::PathBuf {
    repo_root().join("artifacts")
}

/// `results/` output directory (created on demand).
pub fn results_dir(sub: &str) -> std::path::PathBuf {
    let d = repo_root().join("results").join(sub);
    std::fs::create_dir_all(&d).ok();
    d
}
