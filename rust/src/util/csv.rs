//! CSV emission for experiment series (`results/<exp>/<name>.csv`).
//!
//! Every figure/table driver writes its raw series here so plots can be
//! regenerated outside the binary; EXPERIMENTS.md references these files.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

pub struct CsvWriter {
    file: std::io::BufWriter<std::fs::File>,
    columns: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, columns: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        assert_eq!(values.len(), self.columns, "csv row arity mismatch");
        let mut line = String::new();
        for (i, v) in values.iter().enumerate() {
            if i > 0 {
                line.push(',');
            }
            if v.fract() == 0.0 && v.abs() < 9e15 {
                line.push_str(&format!("{}", *v as i64));
            } else {
                line.push_str(&format!("{v:.6}"));
            }
        }
        writeln!(self.file, "{line}")?;
        Ok(())
    }

    pub fn row_mixed(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.columns, "csv row arity mismatch");
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn finish(mut self) -> Result<()> {
        self.file.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join(format!("photon_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["round", "ppl"]).unwrap();
        w.row(&[1.0, 45.25]).unwrap();
        w.row(&[2.0, 40.0]).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "round,ppl\n1,45.250000\n2,40\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let dir = std::env::temp_dir().join(format!("photon_csv2_{}", std::process::id()));
        let mut w = CsvWriter::create(&dir.join("t.csv"), &["a", "b"]).unwrap();
        let _ = w.row(&[1.0]);
    }
}
