//! Deterministic, seedable RNG (xoshiro256** + SplitMix64 seeding).
//!
//! Reproducibility is a design principle of the paper (§6.1: "we seed every
//! local training and the client selection mechanism"); every stochastic
//! component of the coordinator (sampler, corpora, init, faults) draws from
//! one of these, derived from the experiment seed via `derive`.

/// xoshiro256** — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug, PartialEq)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream for a named sub-component. Streams for
    /// different `(label, index)` pairs are decorrelated.
    pub fn derive(&self, label: &str, index: u64) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a over label bytes
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(
            self.s[0]
                .wrapping_add(h)
                .wrapping_add(index.wrapping_mul(0x9E3779B97F4A7C15)),
        )
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        let (mut u1, u2) = (self.f64(), self.f64());
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Partial Fisher-Yates: choose `k` distinct indices from `0..n`.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.usize_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Serializable internal state (for checkpointable streams).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s, gauss_spare: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn derive_streams_decorrelated() {
        let root = Rng::new(7);
        let mut a = root.derive("sampler", 0);
        let mut b = root.derive("sampler", 1);
        let mut c = root.derive("corpus", 0);
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_ne!(x, y);
        assert_ne!(x, z);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            s1 += g;
            s2 += g * g;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::new(9);
        let picks = r.choose_k(64, 8);
        assert_eq!(picks.len(), 8);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 8);
        assert!(picks.iter().all(|&p| p < 64));
    }

    #[test]
    fn choose_k_full_is_permutation() {
        let mut r = Rng::new(13);
        let mut picks = r.choose_k(10, 10);
        picks.sort_unstable();
        assert_eq!(picks, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip() {
        let mut a = Rng::new(21);
        a.next_u64();
        let mut b = Rng::from_state(a.state());
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
