//! Tiny argument parser (clap is unavailable offline).
//!
//! Grammar: `photon <command> [positional...] [--key value] [--flag]`.
//! Unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Declarative spec: which `--options` take values and which are bare flags.
pub struct Spec {
    pub options: &'static [&'static str],
    pub flags: &'static [&'static str],
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, spec: &Spec) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                if let Some((k, v)) = name.split_once('=') {
                    if !spec.options.contains(&k) {
                        bail!("unknown option --{k}");
                    }
                    out.options.insert(k.to_string(), v.to_string());
                } else if spec.flags.contains(&name) {
                    out.flags.push(name.to_string());
                } else if spec.options.contains(&name) {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} needs a value"))?;
                    out.options.insert(name.to_string(), v);
                } else {
                    bail!("unknown option --{name}");
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// A mandatory option; errors with the flag name if absent (used by
    /// commands with no sensible default, e.g. `worker --connect`).
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| anyhow!("--{name} is required for this command"))
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    /// Like `get_usize` but also accepts the literal `auto`, which maps to
    /// 0 ("let the system decide") — used by worker-count knobs such as
    /// `--workers auto`.
    pub fn get_count_or_auto(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some("auto") => Ok(0),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer or 'auto', got {v:?}")),
        }
    }

    /// Comma-separated integer list (`--taus 50,500`); `default` when the
    /// option is absent. Rejects empty items so `--taus 50,,500` fails
    /// loudly.
    pub fn get_u64_list(&self, name: &str, default: &[u64]) -> Result<Vec<u64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|tok| {
                    tok.trim().parse().map_err(|_| {
                        anyhow!("--{name} expects comma-separated integers, got {v:?}")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated float list (`--gammas 1.0,0.5`); `default` when
    /// the option is absent. Rejects empty items like [`Args::get_u64_list`].
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|tok| {
                    tok.trim().parse().map_err(|_| {
                        anyhow!("--{name} expects comma-separated numbers, got {v:?}")
                    })
                })
                .collect(),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: Spec = Spec {
        options: &["config", "rounds", "lr", "workers", "taus"],
        flags: &["fast", "verbose"],
    };

    fn parse(toks: &[&str]) -> Result<Args> {
        Args::parse(toks.iter().map(|s| s.to_string()), &SPEC)
    }

    #[test]
    fn positional_options_flags() {
        let a = parse(&["exp", "fig3", "--config", "m75a", "--fast"]).unwrap();
        assert_eq!(a.positional, ["exp", "fig3"]);
        assert_eq!(a.get("config"), Some("m75a"));
        assert!(a.flag("fast"));
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["--rounds=12"]).unwrap();
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 12);
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["--lr", "0.5"]).unwrap();
        assert_eq!(a.get_f64("lr", 1.0).unwrap(), 0.5);
        assert_eq!(a.get_usize("rounds", 7).unwrap(), 7);
    }

    #[test]
    fn count_or_auto() {
        let a = parse(&["--workers", "auto"]).unwrap();
        assert_eq!(a.get_count_or_auto("workers", 1).unwrap(), 0);
        let a = parse(&["--workers", "4"]).unwrap();
        assert_eq!(a.get_count_or_auto("workers", 1).unwrap(), 4);
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_count_or_auto("workers", 1).unwrap(), 1);
        assert!(parse(&["--workers", "many"])
            .unwrap()
            .get_count_or_auto("workers", 1)
            .is_err());
    }

    #[test]
    fn u64_list() {
        let a = parse(&["--taus", "50,500, 1000"]).unwrap();
        assert_eq!(a.get_u64_list("taus", &[5]).unwrap(), vec![50, 500, 1000]);
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_u64_list("taus", &[5, 7]).unwrap(), vec![5, 7]);
        assert!(parse(&["--taus", "50,,500"])
            .unwrap()
            .get_u64_list("taus", &[])
            .is_err());
        assert!(parse(&["--taus", "x"]).unwrap().get_u64_list("taus", &[]).is_err());
    }

    #[test]
    fn f64_list() {
        let a = parse(&["--taus", "1.0,0.5, 0.25"]).unwrap();
        assert_eq!(a.get_f64_list("taus", &[1.0]).unwrap(), vec![1.0, 0.5, 0.25]);
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_f64_list("taus", &[0.9, 1.0]).unwrap(), vec![0.9, 1.0]);
        assert!(parse(&["--taus", "1.0,,0.5"])
            .unwrap()
            .get_f64_list("taus", &[])
            .is_err());
        assert!(parse(&["--taus", "x"]).unwrap().get_f64_list("taus", &[]).is_err());
    }

    #[test]
    fn unknown_and_missing() {
        assert!(parse(&["--nope"]).is_err());
        assert!(parse(&["--rounds"]).is_err());
        assert!(parse(&["--rounds", "x"]).unwrap().get_usize("rounds", 0).is_err());
    }

    #[test]
    fn require_names_the_missing_flag() {
        let a = parse(&["--config", "m75a"]).unwrap();
        assert_eq!(a.require("config").unwrap(), "m75a");
        let err = a.require("rounds").unwrap_err().to_string();
        assert!(err.contains("--rounds"), "{err}");
    }
}
