//! Tables 1–4: the paper's accounting and configuration tables, reprinted
//! from the config system next to the analogue ladder (which is read from
//! the built artifact manifests when present).

use anyhow::Result;

use crate::config::{
    PAPER_TABLE1, PAPER_TABLE2, PAPER_TABLE3, PAPER_TABLE4,
};
use crate::model::manifest::Manifest;
use crate::util::table::Table;
use crate::util::{artifacts_dir, csv::CsvWriter, results_dir};

fn analog_manifest(name: &str) -> Option<Manifest> {
    Manifest::load(&artifacts_dir().join(name)).ok()
}

/// Table 1: pre-training token/step accounting. The paper's columns are
/// reprinted; the step counts T are *recomputed* from tokens/(l·B) and
/// checked against the paper's reported values.
pub fn table1() -> Result<()> {
    println!("Table 1: pre-training tokens and steps (paper values, recomputed T)");
    let mut t = Table::new(&[
        "dim(Θ)", "D|Θ (Chinchilla)", "D_MPT|Θ", "D*_SEQ", "D*_PAR", "l", "B",
        "T_chinchilla", "T_mpt", "T_seq",
    ]);
    let mut csv = CsvWriter::create(
        &results_dir("table1").join("table1.csv"),
        &["params", "chinchilla_tokens", "seq_tokens", "par_tokens", "t_chinchilla", "t_seq"],
    )?;
    for r in &PAPER_TABLE1 {
        let per_step = (r.l * r.b) as f64;
        let t_chin = r.chinchilla_tokens / per_step;
        let t_mpt = if r.mpt_tokens.is_nan() { f64::NAN } else { r.mpt_tokens / per_step };
        let t_seq = r.seq_tokens / per_step;
        t.row(vec![
            r.size.into(),
            format!("{:.2e}", r.chinchilla_tokens),
            if r.mpt_tokens.is_nan() { "-".into() } else { format!("{:.2e}", r.mpt_tokens) },
            format!("{:.2e}", r.seq_tokens),
            format!("{:.2e}", r.par_tokens),
            r.l.to_string(),
            r.b.to_string(),
            format!("{t_chin:.0}"),
            if t_mpt.is_nan() { "-".into() } else { format!("{t_mpt:.0}") },
            format!("{t_seq:.0}"),
        ]);
        csv.row(&[r.params, r.chinchilla_tokens, r.seq_tokens, r.par_tokens, t_chin, t_seq])?;
    }
    t.print();
    csv.finish()?;
    // Consistency pins against the paper's own reported steps.
    let t75 = PAPER_TABLE1[0].chinchilla_tokens / (1024.0 * 256.0);
    anyhow::ensure!((t75 - 4463.0).abs() < 20.0, "75M T mismatch: {t75}");
    let t7b = PAPER_TABLE1[5].chinchilla_tokens / (2048.0 * 1024.0);
    anyhow::ensure!((t7b - 65804.0).abs() < 400.0, "7B T mismatch: {t7b}");
    println!("[shape OK] recomputed step counts match the paper's Table 1");
    Ok(())
}

/// Table 2: architecture ladder — paper models + our artifact analogues.
pub fn table2() -> Result<()> {
    println!("Table 2: architectures (paper → analogue artifacts)");
    let mut t = Table::new(&[
        "paper", "blocks", "d", "heads", "vocab", "l",
        "analogue", "a.blocks", "a.d", "a.heads", "a.vocab", "a.l", "a.params",
    ]);
    for r in &PAPER_TABLE2 {
        let (ab, ad, ah, av, al, ap) = match analog_manifest(r.analog) {
            Some(m) => (
                m.config.n_blocks.to_string(),
                m.config.d_model.to_string(),
                m.config.n_heads.to_string(),
                m.config.vocab.to_string(),
                m.config.seq_len.to_string(),
                m.n_params.to_string(),
            ),
            None => ("?".into(), "?".into(), "?".into(), "?".into(), "?".into(),
                     "run `make artifacts`".into()),
        };
        t.row(vec![
            r.size.into(), r.blocks.to_string(), r.d.to_string(),
            r.heads.to_string(), r.vocab.to_string(), r.seq.to_string(),
            r.analog.into(), ab, ad, ah, av, al, ap,
        ]);
    }
    t.print();
    // Monotonicity of the analogue ladder (the property the scaling claims
    // need): params strictly increase down the ladder.
    let params: Vec<usize> = PAPER_TABLE2
        .iter()
        .filter_map(|r| analog_manifest(r.analog).map(|m| m.n_params))
        .collect();
    if params.len() == PAPER_TABLE2.len() {
        anyhow::ensure!(
            params.windows(2).all(|w| w[0] < w[1]),
            "analogue ladder not monotone: {params:?}"
        );
        println!("[shape OK] analogue ladder is monotone ({} → {} params)",
                 params[0], params[params.len() - 1]);
    }
    Ok(())
}

/// Table 3: local/server optimization hyperparameters.
pub fn table3() -> Result<()> {
    println!("Table 3: hyperparameters (paper)");
    let mut t = Table::new(&["size", "η_s", "μ_s", "α", "η_max", "T", "batch"]);
    for r in &PAPER_TABLE3 {
        t.row(vec![
            r.size.into(),
            format!("{}", r.eta_s),
            format!("{}", r.mu_s),
            format!("{}", r.alpha),
            format!("{:.1e}", r.eta_max),
            r.t_steps.to_string(),
            r.batch.to_string(),
        ]);
    }
    t.print();
    println!(
        "analogue defaults: η_max 3e-3, α 0.1, cosine over rounds·τ steps, \
         AdamW(0.9, 0.95), clip 1.0, wd 0.1 (see python/compile/configs.py)"
    );
    Ok(())
}

/// Table 4: federated settings per experiment.
pub fn table4() -> Result<()> {
    println!("Table 4: federated hyperparameters (paper)");
    let mut t = Table::new(&["size", "#rounds", "P", "K", "dataset", "τ"]);
    for r in &PAPER_TABLE4 {
        t.row(vec![
            r.size.into(), r.rounds.into(), r.p.into(), r.k.into(),
            r.dataset.into(), r.tau.into(),
        ]);
    }
    t.print();
    println!(
        "analogue defaults: P=8/64, K=8/4, rounds 12, τ=40 \
         (CPU budget; --paper-scale restores τ=500)"
    );
    Ok(())
}
