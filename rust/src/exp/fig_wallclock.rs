//! `wallclock`: the paper's headline systems claim (§4.3, and Photon) —
//! federated rounds hide WAN communication behind τ local steps, so
//! wall-clock throughput stays near-datacenter even over 100 Mbit/s
//! links — measured end-to-end by the event-driven simulator instead of
//! the old analytic byte ratios.
//!
//! Sweeps the link ladder (DATACENTER / CLOUD_WAN / BROADBAND) × τ ×
//! aggregation policy (sync / semi-sync deadline / broadcast-overlap)
//! over a heterogeneous A40/A100/H100 fleet with fault-injected
//! stragglers, and writes one per-round timeline CSV per cell plus a
//! summary CSV.
//!
//! ```text
//! photon exp wallclock [--size 125M] [--clients P] [--sampled K]
//!     [--rounds N] [--taus 50,500] [--straggler p] [--dropout p]
//!     [--slowdown x] [--deadline f] [--mfu u] [--policy all|sync|...]
//!     [--codec q8]
//! ```
//!
//! `--codec` prices the *upload* leg from the update codec's actual
//! encoded bytes (`compress::UpdateCodec::encoded_body_bytes`) — exact
//! for the quantizing/sparsifying codecs — while the broadcast stays
//! dense, so the sweep shows how lossy updates move the wall-clock
//! frontier.

use anyhow::{bail, Result};

use crate::cluster::faults::FaultPlan;
use crate::config::{ExperimentConfig, PAPER_TABLE1};
use crate::link;
use crate::netsim::{Link, BROADBAND, CLOUD_WAN, DATACENTER};
use crate::sim::{
    fleet_profiles, AggregationPolicy, RoundPlan, SimConfig, SimReport, Simulator,
};
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::util::{artifacts_dir, results_dir};

const LADDER: [(&str, Link); 3] = [
    ("datacenter", DATACENTER),
    ("cloud_wan", CLOUD_WAN),
    ("broadband", BROADBAND),
];

struct Cell {
    link_name: &'static str,
    tau: u64,
    report: SimReport,
}

pub fn fig_wallclock(args: &Args) -> Result<()> {
    let size = args.get_or("size", "125M");
    let row = PAPER_TABLE1
        .iter()
        .find(|r| r.size == size)
        .ok_or_else(|| anyhow::anyhow!("unknown --size {size:?} (see table1)"))?;
    let p = args.get_usize("clients", 8)?;
    let k = args.get_usize("sampled", p)?;
    let rounds = args.get_usize("rounds", 10)?;
    let taus = args.get_u64_list("taus", &[50, 500])?;
    let straggler = args.get_f64("straggler", 0.25)?;
    let dropout = args.get_f64("dropout", 0.05)?;
    let slowdown = args.get_f64("slowdown", 4.0)?;
    let deadline = args.get_f64("deadline", 1.5)?;
    let mfu = args.get_f64("mfu", crate::sim::DEFAULT_MFU)?;
    let seed = args.get_u64("seed", 42)?;
    let policies: Vec<AggregationPolicy> = match args.get_or("policy", "all").as_str() {
        "all" => vec![
            AggregationPolicy::Sync,
            AggregationPolicy::SemiSync { deadline_factor: deadline },
            AggregationPolicy::Overlap,
        ],
        one => vec![AggregationPolicy::parse(one, deadline)?],
    };
    if taus.is_empty() {
        bail!("--taus needs at least one value");
    }

    let codec = crate::compress::UpdateCodec::parse(&args.get_or("codec", "none"))?;
    let n_params = row.params as u64;
    let tokens_per_step = row.l * row.b;
    // Raw f32 payload, scaled by the *measured* Photon-Link deflate ratio
    // when artifacts are available (same measurement as `comm`).
    let raw_payload = n_params * 4;
    let payload = match measured_compression_ratio() {
        Some(ratio) => {
            println!("[link] measured deflate ratio {:.3} applied to payloads", ratio);
            (raw_payload as f64 * ratio) as u64
        }
        None => raw_payload,
    };
    // Upload leg: actual encoded bytes under the update codec (dense
    // payload when lossless — identical to the symmetric pre-codec sweep).
    let payload_up = if codec.is_lossy() {
        let up = codec.encoded_body_bytes(n_params as usize);
        println!(
            "[codec] {}: uploads priced at {} of the dense {} bytes",
            codec.label(),
            up,
            raw_payload
        );
        up
    } else {
        payload
    };

    println!(
        "wall-clock simulation: paper-{size} ({:.1}M params, {} tok/step), \
         P={p} K={k} rounds={rounds}, stragglers {straggler} (×{slowdown} slower), \
         dropout {dropout}, deadline ×{deadline}",
        n_params as f64 / 1e6,
        tokens_per_step,
    );

    let fleet = crate::cluster::hardware::FleetSpec::heterogeneous(p);
    let profiles = fleet_profiles(&fleet, n_params, tokens_per_step, mfu);
    let dir = results_dir("wallclock");

    let mut t = Table::new(&[
        "link", "tau", "policy", "total", "mean round", "comm frac", "arrived",
        "late", "dropped",
    ]);
    let mut csv = CsvWriter::create(
        &dir.join("summary.csv"),
        &[
            "link", "tau", "policy", "total_secs", "mean_round_secs", "comm_frac",
            "arrived", "late", "dropped", "total_bytes",
        ],
    )?;
    let mut cells: Vec<Cell> = Vec::new();

    for &tau in &taus {
        let mut cfg = ExperimentConfig::wallclock(p, k, rounds, tau, seed);
        cfg.faults = FaultPlan::new(dropout, straggler, seed);
        cfg.validate()?;
        let plan = RoundPlan::from_config(&cfg);
        for (link_name, link) in LADDER {
            for &policy in &policies {
                let mut sim_cfg =
                    SimConfig::asymmetric(payload, payload_up, link, policy);
                sim_cfg.straggler_slowdown = slowdown;
                let report =
                    Simulator::new(plan.clone(), profiles.clone(), sim_cfg).run();
                report.write_csv(&dir.join(format!(
                    "timeline_{link_name}_tau{tau}_{}.csv",
                    policy.label()
                )))?;
                t.row(vec![
                    link_name.to_string(),
                    tau.to_string(),
                    policy.label().to_string(),
                    human_secs(report.total_secs),
                    human_secs(report.mean_round_secs()),
                    format!("{:.2}%", 100.0 * report.comm_fraction()),
                    report.arrived_total.to_string(),
                    report.late_total.to_string(),
                    report.dropped_total.to_string(),
                ]);
                csv.row_mixed(&[
                    link_name.to_string(),
                    tau.to_string(),
                    policy.label().to_string(),
                    format!("{:.6}", report.total_secs),
                    format!("{:.6}", report.mean_round_secs()),
                    format!("{:.6}", report.comm_fraction()),
                    report.arrived_total.to_string(),
                    report.late_total.to_string(),
                    report.dropped_total.to_string(),
                    report.total_bytes.to_string(),
                ])?;
                cells.push(Cell { link_name, tau, report });
            }
        }
    }
    t.print();
    csv.finish()?;
    println!("[csv] results/wallclock/ ({} timelines + summary.csv)", cells.len());

    // --- qualitative shape checks -------------------------------------
    let find = |name: &str, tau: u64, label: &str| {
        cells
            .iter()
            .find(|c| c.link_name == name && c.tau == tau && c.report.policy.label() == label)
            .map(|c| &c.report)
    };
    if policies.len() > 1 {
        for (link_name, _) in LADDER {
            for &tau in &taus {
                if let (Some(sync), Some(semi)) =
                    (find(link_name, tau, "sync"), find(link_name, tau, "semisync"))
                {
                    crate::exp::common::check_shape(
                        "semi-sync never slower than sync",
                        semi.total_secs <= sync.total_secs + 1e-6,
                        format!(
                            "{link_name} τ={tau}: semi {:.1}s vs sync {:.1}s ({} cut)",
                            semi.total_secs, sync.total_secs, semi.late_total
                        ),
                    );
                }
                if let (Some(sync), Some(over)) =
                    (find(link_name, tau, "sync"), find(link_name, tau, "overlap"))
                {
                    crate::exp::common::check_shape(
                        "broadcast overlap never slower than sync",
                        over.total_secs <= sync.total_secs + 1e-6,
                        format!(
                            "{link_name} τ={tau}: overlap {:.1}s vs sync {:.1}s",
                            over.total_secs, sync.total_secs
                        ),
                    );
                }
            }
        }
    }
    // The headline: at large τ, 100 Mbit/s broadband is within a whisker
    // of the datacenter interconnect (communication hidden behind τ local
    // steps); at small τ the WAN penalty is visible.
    let tau_max = *taus.iter().max().unwrap();
    let tau_min = *taus.iter().min().unwrap();
    let first_policy = policies[0].label();
    if let (Some(dc), Some(bb)) = (
        find("datacenter", tau_max, first_policy),
        find("broadband", tau_max, first_policy),
    ) {
        let ratio = bb.total_secs / dc.total_secs.max(1e-9);
        crate::exp::common::check_shape(
            "WAN ≈ datacenter at large τ",
            ratio < 1.25,
            format!("broadband/datacenter wall-clock = {ratio:.3}× at τ={tau_max}"),
        );
        if tau_min < tau_max {
            if let (Some(dc_s), Some(bb_s)) = (
                find("datacenter", tau_min, first_policy),
                find("broadband", tau_min, first_policy),
            ) {
                let ratio_small = bb_s.total_secs / dc_s.total_secs.max(1e-9);
                crate::exp::common::check_shape(
                    "WAN penalty shrinks as τ grows",
                    ratio < ratio_small,
                    format!("ratio {ratio_small:.2}× at τ={tau_min} → {ratio:.3}× at τ={tau_max}"),
                );
            }
        }
    }
    Ok(())
}

/// Deflate ratio of a measured Photon-Link frame over a real (structured)
/// init payload, when artifacts exist; None in artifact-free checkouts.
fn measured_compression_ratio() -> Option<f64> {
    let m = crate::model::manifest::Manifest::load(&artifacts_dir().join("m75a")).ok()?;
    let params = crate::model::init::init_params(&m, 7);
    let raw = link::encode_model(link::MsgKind::GlobalModel, &params, false).ok()?;
    let comp = link::encode_model(link::MsgKind::GlobalModel, &params, true).ok()?;
    Some(comp.len() as f64 / raw.len() as f64)
}

fn human_secs(s: f64) -> String {
    if s < 120.0 {
        format!("{s:.1}s")
    } else if s < 7200.0 {
        format!("{:.1}m", s / 60.0)
    } else {
        format!("{:.1}h", s / 3600.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(12.34), "12.3s");
        assert_eq!(human_secs(600.0), "10.0m");
        assert_eq!(human_secs(7200.0), "2.0h");
    }

    #[test]
    fn ladder_names_are_distinct() {
        assert_eq!(LADDER.len(), 3);
        assert_ne!(LADDER[0].0, LADDER[1].0);
        assert_ne!(LADDER[1].0, LADDER[2].0);
    }
}
