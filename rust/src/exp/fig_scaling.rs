//! Fig 3 + Fig 9: federated vs centralized perplexity across the model
//! ladder on the IID C4 partition.
//!
//! Paper shapes asserted:
//! * federated ≈ centralized at every size, with the gap (centralized −
//!   federated advantage) *shrinking or flipping* as the model grows (§7.1);
//! * consensus (client-loss std / transient spikes) settles faster for
//!   larger models (§7.3);
//! * the largest sizes outperform their centralized counterparts (fig9,
//!   §7.7).

use anyhow::Result;

use crate::config::CorpusKind;
use crate::exp::common::*;
use crate::util::cli::Args;

fn run_sizes(exp: &str, sizes: &[&str], p: usize, k: usize, args: &Args,
             default_rounds: usize, default_steps: u64) -> Result<()> {
    let scale = Scale::from_args(args, default_rounds, default_steps)?;
    let mut cache = ModelCache::new()?;
    let mut gaps: Vec<(String, f64, f64, f64)> = Vec::new();
    for &size in sizes {
        let cfg = scale.config(size, CorpusKind::C4Iid, p, k);
        let fed = run_fed(&mut cache, &cfg)?;
        let cen = run_central(&mut cache, &cfg)?;
        print_metric_table(
            &format!("{size}: server validation perplexity (fed) vs test perplexity (centralized)"),
            &[&fed, &cen],
            |r| r.server_ppl,
        );
        print_metric_table(
            &format!("{size}: client train perplexity (fed avg) vs train perplexity (centralized)"),
            &[&fed, &cen],
            |r| r.client_ppl_mean,
        );
        let f = final_metric(&fed, |r| r.server_ppl);
        let c = final_metric(&cen, |r| r.server_ppl);
        // Consensus time: first round where client-loss std drops below
        // 25% of its initial value (§7.3's transient-phase length).
        let std0 = fed.log.rounds.first().map(|r| r.client_loss_std).unwrap_or(0.0);
        let consensus = fed
            .log
            .rounds
            .iter()
            .position(|r| r.client_loss_std < 0.25 * std0.max(1e-9))
            .map(|x| x as f64)
            .unwrap_or(f64::NAN);
        gaps.push((size.to_string(), f, c, consensus));
        save_curves(exp, &[&fed, &cen])?;
    }

    println!("\n{exp} summary (final perplexities):");
    let mut t = crate::util::table::Table::new(&[
        "model", "fed ppl", "central ppl", "gap (cen-fed)", "consensus round",
    ]);
    for (name, f, c, cons) in &gaps {
        t.row(vec![
            name.clone(),
            format!("{f:.2}"),
            format!("{c:.2}"),
            format!("{:+.2}", c - f),
            format!("{cons:.0}"),
        ]);
    }
    t.print();

    // Shape: relative gap (fed−cen)/cen narrows (or goes negative) with size.
    if gaps.len() >= 2 {
        let rel = |f: f64, c: f64| (f - c) / c;
        let first = rel(gaps[0].1, gaps[0].2);
        let last = rel(gaps[gaps.len() - 1].1, gaps[gaps.len() - 1].2);
        check_shape(
            "gap shrinks with size",
            last <= first + 0.02,
            format!("relative gap {:.3} ({}) → {:.3} ({})",
                first, gaps[0].0, last, gaps[gaps.len() - 1].0),
        );
    }
    Ok(())
}

/// Fig 3: 75M/125M/350M/1.3B analogues, full participation P=K=8.
pub fn fig3(args: &Args) -> Result<()> {
    run_sizes("fig3", &["m75a", "m125a", "m350a", "m1ba"], 8, 8, args, 10, 20)
}

/// Fig 9: 3B/7B analogues, partial participation K=4 of P=64 (paper
/// Table 4), expected to *beat* centralized.
pub fn fig9(args: &Args) -> Result<()> {
    run_sizes("fig9", &["m3ba", "m7ba"], 64, 4, args, 6, 10)
}
