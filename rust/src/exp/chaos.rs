//! `chaos`: the elastic-fleet resilience sweep — seeded chaos schedules
//! (crash/rejoin, hang, slow-down, link flake) thrown at a localhost TCP
//! fleet across a fault-rate ladder × lease-migration setting, with every
//! cell's realized trace replayed in-process for the bit-parity verdict
//! and the same churned schedule priced through the wall-clock simulator
//! per aggregation policy. The paper's resilience claim (§5: federated
//! pre-training is "robust to partial participation") shows up as
//! *graceful* degradation: participation falls with the fault rate while
//! convergence holds — the same shape as the partial-participation figure
//! (`exp fig6`), but induced by infrastructure failures instead of
//! sampling.
//!
//! ```text
//! photon exp chaos [--config m75a] [--clients P] [--sampled K]
//!     [--rounds N] [--steps T] [--seed S] [--fleet W]
//!     [--rates 0,15,30,45] [--deadline-secs F]
//! ```
//!
//! The rate ladder is sorted, deduplicated, and always includes the
//! quiet rate-0 baseline the shape checks compare against.
//!
//! Writes `results/chaos/resilience.csv`
//! ([`crate::metrics::RESILIENCE_CSV_HEADER`]). Requires compiled
//! artifacts (`make artifacts`).

use std::sync::Arc;

use anyhow::Result;

use crate::chaos::{ChaosConfig, Schedule};
use crate::cluster::faults::FaultPlan;
use crate::config::ExperimentConfig;
use crate::coordinator::Federation;
use crate::exp::common::check_shape;
use crate::metrics::{write_resilience_csv, ResilienceRow, RoundRecord};
use crate::net::{run_loopback, FleetOpts};
use crate::netsim::CLOUD_WAN;
use crate::optim::schedule::CosineSchedule;
use crate::runtime::Runtime;
use crate::sim::{AggregationPolicy, RoundPlan, SimConfig, Simulator};
use crate::util::results_dir;

fn parity(a: &[RoundRecord], b: &[RoundRecord]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.agrees_with(y))
}

pub fn chaos(args: &crate::util::cli::Args) -> Result<()> {
    let model_name = args.get_or("config", "m75a");
    let p = args.get_usize("clients", 8)?;
    let k = args.get_usize("sampled", p.min(6))?;
    let mut rounds = args.get_usize("rounds", 5)?.max(3);
    let mut steps = args.get_u64("steps", 6)?;
    if args.flag("fast") {
        rounds = rounds.min(3);
        steps = steps.min(4);
    }
    let seed = args.get_u64("seed", 42)?;
    let fleet = args.get_usize("fleet", 4)?.max(2);
    let deadline = args.get_f64("deadline-secs", 5.0)?;
    // Normalize the ladder: ascending, unique, and always anchored by a
    // quiet rate-0 baseline — the shape checks compare against it.
    let mut rates = args.get_u64_list("rates", &[0, 15, 30, 45])?;
    rates.push(0);
    rates.sort_unstable();
    rates.dedup();

    let total = rounds as u64 * steps;
    let mut cfg = ExperimentConfig::quickstart(&model_name);
    cfg.label = format!("chaos-{model_name}");
    cfg.n_clients = p;
    cfg.clients_per_round = k;
    cfg.rounds = rounds;
    cfg.local_steps = steps;
    cfg.seed = seed;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), (total / 20).min(100));
    // Client-level faults off: every cut in this sweep is attributable to
    // the injected worker chaos, not the sampler's dropout draws.
    cfg.faults = FaultPlan::none();

    println!(
        "chaos resilience sweep: {model_name} P={p} K={k} rounds={rounds} τ={steps} \
         over {fleet} TCP workers; fault rates {rates:?}% × migration off/on \
         (deadline {deadline}s)"
    );
    let rt = Runtime::cpu()?;
    let model = Arc::new(rt.load_model(&model_name)?);
    let payload = model.n_params() as u64 * 4;
    let base_plan = RoundPlan::from_config(&cfg);

    let mut rows: Vec<ResilienceRow> = Vec::new();
    // Keyed summaries for the shape checks: (rate, migrate) → values.
    let mut participation = Vec::new();
    let mut final_nll = Vec::new();
    let mut all_agree = true;

    println!("\nrate% | migrate | final ppl | participation | cuts mig rejoin | replay");
    for &rate in &rates {
        let ccfg = ChaosConfig::at_rate(rate as f64 / 100.0);
        let schedule =
            Schedule::generate(seed.wrapping_add(rate.wrapping_mul(7919)), fleet, rounds, ccfg);
        for migrate in [false, true] {
            let report = run_loopback(
                cfg.clone(),
                model.clone(),
                FleetOpts {
                    workers: fleet,
                    compress: true,
                    deadline_secs: Some(deadline),
                    chaos: Some(schedule.clone()),
                    migrate,
                    ..FleetOpts::default()
                },
            )?;
            for e in &report.worker_errors {
                println!("[!] {e}");
            }

            // The acceptance invariant: replaying the realized trace
            // in-process reproduces the chaotic fleet bit-for-bit.
            let mut replay = Federation::with_model(cfg.clone(), model.clone())?;
            let replayed = replay.run_trace(&report.trace)?;
            let agree = parity(&replayed, &report.records)
                && replay.global == report.global;
            all_agree &= agree;

            let part = report
                .records
                .iter()
                .map(|r| r.participated as f64 / k as f64)
                .sum::<f64>()
                / report.records.len().max(1) as f64;
            let last = report.records.last();
            let (ppl, nll) =
                last.map(|r| (r.server_ppl, r.server_nll)).unwrap_or((f64::NAN, f64::NAN));
            participation.push(((rate, migrate), part));
            final_nll.push(((rate, migrate), nll));
            println!(
                "{rate:>5} | {:>7} | {ppl:>9.3} | {part:>13.3} | {:>4} {:>3} {:>6} | {}",
                if migrate { "on" } else { "off" },
                report.trace.total_cut(),
                report.trace.total_migrated(),
                report.trace.total_rejoined(),
                if agree { "bit-equal" } else { "DIVERGED" },
            );

            // Price the same churned schedule through the simulator, one
            // row per aggregation policy.
            let churned = base_plan.with_chaos(&schedule, migrate);
            for policy in [
                AggregationPolicy::Sync,
                AggregationPolicy::SemiSync { deadline_factor: 1.5 },
            ] {
                let sim = Simulator::uniform(
                    &churned,
                    0.1,
                    SimConfig::new(payload, CLOUD_WAN, policy),
                )
                .run();
                rows.push(ResilienceRow {
                    fault_pct: rate as f64,
                    migrate,
                    policy: policy.label().to_string(),
                    final_ppl: ppl,
                    final_nll: nll,
                    participation: part,
                    cuts: report.trace.total_cut(),
                    migrations: report.trace.total_migrated(),
                    rejoins: report.trace.total_rejoined(),
                    replay_agree: agree,
                    sim_secs: sim.total_secs,
                    sim_dropped: sim.dropped_total,
                });
            }
        }
    }

    let out = results_dir("chaos").join("resilience.csv");
    write_resilience_csv(&out, &rows)?;

    // --- shape checks ------------------------------------------------------
    check_shape(
        "chaos-replay-parity",
        all_agree,
        "every chaotic fleet bit-equals the in-process replay of its realized trace"
            .into(),
    );
    let part_at = |rate: u64, migrate: bool| {
        participation
            .iter()
            .find(|((r, m), _)| *r == rate && *m == migrate)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    };
    let nll_at = |rate: u64, migrate: bool| {
        final_nll
            .iter()
            .find(|((r, m), _)| *r == rate && *m == migrate)
            .map(|(_, v)| *v)
            .unwrap_or(f64::NAN)
    };
    let (lo, hi) = (*rates.first().unwrap_or(&0), *rates.last().unwrap_or(&0));
    check_shape(
        "chaos-participation-degrades",
        part_at(lo, false) >= part_at(hi, false) - 1e-9 && part_at(lo, false) > 0.99,
        format!(
            "participation {:.3} at {lo}% vs {:.3} at {hi}% faults (migration off)",
            part_at(lo, false),
            part_at(hi, false)
        ),
    );
    // The paper's resilience claim, echoed: convergence degrades
    // *gracefully* — the chaotic run's final NLL stays within a modest
    // factor of the quiet run's, like partial participation vs full.
    check_shape(
        "chaos-graceful-degradation",
        nll_at(hi, false) <= nll_at(lo, false) * 1.25
            && nll_at(hi, true) <= nll_at(lo, true) * 1.25,
        format!(
            "final NLL {:.4} (quiet) → {:.4} (cut) / {:.4} (migrate) at {hi}% faults",
            nll_at(lo, false),
            nll_at(hi, false),
            nll_at(hi, true)
        ),
    );
    check_shape(
        "chaos-migration-helps",
        part_at(hi, true) >= part_at(hi, false) - 1e-9,
        format!(
            "at {hi}% faults, participation {:.3} with migration vs {:.3} without",
            part_at(hi, true),
            part_at(hi, false)
        ),
    );
    println!("wrote {}", out.display());
    Ok(())
}
