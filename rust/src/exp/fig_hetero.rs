//! Fig 4 / 5 / 12 / 14: the naturally heterogeneous Pile-analogue partition
//! (8 genres, one per client, §6.3).
//!
//! Paper shapes asserted:
//! * fig4 — server perplexity converges despite heterogeneity; client
//!   variance is higher than IID early, then collapses (consensus);
//! * fig5 — centralized activation norms outpace the federated clients',
//!   whose norms are pulled back at round boundaries (§7.2);
//! * fig12/fig14 — the fig7/fig8 norm relations persist under
//!   heterogeneity.

use anyhow::Result;

use crate::config::CorpusKind;
use crate::exp::common::*;
use crate::util::cli::Args;

const SIZES: [&str; 2] = ["m75a", "m125a"];

fn hetero_runs(
    args: &Args,
    sizes: &[&str],
) -> Result<(ModelCache, Vec<(String, Curve, Curve)>)> {
    let scale = Scale::from_args(args, 12, 25)?;
    let mut cache = ModelCache::new()?;
    let mut out = Vec::new();
    for &size in sizes {
        let cfg = scale.config(size, CorpusKind::PileHetero { j: 1 }, 8, 8);
        let fed = run_fed(&mut cache, &cfg)?;
        let cen = run_central(&mut cache, &cfg)?;
        out.push((size.to_string(), fed, cen));
    }
    Ok((cache, out))
}

/// Fig 4: heterogeneous perplexity, fed vs centralized, 75M/125M analogues.
pub fn fig4(args: &Args) -> Result<()> {
    let (_cache, runs) = hetero_runs(args, &SIZES)?;
    for (size, fed, cen) in &runs {
        print_metric_table(
            &format!("{size} (Pile-analog): server val ppl vs centralized test ppl"),
            &[fed, cen],
            |r| r.server_ppl,
        );
        save_curves("fig4", &[fed, cen])?;
        // Convergence: final server ppl within 20% of centralized.
        let f = final_metric(fed, |r| r.server_ppl);
        let c = final_metric(cen, |r| r.server_ppl);
        check_shape(
            &format!("{size} heterogeneous convergence"),
            f < 1.2 * c,
            format!("fed {f:.2} vs central {c:.2}"),
        );
        // Consensus maintained: despite one-genre-per-client heterogeneity,
        // client losses stay in a tight relative band (the paper's clients
        // "reach consensus" and track each other after the transient).
        let last = fed.log.rounds.last().unwrap();
        let dispersion = last.client_loss_std / last.client_loss_mean.max(1e-9);
        check_shape(
            &format!("{size} consensus maintained"),
            dispersion < 0.05,
            format!("final client loss dispersion {:.1}%", 100.0 * dispersion),
        );
    }
    Ok(())
}

/// Fig 5: output-activation L2 norms — centralized outpaces federated.
pub fn fig5(args: &Args) -> Result<()> {
    let (_cache, runs) = hetero_runs(args, &SIZES)?;
    for (size, fed, cen) in &runs {
        print_metric_table(
            &format!("{size} (Pile-analog): output activation L2 norms"),
            &[fed, cen],
            |r| r.act_norm_mean,
        );
        save_curves("fig5", &[fed, cen])?;
        let f = final_metric(fed, |r| r.act_norm_mean);
        let c = final_metric(cen, |r| r.act_norm_mean);
        // NOTE (recorded deviation, EXPERIMENTS.md): in the paper the
        // *centralized* activations outpace the federated ones because the
        // centralized 75M/125M runs destabilize and spike; at analogue
        // scale our centralized baseline stays stable, so the ordering can
        // invert. We report both final norms and flag the paper ordering.
        check_shape(
            &format!("{size} centralized activations outpace federated (paper ordering)"),
            c > f,
            format!("central {c:.1} vs fed {f:.1}"),
        );
    }
    Ok(())
}

/// Fig 12: fig7's norm triple under heterogeneity.
pub fn fig12(args: &Args) -> Result<()> {
    let (_cache, runs) = hetero_runs(args, &SIZES)?;
    for (size, fed, _cen) in &runs {
        print_metric_table(
            &format!("{size} (Pile-analog): global vs client-avg vs client model norms"),
            &[fed],
            |r| r.global_model_norm,
        );
        crate::exp::fig_norms::print_norm_triple(size, fed);
        save_curves("fig12", &[fed])?;
        crate::exp::fig_norms::check_norm_consensus(size, fed);
    }
    Ok(())
}

/// Fig 14: fig8's gradient norms under heterogeneity. The paper's note:
/// the pseudo-gradient decays *faster* than local step gradients here
/// (model adapting to heterogeneity, not just LR decay).
pub fn fig14(args: &Args) -> Result<()> {
    let (_cache, runs) = hetero_runs(args, &SIZES)?;
    for (size, fed, _cen) in &runs {
        crate::exp::fig_norms::print_grad_norms(size, fed);
        save_curves("fig14", &[fed])?;
        crate::exp::fig_norms::check_pseudo_grad_decay(size, fed);
    }
    Ok(())
}
