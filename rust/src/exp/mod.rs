//! Experiment drivers: one per table/figure of the paper's evaluation
//! (docs/REPRODUCTION.md maps each id to its paper artifact, exact
//! command, expected outputs, runtime, and seed; docs/ARCHITECTURE.md
//! maps modules to paper sections).
//!
//! `photon exp <id> [--fast] [--rounds N] [--steps N] [--seed S]`
//! regenerates the paper artifact: prints the paper-style series/rows,
//! writes raw CSVs under `results/<id>/`, and checks the qualitative
//! "shape" claims (who wins, what shrinks, where the crossover sits).
//!
//! Training-backed drivers (`fig3`…`table56`) need compiled artifacts
//! (`make artifacts`); the analytic ones (`table1`–`table4`, `comm`) and
//! the wall-clock simulation (`wallclock`) run artifact-free.

pub mod async_agg;
pub mod chaos;
pub mod comm;
pub mod common;
pub mod distributed;
pub mod fig_ablation;
pub mod fig_hetero;
pub mod fig_norms;
pub mod fig_partial;
pub mod fig_scaling;
pub mod fig_wallclock;
pub mod table56;
pub mod tables;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub struct ExpInfo {
    pub id: &'static str,
    pub what: &'static str,
}

pub const EXPERIMENTS: [ExpInfo; 23] = [
    ExpInfo { id: "table1", what: "token/step accounting (Chinchilla vs MPT vs seq/par)" },
    ExpInfo { id: "table2", what: "architecture ladder (paper + analogues)" },
    ExpInfo { id: "table3", what: "optimization hyperparameters" },
    ExpInfo { id: "table4", what: "federated hyperparameters (P, K, D, τ)" },
    ExpInfo { id: "fig3", what: "fed vs centralized perplexity across sizes (IID C4)" },
    ExpInfo { id: "fig4", what: "heterogeneous Pile partition perplexity" },
    ExpInfo { id: "fig5", what: "output-activation L2 norms, fed vs centralized" },
    ExpInfo { id: "fig6", what: "partial participation 4/64 matches full" },
    ExpInfo { id: "fig7", what: "global vs client vs client-avg model norms" },
    ExpInfo { id: "fig8", what: "pseudo-gradient vs local gradient norms" },
    ExpInfo { id: "fig9", what: "largest models beat centralized" },
    ExpInfo { id: "fig10", what: "outer-optimizer ablation (FedAvg/SGD+N/KeepOpt)" },
    ExpInfo { id: "fig11", what: "global model norm vs server momentum norm" },
    ExpInfo { id: "fig12", what: "fig7 norms under heterogeneity" },
    ExpInfo { id: "fig13", what: "fig7 norms under partial participation" },
    ExpInfo { id: "fig14", what: "fig8 norms under heterogeneity" },
    ExpInfo { id: "fig15", what: "fig8 norms under partial participation" },
    ExpInfo { id: "table56", what: "in-context learning across the ladder" },
    ExpInfo { id: "comm", what: "communication: federated vs DDP + lossy update-codec sweep (headline 1)" },
    ExpInfo { id: "wallclock", what: "event-driven wall-clock: link ladder × τ × aggregation policy (§4.3)" },
    ExpInfo { id: "distributed", what: "deployment plane: TCP worker fleet bit-equals the in-process federation (§4.1)" },
    ExpInfo { id: "chaos", what: "resilience: seeded fault rate × migration sweep, chaotic fleet bit-equals its trace replay (§5)" },
    ExpInfo { id: "async", what: "async staleness sweep: γ × fault rate × τ, buffered fleet bit-equals its ledger replay (§3)" },
];

pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => tables::table1(),
        "table2" => tables::table2(),
        "table3" => tables::table3(),
        "table4" => tables::table4(),
        "fig3" => fig_scaling::fig3(args),
        "fig9" => fig_scaling::fig9(args),
        "fig4" => fig_hetero::fig4(args),
        "fig5" => fig_hetero::fig5(args),
        "fig12" => fig_hetero::fig12(args),
        "fig14" => fig_hetero::fig14(args),
        "fig6" => fig_partial::fig6(args),
        "fig13" => fig_partial::fig13(args),
        "fig15" => fig_partial::fig15(args),
        "fig7" => fig_norms::fig7(args),
        "fig8" => fig_norms::fig8(args),
        "fig11" => fig_norms::fig11(args),
        "fig10" => fig_ablation::fig10(args),
        "table56" => table56::table56(args),
        "comm" => comm::comm(args),
        "wallclock" => fig_wallclock::fig_wallclock(args),
        "distributed" => distributed::distributed(args),
        "chaos" => chaos::chaos(args),
        "async" => async_agg::exp_async(args),
        "all" => {
            for e in &EXPERIMENTS {
                println!("\n################ {} ################", e.id);
                run(e.id, args)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment {other:?} (see `photon list`)"),
    }
}
