//! Shared machinery for the experiment drivers: a compiled-model cache, a
//! federated-vs-centralized runner pair, CSV emission, scale flags, and the
//! qualitative-shape assertion helpers.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{CorpusKind, ExperimentConfig};
use crate::coordinator::{run_centralized, Federation};
use crate::metrics::MetricsLog;
use crate::optim::schedule::CosineSchedule;
use crate::runtime::{ModelRuntime, Runtime};
use crate::util::cli::Args;
use crate::util::{results_dir, table::Table};

/// Compiled-artifact cache: each model config's HLO is compiled once per
/// process even when several experiment variants use it.
pub struct ModelCache {
    rt: Runtime,
    models: BTreeMap<String, Arc<ModelRuntime>>,
}

impl ModelCache {
    pub fn new() -> Result<ModelCache> {
        Ok(ModelCache { rt: Runtime::cpu()?, models: BTreeMap::new() })
    }

    pub fn get(&mut self, name: &str) -> Result<Arc<ModelRuntime>> {
        if let Some(m) = self.models.get(name) {
            return Ok(m.clone());
        }
        eprintln!("[photon] compiling artifacts for {name} ...");
        let m = Arc::new(self.rt.load_model(name)?);
        self.models.insert(name.to_string(), m.clone());
        Ok(m)
    }
}

/// Experiment scale knobs taken from the CLI. Defaults reproduce the
/// curve shapes in a few minutes on CPU; `--paper-scale` restores the
/// paper's τ=500 round length (hours).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub rounds: usize,
    pub local_steps: u64,
    pub eval_batches: usize,
    pub seed: u64,
    /// Round-engine workers (`--workers N|auto`; 0 = auto, 1 = sequential).
    pub workers: usize,
}

impl Scale {
    pub fn from_args(args: &Args, default_rounds: usize, default_steps: u64) -> Result<Scale> {
        let mut rounds = args.get_usize("rounds", default_rounds)?;
        let mut steps = args.get_u64("steps", default_steps)?;
        if args.flag("fast") {
            rounds = rounds.min(6);
            steps = steps.min(15);
        }
        if args.flag("paper-scale") {
            steps = 500;
        }
        Ok(Scale {
            rounds,
            local_steps: steps,
            eval_batches: args.get_usize("eval-batches", 4)?,
            seed: args.get_u64("seed", 42)?,
            workers: args.get_count_or_auto("workers", 1)?,
        })
    }

    /// Build a figure config for (model, corpus, P, K) at this scale.
    pub fn config(
        &self,
        model: &str,
        corpus: CorpusKind,
        p: usize,
        k: usize,
    ) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::figure_default(model, corpus);
        cfg.n_clients = p;
        cfg.clients_per_round = k;
        cfg.rounds = self.rounds;
        cfg.local_steps = self.local_steps;
        cfg.eval_batches = self.eval_batches;
        cfg.seed = self.seed;
        cfg.exec.workers = self.workers;
        let total = self.rounds as u64 * self.local_steps;
        cfg.schedule =
            CosineSchedule::new(3e-3, 0.1, total.max(2), (total / 20).min(50));
        cfg.label = format!("{model}-{p}x{k}");
        cfg
    }
}

/// One labeled training curve (federated run or centralized baseline).
pub struct Curve {
    pub label: String,
    pub log: MetricsLog,
}

/// Run the federated experiment for `cfg` using a cached model.
pub fn run_fed(cache: &mut ModelCache, cfg: &ExperimentConfig) -> Result<Curve> {
    let model = cache.get(&cfg.model)?;
    let mut fed = Federation::with_model(cfg.clone(), model)?;
    fed.run()?;
    Ok(Curve { label: format!("fed-{}", cfg.label), log: fed.log })
}

/// Run the centralized baseline for `cfg`.
pub fn run_central(cache: &mut ModelCache, cfg: &ExperimentConfig) -> Result<Curve> {
    let model = cache.get(&cfg.model)?;
    let log = run_centralized(cfg, &model)?;
    Ok(Curve { label: format!("central-{}", cfg.model), log })
}

/// Write each curve's full metrics CSV under `results/<exp>/`.
pub fn save_curves(exp: &str, curves: &[&Curve]) -> Result<()> {
    let dir = results_dir(exp);
    for c in curves {
        c.log.write_csv(&dir.join(format!("{}.csv", c.label)))?;
    }
    println!("[csv] results/{exp}/ ({} curves)", curves.len());
    Ok(())
}

/// Print a per-round comparison of one metric across curves.
pub fn print_metric_table(
    title: &str,
    curves: &[&Curve],
    metric: impl Fn(&crate::metrics::RoundRecord) -> f64,
) {
    println!("\n{title}");
    let mut header = vec!["round".to_string()];
    header.extend(curves.iter().map(|c| c.label.clone()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    let rounds = curves.iter().map(|c| c.log.rounds.len()).max().unwrap_or(0);
    for r in 0..rounds {
        let mut row = vec![r.to_string()];
        for c in curves {
            row.push(match c.log.rounds.get(r) {
                Some(rec) => format!("{:.3}", metric(rec)),
                None => "-".into(),
            });
        }
        t.row(row);
    }
    t.print();
}

/// Final value of a metric on a curve.
pub fn final_metric(c: &Curve, metric: impl Fn(&crate::metrics::RoundRecord) -> f64) -> f64 {
    c.log.rounds.last().map(&metric).unwrap_or(f64::NAN)
}

/// Report a qualitative shape check. Failures are loud but non-fatal at
/// tiny `--fast` scales (stochastic runs); the default scale is chosen so
/// these hold.
pub fn check_shape(name: &str, ok: bool, detail: String) {
    if ok {
        println!("[shape OK] {name}: {detail}");
    } else {
        println!("[shape !!] {name}: {detail} (rerun without --fast / with more --rounds)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::{Args, Spec};

    const SPEC: Spec = Spec {
        options: &["rounds", "steps", "seed", "eval-batches", "workers"],
        flags: &["fast", "paper-scale"],
    };

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()), &SPEC).unwrap()
    }

    #[test]
    fn scale_defaults_and_flags() {
        let s = Scale::from_args(&args(&[]), 12, 40).unwrap();
        assert_eq!((s.rounds, s.local_steps), (12, 40));
        let s = Scale::from_args(&args(&["--fast"]), 12, 40).unwrap();
        assert_eq!((s.rounds, s.local_steps), (6, 15));
        let s = Scale::from_args(&args(&["--paper-scale"]), 12, 40).unwrap();
        assert_eq!(s.local_steps, 500);
        let s = Scale::from_args(&args(&["--rounds", "3", "--steps", "7"]), 12, 40).unwrap();
        assert_eq!((s.rounds, s.local_steps), (3, 7));
        let s = Scale::from_args(&args(&["--workers", "auto"]), 12, 40).unwrap();
        assert_eq!(s.workers, 0);
        let s = Scale::from_args(&args(&[]), 12, 40).unwrap();
        assert_eq!(s.workers, 1, "sequential by default");
    }

    #[test]
    fn scale_config_shapes() {
        let s = Scale { rounds: 4, local_steps: 10, eval_batches: 2, seed: 1, workers: 3 };
        let cfg = s.config("m75a", CorpusKind::C4Iid, 8, 4);
        cfg.validate().unwrap();
        assert_eq!(cfg.rounds, 4);
        assert_eq!(cfg.clients_per_round, 4);
        assert_eq!(cfg.total_sequential_steps(), 40);
        assert_eq!(cfg.exec.workers, 3);
        assert!(cfg.exec.serialize_dispatch, "dispatch stays serialized by default");
    }
}
