//! `async`: the buffered-asynchronous aggregation sweep — staleness
//! discount γ × fault rate × local-round length τ over a localhost TCP
//! fleet running with no round barrier ([`crate::net::ServeOpts::async_agg`]).
//! Every cell's realized grant/fold ledger is replayed in-process via
//! `Federation::run_async_trace` for the bit-parity verdict, and a
//! straggler-marked copy of the schedule is priced through the wall-clock
//! simulator under the `async` and `semisync` policies. The paper's
//! motivation for relaxing the barrier (§3: stragglers gate every
//! synchronous round) shows up as two shapes: async wall-clock never
//! exceeds semi-sync on a straggler fleet, and at γ≈1 on a quiet fleet
//! the final NLL stays within a modest band of the synchronous run's.
//!
//! ```text
//! photon exp async [--config m75a] [--clients P] [--fold-k K]
//!     [--rounds N] [--steps T] [--taus T1,T2] [--seed S] [--fleet W]
//!     [--gammas 1.0,0.5] [--rates 0,25] [--deadline-secs F]
//! ```
//!
//! The rate ladder always includes the quiet rate-0 baseline and the
//! gamma ladder always includes γ=1 (no discount) — the shape checks
//! compare against both anchors.
//!
//! Writes `results/async/staleness.csv`
//! ([`crate::metrics::ASYNC_CSV_HEADER`]). Requires compiled artifacts
//! (`make artifacts`).

use std::sync::Arc;

use anyhow::Result;

use crate::chaos::{ChaosConfig, Schedule};
use crate::cluster::faults::FaultPlan;
use crate::config::ExperimentConfig;
use crate::coordinator::Federation;
use crate::exp::common::check_shape;
use crate::metrics::{write_async_csv, AsyncRow, RoundRecord};
use crate::net::{run_loopback, FleetOpts};
use crate::netsim::CLOUD_WAN;
use crate::optim::schedule::CosineSchedule;
use crate::runtime::Runtime;
use crate::sim::{AggregationPolicy, RoundPlan, SimConfig, Simulator};
use crate::util::results_dir;

fn parity(a: &[RoundRecord], b: &[RoundRecord]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.agrees_with(y))
}

/// One cell's config: the shared base at a given τ, epochs = rounds.
fn cell_config(
    model_name: &str,
    p: usize,
    k: usize,
    rounds: usize,
    tau: u64,
    seed: u64,
) -> ExperimentConfig {
    let total = rounds as u64 * tau;
    let mut cfg = ExperimentConfig::quickstart(model_name);
    cfg.label = format!("async-{model_name}-t{tau}");
    cfg.n_clients = p;
    cfg.clients_per_round = k;
    cfg.rounds = rounds;
    cfg.local_steps = tau;
    cfg.seed = seed;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), (total / 20).min(100));
    // Client-level faults off: every cut in this sweep is attributable to
    // the injected worker chaos, not the sampler's dropout draws.
    cfg.faults = FaultPlan::none();
    cfg
}

pub fn exp_async(args: &crate::util::cli::Args) -> Result<()> {
    let model_name = args.get_or("config", "m75a");
    let p = args.get_usize("clients", 6)?;
    let k = args.get_usize("fold-k", p.min(3))?.max(1).min(p);
    let mut rounds = args.get_usize("rounds", 5)?.max(2);
    let steps = args.get_u64("steps", 6)?;
    let mut taus = args.get_u64_list("taus", &[steps])?;
    if args.flag("fast") {
        rounds = rounds.min(3);
        taus.truncate(1);
        for t in taus.iter_mut() {
            *t = (*t).min(4);
        }
    }
    taus.sort_unstable();
    taus.dedup();
    let seed = args.get_u64("seed", 42)?;
    let fleet = args.get_usize("fleet", 4)?.max(2);
    let deadline = args.get_f64("deadline-secs", 5.0)?;
    // Normalize both ladders: the shape checks anchor on the rate-0
    // baseline and the γ=1 (no-discount) column.
    let mut rates = args.get_u64_list("rates", &[0, 25])?;
    rates.push(0);
    rates.sort_unstable();
    rates.dedup();
    let mut gammas = args.get_f64_list("gammas", &[1.0, 0.5])?;
    for &g in &gammas {
        anyhow::ensure!(g > 0.0 && g <= 1.0, "--gammas entries must be in (0, 1], got {g}");
    }
    if !gammas.iter().any(|&g| g == 1.0) {
        gammas.push(1.0);
    }
    gammas.sort_by(|a, b| b.partial_cmp(a).unwrap());
    gammas.dedup();

    println!(
        "async staleness sweep: {model_name} P={p} K={k} epochs={rounds} τ={taus:?} \
         over {fleet} TCP workers; γ {gammas:?} × fault rates {rates:?}% \
         (deadline {deadline}s)"
    );
    let rt = Runtime::cpu()?;
    let model = Arc::new(rt.load_model(&model_name)?);
    let payload = model.n_params() as u64 * 4;

    let mut rows: Vec<AsyncRow> = Vec::new();
    let mut all_agree = true;
    let mut sim_async_wins = true;
    // (gamma, rate, tau) → final NLL, for the γ≈1 tracking check.
    let mut finals: Vec<((f64, u64, u64), f64)> = Vec::new();

    println!("\n gamma | rate% | tau | final ppl | folds cuts | stale max/mean | replay | sim a/s secs");
    for (ti, &tau) in taus.iter().enumerate() {
        for &rate in &rates {
            for (gi, &gamma) in gammas.iter().enumerate() {
                let cell_seed = seed
                    .wrapping_add(rate.wrapping_mul(7919))
                    .wrapping_add((gi as u64).wrapping_mul(104_729))
                    .wrapping_add((ti as u64).wrapping_mul(1_299_709));
                let cfg = cell_config(&model_name, p, k, rounds, tau, cell_seed);
                // Async chaos cells are keyed by *grant id*, which can run
                // far past the epoch count — generate a schedule wide
                // enough to cover every grant the run could plausibly
                // issue (cells past the extent are quiet).
                let grant_budget = rounds * k.max(fleet) * 4;
                let schedule = Schedule::generate(
                    cell_seed,
                    fleet,
                    grant_budget,
                    ChaosConfig::at_rate(rate as f64 / 100.0),
                );
                let report = run_loopback(
                    cfg.clone(),
                    model.clone(),
                    FleetOpts {
                        workers: fleet,
                        compress: true,
                        deadline_secs: Some(deadline),
                        chaos: (rate > 0).then(|| schedule.clone()),
                        async_agg: Some((k, gamma)),
                        ..FleetOpts::default()
                    },
                )?;
                for e in &report.worker_errors {
                    println!("[!] {e}");
                }
                let trace = report
                    .async_trace
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("async fleet returned no trace"))?;
                trace
                    .check_exactly_once()
                    .map_err(|e| anyhow::anyhow!("async ledger violation: {e}"))?;

                // The acceptance invariant: replaying the realized async
                // ledger in-process reproduces the fleet bit-for-bit.
                let mut replay = Federation::with_model(cfg.clone(), model.clone())?;
                let replayed = replay.run_async_trace(&trace)?;
                let agree =
                    parity(&replayed, &report.records) && replay.global == report.global;
                all_agree &= agree;

                let last = report.records.last();
                let (ppl, nll) = last
                    .map(|r| (r.server_ppl, r.server_nll))
                    .unwrap_or((f64::NAN, f64::NAN));
                finals.push(((gamma, rate, tau), nll));

                // Price a straggler-marked copy of the same schedule
                // through the simulator: async folds at the K-th arrival,
                // semi-sync waits out its deadline factor.
                let mut plan = RoundPlan::from_config(&cfg);
                for spec in plan.rounds.iter_mut() {
                    if let Some(pt) = spec.participants.last_mut() {
                        pt.straggler = true;
                    }
                }
                let price = |policy| {
                    Simulator::uniform(&plan, 0.1, SimConfig::new(payload, CLOUD_WAN, policy))
                        .run()
                        .total_secs
                };
                let sim_async = price(AggregationPolicy::Async { k, gamma });
                let sim_semi = price(AggregationPolicy::SemiSync { deadline_factor: 1.5 });
                sim_async_wins &= sim_async <= sim_semi + 1e-9;

                println!(
                    " {gamma:>5.2} | {rate:>5} | {tau:>3} | {ppl:>9.3} | {:>5} {:>4} | \
                     {:>8} /{:>5.2} | {} | {sim_async:>6.1}/{sim_semi:>6.1}",
                    trace.total_folded(),
                    trace.total_cut(),
                    trace.staleness_max(),
                    trace.staleness_mean(),
                    if agree { "bit-equal" } else { "DIVERGED" },
                );
                rows.push(AsyncRow {
                    gamma,
                    fault_pct: rate as f64,
                    tau,
                    k,
                    final_ppl: ppl,
                    final_nll: nll,
                    folds: trace.total_folded(),
                    cuts: trace.total_cut(),
                    staleness_max: trace.staleness_max(),
                    staleness_mean: trace.staleness_mean(),
                    replay_agree: agree,
                    sim_async_secs: sim_async,
                    sim_semisync_secs: sim_semi,
                });
            }
        }
    }

    let out = results_dir("async").join("staleness.csv");
    write_async_csv(&out, &rows)?;

    // --- shape checks ------------------------------------------------------
    check_shape(
        "async-replay-parity",
        all_agree,
        "every async fleet bit-equals the in-process replay of its realized ledger"
            .into(),
    );
    check_shape(
        "async-beats-semisync-on-stragglers",
        sim_async_wins,
        "simulated async wall-clock never exceeds semi-sync on a straggler fleet"
            .into(),
    );
    // The tracking band: at γ=1 (no discount) on the quiet ladder rung,
    // dropping the barrier costs convergence only modestly — the final
    // NLL of a plain synchronous run of the same config bounds it within
    // a 1.5× band.
    let tau0 = taus[0];
    let quiet_nll = finals
        .iter()
        .find(|((g, r, t), _)| *g == 1.0 && *r == 0 && *t == tau0)
        .map(|(_, v)| *v)
        .unwrap_or(f64::NAN);
    let sync_seed = seed
        .wrapping_add((gammas.iter().position(|&g| g == 1.0).unwrap_or(0) as u64)
            .wrapping_mul(104_729));
    let sync_cfg = cell_config(&model_name, p, k, rounds, tau0, sync_seed);
    let mut sync_fed = Federation::with_model(sync_cfg, model.clone())?;
    sync_fed.run()?;
    let sync_nll = sync_fed
        .log
        .rounds
        .last()
        .map(|r| r.server_nll)
        .unwrap_or(f64::NAN);
    check_shape(
        "async-tracks-sync-at-gamma-1",
        quiet_nll <= sync_nll * 1.5,
        format!(
            "quiet γ=1 async final NLL {quiet_nll:.4} vs synchronous {sync_nll:.4} \
             (band 1.5×)"
        ),
    );
    println!("wrote {}", out.display());
    Ok(())
}
