//! `comm`: the paper's headline communication claim — federated
//! pre-training needs orders-of-magnitude less communication than
//! data-parallel (DDP) training for the same sequential step count (§4.3),
//! and the per-round communication is a negligible fraction of wall-clock
//! even on WAN links.
//!
//! Bytes come from the netsim cost model over *both* the paper's model
//! sizes and our artifact ladder (real manifest payloads, plus measured
//! Photon-Link compressed payload sizes of an actual trained model).

use anyhow::Result;

use crate::config::{PAPER_TABLE1, PAPER_TABLE2};
use crate::link;
use crate::model::manifest::Manifest;
use crate::netsim::*;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::table::Table;
use crate::util::{artifacts_dir, results_dir};

pub fn comm(args: &Args) -> Result<()> {
    let tau = args.get_u64("steps", 500)?; // paper's τ
    let rounds = args.get_u64("rounds", 20)? as u64;
    let workers = 8usize;

    println!(
        "Communication accounting: DDP Ring-AllReduce vs federated rounds \
         (τ={tau}, {workers} workers, {rounds} rounds)"
    );
    let mut t = Table::new(&[
        "model", "payload", "DDP bytes/worker", "FL bytes/client", "ratio",
        "FL comm frac (WAN, 1s/step)",
    ]);
    let mut csv = CsvWriter::create(
        &results_dir("comm").join("comm.csv"),
        &["params", "payload_bytes", "ddp_bytes", "fed_bytes", "ratio", "wan_comm_frac"],
    )?;

    let mut rows: Vec<(String, u64)> = PAPER_TABLE1
        .iter()
        .map(|r| (format!("paper-{}", r.size), (r.params * 4.0) as u64))
        .collect();
    for r in &PAPER_TABLE2 {
        if let Ok(m) = Manifest::load(&artifacts_dir().join(r.analog)) {
            rows.push((format!("analog-{}", r.analog), m.payload_bytes() as u64));
        }
    }

    let mut ratios = Vec::new();
    for (name, payload) in &rows {
        let ddp = ddp_total_bytes(*payload, workers, rounds * tau);
        let fed = fed_total_bytes(*payload, rounds);
        let ratio = ddp as f64 / fed as f64;
        let frac = fed_comm_fraction(*payload, &CLOUD_WAN, tau, 1.0);
        t.row(vec![
            name.clone(),
            human_bytes(*payload),
            human_bytes(ddp),
            human_bytes(fed),
            format!("{ratio:.0}x"),
            format!("{:.3}%", frac * 100.0),
        ]);
        csv.row(&[
            (*payload / 4) as f64, *payload as f64, ddp as f64, fed as f64, ratio,
            frac,
        ])?;
        ratios.push(ratio);
    }
    t.print();
    csv.finish()?;

    // Measured link payloads: compress an actual (structured) model payload.
    if let Ok(m) = Manifest::load(&artifacts_dir().join("m350a")) {
        let params = crate::model::init::init_params(&m, 7);
        let raw = link::encode_model(link::MsgKind::GlobalModel, &params, false)?;
        let comp = link::encode_model(link::MsgKind::GlobalModel, &params, true)?;
        println!(
            "\nPhoton-Link measured payload (m350a, {} params): raw {} → deflate {} ({:.1}%)",
            m.n_params,
            human_bytes(raw.len() as u64),
            human_bytes(comp.len() as u64),
            100.0 * comp.len() as f64 / raw.len() as f64
        );
    }

    let min_ratio = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    crate::exp::common::check_shape(
        "orders-of-magnitude communication reduction",
        min_ratio > 100.0,
        format!("min DDP/FL ratio {min_ratio:.0}× (τ·(n−1)/n = {:.0}×)",
                tau as f64 * (workers as f64 - 1.0) / workers as f64),
    );
    Ok(())
}

fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1}{}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512.0B");
        assert_eq!(human_bytes(2048), "2.0KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0MB");
    }
}
