//! `comm`: the paper's headline communication claim — federated
//! pre-training needs orders-of-magnitude less communication than
//! data-parallel (DDP) training for the same sequential step count (§4.3),
//! and the per-round communication is a negligible fraction of wall-clock
//! even on WAN links — plus the **lossy update-codec sweep**: how far the
//! `compress` registry (q8/q4 quantization, top-k + error feedback) pushes
//! the bytes-on-wire frontier, and (with artifacts) what it costs in final
//! loss versus the lossless baseline.
//!
//! ```text
//! photon exp comm [--steps τ] [--rounds N] [--taus 50,500] [--fast]
//! ```
//!
//! DDP-vs-FL bytes come from the netsim cost model over *both* the paper's
//! model sizes and our artifact ladder (real manifest payloads, plus
//! measured Photon-Link compressed payload sizes of an actual trained
//! model). The codec sweep measures *actual framed wire bytes* through
//! `link::encode_update`, each codec under its own transport config:
//! `none` ships raw dense frames (its registry meaning — no deflate
//! requested), `deflate` and the lossy codecs ship with transport deflate
//! on (what `photon serve` does by default). Ratios are reported against
//! the raw `none` baseline; the `deflate` row is the deployed lossless
//! reference. `--fast` shrinks the synthetic vector and skips the
//! training-backed loss comparison (CI smoke mode).

use anyhow::Result;

use crate::compress::UpdateCodec;
use crate::config::{ExperimentConfig, PAPER_TABLE1, PAPER_TABLE2};
use crate::link;
use crate::model::manifest::Manifest;
use crate::netsim::*;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::util::table::Table;
use crate::util::{artifacts_dir, results_dir};

pub fn comm(args: &Args) -> Result<()> {
    let tau = args.get_u64("steps", 500)?; // paper's τ
    let rounds = args.get_u64("rounds", 20)?;
    let workers = 8usize;

    println!(
        "Communication accounting: DDP Ring-AllReduce vs federated rounds \
         (τ={tau}, {workers} workers, {rounds} rounds)"
    );
    let mut t = Table::new(&[
        "model", "payload", "DDP bytes/worker", "FL bytes/client", "ratio",
        "FL comm frac (WAN, 1s/step)",
    ]);
    let mut csv = CsvWriter::create(
        &results_dir("comm").join("comm.csv"),
        &["params", "payload_bytes", "ddp_bytes", "fed_bytes", "ratio", "wan_comm_frac"],
    )?;

    let mut rows: Vec<(String, u64)> = PAPER_TABLE1
        .iter()
        .map(|r| (format!("paper-{}", r.size), (r.params * 4.0) as u64))
        .collect();
    for r in &PAPER_TABLE2 {
        if let Ok(m) = Manifest::load(&artifacts_dir().join(r.analog)) {
            rows.push((format!("analog-{}", r.analog), m.payload_bytes() as u64));
        }
    }

    let mut ratios = Vec::new();
    for (name, payload) in &rows {
        let ddp = ddp_total_bytes(*payload, workers, rounds * tau);
        let fed = fed_total_bytes(*payload, rounds);
        let ratio = ddp as f64 / fed as f64;
        let frac = fed_comm_fraction(*payload, &CLOUD_WAN, tau, 1.0);
        t.row(vec![
            name.clone(),
            human_bytes(*payload),
            human_bytes(ddp),
            human_bytes(fed),
            format!("{ratio:.0}x"),
            format!("{:.3}%", frac * 100.0),
        ]);
        csv.row(&[
            (*payload / 4) as f64, *payload as f64, ddp as f64, fed as f64, ratio,
            frac,
        ])?;
        ratios.push(ratio);
    }
    t.print();
    csv.finish()?;

    // Measured link payloads: compress an actual (structured) model payload.
    if let Ok(m) = Manifest::load(&artifacts_dir().join("m350a")) {
        let params = crate::model::init::init_params(&m, 7);
        let raw = link::encode_model(link::MsgKind::GlobalModel, &params, false)?;
        let comp = link::encode_model(link::MsgKind::GlobalModel, &params, true)?;
        println!(
            "\nPhoton-Link measured payload (m350a, {} params): raw {} → deflate {} ({:.1}%)",
            m.n_params,
            human_bytes(raw.len() as u64),
            human_bytes(comp.len() as u64),
            100.0 * comp.len() as f64 / raw.len() as f64
        );
    }

    let min_ratio = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
    crate::exp::common::check_shape(
        "orders-of-magnitude communication reduction",
        min_ratio > 100.0,
        format!("min DDP/FL ratio {min_ratio:.0}× (τ·(n−1)/n = {:.0}×)",
                tau as f64 * (workers as f64 - 1.0) / workers as f64),
    );

    codec_sweep(args)?;
    if !args.flag("fast") {
        codec_loss_sweep(args)?;
    }
    Ok(())
}

/// The bandwidth frontier: encode one synthetic pseudo-gradient through
/// every registry codec and measure the **actual framed wire bytes** —
/// each codec under its own transport config (`none` = raw dense, its
/// registry meaning; everything else with the deflate `photon serve`
/// ships by default) — plus the reconstruction error and the WAN comm
/// fraction those bytes imply at each τ. Ratios are vs the raw `none`
/// baseline; compare against the `deflate` row for the deployed lossless
/// reference.
fn codec_sweep(args: &Args) -> Result<()> {
    let n = if args.flag("fast") { 20_000 } else { 200_000 };
    let taus = args.get_u64_list("taus", &[50, 500])?;
    let codecs = [
        UpdateCodec::None,
        UpdateCodec::Deflate,
        UpdateCodec::parse("q8")?,
        UpdateCodec::parse("q4")?,
        UpdateCodec::parse("topk")?,
    ];

    // A pseudo-gradient-shaped payload: zero-mean noise at a realistic
    // update magnitude. Gaussian f32 mantissas are deflate's worst case,
    // which keeps the lossless baseline honest.
    let mut rng = Rng::new(7);
    let delta: Vec<f32> = (0..n).map(|_| rng.gauss_f32() * 0.01).collect();
    let dense_l2: f64 = delta.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();

    println!(
        "\nUpdate-codec sweep ({n}-element pseudo-gradient; none = raw dense, \
         others with transport deflate):"
    );
    let mut t = Table::new(&[
        "codec", "wire bytes", "vs raw none", "rel err", "WAN frac τ=min",
        "WAN frac τ=max",
    ]);
    let mut csv = CsvWriter::create(
        &results_dir("comm").join("codec_sweep.csv"),
        &["codec", "tau", "wire_bytes", "ratio_vs_raw_none", "rel_err", "wan_comm_frac"],
    )?;

    let tau_min = *taus.iter().min().unwrap_or(&50);
    let tau_max = *taus.iter().max().unwrap_or(&500);
    let mut none_bytes = 0u64;
    let mut q8_ratio = 0.0f64;
    for codec in &codecs {
        let mut residual = Vec::new();
        let compress = !matches!(codec, UpdateCodec::None);
        let frame = link::encode_update(
            link::MsgKind::ClientUpdate,
            &delta,
            codec,
            42,
            &mut residual,
            compress,
        )?;
        let wire = frame.len() as u64;
        let (_, back) = link::decode_update(&frame, codec, n)?;
        let err_l2: f64 = delta
            .iter()
            .zip(&back)
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let rel_err = if dense_l2 > 0.0 { err_l2 / dense_l2 } else { 0.0 };
        if matches!(codec, UpdateCodec::None) {
            none_bytes = wire;
        }
        let ratio = none_bytes as f64 / wire as f64;
        if matches!(codec, UpdateCodec::Q8 { .. }) {
            q8_ratio = ratio;
        }
        let frac = |tau: u64| {
            // One broadcast (dense) down + one coded update up per round of
            // τ steps at 1 s/step on the WAN rung.
            let comm = CLOUD_WAN.transfer_secs(4 * n as u64)
                + CLOUD_WAN.transfer_secs(wire);
            comm / (comm + tau as f64)
        };
        t.row(vec![
            codec.label(),
            human_bytes(wire),
            format!("{ratio:.2}x"),
            format!("{rel_err:.4}"),
            format!("{:.3}%", 100.0 * frac(tau_min)),
            format!("{:.3}%", 100.0 * frac(tau_max)),
        ]);
        for &tau in &taus {
            csv.row_mixed(&[
                codec.label(),
                tau.to_string(),
                wire.to_string(),
                format!("{ratio:.6}"),
                format!("{rel_err:.6}"),
                format!("{:.6}", frac(tau)),
            ])?;
        }
    }
    t.print();
    csv.finish()?;

    crate::exp::common::check_shape(
        "q8 ≥ 4× wire-byte reduction vs lossless none",
        q8_ratio >= 4.0,
        format!("q8 ships {q8_ratio:.2}× fewer framed bytes than raw none"),
    );
    println!("[csv] {}", results_dir("comm").join("codec_sweep.csv").display());
    Ok(())
}

/// The quality frontier (needs `make artifacts`): train the same tiny
/// federation under each codec and compare final server NLL against the
/// lossless baseline. Skipped silently on artifact-free checkouts.
fn codec_loss_sweep(args: &Args) -> Result<()> {
    let rt = match crate::runtime::Runtime::cpu() {
        Ok(rt) => rt,
        Err(_) => return Ok(()),
    };
    let model_name = args.get_or("config", "m75a");
    let model = match rt.load_model(&model_name) {
        Ok(m) => std::sync::Arc::new(m),
        Err(_) => {
            println!("\n(no artifacts — skipping the codec loss sweep; run `make artifacts`)");
            return Ok(());
        }
    };

    let rounds = args.get_usize("rounds", 20)?.clamp(4, 8);
    let steps = args.get_u64("steps", 500)?.clamp(6, 10);
    let mut base = ExperimentConfig::quickstart(&model_name);
    base.label = "comm-codec".into();
    base.rounds = rounds;
    base.local_steps = steps;
    base.eval_batches = 2;
    base.seed = args.get_u64("seed", 42)?;

    println!(
        "\nCodec × convergence ({model_name}, {rounds} rounds × τ={steps}, seed {}):",
        base.seed
    );
    let mut t = Table::new(&["codec", "final nll", "Δ vs none", "wire bytes/round"]);
    let mut csv = CsvWriter::create(
        &results_dir("comm").join("codec_loss.csv"),
        &["codec_tag", "final_nll", "rel_delta", "wire_bytes_last_round"],
    )?;
    let mut none_nll = f64::NAN;
    let mut q8_rel = f64::NAN;
    for name in ["none", "q8", "q4", "topk"] {
        let codec = UpdateCodec::parse(name)?;
        let mut cfg = base.clone();
        cfg.codec = codec;
        let mut fed =
            crate::coordinator::Federation::with_model(cfg, model.clone())?;
        let records = fed.run()?;
        let last = records.last().expect("at least one round");
        let rel = if none_nll.is_finite() {
            (last.server_nll - none_nll).abs() / none_nll
        } else {
            0.0
        };
        if name == "none" {
            none_nll = last.server_nll;
        }
        if name == "q8" {
            q8_rel = rel;
        }
        t.row(vec![
            codec.label(),
            format!("{:.5}", last.server_nll),
            format!("{:+.3}%", 100.0 * rel),
            human_bytes(last.comm_bytes_wire),
        ]);
        let (tag, _) = codec.tag_param();
        csv.row(&[
            tag as f64,
            last.server_nll,
            rel,
            last.comm_bytes_wire as f64,
        ])?;
    }
    t.print();
    csv.finish()?;
    crate::exp::common::check_shape(
        "q8 final loss within 2% of lossless",
        q8_rel.is_finite() && q8_rel <= 0.02,
        format!("|nll(q8) − nll(none)|/nll(none) = {:.3}%", 100.0 * q8_rel),
    );
    Ok(())
}

fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1}{}", UNITS[u])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512.0B");
        assert_eq!(human_bytes(2048), "2.0KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0MB");
    }
}
