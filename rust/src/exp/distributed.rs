//! `distributed`: the deployment-plane parity sweep — a localhost TCP
//! fleet (`net::harness`) must reproduce the in-process `Federation::run`
//! **bit for bit**: same global model, same round-record stream (wall-clock
//! aside), under partial participation, dropouts, and stragglers; a
//! worker crashed mid-round must be cut through the dropped-client path
//! with the remaining run still bit-reproducible from the recorded cut
//! schedule; and the same bit-parity must hold with a **lossy update
//! codec** (`q8`) negotiated — the wire's encode→decode transform is
//! replayed identically by the in-process transit pass.
//!
//! ```text
//! photon exp distributed [--config m75a] [--clients P] [--sampled K]
//!     [--rounds N] [--steps T] [--seed S] [--fleet W]
//!     [--dropout p] [--straggler p] [--codec q8]
//! ```
//!
//! Requires compiled artifacts (`make artifacts`).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::Result;

use crate::cluster::faults::FaultPlan;
use crate::compress::UpdateCodec;
use crate::config::ExperimentConfig;
use crate::coordinator::Federation;
use crate::exp::common::check_shape;
use crate::metrics::RoundRecord;
use crate::net::{run_loopback, FleetOpts};
use crate::optim::schedule::CosineSchedule;
use crate::runtime::Runtime;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::results_dir;

fn parity(a: &[RoundRecord], b: &[RoundRecord]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.agrees_with(y))
}

pub fn distributed(args: &Args) -> Result<()> {
    let model_name = args.get_or("config", "m75a");
    let p = args.get_usize("clients", 8)?;
    let k = args.get_usize("sampled", p.min(8))?;
    let rounds = args.get_usize("rounds", 4)?.max(3);
    let steps = args.get_u64("steps", 8)?;
    let seed = args.get_u64("seed", 42)?;
    let fleet = args.get_usize("fleet", 4)?.max(1);
    let dropout = args.get_f64("dropout", 0.1)?;
    let straggler = args.get_f64("straggler", 0.25)?;

    let total = rounds as u64 * steps;
    let mut cfg = ExperimentConfig::quickstart(&model_name);
    cfg.label = format!("distributed-{model_name}");
    cfg.n_clients = p;
    cfg.clients_per_round = k;
    cfg.rounds = rounds;
    cfg.local_steps = steps;
    cfg.seed = seed;
    cfg.schedule = CosineSchedule::new(3e-3, 0.1, total.max(2), (total / 20).min(100));
    cfg.faults = FaultPlan::new(dropout, straggler, seed);

    println!(
        "distributed parity: {model_name} P={p} K={k} rounds={rounds} τ={steps} \
         over {fleet} TCP workers (dropout {dropout}, stragglers {straggler})"
    );
    let rt = Runtime::cpu()?;
    let model = Arc::new(rt.load_model(&model_name)?);

    // --- reference: the in-process federation ------------------------------
    let mut fed = Federation::with_model(cfg.clone(), model.clone())?;
    let reference = fed.run()?;

    // --- the same config over a localhost TCP fleet ------------------------
    let fleet_report = run_loopback(
        cfg.clone(),
        model.clone(),
        FleetOpts { workers: fleet, compress: true, ..FleetOpts::default() },
    )?;
    for e in &fleet_report.worker_errors {
        println!("[!] {e}");
    }

    println!("\nround | in-process ppl | tcp-fleet ppl | participated | bit-equal");
    let mut w = CsvWriter::create(
        &results_dir("distributed").join("parity.csv"),
        &["round", "ref_ppl", "net_ppl", "ref_participated", "net_participated", "agree"],
    )?;
    for (r, n) in reference.iter().zip(&fleet_report.records) {
        let ok = r.agrees_with(n);
        println!(
            "{:>5} | {:>14.6} | {:>13.6} | {:>6} vs {:<3} | {}",
            r.round,
            r.server_ppl,
            n.server_ppl,
            r.participated,
            n.participated,
            if ok { "yes" } else { "NO" },
        );
        w.row(&[
            r.round as f64,
            r.server_ppl,
            n.server_ppl,
            r.participated as f64,
            n.participated as f64,
            ok as usize as f64,
        ])?;
    }
    w.finish()?;

    let records_ok = parity(&reference, &fleet_report.records);
    let global_ok = fed.global == fleet_report.global;
    check_shape(
        "distributed-parity",
        records_ok && global_ok && fleet_report.cuts.is_empty(),
        format!(
            "{} rounds over {fleet} workers: records {} + global model {} \
             (cuts: {:?})",
            reference.len(),
            if records_ok { "bit-equal" } else { "DIVERGED" },
            if global_ok { "bit-equal" } else { "DIVERGED" },
            fleet_report.cuts,
        ),
    );

    // --- fault drill: crash a worker mid-round, replay the cut in-process --
    let crash_round = 1u64;
    let crashed = run_loopback(
        cfg.clone(),
        model.clone(),
        FleetOpts {
            workers: fleet,
            compress: true,
            die_at_round: BTreeMap::from([(0usize, crash_round)]),
            ..FleetOpts::default()
        },
    )?;
    let mut replay = Federation::with_model(cfg, model.clone())?;
    let mut replayed = Vec::with_capacity(rounds);
    for round in 0..rounds {
        let cut = crashed
            .cuts
            .iter()
            .find(|(r, _)| *r == round)
            .map(|(_, c)| c.clone())
            .unwrap_or_default();
        replayed.push(replay.run_round_cut(&cut)?);
    }
    let cut_round_lost = crashed
        .cuts
        .iter()
        .any(|(r, c)| *r == crash_round as usize && !c.is_empty());
    let crash_records_ok = parity(&replayed, &crashed.records);
    let crash_global_ok = replay.global == crashed.global;
    check_shape(
        "distributed-crash-cut",
        cut_round_lost && crash_records_ok && crash_global_ok,
        format!(
            "worker 0 killed in round {crash_round}: cuts {:?}; replayed run \
             records {} + global {}",
            crashed.cuts,
            if crash_records_ok { "bit-equal" } else { "DIVERGED" },
            if crash_global_ok { "bit-equal" } else { "DIVERGED" },
        ),
    );

    // --- lossy-codec parity: negotiate a codec over the wire ---------------
    // The worker encodes each pseudo-delta (stochastic rounding seeded per
    // (round, client) from the task spec), the server decodes-then-folds;
    // the in-process run applies the identical transform, so records and
    // global model must still be bit-equal — and the wire accounting must
    // show the codec actually shrank the update frames.
    let codec = UpdateCodec::parse(&args.get_or("codec", "q8"))?;
    let mut cfg_codec = replay.cfg.clone();
    cfg_codec.label = format!("distributed-{model_name}-{}", codec.label());
    cfg_codec.codec = codec;
    let mut fed_codec = Federation::with_model(cfg_codec.clone(), model.clone())?;
    let ref_codec = fed_codec.run()?;
    let fleet_codec = run_loopback(
        cfg_codec,
        model,
        FleetOpts { workers: fleet, compress: true, ..FleetOpts::default() },
    )?;
    for e in &fleet_codec.worker_errors {
        println!("[!] {e}");
    }
    let codec_records_ok = parity(&ref_codec, &fleet_codec.records);
    let codec_global_ok = fed_codec.global == fleet_codec.global;
    // Lossless codecs keep the dense payload, so only lossy ones must
    // land below the dense estimate.
    let wire_shrank = !codec.is_lossy()
        || ref_codec
            .iter()
            .filter(|r| r.participated > 0)
            .all(|r| r.comm_bytes_wire < r.comm_bytes);
    check_shape(
        &format!("distributed-parity-{}", codec.label()),
        codec_records_ok && codec_global_ok && fleet_codec.cuts.is_empty() && wire_shrank,
        format!(
            "{} rounds with codec {} negotiated: records {} + global {} \
             (wire bytes {} dense estimate; cuts {:?})",
            ref_codec.len(),
            codec.label(),
            if codec_records_ok { "bit-equal" } else { "DIVERGED" },
            if codec_global_ok { "bit-equal" } else { "DIVERGED" },
            if wire_shrank { "below" } else { "NOT below" },
            fleet_codec.cuts,
        ),
    );
    println!(
        "wrote {}",
        results_dir("distributed").join("parity.csv").display()
    );
    Ok(())
}
