//! Tables 5–6: downstream in-context-learning comparison across the ladder
//! (§7.9). Three ladder sizes are federally pre-trained with the same
//! recipe, then scored on the 13 synthetic MC task families by
//! length-normalized option log-likelihood. The paper's claim under test:
//! the biggest model wins most head-to-head comparisons.

use anyhow::Result;

use crate::config::CorpusKind;
use crate::data::corpus::SyntheticCorpus;
use crate::evalharness::{task_accuracy, TaskFamily, TASKS_TABLE5, TASKS_TABLE6};
use crate::exp::common::*;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;
use crate::util::results_dir;
use crate::util::table::Table;

/// The paper evaluates Photon-1B/3B/7B; we use the matching analogues.
const SIZES: [(&str, &str); 3] =
    [("m1ba", "Photon-1B"), ("m3ba", "Photon-3B"), ("m7ba", "Photon-7B")];

pub fn table56(args: &Args) -> Result<()> {
    let scale = Scale::from_args(args, 6, 12)?;
    let n_items = args.get_usize("items", if args.flag("fast") { 10 } else { 24 })?;
    let mut cache = ModelCache::new()?;

    // Federated pre-training of each ladder size (paper recipe: K=4/P=64
    // for the big models; full participation for 1B-analog).
    let mut trained: Vec<(String, Vec<f32>, std::sync::Arc<crate::runtime::ModelRuntime>)> =
        Vec::new();
    for (model, label) in SIZES {
        let (p, k) = if model == "m1ba" { (8, 8) } else { (64, 4) };
        let cfg = scale.config(model, CorpusKind::C4Iid, p, k);
        let rt = cache.get(model)?;
        let mut fed = crate::coordinator::Federation::with_model(cfg, rt.clone())?;
        fed.run()?;
        println!(
            "{label}: trained {} rounds, final server ppl {:.2}",
            fed.log.rounds.len(),
            fed.log.last().map(|r| r.server_ppl).unwrap_or(f64::NAN)
        );
        trained.push((label.to_string(), fed.global.clone(), rt));
    }

    // Score every task family for every model. Tasks are built over the
    // *training* corpus (C4-analog) so scoring is in-distribution — the
    // paper's suite likewise probes capabilities the pre-training data
    // supports. With a single category, distractors are perturbed-path
    // continuations (random-start chains), so the discriminating signal is
    // exactly the learned bigram structure.
    let mut results: Vec<Vec<f64>> = Vec::new(); // [model][task]
    let mut families: Vec<TaskFamily> = Vec::new();
    for (label, params, rt) in &trained {
        let corpus = SyntheticCorpus::c4(rt.manifest.config.vocab);
        let fams = TaskFamily::suite(&corpus, rt.manifest.config.seq_len);
        let mut accs = Vec::new();
        for fam in &fams {
            let acc = task_accuracy(rt, params, &corpus, fam, n_items, scale.seed)?;
            accs.push(acc);
        }
        println!("{label}: mean accuracy {:.3}", accs.iter().sum::<f64>() / accs.len() as f64);
        if families.is_empty() {
            families = fams;
        }
        results.push(accs);
    }

    // Print in the paper's two-table layout.
    for (tbl, names) in [("Table 5", &TASKS_TABLE5[..]), ("Table 6", &TASKS_TABLE6[..])] {
        println!("\n{tbl}: in-context learning accuracy");
        let mut header = vec!["Name".to_string()];
        header.extend(names.iter().map(|s| s.to_string()));
        let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&hdr);
        for ((label, _, _), accs) in trained.iter().zip(&results) {
            let mut row = vec![label.clone()];
            for name in names {
                let idx = families.iter().position(|f| f.name == *name).unwrap();
                row.push(format!("{:.3}", accs[idx]));
            }
            t.row(row);
        }
        t.print();
    }

    // CSV + the paper's headline count: biggest model wins N of 13.
    let mut csv = CsvWriter::create(
        &results_dir("table56").join("accuracy.csv"),
        &["task", "photon_1b", "photon_3b", "photon_7b"],
    )?;
    let mut wins = 0;
    for (i, fam) in families.iter().enumerate() {
        csv.row_mixed(&[
            fam.name.clone(),
            format!("{:.4}", results[0][i]),
            format!("{:.4}", results[1][i]),
            format!("{:.4}", results[2][i]),
        ])?;
        if results[2][i] >= results[0][i] && results[2][i] >= results[1][i] {
            wins += 1;
        }
    }
    csv.finish()?;
    check_shape(
        "biggest model wins most comparisons",
        wins * 2 >= families.len(),
        format!("Photon-7B analog wins {wins} of {} (paper: 11 of 13)", families.len()),
    );
    Ok(())
}
