//! Fig 6 / 13 / 15: partial participation — sampling K=4 of P=64 clients
//! (6.25%) matches full participation (§7.4), with the same norm dynamics
//! (fig13 ↔ fig7, fig15 ↔ fig8).

use anyhow::Result;

use crate::config::CorpusKind;
use crate::exp::common::*;
use crate::util::cli::Args;

const SIZES: [&str; 2] = ["m75a", "m125a"];

fn partial_and_full(
    args: &Args,
    size: &str,
    cache: &mut ModelCache,
) -> Result<(Curve, Curve, Curve)> {
    let scale = Scale::from_args(args, 10, 20)?;
    // 6.25% participation: K=4 of P=64.
    let mut partial_cfg = scale.config(size, CorpusKind::C4Iid, 64, 4);
    partial_cfg.label = format!("{size}-64x4");
    let partial = run_fed(cache, &partial_cfg)?;
    // Full participation baseline: P=K=8.
    let full_cfg = scale.config(size, CorpusKind::C4Iid, 8, 8);
    let full = run_fed(cache, &full_cfg)?;
    let central = run_central(cache, &full_cfg)?;
    Ok((partial, full, central))
}

/// Fig 6: perplexity under 6.25% participation vs full participation.
pub fn fig6(args: &Args) -> Result<()> {
    let mut cache = ModelCache::new()?;
    for size in SIZES {
        let (partial, full, central) = partial_and_full(args, size, &mut cache)?;
        print_metric_table(
            &format!("{size}: server val ppl — 4/64 partial vs 8/8 full vs centralized"),
            &[&partial, &full, &central],
            |r| r.server_ppl,
        );
        save_curves("fig6", &[&partial, &full, &central])?;
        let p = final_metric(&partial, |r| r.server_ppl);
        let f = final_metric(&full, |r| r.server_ppl);
        check_shape(
            &format!("{size} partial ≈ full"),
            (p - f).abs() / f < 0.15,
            format!("partial {p:.2} vs full {f:.2} ({:+.1}%)", 100.0 * (p - f) / f),
        );
        // Half the parallel compute per round (4 clients vs 8).
        println!(
            "[compute] per-round client-steps: partial {} vs full {}",
            partial.log.rounds[0].participated as u64 * 40,
            full.log.rounds[0].participated as u64 * 40
        );
    }
    Ok(())
}

/// Fig 13: the fig7 norm triple under partial participation.
pub fn fig13(args: &Args) -> Result<()> {
    let mut cache = ModelCache::new()?;
    for size in SIZES {
        let (partial, _full, _central) = partial_and_full(args, size, &mut cache)?;
        crate::exp::fig_norms::print_norm_triple(size, &partial);
        save_curves("fig13", &[&partial])?;
        crate::exp::fig_norms::check_norm_consensus(size, &partial);
    }
    Ok(())
}

/// Fig 15: the fig8 gradient norms under partial participation.
pub fn fig15(args: &Args) -> Result<()> {
    let mut cache = ModelCache::new()?;
    for size in SIZES {
        let (partial, _full, _central) = partial_and_full(args, size, &mut cache)?;
        crate::exp::fig_norms::print_grad_norms(size, &partial);
        save_curves("fig15", &[&partial])?;
        crate::exp::fig_norms::check_pseudo_grad_decay(size, &partial);
    }
    Ok(())
}
