//! Fig 10: the outer-optimizer ablation (§7.8).
//!
//! Three algorithms × two local-batch regimes:
//! * FedAvg (stateless clients)          — the paper's winner
//! * SGD+N (server-side Nesterov)        — initial speedup, worse final
//! * FedAvg-KeepOpt (client AdamW kept)  — inflates model norm, diverges
//!
//! Shapes asserted: FedAvg reaches the lowest final training cross-entropy,
//! and KeepOpt/momentum grow the global-model L2 norm faster than FedAvg
//! (panels c/d of the paper's figure).

use anyhow::Result;

use crate::config::{CorpusKind, OptStatePolicy};
use crate::exp::common::*;
use crate::optim::outer::{OuterHyper, OuterOptKind};
use crate::util::cli::Args;

struct Variant {
    name: &'static str,
    outer: OuterOptKind,
    lr: f64,
    policy: OptStatePolicy,
}

const VARIANTS: [Variant; 3] = [
    Variant { name: "FedAvg", outer: OuterOptKind::FedAvg, lr: 1.0, policy: OptStatePolicy::Stateless },
    Variant {
        name: "SGD+N",
        outer: OuterOptKind::FedMomentum { nesterov: true },
        lr: 0.7,
        policy: OptStatePolicy::Stateless,
    },
    Variant { name: "FedAvg-KeepOpt", outer: OuterOptKind::FedAvg, lr: 1.0, policy: OptStatePolicy::KeepOpt },
];

fn run_regime(args: &Args, model: &str, regime: &str) -> Result<Vec<Curve>> {
    let scale = Scale::from_args(args, 10, 25)?;
    let mut cache = ModelCache::new()?;
    let mut curves = Vec::new();
    for v in &VARIANTS {
        let mut cfg = scale.config(model, CorpusKind::C4Iid, 8, 8);
        cfg.outer = v.outer;
        cfg.outer_hyper = OuterHyper { lr: v.lr, momentum: 0.9, ..OuterHyper::default() };
        cfg.opt_state = v.policy;
        cfg.label = format!("{}-{}", v.name, regime);
        curves.push(run_fed(&mut cache, &cfg)?);
    }
    Ok(curves)
}

pub fn fig10(args: &Args) -> Result<()> {
    // (a) large local batches: the m125a artifact (device batch 4 here,
    //     256 in the paper); (b) small local batches: m125a_b2 (batch 2) —
    //     same model, half the local batch, double the gradient noise.
    for (regime, model) in [("large-batch", "m125a"), ("small-batch", "m125a_b2")] {
        println!("\n=== fig10 ({regime}: {model}) ===");
        let curves = run_regime(args, model, regime)?;
        let refs: Vec<&Curve> = curves.iter().collect();
        print_metric_table(
            &format!("{regime}: client training cross-entropy"),
            &refs,
            |r| r.client_loss_mean,
        );
        print_metric_table(
            &format!("{regime}: global model L2 norm"),
            &refs,
            |r| r.global_model_norm,
        );
        save_curves("fig10", &refs)?;

        let final_ce: Vec<f64> =
            curves.iter().map(|c| final_metric(c, |r| r.client_loss_mean)).collect();
        check_shape(
            &format!("{regime}: FedAvg lowest final cross-entropy"),
            final_ce[0] <= final_ce[1] + 0.05 && final_ce[0] <= final_ce[2] + 0.05,
            format!(
                "FedAvg {:.3} vs SGD+N {:.3} vs KeepOpt {:.3}",
                final_ce[0], final_ce[1], final_ce[2]
            ),
        );
        let norm_growth: Vec<f64> = curves
            .iter()
            .map(|c| {
                let first = c.log.rounds.first().map(|r| r.global_model_norm).unwrap_or(1.0);
                final_metric(c, |r| r.global_model_norm) / first
            })
            .collect();
        check_shape(
            &format!("{regime}: KeepOpt/momentum inflate the model norm"),
            norm_growth[2] >= norm_growth[0] || norm_growth[1] >= norm_growth[0],
            format!(
                "norm growth FedAvg {:.3}× SGD+N {:.3}× KeepOpt {:.3}×",
                norm_growth[0], norm_growth[1], norm_growth[2]
            ),
        );
    }
    Ok(())
}
