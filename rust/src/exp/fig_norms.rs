//! Fig 7 / 8 / 11: the norm diagnostics of federated training on IID C4.
//!
//! * fig7 — L2 norms of the global model, the client models, and the client
//!   average: the server first "pulls back" client norms, then global and
//!   local norms converge together (§7.5).
//! * fig8 — FedAvg pseudo-gradient norm vs per-step client gradient norms:
//!   the pseudo-gradient starts much larger and decays to comparable or
//!   smaller magnitude as clients converge (§7.6).
//! * fig11 — global model norm vs the server-side Nesterov momentum norm
//!   (β = 0.7) across the ladder.

use anyhow::Result;

use crate::config::CorpusKind;
use crate::exp::common::*;
use crate::metrics::RoundRecord;
use crate::optim::outer::{OuterHyper, OuterOptKind};
use crate::util::cli::Args;
use crate::util::table::Table;

pub(crate) fn print_norm_triple(size: &str, fed: &Curve) {
    println!("\n{size}: model-norm triple (fig7)");
    let mut t = Table::new(&["round", "global", "client_avg", "client_mean"]);
    for r in &fed.log.rounds {
        t.row(vec![
            r.round.to_string(),
            format!("{:.3}", r.global_model_norm),
            format!("{:.3}", r.client_avg_norm),
            format!("{:.3}", r.client_model_norm_mean),
        ]);
    }
    t.print();
}

pub(crate) fn check_norm_consensus(size: &str, fed: &Curve) {
    // Late in training, global and client-average norms agree closely.
    if let Some(last) = fed.log.rounds.last() {
        let rel = (last.global_model_norm - last.client_avg_norm).abs()
            / last.client_avg_norm.max(1e-9);
        check_shape(
            &format!("{size} global/client norm consensus"),
            rel < 0.05,
            format!("relative norm gap {rel:.4}"),
        );
    }
}

pub(crate) fn print_grad_norms(size: &str, fed: &Curve) {
    println!("\n{size}: gradient norms (fig8)");
    let mut t = Table::new(&["round", "pseudo_grad", "step_grad_mean", "applied_update_mean"]);
    for r in &fed.log.rounds {
        t.row(vec![
            r.round.to_string(),
            format!("{:.4}", r.pseudo_grad_norm),
            format!("{:.4}", r.step_grad_norm_mean),
            format!("{:.4}", r.applied_update_norm_mean),
        ]);
    }
    t.print();
}

pub(crate) fn check_pseudo_grad_decay(size: &str, fed: &Curve) {
    let rs = &fed.log.rounds;
    if rs.len() < 3 {
        return;
    }
    let first = rs[0].pseudo_grad_norm;
    let last = rs.last().unwrap().pseudo_grad_norm;
    check_shape(
        &format!("{size} pseudo-gradient decays"),
        last < first,
        format!("{first:.3} → {last:.3}"),
    );
    // Starts larger than the applied per-step updates (it summarizes τ
    // steps), approaches their magnitude at convergence (§7.6).
    check_shape(
        &format!("{size} pseudo-grad starts above per-step updates"),
        rs[0].pseudo_grad_norm > rs[0].applied_update_norm_mean,
        format!(
            "round0: pseudo {:.3} vs applied {:.3}",
            rs[0].pseudo_grad_norm, rs[0].applied_update_norm_mean
        ),
    );
}

fn fed_runs(
    args: &Args,
    sizes: &[&str],
    outer: Option<(OuterOptKind, OuterHyper)>,
    default_rounds: usize,
    default_steps: u64,
) -> Result<Vec<(String, Curve)>> {
    let scale = Scale::from_args(args, default_rounds, default_steps)?;
    let mut cache = ModelCache::new()?;
    let mut out = Vec::new();
    for &size in sizes {
        let mut cfg = scale.config(size, CorpusKind::C4Iid, 8, 8);
        if let Some((kind, hyper)) = outer {
            cfg.outer = kind;
            cfg.outer_hyper = hyper;
        }
        out.push((size.to_string(), run_fed(&mut cache, &cfg)?));
    }
    Ok(out)
}

/// Fig 7: 75M and 350M analogues, IID C4, full participation.
pub fn fig7(args: &Args) -> Result<()> {
    for (size, fed) in fed_runs(args, &["m75a", "m350a"], None, 12, 20)? {
        print_norm_triple(&size, &fed);
        save_curves("fig7", &[&fed])?;
        check_norm_consensus(&size, &fed);
    }
    Ok(())
}

/// Fig 8: pseudo-gradient vs local gradients, 75M and 350M analogues.
pub fn fig8(args: &Args) -> Result<()> {
    for (size, fed) in fed_runs(args, &["m75a", "m350a"], None, 12, 20)? {
        print_grad_norms(&size, &fed);
        save_curves("fig8", &[&fed])?;
        check_pseudo_grad_decay(&size, &fed);
    }
    Ok(())
}

/// Fig 11: model norm vs server momentum norm with Nesterov β = 0.7
/// across four ladder sizes.
pub fn fig11(args: &Args) -> Result<()> {
    let hyper = OuterHyper { lr: 0.7, momentum: 0.7, ..OuterHyper::default() };
    let runs = fed_runs(
        args,
        &["m75a", "m125a", "m350a", "m1ba"],
        Some((OuterOptKind::FedMomentum { nesterov: true }, hyper)),
        8,
        15,
    )?;
    for (size, fed) in &runs {
        println!("\n{size}: global model norm vs server momentum norm (fig11)");
        let mut t = Table::new(&["round", "model_norm", "momentum_norm"]);
        for r in &fed.log.rounds {
            t.row(vec![
                r.round.to_string(),
                format!("{:.3}", r.global_model_norm),
                format!("{:.3}", r.momentum_norm),
            ]);
        }
        t.print();
        save_curves("fig11", &[fed])?;
        // Momentum tracks a moving average: bounded, nonzero after round 0.
        let max_m = fed
            .log
            .rounds
            .iter()
            .map(|r: &RoundRecord| r.momentum_norm)
            .fold(0.0f64, f64::max);
        check_shape(
            &format!("{size} momentum bounded"),
            max_m > 0.0 && max_m < 10.0 * fed.log.rounds[0].global_model_norm,
            format!("max momentum norm {max_m:.3}"),
        );
    }
    Ok(())
}
