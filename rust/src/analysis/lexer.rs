//! A lightweight Rust tokenizer for the static-analysis plane.
//!
//! This is not a full lexer — it produces exactly what the lint rules
//! need: identifiers, literals, and punctuation with line numbers, plus
//! the comment stream (where `lint:allow` directives live). Everything
//! inside strings, chars, and comments is opaque to the rules, so a
//! diagnostic message that *mentions* a forbidden name never trips the
//! rule that forbids it.
//!
//! Handled faithfully: line comments, nested block comments, string
//! escapes, raw strings (`r#"…"#` with any number of `#`), byte strings,
//! char literals vs lifetimes (`'a'` vs `'a`), and numeric literals with
//! suffixes/underscores. Anything else is a single-character punct token.

/// Token class. Multi-character operators are emitted as consecutive
/// single-character [`TokKind::Punct`] tokens; rules that care about `::`
/// check adjacency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `let`, `HashMap`, …).
    Ident,
    /// A lifetime (`'a`) — distinguished from char literals.
    Lifetime,
    /// String literal of any flavor (plain, raw, byte).
    Str,
    /// Char or byte-char literal.
    Char,
    /// Numeric literal (kept as text, suffix included).
    Num,
    /// One punctuation character.
    Punct,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line.
    pub line: usize,
}

impl Tok {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }
}

/// One comment (line or block), attributed to its starting line. Block
/// comment text keeps interior newlines.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Tokenize `src` into (tokens, comments). Never fails: unterminated
/// constructs simply run to end-of-file (the real compiler will report
/// them; the linter stays quiet rather than guessing).
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let n = chars.len();

    let bump = |c: char, line: &mut usize| {
        if c == '\n' {
            *line += 1;
        }
    };

    while i < n {
        let c = chars[i];
        // Whitespace.
        if c.is_whitespace() {
            bump(c, &mut line);
            i += 1;
            continue;
        }
        // Line comment (also doc comments `///`, `//!`).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            comments.push(Comment {
                line,
                text: chars[start..i].iter().collect(),
            });
            continue;
        }
        // Block comment, nested per Rust rules.
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    bump(chars[i], &mut line);
                    i += 1;
                }
            }
            comments.push(Comment {
                line: start_line,
                text: chars[start..i.min(n)].iter().collect(),
            });
            continue;
        }
        // Raw strings: r"…", r#"…"#, br#"…"# … (any number of #).
        if c == 'r' || c == 'b' {
            let mut j = i;
            if chars[j] == 'b' && j + 1 < n && chars[j + 1] == 'r' {
                j += 1;
            }
            if chars[j] == 'r' {
                let mut k = j + 1;
                let mut hashes = 0usize;
                while k < n && chars[k] == '#' {
                    hashes += 1;
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    // Consume to `"` followed by `hashes` #'s.
                    let tok_line = line;
                    k += 1;
                    loop {
                        if k >= n {
                            break;
                        }
                        if chars[k] == '"' {
                            let mut h = 0usize;
                            while k + 1 + h < n && h < hashes && chars[k + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                k += 1 + hashes;
                                break;
                            }
                        }
                        bump(chars[k], &mut line);
                        k += 1;
                    }
                    toks.push(Tok { kind: TokKind::Str, text: String::new(), line: tok_line });
                    i = k;
                    continue;
                }
            }
            // Plain byte string b"…" / byte char b'…'.
            if c == 'b' && i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '\'') {
                i += 1; // fall through to the string/char scanners below
                // (chars[i] is now the quote)
            }
        }
        let c = chars[i];
        // String literal with escapes.
        if c == '"' {
            let tok_line = line;
            i += 1;
            while i < n {
                if chars[i] == '\\' && i + 1 < n {
                    bump(chars[i + 1], &mut line);
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    i += 1;
                    break;
                }
                bump(chars[i], &mut line);
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: String::new(), line: tok_line });
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // Escaped char literal: '\n', '\'', '\u{…}' …
                i += 2;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                continue;
            }
            if i + 1 < n && is_ident_start(chars[i + 1]) {
                let mut k = i + 1;
                while k < n && is_ident_cont(chars[k]) {
                    k += 1;
                }
                if k < n && chars[k] == '\'' {
                    // 'a' — char literal.
                    toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                    i = k + 1;
                } else {
                    // 'a — lifetime.
                    let text: String = chars[i + 1..k].iter().collect();
                    toks.push(Tok { kind: TokKind::Lifetime, text, line });
                    i = k;
                }
                continue;
            }
            // '(' — punctuation char literal.
            if i + 2 < n && chars[i + 2] == '\'' {
                toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i += 3;
                continue;
            }
            // Lone quote (macro land) — treat as punct and move on.
            toks.push(Tok { kind: TokKind::Punct, text: "'".into(), line });
            i += 1;
            continue;
        }
        // Identifier / keyword (also the r#ident raw-identifier form).
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(chars[i]) {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Number (suffixes and underscores kept; `1..2` stops at the range).
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n {
                let d = chars[i];
                if d == '.' {
                    if i + 1 < n && chars[i + 1] == '.' {
                        break; // range operator
                    }
                    if i + 1 < n && !chars[i + 1].is_ascii_digit() && chars[i + 1] != 'f' {
                        break; // method call on a literal: 1.max(…)
                    }
                    i += 1;
                } else if is_ident_cont(d) {
                    i += 1;
                } else {
                    break;
                }
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: chars[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Everything else: one punct char.
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn idents_and_puncts_with_lines() {
        let (toks, _) = lex("let x = a.b();\nfoo::bar(x)");
        let on_2: Vec<&str> = toks
            .iter()
            .filter(|t| t.line == 2 && t.kind == TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(on_2, ["foo", "bar", "x"]);
    }

    #[test]
    fn strings_hide_their_contents() {
        assert_eq!(idents(r#"let m = "HashMap::new() Instant::now";"#), ["let", "m"]);
        // Escaped quote does not end the string early.
        assert_eq!(idents(r#"x("a\"HashMap", y)"#), ["x", "y"]);
    }

    #[test]
    fn raw_strings_and_hashes() {
        assert_eq!(idents(r##"let s = r#"thread_rng() "quoted" "#; t"##), ["let", "s", "t"]);
        assert_eq!(idents(r#"let s = r"panic!"; u"#), ["let", "s", "u"]);
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let (toks, comments) = lex("a // HashMap here\nb /* Instant::now\n still */ c");
        let names: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(names, ["a", "b", "c"]);
        assert_eq!(comments.len(), 2);
        assert_eq!(comments[0].line, 1);
        assert!(comments[0].text.contains("HashMap"));
        assert_eq!(comments[1].line, 2);
        // The block comment spans a newline; the token after it is on line 3.
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let (toks, _) = lex("fn f<'a>(x: &'a str) { let c = 'y'; let p = '('; }");
        let lifetimes: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(lifetimes, ["a", "a"]);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn numbers_keep_suffix_and_stop_at_ranges() {
        let (toks, _) = lex("0..n 1_000u64 2.5f32");
        let nums: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, ["0", "1_000u64", "2.5f32"]);
    }

    #[test]
    fn nested_block_comments() {
        let (toks, comments) = lex("a /* outer /* inner */ still outer */ b");
        assert_eq!(toks.len(), 2);
        assert_eq!(comments.len(), 1);
        assert!(comments[0].text.contains("inner"));
    }
}
