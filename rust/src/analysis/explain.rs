//! Long-form rule documentation for `photon lint --explain <rule>`.
//!
//! Each writeup names the contract the rule enforces, what trips it, how
//! to fix a hit, and when (if ever) a `lint:allow` is appropriate. The
//! same material lives in docs/ANALYSIS.md; this copy ships inside the
//! binary so a CI log line can say `--explain nondet-map` and mean it.

use super::{ALLOW_POLICY, LOCK_ORDER, NONDET_MAP, NONDET_RNG, NONDET_TIME, WIRE_ALLOC, WIRE_PANIC};

pub fn explain(rule: &str) -> Option<&'static str> {
    match rule {
        r if r == NONDET_MAP => Some(
            "nondet-map — hash-ordered containers in determinism-scoped modules\n\
             \n\
             Contract: a federated round is a pure function of (config, seed,\n\
             trace). `Federation::run`, the TCP fleet, and trace replay must\n\
             produce bit-identical parameters (ARCHITECTURE.md, determinism\n\
             contracts; docs/TESTING.md parity invariants).\n\
             \n\
             Why it trips: std's HashMap/HashSet iteration order depends on a\n\
             per-process random hasher seed. Any fold, drain, serialization, or\n\
             f32 accumulation over such a container can differ between two runs\n\
             of the same round — float addition is not associative, so even a\n\
             sum over the same elements in a different order breaks parity.\n\
             The rule bans the *type* in scoped modules (coordinator, net, link,\n\
             chaos, metrics, model, optim, compress, data, sim, ckpt, cluster,\n\
             exp, evalharness, netsim): once the type is present, an\n\
             order-dependent fold is one refactor away.\n\
             \n\
             Fix: use BTreeMap/BTreeSet (ordered, deterministic), or collect to\n\
             a Vec and sort by a stable key before iterating.\n\
             \n\
             Allow: only for a container that is provably never iterated (point\n\
             lookups only) — say so: // lint:allow(nondet-map): point lookups\n\
             only, never iterated. Prefer the BTree swap; it is usually free.",
        ),
        r if r == NONDET_TIME => Some(
            "nondet-time — host-clock reads outside the wall-clock allowlist\n\
             \n\
             Contract: round math, protocol state, and metrics must not depend\n\
             on when or where a run executes (parity across fleet/sim/replay).\n\
             \n\
             Why it trips: Instant::now()/SystemTime::now() smuggle host timing\n\
             into state. A timeout that changes a round outcome, a timestamp\n\
             folded into a metric the parity test compares, a duration used to\n\
             pick a codec — all make two identical runs diverge.\n\
             \n\
             Allowlisted: net/server.rs, net/harness.rs, net/worker.rs (socket\n\
             deadlines, session ids, liveness), benchkit.rs (reporting), util/,\n\
             runtime/, analysis/, main.rs, testkit.rs. These layers may measure\n\
             time but must keep it out of anything the contracts compare.\n\
             \n\
             Fix: move the measurement to the harness/server layer, or thread\n\
             simulated time (sim/plan) through explicitly.\n\
             \n\
             Allow: reporting-only reads in scoped files, e.g.\n\
             // lint:allow(nondet-time): wall_secs is reporting-only; parity\n\
             ignores it.",
        ),
        r if r == NONDET_RNG => Some(
            "nondet-rng — randomness that does not come from util::rng\n\
             \n\
             Contract: \"we seed every local training and the client selection\n\
             mechanism\" (paper §6.1). Every stochastic draw must come from a\n\
             util::rng::Rng stream derived from the experiment seed via\n\
             derive(label, index), so any run can be replayed bit-exactly.\n\
             \n\
             Why it trips: thread_rng/from_entropy/getrandom/OsRng/StdRng/\n\
             SmallRng/RandomState (and any rand:: path) pull ambient entropy.\n\
             One such draw anywhere below the experiment root makes the run\n\
             unreplayable and the chaos soak's replay checks meaningless.\n\
             \n\
             Fix: accept an &mut util::rng::Rng (or derive a child stream with\n\
             a stable label) instead of constructing an RNG locally.\n\
             \n\
             Allow: essentially never. util/rng.rs itself is the only exempt\n\
             file.",
        ),
        r if r == WIRE_PANIC => Some(
            "wire-panic — panics or raw indexing on wire-decoded data\n\
             \n\
             Contract: \"malformed ⇒ cut, never crash\" (docs/PROTOCOL.md). A\n\
             hostile or corrupted frame may cost the peer its connection; it\n\
             must never take down the coordinator or a worker.\n\
             \n\
             Why it trips: in net/ and link/, .unwrap()/.expect()/panic!/\n\
             unreachable!/todo!/unimplemented! turn a bad byte into a process\n\
             abort; `v[i]` on a value let-bound from decode/read_frame/read_msg\n\
             panics on an attacker-chosen index. (#[cfg(test)] code is exempt.)\n\
             \n\
             Fix: propagate with `?`, bail! with a diagnostic, or use\n\
             get()/get_mut() and handle None. The server's accept loop already\n\
             converts Err into a connection cut.\n\
             \n\
             Allow: genuinely infallible cases, with the proof in the reason,\n\
             e.g. // lint:allow(wire-panic): try_into on a fixed 8-byte slice\n\
             of a length-checked header is infallible.",
        ),
        r if r == WIRE_ALLOC => Some(
            "wire-alloc — allocations sized by untrusted decoded lengths\n\
             \n\
             Contract: a frame that passes magic/version/checksum validation is\n\
             still untrusted input. Resource use must be bounded by what was\n\
             actually received, not by what the frame *claims*.\n\
             \n\
             Why it trips: Vec::with_capacity(n)/.reserve(n)/vec![x; n] where\n\
             `n` was let-bound from a decoder integer (Dec::u8/u16/u32/u64/i64\n\
             or from_le_bytes) lets a 30-byte frame demand a 2^60-element\n\
             allocation — an OOM kill, which on the coordinator is a\n\
             fleet-wide outage.\n\
             \n\
             Fix: size through Dec::capacity_hint(n, min_elem_bytes), which\n\
             clamps the claim to what the remaining payload could possibly\n\
             hold, or validate `n` against a hard protocol bound first.\n\
             \n\
             Allow: when a bound is enforced immediately before, cite it:\n\
             // lint:allow(wire-alloc): len is ensure-bounded to\n\
             MAX_FRAME_BYTES above.",
        ),
        r if r == LOCK_ORDER => Some(
            "lock-order — cycles in the inter-procedural lock-acquisition graph\n\
             \n\
             Contract: the coordinator must survive chaos (worker crashes,\n\
             rejoins, lease migration) without wedging. A deadlock is a silent\n\
             hang — worse than a crash, because the soak harness only notices\n\
             at its timeout.\n\
             \n\
             How it works: for every function in net/, runtime/, and\n\
             coordinator/round_exec.rs, the pass extracts Mutex/RwLock\n\
             acquisition sites (.lock(), and .read()/.write() in files that\n\
             mention RwLock), tracks which guards are still held (let-bound ⇒\n\
             rest of function, temporary ⇒ rest of statement), follows calls\n\
             into other scoped functions, and adds an edge A→B whenever B is\n\
             acquired while A is held. A cycle means two call paths can take\n\
             the same locks in opposite orders — a deadlock waiting for the\n\
             right interleaving.\n\
             \n\
             Fix: impose one global acquisition order and restructure the\n\
             offending path; narrow a guard's scope with an explicit drop() so\n\
             the second lock is taken after the first is released.\n\
             \n\
             Allow: not suppressible — the finding is structural, spanning\n\
             functions and files; there is no single line to exempt. The\n\
             nightly ThreadSanitizer job cross-checks these findings\n\
             dynamically.",
        ),
        r if r == ALLOW_POLICY => Some(
            "allow-policy — malformed or reason-less lint:allow suppressions\n\
             \n\
             The only way to silence a finding is\n\
             // lint:allow(rule): <reason>\n\
             on the violating line or the line directly above it. The reason is\n\
             mandatory and should state *why the contract still holds* at this\n\
             site — it is the reviewable artifact that keeps suppressions\n\
             honest. A bare lint:allow(rule), an unknown rule name, or an\n\
             attempt to suppress allow-policy/lock-order is itself a violation,\n\
             and allow-policy findings cannot be suppressed.",
        ),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::super::RULES;
    use super::*;

    #[test]
    fn every_registered_rule_has_a_writeup() {
        for (rule, _) in RULES {
            let text = explain(rule).unwrap_or_else(|| panic!("missing --explain for {rule}"));
            assert!(text.starts_with(rule), "writeup for {rule} must lead with its name");
            assert!(text.len() > 200, "writeup for {rule} is too thin");
        }
    }

    #[test]
    fn unknown_rule_is_none() {
        assert!(explain("no-such-rule").is_none());
    }
}
