//! Inter-procedural lock-order analysis (`lock-order`).
//!
//! Extracts Mutex/RwLock acquisition sites per function across the
//! concurrency-scoped files (`net/`, `runtime/`, `coordinator/round_exec.rs`),
//! threads call edges through to a transitive-acquire closure, and fails
//! on cycles in the resulting lock-acquisition graph — the static
//! complement to the nightly ThreadSanitizer job.
//!
//! Approximations (all conservative, all documented in docs/ANALYSIS.md):
//! - a lock's identity is the receiver identifier before `.lock()` /
//!   `.read()` / `.write()` (`self.gate.lock()` and `other.gate.lock()`
//!   collapse into one class `gate`);
//! - `.read()`/`.write()` count only in files that mention `RwLock`, so
//!   `io::Read`/`io::Write` never masquerade as locks;
//! - a guard in a `let` statement is assumed held to the end of the
//!   function; a temporary guard to the end of its statement;
//! - calls resolve by bare name across the scoped files (no paths, no
//!   generics) — good enough for a repo that keeps locking local;
//! - self-edges (re-acquiring the same class) are not reported: with
//!   statement-scoped guards they are overwhelmingly the benign
//!   drop-then-retake pattern, and true re-entrancy is TSan's job.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{lex, Tok, TokKind};
use super::rules::matching;
use super::{Diagnostic, LOCK_ORDER};

/// Files included in the acquisition graph.
pub fn in_scope(path: &str) -> bool {
    path.starts_with("net/") || path.starts_with("runtime/") || path == "coordinator/round_exec.rs"
}

#[derive(Clone, Debug)]
enum Event {
    Acquire { lock: String, line: usize, bound: bool },
    Call { name: String, line: usize },
    StmtEnd,
}

struct FnBody {
    file: String,
    events: Vec<Event>,
}

/// One ordered edge in the acquisition graph: `to` was acquired while
/// `from` was held, first observed at `file:line`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Edge {
    pub from: String,
    pub to: String,
    pub file: String,
    pub line: usize,
}

pub struct LockReport {
    /// Every lock class seen, sorted.
    pub locks: Vec<String>,
    /// Ordered acquisition edges, deduplicated, sorted.
    pub edges: Vec<Edge>,
    /// A witness cycle (`a → b → … → a`) if the graph has one.
    pub cycle: Option<Vec<String>>,
}

impl LockReport {
    pub fn diagnostics(&self) -> Vec<Diagnostic> {
        let Some(cycle) = &self.cycle else { return Vec::new() };
        // Anchor the diagnostic at the edge that closes the cycle.
        let (file, line) = self
            .edges
            .iter()
            .find(|e| e.from == cycle[cycle.len() - 2] && e.to == cycle[cycle.len() - 1])
            .map(|e| (e.file.clone(), e.line))
            .unwrap_or_default();
        vec![Diagnostic {
            file,
            line,
            rule: LOCK_ORDER,
            message: format!(
                "lock acquisition cycle: {} — two threads taking these locks in \
                 opposite orders can deadlock; impose a single global order",
                cycle.join(" → "),
            ),
        }]
    }

    /// One-line summary for the CLI and CI logs.
    pub fn summary(&self) -> String {
        match &self.cycle {
            None => format!(
                "[lock-order] acquisition graph: {} lock class(es), {} edge(s), acyclic",
                self.locks.len(),
                self.edges.len(),
            ),
            Some(c) => format!(
                "[lock-order] acquisition graph: {} lock class(es), {} edge(s), CYCLE: {}",
                self.locks.len(),
                self.edges.len(),
                c.join(" → "),
            ),
        }
    }
}

/// Walk back from `i` (exclusive) over one bracketed group, returning the
/// index before the group's opener; used to hop `[idx]` / `(args)` when
/// hunting the receiver of a method call.
fn skip_group_back(toks: &[Tok], i: usize) -> usize {
    let (close, open) = match toks[i].text.as_str() {
        "]" => (']', '['),
        ")" => (')', '('),
        _ => return i,
    };
    let mut depth = 0usize;
    let mut j = i;
    loop {
        if toks[j].is_punct(close) {
            depth += 1;
        } else if toks[j].is_punct(open) {
            depth -= 1;
            if depth == 0 {
                return j.saturating_sub(1);
            }
        }
        if j == 0 {
            return 0;
        }
        j -= 1;
    }
}

/// Receiver identifier of the method call whose `.` is at `dot`:
/// `queue[i].lock()` → `queue`, `self.gate.lock()` → `gate`.
fn receiver(toks: &[Tok], dot: usize) -> Option<String> {
    if dot == 0 {
        return None;
    }
    let mut j = dot - 1;
    j = skip_group_back(toks, j);
    let t = &toks[j];
    (t.kind == TokKind::Ident).then(|| t.text.clone())
}

/// True if the statement containing token `i` starts with (or contains) a
/// `let` — i.e. the value produced here is bound, so a guard lives past
/// the statement.
fn in_let_statement(toks: &[Tok], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        j -= 1;
        let t = &toks[j];
        if t.is_punct(';') || t.is_punct('{') || t.is_punct('}') {
            return false;
        }
        if t.is_ident("let") {
            return true;
        }
    }
    false
}

/// Extract per-function event streams from one file.
fn extract(file: &str, src: &str, fns: &mut BTreeMap<String, FnBody>) {
    let (toks, _) = lex(src);
    let has_rwlock = toks.iter().any(|t| t.is_ident("RwLock"));
    let mut i = 0usize;
    while i < toks.len() {
        if !toks[i].is_ident("fn") {
            i += 1;
            continue;
        }
        let Some(name_tok) = toks.get(i + 1) else { break };
        if name_tok.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let name = name_tok.text.clone();
        // Find the body: first `{` after the signature (skipping the
        // argument list and any bracketed generics), or `;` for a
        // body-less trait method.
        let mut j = i + 2;
        let mut body = None;
        while j < toks.len() {
            if toks[j].is_punct('(') || toks[j].is_punct('[') {
                j = matching(&toks, j) + 1;
                continue;
            }
            if toks[j].is_punct(';') {
                break;
            }
            if toks[j].is_punct('{') {
                body = Some((j, matching(&toks, j)));
                break;
            }
            j += 1;
        }
        let Some((open, close)) = body else {
            i = j + 1;
            continue;
        };
        let mut events = Vec::new();
        let mut k = open + 1;
        while k < close.min(toks.len()) {
            let t = &toks[k];
            if t.is_punct(';') {
                events.push(Event::StmtEnd);
                k += 1;
                continue;
            }
            if t.kind != TokKind::Ident {
                k += 1;
                continue;
            }
            let after_dot = toks[k - 1].is_punct('.');
            let zero_arg_call = k + 2 < toks.len()
                && toks[k + 1].is_punct('(')
                && toks[k + 2].is_punct(')');
            let is_acquire = after_dot
                && zero_arg_call
                && (t.text == "lock" || (has_rwlock && (t.text == "read" || t.text == "write")));
            if is_acquire {
                if let Some(lock) = receiver(&toks, k - 1) {
                    events.push(Event::Acquire {
                        lock,
                        line: t.line,
                        bound: in_let_statement(&toks, k),
                    });
                }
                k += 3;
                continue;
            }
            // Call-like: name( … ). Resolution against the fn table
            // happens at graph-build time; method names that match no
            // known function are ignored there.
            if k + 1 < toks.len() && toks[k + 1].is_punct('(') && !toks[k - 1].is_ident("fn") {
                events.push(Event::Call { name: t.text.clone(), line: t.line });
            }
            k += 1;
        }
        // Nested fns are rare; name collisions collapse (last wins),
        // which only ever merges event streams conservatively.
        fns.insert(name, FnBody { file: file.to_string(), events });
        i = close + 1;
    }
}

/// Every lock class `f` (or anything it transitively calls) can acquire.
fn transitive_acquires(
    f: &str,
    fns: &BTreeMap<String, FnBody>,
    memo: &mut BTreeMap<String, BTreeSet<String>>,
    visiting: &mut BTreeSet<String>,
) -> BTreeSet<String> {
    if let Some(hit) = memo.get(f) {
        return hit.clone();
    }
    if !visiting.insert(f.to_string()) {
        return BTreeSet::new(); // recursion backstop
    }
    let mut acc = BTreeSet::new();
    if let Some(body) = fns.get(f) {
        for ev in &body.events {
            match ev {
                Event::Acquire { lock, .. } => {
                    acc.insert(lock.clone());
                }
                Event::Call { name, .. } if fns.contains_key(name) => {
                    acc.extend(transitive_acquires(name, fns, memo, visiting));
                }
                _ => {}
            }
        }
    }
    visiting.remove(f);
    memo.insert(f.to_string(), acc.clone());
    acc
}

/// Build the acquisition graph over `files` (`(normalized path, source)`)
/// and check it for cycles.
pub fn analyze(files: &[(String, String)]) -> LockReport {
    let mut fns: BTreeMap<String, FnBody> = BTreeMap::new();
    for (path, src) in files {
        extract(path, src, &mut fns);
    }

    let mut memo = BTreeMap::new();
    let mut locks: BTreeSet<String> = BTreeSet::new();
    let mut edge_set: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for body in fns.values() {
        let mut held: Vec<(String, bool)> = Vec::new();
        for ev in &body.events {
            match ev {
                Event::Acquire { lock, line, bound } => {
                    locks.insert(lock.clone());
                    for (h, _) in &held {
                        if h != lock {
                            edge_set
                                .entry((h.clone(), lock.clone()))
                                .or_insert_with(|| (body.file.clone(), *line));
                        }
                    }
                    held.push((lock.clone(), *bound));
                }
                Event::Call { name, line } => {
                    if held.is_empty() || !fns.contains_key(name) {
                        continue;
                    }
                    let mut visiting = BTreeSet::new();
                    for t in transitive_acquires(name, &fns, &mut memo, &mut visiting) {
                        locks.insert(t.clone());
                        for (h, _) in &held {
                            if *h != t {
                                edge_set
                                    .entry((h.clone(), t.clone()))
                                    .or_insert_with(|| (body.file.clone(), *line));
                            }
                        }
                    }
                }
                Event::StmtEnd => held.retain(|(_, bound)| *bound),
            }
        }
    }

    let edges: Vec<Edge> = edge_set
        .into_iter()
        .map(|((from, to), (file, line))| Edge { from, to, file, line })
        .collect();
    let cycle = find_cycle(&locks, &edges);
    LockReport { locks: locks.into_iter().collect(), edges, cycle }
}

/// DFS cycle detection; returns a witness path `a → … → a`.
fn find_cycle(locks: &BTreeSet<String>, edges: &[Edge]) -> Option<Vec<String>> {
    let mut succ: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for e in edges {
        succ.entry(e.from.as_str()).or_default().push(e.to.as_str());
    }
    // 0 = white, 1 = on stack, 2 = done.
    let mut color: BTreeMap<&str, u8> = locks.iter().map(|l| (l.as_str(), 0u8)).collect();

    fn dfs<'a>(
        node: &'a str,
        succ: &BTreeMap<&'a str, Vec<&'a str>>,
        color: &mut BTreeMap<&'a str, u8>,
        stack: &mut Vec<&'a str>,
    ) -> Option<Vec<String>> {
        color.insert(node, 1);
        stack.push(node);
        for &next in succ.get(node).into_iter().flatten() {
            match color.get(next).copied().unwrap_or(0) {
                1 => {
                    let from = stack.iter().position(|&s| s == next).unwrap_or(0);
                    let mut cycle: Vec<String> =
                        stack[from..].iter().map(|s| s.to_string()).collect();
                    cycle.push(next.to_string());
                    return Some(cycle);
                }
                0 => {
                    if let Some(c) = dfs(next, succ, color, stack) {
                        return Some(c);
                    }
                }
                _ => {}
            }
        }
        stack.pop();
        color.insert(node, 2);
        None
    }

    let nodes: Vec<&str> = locks.iter().map(|l| l.as_str()).collect();
    for node in nodes {
        if color.get(node).copied().unwrap_or(0) == 0 {
            let mut stack = Vec::new();
            if let Some(c) = dfs(node, &succ, &mut color, &mut stack) {
                return Some(c);
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> LockReport {
        analyze(&[(path.to_string(), src.to_string())])
    }

    #[test]
    fn opposite_orders_cycle() {
        let src = "
            fn a(&self) {
                let g1 = self.alpha.lock();
                let g2 = self.beta.lock();
            }
            fn b(&self) {
                let g1 = self.beta.lock();
                let g2 = self.alpha.lock();
            }
        ";
        let r = one("net/server.rs", src);
        assert_eq!(r.locks, ["alpha", "beta"]);
        let cycle = r.cycle.expect("opposite acquisition orders must cycle");
        assert_eq!(cycle.first(), cycle.last());
        assert_eq!(r.diagnostics().len(), 1);
        assert!(r.summary().contains("CYCLE"), "{}", r.summary());
    }

    #[test]
    fn consistent_order_is_acyclic() {
        let src = "
            fn a(&self) { let g1 = self.alpha.lock(); let g2 = self.beta.lock(); }
            fn b(&self) { let g1 = self.alpha.lock(); let g2 = self.beta.lock(); }
        ";
        let r = one("net/server.rs", src);
        assert!(r.cycle.is_none());
        assert_eq!(r.edges.len(), 1);
        assert_eq!((r.edges[0].from.as_str(), r.edges[0].to.as_str()), ("alpha", "beta"));
        assert!(r.diagnostics().is_empty());
    }

    #[test]
    fn temporary_guard_dropped_at_statement_end() {
        // Neither guard is let-bound, so no two are ever held together.
        let src = "
            fn a(&self) { *self.alpha.lock() += 1; *self.beta.lock() += 1; }
            fn b(&self) { *self.beta.lock() += 1; *self.alpha.lock() += 1; }
        ";
        let r = one("net/server.rs", src);
        assert!(r.edges.is_empty(), "{:?}", r.edges);
        assert!(r.cycle.is_none());
    }

    #[test]
    fn interprocedural_cycle_through_helper() {
        let src = "
            fn helper(&self) { let g = self.beta.lock(); }
            fn a(&self) {
                let g = self.alpha.lock();
                helper();
            }
            fn b(&self) {
                let g = self.beta.lock();
                let h = self.alpha.lock();
            }
        ";
        let r = one("runtime/mod.rs", src);
        assert!(r.cycle.is_some(), "{:?}", r.edges);
    }

    #[test]
    fn self_reacquire_not_flagged() {
        let src = "fn a(&self) { let g = self.alpha.lock(); let h = self.alpha.lock(); }";
        let r = one("net/server.rs", src);
        assert!(r.edges.is_empty());
        assert!(r.cycle.is_none());
    }

    #[test]
    fn io_read_write_are_not_locks() {
        // No RwLock in the file ⇒ zero-arg read()/write() are ignored.
        let src = "fn a(s: &mut S) { let n = s.read(); s.write(); }";
        let r = one("net/worker.rs", src);
        assert!(r.locks.is_empty(), "{:?}", r.locks);
    }

    #[test]
    fn rwlock_read_write_count_when_present() {
        let src = "
            struct S { table: RwLock<u8> }
            fn a(&self) { let g = self.table.read(); let h = self.index.write(); }
            fn b(&self) { let g = self.index.write(); let h = self.table.read(); }
        ";
        let r = one("runtime/mod.rs", src);
        assert_eq!(r.locks, ["index", "table"]);
        assert!(r.cycle.is_some());
    }

    #[test]
    fn indexed_receiver_collapses_to_base() {
        let src = "fn a(q: &[M]) { let g = queue[i].lock(); let s = slots[i].lock(); }";
        let r = one("coordinator/round_exec.rs", src);
        assert_eq!(r.locks, ["queue", "slots"]);
        assert_eq!(r.edges.len(), 1);
    }
}
