//! `photon lint` — the determinism & concurrency static-analysis plane.
//!
//! The repo's headline guarantees (bit-exact parity between
//! `Federation::run`, the TCP fleet, and trace replay; "malformed frame ⇒
//! cut, never crash") are *determinism contracts* stated in
//! docs/ARCHITECTURE.md and docs/PROTOCOL.md. Tests enforce them only on
//! the paths tests happen to exercise; this module enforces them at the
//! source level, over every path, with zero external dependencies.
//!
//! Layers:
//! - [`lexer`] — a lightweight Rust tokenizer (comments kept separately,
//!   so `lint:allow` directives and doc text never look like code);
//! - [`rules`] — per-file visitors: `nondet-map`, `nondet-time`,
//!   `nondet-rng`, `wire-panic`, `wire-alloc`;
//! - [`locks`] — the inter-procedural Mutex/RwLock acquisition graph and
//!   its cycle check (`lock-order`);
//! - [`explain`] — the `photon lint --explain <rule>` writeups.
//!
//! Suppression policy: a violation may be silenced only by a
//! `lint:allow` comment — rule name in parentheses, then a colon and a
//! mandatory reason — on the same line or the line above; a reason-less
//! allow is itself a violation (`allow-policy`). See docs/ANALYSIS.md.

pub mod explain;
pub mod lexer;
pub mod locks;
pub mod rules;

use std::fmt;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use lexer::{lex, Comment, Tok};

pub const NONDET_MAP: &str = "nondet-map";
pub const NONDET_TIME: &str = "nondet-time";
pub const NONDET_RNG: &str = "nondet-rng";
pub const WIRE_PANIC: &str = "wire-panic";
pub const WIRE_ALLOC: &str = "wire-alloc";
pub const LOCK_ORDER: &str = "lock-order";
pub const ALLOW_POLICY: &str = "allow-policy";

/// All rules, with one-line summaries (shown by `photon lint --explain`).
pub const RULES: &[(&str, &str)] = &[
    (NONDET_MAP, "hash-ordered containers in determinism-scoped modules"),
    (NONDET_TIME, "host-clock reads outside the wall-clock allowlist"),
    (NONDET_RNG, "randomness that does not come from util::rng"),
    (WIRE_PANIC, "panics or raw indexing on wire-decoded data in net/ and link/"),
    (WIRE_ALLOC, "allocations sized by untrusted decoded lengths"),
    (LOCK_ORDER, "cycles in the inter-procedural lock-acquisition graph"),
    (ALLOW_POLICY, "malformed or reason-less lint:allow suppressions"),
];

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    /// Path relative to the source root, `/`-separated.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{} [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Everything `lint_tree` learned about one source tree.
pub struct Report {
    /// Files scanned.
    pub files: usize,
    /// Surviving (un-suppressed) violations, sorted by file/line/rule.
    pub diagnostics: Vec<Diagnostic>,
    /// The lock-acquisition analysis over the concurrency-scoped files.
    pub locks: locks::LockReport,
}

/// A parsed, well-formed `lint:allow` directive (rule + reason).
struct Allow {
    line: usize,
    rule: &'static str,
}

fn known_rule(name: &str) -> Option<&'static str> {
    RULES.iter().find(|(r, _)| *r == name).map(|(r, _)| *r)
}

/// Strip tooling prefixes so fixtures and real files normalize the same
/// way ("rust/src/net/proto.rs" and "net/proto.rs" are the same module).
fn norm_path(p: &str) -> String {
    let p = p.replace('\\', "/");
    let p = p.strip_prefix("./").unwrap_or(&p);
    for prefix in ["rust/src/", "src/"] {
        if let Some(rest) = p.strip_prefix(prefix) {
            return rest.to_string();
        }
    }
    p.to_string()
}

/// Parse every `lint:allow` directive in the comment stream. Malformed
/// directives (unknown rule, missing reason, unsuppressible rule) become
/// `allow-policy` diagnostics instead of allows — a suppression that does
/// not explain itself is a violation in its own right.
fn parse_allows(path: &str, comments: &[Comment], policy: &mut Vec<Diagnostic>) -> Vec<Allow> {
    let mut allows = Vec::new();
    for c in comments {
        let mut search = 0usize;
        while let Some(pos) = c.text[search..].find("lint:allow") {
            let at = search + pos;
            search = at + "lint:allow".len();
            let line = c.line + c.text[..at].matches('\n').count();
            let mut bad = |msg: String, policy: &mut Vec<Diagnostic>| {
                policy.push(Diagnostic {
                    file: path.to_string(),
                    line,
                    rule: ALLOW_POLICY,
                    message: msg,
                });
            };
            let rest = &c.text[search..];
            let Some(rest) = rest.strip_prefix('(') else {
                // Prose mention ("see lint:allow below"), not a directive.
                // Fail-closed: a typo'd directive suppresses nothing, so
                // the underlying diagnostic still fires.
                continue;
            };
            let Some(close) = rest.find(')') else {
                bad("malformed suppression: unclosed `lint:allow(`".into(), policy);
                continue;
            };
            let rule_name = rest[..close].trim();
            let reason = rest[close + 1..]
                .trim_start()
                .strip_prefix(':')
                .map(|r| {
                    r.lines()
                        .next()
                        .unwrap_or("")
                        .trim()
                        .trim_end_matches("*/")
                        .trim()
                        .to_string()
                })
                .unwrap_or_default();
            match known_rule(rule_name) {
                None => bad(
                    format!("lint:allow names unknown rule `{rule_name}` (see --explain)"),
                    policy,
                ),
                Some(r) if r == ALLOW_POLICY => bad(
                    "allow-policy cannot be suppressed: fix the malformed directive".into(),
                    policy,
                ),
                Some(r) if r == LOCK_ORDER => bad(
                    "lock-order findings are structural (cycles across functions) and \
                     cannot be suppressed at a line; break the cycle instead"
                        .into(),
                    policy,
                ),
                Some(_) if reason.is_empty() => bad(
                    format!(
                        "lint:allow({rule_name}) without a reason: every suppression \
                         must say why the site is exempt"
                    ),
                    policy,
                ),
                Some(r) => allows.push(Allow { line, rule: r }),
            }
        }
    }
    allows
}

/// Per-token mask: true inside `#[cfg(test)]` / `#[test]` items. Test
/// code may unwrap and hash to its heart's content — it never runs on the
/// wire or in round math.
fn test_spans(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let is_attr = toks[i].is_punct('#') && i + 1 < toks.len() && toks[i + 1].is_punct('[');
        if !is_attr {
            i += 1;
            continue;
        }
        let close = rules::matching(toks, i + 1);
        let inner: Vec<&str> = toks[i + 2..close.min(toks.len())]
            .iter()
            .filter(|t| t.kind == lexer::TokKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        let is_test_attr = inner == ["test"] || inner == ["cfg", "test"];
        if !is_test_attr {
            i = close + 1;
            continue;
        }
        // Find the item the attribute decorates: the next `{…}` block (or
        // a `;` for block-less items), skipping stacked attributes and the
        // signature's parens/brackets.
        let mut j = close + 1;
        let mut end = None;
        while j < toks.len() {
            if toks[j].is_punct('#') && j + 1 < toks.len() && toks[j + 1].is_punct('[') {
                j = rules::matching(toks, j + 1) + 1;
                continue;
            }
            if toks[j].is_punct('(') || toks[j].is_punct('[') {
                j = rules::matching(toks, j) + 1;
                continue;
            }
            if toks[j].is_punct(';') {
                end = Some(j);
                break;
            }
            if toks[j].is_punct('{') {
                end = Some(rules::matching(toks, j));
                break;
            }
            j += 1;
        }
        match end {
            Some(e) => {
                let e = e.min(toks.len() - 1);
                for m in mask.iter_mut().take(e + 1).skip(i) {
                    *m = true;
                }
                i = e + 1;
            }
            None => break,
        }
    }
    mask
}

/// Lint one file's source. `virtual_path` decides rule scoping, so fixture
/// snippets can opt into any scope by claiming a path inside it. Returns
/// surviving diagnostics, sorted and deduplicated.
pub fn lint_source(virtual_path: &str, source: &str) -> Vec<Diagnostic> {
    let path = norm_path(virtual_path);
    let (toks, comments) = lex(source);
    let is_test = test_spans(&toks);
    let ctx = rules::FileCtx { path: &path, toks: &toks, is_test: &is_test };

    let mut diags = Vec::new();
    rules::nondet_map(&ctx, &mut diags);
    rules::nondet_time(&ctx, &mut diags);
    rules::nondet_rng(&ctx, &mut diags);
    rules::wire_panic(&ctx, &mut diags);
    rules::wire_alloc(&ctx, &mut diags);

    let mut policy = Vec::new();
    let allows = parse_allows(&path, &comments, &mut policy);
    let suppressed = |d: &Diagnostic| {
        allows
            .iter()
            .any(|a| a.rule == d.rule && (a.line == d.line || a.line + 1 == d.line))
    };
    let mut kept: Vec<Diagnostic> = diags.into_iter().filter(|d| !suppressed(d)).collect();
    kept.extend(policy);
    kept.sort_by(|a, b| (a.line, a.rule, &a.message).cmp(&(b.line, b.rule, &b.message)));
    kept.dedup();
    kept
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(root, &p, out)?;
        } else if p.extension().and_then(|x| x.to_str()) == Some("rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `src_root` (deterministic order), then run
/// the cross-file lock-order analysis over the concurrency-scoped subset.
pub fn lint_tree(src_root: &Path) -> Result<Report> {
    let mut paths = Vec::new();
    walk(src_root, src_root, &mut paths)?;
    paths.sort();

    let mut diags = Vec::new();
    let mut lock_files: Vec<(String, String)> = Vec::new();
    for rel in &paths {
        let full = src_root.join(rel);
        let src = fs::read_to_string(&full)
            .with_context(|| format!("reading {}", full.display()))?;
        diags.extend(lint_source(rel, &src));
        if locks::in_scope(&norm_path(rel)) {
            lock_files.push((norm_path(rel), src));
        }
    }
    let locks_report = locks::analyze(&lock_files);
    diags.extend(locks_report.diagnostics());
    diags.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.message).cmp(&(&b.file, b.line, b.rule, &b.message))
    });
    diags.dedup();
    Ok(Report { files: paths.len(), diagnostics: diags, locks: locks_report })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_on_same_or_preceding_line_suppresses() {
        let src = "use std::collections::HashMap; // lint:allow(nondet-map): ordered drain below\n\
                   // lint:allow(nondet-map): keys sorted before the fold\n\
                   fn f() { let m: HashMap<u8, u8> = HashMap::new(); }\n";
        assert!(lint_source("metrics/mod.rs", src).is_empty());
    }

    #[test]
    fn allow_does_not_leak_to_other_lines_or_rules() {
        let src = "// lint:allow(nondet-map): only covers the next line\n\
                   fn a() { let m = HashMap::new(); }\n\
                   fn b() { let m = HashMap::new(); }\n";
        let d = lint_source("metrics/mod.rs", src);
        assert_eq!(d.len(), 1);
        assert_eq!((d[0].line, d[0].rule), (3, NONDET_MAP));
        // A nondet-map allow does not silence a nondet-time hit.
        let src = "// lint:allow(nondet-map): wrong rule\nfn f() { let t = Instant::now(); }\n";
        let d = lint_source("metrics/mod.rs", src);
        assert_eq!((d[0].line, d[0].rule), (2, NONDET_TIME));
    }

    #[test]
    fn reasonless_allow_is_a_policy_violation() {
        let src = "// lint:allow(nondet-map)\nfn f() { let m = HashMap::new(); }\n";
        let d = lint_source("metrics/mod.rs", src);
        let rules: Vec<_> = d.iter().map(|x| x.rule).collect();
        assert!(rules.contains(&ALLOW_POLICY), "{d:?}");
        assert!(rules.contains(&NONDET_MAP), "reason-less allow must not suppress: {d:?}");
    }

    #[test]
    fn prose_mention_of_the_directive_is_ignored() {
        // Doc text that *talks about* the directive (no opening paren
        // right after it) is neither a suppression nor a violation.
        let d = lint_source("metrics/mod.rs", "// see lint:allow in docs/ANALYSIS.md\n");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn unknown_rule_in_allow_is_flagged() {
        let d = lint_source("metrics/mod.rs", "// lint:allow(no-such-rule): whatever\n");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, ALLOW_POLICY);
        assert!(d[0].message.contains("no-such-rule"));
    }

    #[test]
    fn lock_order_and_allow_policy_cannot_be_suppressed() {
        let d = lint_source("net/server.rs", "// lint:allow(lock-order): nope\n");
        assert_eq!(d[0].rule, ALLOW_POLICY);
        let d = lint_source("net/server.rs", "// lint:allow(allow-policy): nope\n");
        assert_eq!(d[0].rule, ALLOW_POLICY);
    }

    #[test]
    fn diagnostic_rendering_is_stable() {
        let d = lint_source("exp/common.rs", "fn f() { let m = HashMap::new(); }\n");
        let line = d[0].to_string();
        assert!(line.starts_with("exp/common.rs:1 [nondet-map] "), "{line}");
    }

    #[test]
    fn virtual_path_prefixes_normalize() {
        for p in ["rust/src/metrics/mod.rs", "src/metrics/mod.rs", "metrics/mod.rs"] {
            assert_eq!(
                lint_source(p, "fn f() { let m = HashMap::new(); }\n").len(),
                1,
                "path {p} should be in scope"
            );
        }
    }
}
