//! Per-file lint rules: nondeterminism (hash order, wall clocks, foreign
//! RNGs) and wire panic-safety (panics and unbounded allocation on decode
//! paths). Each rule is a small visitor over the token stream produced by
//! [`super::lexer`]; scoping is by module path so fixtures can exercise a
//! rule by claiming a virtual path inside (or outside) its scope.

use super::lexer::{Tok, TokKind};
use super::{Diagnostic, NONDET_MAP, NONDET_RNG, NONDET_TIME, WIRE_ALLOC, WIRE_PANIC};

/// One source file as seen by the rules: normalized path (relative to
/// `src/`, `/`-separated), tokens, and a per-token "inside `#[cfg(test)]`
/// or `#[test]`" mask computed by the driver.
pub struct FileCtx<'a> {
    pub path: &'a str,
    pub toks: &'a [Tok],
    pub is_test: &'a [bool],
}

/// Modules whose state feeds round math, the wire protocol, metrics, or
/// replay — anywhere hash-iteration order could leak into observable
/// behavior. Root-level files (main.rs, benchkit.rs, testkit.rs) and
/// `util/`, `config/`, `runtime/`, `analysis/` are deliberately outside.
const DETERMINISM_SCOPE: &[&str] = &[
    "chaos/",
    "ckpt/",
    "cluster/",
    "compress/",
    "coordinator/",
    "data/",
    "evalharness/",
    "exp/",
    "link/",
    "metrics/",
    "model/",
    "net/",
    "netsim/",
    "obs/",
    "optim/",
    "sim/",
];

/// Files allowed to read host clocks: transport/liveness layers (timeouts,
/// deadlines, session ids) and reporting harnesses. Everything they derive
/// from a clock must stay out of round math — that is what keeps parity
/// between `Federation::run`, the TCP fleet, and trace replay.
const WALL_CLOCK_FILES: &[&str] = &[
    "net/server.rs",
    "net/harness.rs",
    "net/worker.rs",
    "net/subagg.rs",
    "net/poll.rs",
    // the observability plane's ONE sanctioned wall-clock read: event
    // timestamps (`ts_us`) are display metadata, never an ordering key —
    // every other obs/ file must stay clock-free so replay is pure.
    "obs/clock.rs",
    "benchkit.rs",
    "main.rs",
    "testkit.rs",
];
const WALL_CLOCK_DIRS: &[&str] = &["util/", "runtime/", "analysis/"];

/// Identifiers that mean "an RNG that is not `util::rng`".
const FOREIGN_RNG: &[&str] = &[
    "thread_rng",
    "from_entropy",
    "getrandom",
    "OsRng",
    "ThreadRng",
    "StdRng",
    "SmallRng",
    "RandomState",
];

/// Decoder methods on `ckpt::Dec` that yield attacker-controlled integers
/// (plus `from_le_bytes`, the raw-header equivalent). A `let` whose RHS
/// calls one of these taints the bound name as an untrusted length.
const DEC_INT_METHODS: &[&str] = &["u8", "u16", "u32", "u64", "i64", "from_le_bytes"];

/// Calls whose result carries a whole decoded frame/message; `let`
/// bindings from them are tainted for the indexing check.
const DECODE_SOURCES: &[&str] = &["read_msg", "read_frame", "recv_frame"];

pub fn in_determinism_scope(path: &str) -> bool {
    DETERMINISM_SCOPE.iter().any(|p| path.starts_with(p))
}

pub fn wall_clock_allowed(path: &str) -> bool {
    WALL_CLOCK_FILES.contains(&path) || WALL_CLOCK_DIRS.iter().any(|p| path.starts_with(p))
}

pub fn in_wire_scope(path: &str) -> bool {
    // ckpt/store.rs decodes spill files it wrote itself, but a torn write
    // or disk corruption reaches its decoder exactly like a hostile frame
    // reaches the link layer — same rules apply.
    path.starts_with("net/") || path.starts_with("link/") || path == "ckpt/store.rs"
}

/// Forbid `HashMap`/`HashSet` anywhere in determinism-scoped modules. The
/// ban is on the *type*, not just iteration: once the type is present, an
/// order-dependent fold is one refactor away, and token-level analysis
/// cannot prove it never happens.
pub fn nondet_map(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !in_determinism_scope(ctx.path) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        if ctx.is_test[i] || t.kind != TokKind::Ident {
            continue;
        }
        if t.text == "HashMap" || t.text == "HashSet" {
            out.push(Diagnostic {
                file: ctx.path.to_string(),
                line: t.line,
                rule: NONDET_MAP,
                message: format!(
                    "std::collections::{} in a determinism-scoped module: hash iteration \
                     order varies per process, breaking bit-exact parity; use BTree{} \
                     or sort before folding",
                    t.text,
                    &t.text[4..],
                ),
            });
        }
    }
}

/// Forbid `Instant::now` / `SystemTime::now` outside the wall-clock
/// allowlist. Round math and protocol state must be a pure function of
/// (config, seed, trace).
pub fn nondet_time(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if wall_clock_allowed(ctx.path) {
        return;
    }
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if ctx.is_test[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind == TokKind::Ident && (t.text == "Instant" || t.text == "SystemTime") {
            let now = i + 3 < toks.len()
                && toks[i + 1].is_punct(':')
                && toks[i + 2].is_punct(':')
                && toks[i + 3].is_ident("now");
            if now {
                out.push(Diagnostic {
                    file: ctx.path.to_string(),
                    line: t.line,
                    rule: NONDET_TIME,
                    message: format!(
                        "{}::now() outside the wall-clock allowlist: host clocks must not \
                         reach round math or metrics (parity across fleet/sim/replay); \
                         measure in net/server, net/harness, or benchkit instead",
                        t.text,
                    ),
                });
            }
        }
    }
}

/// Forbid any RNG that is not `util::rng`. Reproducibility is seeded at
/// the experiment root; an ambient entropy source anywhere below it makes
/// runs unreplayable.
pub fn nondet_rng(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if ctx.path == "util/rng.rs" {
        return;
    }
    let toks = ctx.toks;
    for i in 0..toks.len() {
        if ctx.is_test[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let name = toks[i].text.as_str();
        let foreign = FOREIGN_RNG.contains(&name)
            || (name == "rand" && i + 1 < toks.len() && toks[i + 1].is_punct(':'));
        if foreign {
            out.push(Diagnostic {
                file: ctx.path.to_string(),
                line: toks[i].line,
                rule: NONDET_RNG,
                message: format!(
                    "foreign RNG `{name}`: every stochastic draw must come from a \
                     util::rng::Rng stream derived from the experiment seed",
                ),
            });
        }
    }
}

/// Index of the token matching an opening bracket at `open` (`(`, `[`,
/// `{`). Returns `toks.len()` if unbalanced (unterminated input).
pub(crate) fn matching(toks: &[Tok], open: usize) -> usize {
    let (o, c) = match toks[open].text.as_str() {
        "(" => ('(', ')'),
        "[" => ('[', ']'),
        _ => ('{', '}'),
    };
    let mut depth = 0usize;
    for (j, t) in toks.iter().enumerate().skip(open) {
        if t.is_punct(o) {
            depth += 1;
        } else if t.is_punct(c) {
            depth -= 1;
            if depth == 0 {
                return j;
            }
        }
    }
    toks.len()
}

/// Names bound by the `let` starting at token `i` (which is the `let`
/// itself), plus the token range of its initializer expression. Pattern
/// idents are everything before the `=`, minus binding noise words.
fn let_binding(toks: &[Tok], i: usize) -> Option<(Vec<String>, usize, usize)> {
    const NOISE: &[&str] = &["mut", "ref", "Some", "Ok", "Err", "None", "else"];
    let mut eq = None;
    let mut j = i + 1;
    // Find the `=` that starts the initializer (skip `==`, `=>`, and any
    // bracketed type params in the pattern).
    while j < toks.len() && !toks[j].is_punct(';') {
        if toks[j].is_punct('(') || toks[j].is_punct('[') {
            j = matching(toks, j) + 1;
            continue;
        }
        if toks[j].is_punct('=') {
            let next_eq = toks.get(j + 1).map(|t| t.is_punct('=') || t.is_punct('>'));
            if next_eq != Some(true) {
                eq = Some(j);
                break;
            }
            j += 2;
            continue;
        }
        j += 1;
    }
    let eq = eq?;
    let names: Vec<String> = toks[i + 1..eq]
        .iter()
        .filter(|t| t.kind == TokKind::Ident && !NOISE.contains(&t.text.as_str()))
        .map(|t| t.text.clone())
        .collect();
    // Initializer runs to the `;` at the same nesting depth.
    let mut depth = 0i64;
    let mut end = eq + 1;
    while end < toks.len() {
        let t = &toks[end];
        if t.is_punct('(') || t.is_punct('[') || t.is_punct('{') {
            depth += 1;
        } else if t.is_punct(')') || t.is_punct(']') || t.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                break;
            }
        } else if t.is_punct(';') && depth == 0 {
            break;
        }
        end += 1;
    }
    Some((names, eq + 1, end))
}

/// True if `toks[span]` uses identifier `name` as a value (not as a
/// method name, i.e. not right after `.`).
fn uses_ident(toks: &[Tok], span: std::ops::Range<usize>, name: &str) -> bool {
    for j in span {
        if toks[j].is_ident(name) && (j == 0 || !toks[j - 1].is_punct('.')) {
            return true;
        }
    }
    false
}

/// Panic-safety on the wire: in `net/` and `link/`, forbid
/// `unwrap`/`expect`/panic-family macros, and forbid `v[i]` indexing when
/// `v` was let-bound from a frame/message decode. Taint is per-function.
pub fn wire_panic(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !in_wire_scope(ctx.path) {
        return;
    }
    const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
    let toks = ctx.toks;
    let mut tainted: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if ctx.is_test[i] {
            continue;
        }
        let t = &toks[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let name = t.text.as_str();
        if name == "fn" {
            tainted.clear();
            continue;
        }
        if name == "let" {
            if let Some((names, rhs_start, rhs_end)) = let_binding(toks, i) {
                let from_decode = toks[rhs_start..rhs_end].iter().any(|r| {
                    r.kind == TokKind::Ident
                        && (r.text.starts_with("decode") || DECODE_SOURCES.contains(&r.text.as_str()))
                });
                if from_decode {
                    tainted.extend(names);
                }
            }
            continue;
        }
        let after_dot = i > 0 && toks[i - 1].is_punct('.');
        if after_dot && (name == "unwrap" || name == "expect") {
            out.push(Diagnostic {
                file: ctx.path.to_string(),
                line: t.line,
                rule: WIRE_PANIC,
                message: format!(
                    ".{name}() on the wire path: a malformed or hostile frame must cut \
                     the connection, never crash the process; propagate with `?`/bail!",
                ),
            });
            continue;
        }
        if PANIC_MACROS.contains(&name) && i + 1 < toks.len() && toks[i + 1].is_punct('!') {
            out.push(Diagnostic {
                file: ctx.path.to_string(),
                line: t.line,
                rule: WIRE_PANIC,
                message: format!(
                    "{name}! on the wire path: malformed input must produce an error, \
                     not a process abort; bail! with a diagnostic instead",
                ),
            });
            continue;
        }
        if !after_dot
            && tainted.iter().any(|n| n == name)
            && i + 1 < toks.len()
            && toks[i + 1].is_punct('[')
        {
            out.push(Diagnostic {
                file: ctx.path.to_string(),
                line: t.line,
                rule: WIRE_PANIC,
                message: format!(
                    "direct indexing of wire-derived value `{name}`: indexes inside a \
                     decoded frame are attacker-controlled; use get()/get_mut() and \
                     handle None",
                ),
            });
        }
    }
}

/// Allocation bounded by untrusted lengths: in `net/` and `link/`, a
/// `Vec::with_capacity` / `.reserve` / `vec![x; n]` whose size expression
/// uses a let-bound integer decoded off the wire must go through
/// `Dec::capacity_hint` (or carry a reasoned `lint:allow` pointing at the
/// bound that makes it safe).
pub fn wire_alloc(ctx: &FileCtx, out: &mut Vec<Diagnostic>) {
    if !in_wire_scope(ctx.path) {
        return;
    }
    fn flag(
        toks: &[Tok],
        tainted: &[String],
        path: &str,
        args: std::ops::Range<usize>,
        what: &str,
        line: usize,
        out: &mut Vec<Diagnostic>,
    ) {
        if uses_ident(toks, args.clone(), "capacity_hint") {
            return;
        }
        for name in tainted {
            if uses_ident(toks, args.clone(), name) {
                out.push(Diagnostic {
                    file: path.to_string(),
                    line,
                    rule: WIRE_ALLOC,
                    message: format!(
                        "{what} sized by wire-decoded integer `{name}`: a checksum-valid \
                         frame can still declare a 2^60 length; clamp through \
                         Dec::capacity_hint or validate against a hard bound first",
                    ),
                });
                return;
            }
        }
    }
    let toks = ctx.toks;
    let mut tainted: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if ctx.is_test[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        let name = toks[i].text.as_str();
        if name == "fn" {
            tainted.clear();
            continue;
        }
        if name == "let" {
            if let Some((names, rhs_start, rhs_end)) = let_binding(toks, i) {
                let from_dec_int = (rhs_start..rhs_end).any(|j| {
                    toks[j].kind == TokKind::Ident
                        && DEC_INT_METHODS.contains(&toks[j].text.as_str())
                        && j + 1 < toks.len()
                        && toks[j + 1].is_punct('(')
                });
                if from_dec_int {
                    tainted.extend(names);
                }
            }
            continue;
        }
        match name {
            "with_capacity" | "reserve" if i + 1 < toks.len() && toks[i + 1].is_punct('(') => {
                let close = matching(toks, i + 1);
                flag(toks, &tainted, ctx.path, i + 2..close, "allocation", toks[i].line, out);
            }
            "vec" if i + 2 < toks.len() && toks[i + 1].is_punct('!') && toks[i + 2].is_punct('[') => {
                let close = matching(toks, i + 2);
                flag(toks, &tainted, ctx.path, i + 3..close, "vec! allocation", toks[i].line, out);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lint_source;
    use super::*;

    fn rules_at(path: &str, src: &str) -> Vec<(usize, &'static str)> {
        lint_source(path, src).into_iter().map(|d| (d.line, d.rule)).collect()
    }

    #[test]
    fn scope_tables() {
        assert!(in_determinism_scope("coordinator/federation.rs"));
        assert!(in_determinism_scope("net/proto.rs"));
        assert!(!in_determinism_scope("util/cli.rs"));
        assert!(!in_determinism_scope("benchkit.rs"));
        assert!(wall_clock_allowed("net/server.rs"));
        assert!(wall_clock_allowed("util/mod.rs"));
        assert!(!wall_clock_allowed("coordinator/federation.rs"));
        // obs/: clock.rs is the sole sanctioned wall-clock site; the rest
        // of the plane is determinism-scoped and clock-free.
        assert!(wall_clock_allowed("obs/clock.rs"));
        assert!(!wall_clock_allowed("obs/event.rs"));
        assert!(in_determinism_scope("obs/view.rs"));
        // tree-mode transport/liveness layers may read clocks for
        // timeouts; everything they derive from one stays out of round
        // math (see the determinism contract in docs/ARCHITECTURE.md).
        assert!(wall_clock_allowed("net/subagg.rs"));
        assert!(wall_clock_allowed("net/poll.rs"));
        assert!(in_wire_scope("link/mod.rs"));
        assert!(in_wire_scope("net/subagg.rs"));
        // the state store's spill-file decoder is wire-scoped: torn writes
        // reach it exactly like hostile frames reach the link layer.
        assert!(in_wire_scope("ckpt/store.rs"));
        assert!(!in_wire_scope("ckpt/mod.rs"));
        assert!(!in_wire_scope("model/mod.rs"));
    }

    #[test]
    fn hash_containers_flagged_in_scope_only() {
        let src = "use std::collections::HashMap;\nfn f() { let m: HashMap<u32, u32> = HashMap::new(); }\n";
        let hits = rules_at("metrics/mod.rs", src);
        assert_eq!(hits, [(1, "nondet-map"), (2, "nondet-map")]);
        assert!(rules_at("util/json.rs", src).is_empty());
    }

    #[test]
    fn clock_reads_flagged_outside_allowlist() {
        let src = "fn f() { let t = Instant::now(); let s = SystemTime::now(); }\n";
        assert_eq!(
            rules_at("coordinator/mod.rs", src),
            [(1, "nondet-time"), (1, "nondet-time")]
        );
        assert!(rules_at("net/harness.rs", src).is_empty());
    }

    #[test]
    fn foreign_rng_flagged() {
        let src = "fn f() { let r = rand::thread_rng(); }\n";
        let hits = rules_at("data/corpus.rs", src);
        assert_eq!(hits, [(1, "nondet-rng")]);
        // `rand` only counts when path-qualified; a field named rand is fine.
        assert!(rules_at("data/corpus.rs", "fn f(s: S) { let x = s.rand; }\n").is_empty());
    }

    #[test]
    fn wire_panics_flagged_only_in_wire_modules() {
        let src = "fn f() { x.unwrap(); y.expect(\"m\"); panic!(\"no\"); }\n";
        assert_eq!(
            rules_at("net/proto.rs", src),
            [(1, "wire-panic"), (1, "wire-panic"), (1, "wire-panic")]
        );
        assert!(rules_at("model/mod.rs", src).is_empty());
    }

    #[test]
    fn tainted_indexing_is_function_scoped() {
        let src = "fn f(frame: &[u8]) {\n let msg = Msg::decode(frame)?;\n let b = msg[0];\n}\nfn g(msg: &[u8]) { let b = msg[0]; }\n";
        assert_eq!(rules_at("net/worker.rs", src), [(3, "wire-panic")]);
    }

    #[test]
    fn unwrap_or_else_is_not_unwrap() {
        let src = "fn f() { let v = x.unwrap_or_else(|_| 0); }\n";
        assert!(rules_at("net/server.rs", src).is_empty());
    }

    #[test]
    fn wire_alloc_requires_capacity_hint() {
        let bad = "fn f(d: &mut Dec) -> Result<()> {\n let n = d.u64()? as usize;\n let v: Vec<u8> = Vec::with_capacity(n);\n Ok(())\n}\n";
        assert_eq!(rules_at("net/proto.rs", bad), [(3, "wire-alloc")]);
        let good = bad.replace("Vec::with_capacity(n)", "Vec::with_capacity(d.capacity_hint(n, 8))");
        assert!(rules_at("net/proto.rs", &good).is_empty());
    }

    #[test]
    fn vec_macro_with_decoded_len_flagged() {
        let src = "fn f(r: &mut R) -> Result<()> {\n let len = u32::from_le_bytes(h) as usize;\n let buf = vec![0u8; len];\n Ok(())\n}\n";
        assert_eq!(rules_at("net/proto.rs", src), [(3, "wire-alloc")]);
    }

    #[test]
    fn cfg_test_spans_are_exempt() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n use std::collections::HashMap;\n #[test]\n fn t() { x.unwrap(); }\n}\n";
        assert!(rules_at("net/proto.rs", src).is_empty());
        assert!(rules_at("coordinator/mod.rs", src).is_empty());
    }
}
