//! Checkpointing (paper §4.1): the Photon Aggregator "guarantees robustness
//! in case of failures by keeping the state of the FL continuously
//! checkpointed" — global model, outer-optimizer snapshot, bookkeeping —
//! and each LLM Node tracks "the optimizer and data loading index states".
//!
//! One binary file holds the whole federation state; resume is bit-exact
//! (asserted by integration_ckpt.rs). Format: little-endian sections with a
//! magic/version header and an FNV-1a trailer checksum.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::stream::StreamCursor;

const MAGIC: &[u8; 4] = b"PHCK";
/// v2: per-client `cursors` became a vector (one cursor per connectivity
/// island) so multi-island clients resume sample-exact. v1 files saved only
/// `streams[0]` and are rejected — they cannot restore a hetero fleet
/// faithfully.
const VERSION: u32 = 2;

/// Per-client persisted state: KeepOpt moments + one stream cursor per
/// connectivity island (single-island clients have exactly one).
#[derive(Clone, Debug, PartialEq)]
pub struct ClientCkpt {
    pub opt_m: Vec<f32>,
    pub opt_v: Vec<f32>,
    pub local_step: i64,
    pub cursors: Vec<StreamCursor>,
}

/// Full federation state at a round boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: u64,
    /// Cumulative sequential optimizer steps (drives the LR schedule).
    pub seq_step: u64,
    pub global: Vec<f32>,
    pub outer_t: u64,
    pub outer_m: Vec<f64>,
    pub outer_v: Vec<f64>,
    /// Indexed by client id; empty entries for clients with no state.
    pub clients: Vec<Option<ClientCkpt>>,
    /// Wall-clock bookkeeping (unix seconds, elapsed training seconds).
    pub timestamp: u64,
    pub elapsed_secs: f64,
}

// --- binary encoding helpers ---------------------------------------------

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn state4(&mut self, s: &[u64; 4]) {
        for x in s {
            self.u64(*x);
        }
    }
}

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("checkpoint truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn state4(&mut self) -> Result<[u64; 4]> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc { buf: Vec::new() };
        e.buf.extend_from_slice(MAGIC);
        e.u32(VERSION);
        e.u64(self.round);
        e.u64(self.seq_step);
        e.u64(self.timestamp);
        e.f64(self.elapsed_secs);
        e.f32s(&self.global);
        e.u64(self.outer_t);
        e.f64s(&self.outer_m);
        e.f64s(&self.outer_v);
        e.u64(self.clients.len() as u64);
        for c in &self.clients {
            match c {
                None => e.u32(0),
                Some(c) => {
                    e.u32(1);
                    e.f32s(&c.opt_m);
                    e.f32s(&c.opt_v);
                    e.i64(c.local_step);
                    e.u64(c.cursors.len() as u64);
                    for cur in &c.cursors {
                        e.state4(&cur.mix_state);
                        e.u64(cur.bucket_states.len() as u64);
                        for (st, drawn) in &cur.bucket_states {
                            e.state4(st);
                            e.u64(*drawn);
                        }
                    }
                }
            }
        }
        let sum = fnv1a(&e.buf);
        e.u64(sum);
        e.buf
    }

    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 16 || &bytes[..4] != MAGIC {
            bail!("not a photon checkpoint");
        }
        let body = &bytes[..bytes.len() - 8];
        let trailer =
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if fnv1a(body) != trailer {
            bail!("checkpoint checksum mismatch");
        }
        let mut d = Dec { b: body, i: 4 };
        let version = d.u32()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let round = d.u64()?;
        let seq_step = d.u64()?;
        let timestamp = d.u64()?;
        let elapsed_secs = d.f64()?;
        let global = d.f32s()?;
        let outer_t = d.u64()?;
        let outer_m = d.f64s()?;
        let outer_v = d.f64s()?;
        let n_clients = d.u64()? as usize;
        let mut clients = Vec::with_capacity(n_clients);
        for _ in 0..n_clients {
            if d.u32()? == 0 {
                clients.push(None);
                continue;
            }
            let opt_m = d.f32s()?;
            let opt_v = d.f32s()?;
            let local_step = d.i64()?;
            let n_cursors = d.u64()? as usize;
            let mut cursors = Vec::with_capacity(n_cursors);
            for _ in 0..n_cursors {
                let mix_state = d.state4()?;
                let nb = d.u64()? as usize;
                let mut bucket_states = Vec::with_capacity(nb);
                for _ in 0..nb {
                    let st = d.state4()?;
                    let drawn = d.u64()?;
                    bucket_states.push((st, drawn));
                }
                cursors.push(StreamCursor { mix_state, bucket_states });
            }
            clients.push(Some(ClientCkpt { opt_m, opt_v, local_step, cursors }));
        }
        Ok(Checkpoint {
            round,
            seq_step,
            global,
            outer_t,
            outer_m,
            outer_v,
            clients,
            timestamp,
            elapsed_secs,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        // Atomic-ish: write then rename.
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&self.encode())?;
        f.sync_all().ok();
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Checkpoint::decode(&bytes)
    }
}

/// Latest checkpoint in a directory (`ckpt_round_<n>.bin` naming), for the
/// paper's "automatic federated training resumption from the most recent
/// round" (§6.2).
pub fn latest_in(dir: &Path) -> Result<Option<(u64, std::path::PathBuf)>> {
    if !dir.is_dir() {
        return Ok(None);
    }
    let mut best: Option<(u64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if let Some(num) = name
            .strip_prefix("ckpt_round_")
            .and_then(|s| s.strip_suffix(".bin"))
        {
            if let Ok(r) = num.parse::<u64>() {
                if best.as_ref().map(|(b, _)| r > *b).unwrap_or(true) {
                    best = Some((r, p));
                }
            }
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Checkpoint {
        Checkpoint {
            round: 3,
            seq_step: 1500,
            global: vec![0.5, -1.25, 3.0],
            outer_t: 3,
            outer_m: vec![0.125, -2.5],
            outer_v: vec![],
            clients: vec![
                None,
                Some(ClientCkpt {
                    opt_m: vec![1.0],
                    opt_v: vec![2.0],
                    local_step: 40,
                    // Two islands → two cursors (the hetero-fleet case that
                    // v1 silently truncated to cursors[0]).
                    cursors: vec![
                        StreamCursor {
                            mix_state: [1, 2, 3, 4],
                            bucket_states: vec![([5, 6, 7, 8], 9)],
                        },
                        StreamCursor {
                            mix_state: [10, 11, 12, 13],
                            bucket_states: vec![([14, 15, 16, 17], 18), ([19, 20, 21, 22], 23)],
                        },
                    ],
                }),
            ],
            timestamp: 1_700_000_000,
            elapsed_secs: 12.5,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let c = toy();
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = toy().encode();
        bytes[10] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    #[test]
    fn save_load_and_latest() {
        let dir = std::env::temp_dir().join(format!("photon_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut c = toy();
        c.save(&dir.join("ckpt_round_3.bin")).unwrap();
        c.round = 7;
        c.save(&dir.join("ckpt_round_7.bin")).unwrap();
        let (r, p) = latest_in(&dir).unwrap().unwrap();
        assert_eq!(r, 7);
        assert_eq!(Checkpoint::load(&p).unwrap().round, 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_in_missing_dir_is_none() {
        assert!(latest_in(Path::new("/nonexistent/xyz")).unwrap().is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::decode(b"garbage").is_err());
    }
}
