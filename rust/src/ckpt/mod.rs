//! Checkpointing (paper §4.1): the Photon Aggregator "guarantees robustness
//! in case of failures by keeping the state of the FL continuously
//! checkpointed" — global model, outer-optimizer snapshot, bookkeeping —
//! and each LLM Node tracks "the optimizer and data loading index states".
//!
//! One binary file holds the whole federation state; resume is bit-exact
//! (asserted by integration_ckpt.rs). Format: little-endian sections with a
//! magic/version header and an FNV-1a trailer checksum.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::stream::StreamCursor;

mod store;
pub use store::StateStore;

const MAGIC: &[u8; 4] = b"PHCK";
/// v2: per-client `cursors` became a vector (one cursor per connectivity
/// island) so multi-island clients resume sample-exact. v1 files saved only
/// `streams[0]` and are rejected — they cannot restore a hetero fleet
/// faithfully.
/// v3: per-client `residual` — the top-k error-feedback state
/// (`compress::UpdateCodec::TopK`) — joined the client record, so a lossy
/// federation resumes with its un-sent gradient mass intact. v2 files are
/// still decoded (they predate error feedback, so an empty residual
/// restores them exactly); v1 files remain rejected.
const VERSION: u32 = 3;
/// Oldest checkpoint version this build still decodes.
const MIN_DECODE_VERSION: u32 = 2;

/// Per-client persisted state: KeepOpt moments, one stream cursor per
/// connectivity island (single-island clients have exactly one), and the
/// update-codec error-feedback residual (empty unless a `topk` codec is
/// active).
#[derive(Clone, Debug, PartialEq)]
pub struct ClientCkpt {
    pub opt_m: Vec<f32>,
    pub opt_v: Vec<f32>,
    pub local_step: i64,
    pub cursors: Vec<StreamCursor>,
    /// Error-feedback residual of the lossy update codec (`topk`): the
    /// gradient mass withheld from previous rounds' transmissions. Empty
    /// means zero. Travels with the rest of the client state over the
    /// deployment plane, so workers stay stateless.
    pub residual: Vec<f32>,
}

/// Full federation state at a round boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub round: u64,
    /// Cumulative sequential optimizer steps (drives the LR schedule).
    pub seq_step: u64,
    pub global: Vec<f32>,
    pub outer_t: u64,
    pub outer_m: Vec<f64>,
    pub outer_v: Vec<f64>,
    /// Indexed by client id; empty entries for clients with no state.
    pub clients: Vec<Option<ClientCkpt>>,
    /// Wall-clock bookkeeping (unix seconds, elapsed training seconds).
    pub timestamp: u64,
    pub elapsed_secs: f64,
}

// --- binary encoding helpers ----------------------------------------------
// Shared with the deployment plane: `net::proto` frames reuse this codec
// (little-endian fields, length-prefixed vectors) so a client's persisted
// state and its over-the-wire state are the same bytes.

pub(crate) struct Enc {
    pub(crate) buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn new() -> Enc {
        Enc { buf: Vec::new() }
    }
    pub(crate) fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    pub(crate) fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
    pub(crate) fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    pub(crate) fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    pub(crate) fn state4(&mut self, s: &[u64; 4]) {
        for x in s {
            self.u64(*x);
        }
    }
    pub(crate) fn cursor(&mut self, cur: &StreamCursor) {
        self.state4(&cur.mix_state);
        self.u64(cur.bucket_states.len() as u64);
        for (st, drawn) in &cur.bucket_states {
            self.state4(st);
            self.u64(*drawn);
        }
    }
    pub(crate) fn bytes(&mut self, b: &[u8]) {
        self.u64(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
    pub(crate) fn client(&mut self, c: &ClientCkpt) {
        self.f32s(&c.opt_m);
        self.f32s(&c.opt_v);
        self.i64(c.local_step);
        self.u64(c.cursors.len() as u64);
        for cur in &c.cursors {
            self.cursor(cur);
        }
        self.f32s(&c.residual);
    }
}

pub(crate) struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    pub(crate) fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }
    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("payload truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    pub(crate) fn done(&self) -> bool {
        self.i == self.b.len()
    }
    /// Bytes left to decode.
    pub(crate) fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
    /// Safe `Vec` pre-allocation for a wire-declared element count: never
    /// reserve more than the remaining bytes could possibly hold (counts
    /// come off untrusted frames — a checksummed-valid frame can still
    /// declare 2^60 elements, and `with_capacity` on that aborts the
    /// process).
    pub(crate) fn capacity_hint(&self, n: usize, min_elem_bytes: usize) -> usize {
        n.min(self.remaining() / min_elem_bytes.max(1) + 1)
    }
    pub(crate) fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    pub(crate) fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    pub(crate) fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    pub(crate) fn str(&mut self) -> Result<String> {
        let n = self.u64()? as usize;
        let raw = self.take(n)?;
        Ok(std::str::from_utf8(raw)
            .map_err(|_| anyhow::anyhow!("invalid utf-8 string field"))?
            .to_string())
    }
    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let bytes = n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("f32 vector overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    pub(crate) fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u64()? as usize;
        let bytes = n.checked_mul(8).ok_or_else(|| anyhow::anyhow!("f64 vector overflow"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    pub(crate) fn state4(&mut self) -> Result<[u64; 4]> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }
    pub(crate) fn cursor(&mut self) -> Result<StreamCursor> {
        let mix_state = self.state4()?;
        let nb = self.u64()? as usize;
        // 40 = [u64; 4] state + drawn count per bucket.
        let mut bucket_states = Vec::with_capacity(self.capacity_hint(nb, 40));
        for _ in 0..nb {
            let st = self.state4()?;
            let drawn = self.u64()?;
            bucket_states.push((st, drawn));
        }
        Ok(StreamCursor { mix_state, bucket_states })
    }
    /// Length-prefixed raw byte blob (`take` bounds the allocation by the
    /// remaining payload, so a wire-declared length cannot over-allocate).
    pub(crate) fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        Ok(self.take(n)?.to_vec())
    }
    pub(crate) fn client(&mut self) -> Result<ClientCkpt> {
        self.client_compat(true)
    }
    /// Client record decode across checkpoint versions: v2 files predate
    /// the codec residual, so `with_residual = false` restores them with
    /// the (exactly faithful) empty residual instead of failing.
    pub(crate) fn client_compat(&mut self, with_residual: bool) -> Result<ClientCkpt> {
        let opt_m = self.f32s()?;
        let opt_v = self.f32s()?;
        let local_step = self.i64()?;
        let n_cursors = self.u64()? as usize;
        // 48 = minimum encoded cursor (mix state + empty bucket list).
        let mut cursors = Vec::with_capacity(self.capacity_hint(n_cursors, 48));
        for _ in 0..n_cursors {
            cursors.push(self.cursor()?);
        }
        let residual = if with_residual { self.f32s()? } else { Vec::new() };
        Ok(ClientCkpt { opt_m, opt_v, local_step, cursors, residual })
    }
}

pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Checkpoint {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::new();
        e.buf.extend_from_slice(MAGIC);
        e.u32(VERSION);
        e.u64(self.round);
        e.u64(self.seq_step);
        e.u64(self.timestamp);
        e.f64(self.elapsed_secs);
        e.f32s(&self.global);
        e.u64(self.outer_t);
        e.f64s(&self.outer_m);
        e.f64s(&self.outer_v);
        e.u64(self.clients.len() as u64);
        for c in &self.clients {
            match c {
                None => e.u32(0),
                Some(c) => {
                    e.u32(1);
                    e.client(c);
                }
            }
        }
        let sum = fnv1a(&e.buf);
        e.u64(sum);
        e.buf
    }

    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < 16 || &bytes[..4] != MAGIC {
            bail!("not a photon checkpoint");
        }
        let body = &bytes[..bytes.len() - 8];
        let trailer =
            u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
        if fnv1a(body) != trailer {
            bail!("checkpoint checksum mismatch");
        }
        let mut d = Dec::new(&body[4..]);
        let version = d.u32()?;
        if !(MIN_DECODE_VERSION..=VERSION).contains(&version) {
            bail!("unsupported checkpoint version {version}");
        }
        let round = d.u64()?;
        let seq_step = d.u64()?;
        let timestamp = d.u64()?;
        let elapsed_secs = d.f64()?;
        let global = d.f32s()?;
        let outer_t = d.u64()?;
        let outer_m = d.f64s()?;
        let outer_v = d.f64s()?;
        let n_clients = d.u64()? as usize;
        let mut clients = Vec::with_capacity(d.capacity_hint(n_clients, 4));
        for _ in 0..n_clients {
            if d.u32()? == 0 {
                clients.push(None);
                continue;
            }
            clients.push(Some(d.client_compat(version >= 3)?));
        }
        Ok(Checkpoint {
            round,
            seq_step,
            global,
            outer_t,
            outer_m,
            outer_v,
            clients,
            timestamp,
            elapsed_secs,
        })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        // Atomic-ish: write then rename.
        let tmp = path.with_extension("tmp");
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&self.encode())?;
        f.sync_all().ok();
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Checkpoint::decode(&bytes)
    }
}

/// Latest checkpoint in a directory (`ckpt_round_<n>.bin` naming), for the
/// paper's "automatic federated training resumption from the most recent
/// round" (§6.2).
pub fn latest_in(dir: &Path) -> Result<Option<(u64, std::path::PathBuf)>> {
    if !dir.is_dir() {
        return Ok(None);
    }
    let mut best: Option<(u64, std::path::PathBuf)> = None;
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if let Some(num) = name
            .strip_prefix("ckpt_round_")
            .and_then(|s| s.strip_suffix(".bin"))
        {
            if let Ok(r) = num.parse::<u64>() {
                if best.as_ref().map(|(b, _)| r > *b).unwrap_or(true) {
                    best = Some((r, p));
                }
            }
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Checkpoint {
        Checkpoint {
            round: 3,
            seq_step: 1500,
            global: vec![0.5, -1.25, 3.0],
            outer_t: 3,
            outer_m: vec![0.125, -2.5],
            outer_v: vec![],
            clients: vec![
                None,
                Some(ClientCkpt {
                    opt_m: vec![1.0],
                    opt_v: vec![2.0],
                    local_step: 40,
                    // Two islands → two cursors (the hetero-fleet case that
                    // v1 silently truncated to cursors[0]).
                    cursors: vec![
                        StreamCursor {
                            mix_state: [1, 2, 3, 4],
                            bucket_states: vec![([5, 6, 7, 8], 9)],
                        },
                        StreamCursor {
                            mix_state: [10, 11, 12, 13],
                            bucket_states: vec![([14, 15, 16, 17], 18), ([19, 20, 21, 22], 23)],
                        },
                    ],
                    residual: vec![0.5, -0.25, 0.0],
                }),
            ],
            timestamp: 1_700_000_000,
            elapsed_secs: 12.5,
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let c = toy();
        assert_eq!(Checkpoint::decode(&c.encode()).unwrap(), c);
    }

    #[test]
    fn corruption_detected() {
        let mut bytes = toy().encode();
        bytes[10] ^= 0xFF;
        assert!(Checkpoint::decode(&bytes).is_err());
    }

    #[test]
    fn save_load_and_latest() {
        let dir = std::env::temp_dir().join(format!("photon_ck_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut c = toy();
        c.save(&dir.join("ckpt_round_3.bin")).unwrap();
        c.round = 7;
        c.save(&dir.join("ckpt_round_7.bin")).unwrap();
        let (r, p) = latest_in(&dir).unwrap().unwrap();
        assert_eq!(r, 7);
        assert_eq!(Checkpoint::load(&p).unwrap().round, 7);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_in_missing_dir_is_none() {
        assert!(latest_in(Path::new("/nonexistent/xyz")).unwrap().is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::decode(b"garbage").is_err());
    }

    /// Encode `ck` exactly as a pre-residual (v2) build would have.
    fn encode_as_v2(ck: &Checkpoint) -> Vec<u8> {
        let mut e = Enc::new();
        e.buf.extend_from_slice(MAGIC);
        e.u32(2);
        e.u64(ck.round);
        e.u64(ck.seq_step);
        e.u64(ck.timestamp);
        e.f64(ck.elapsed_secs);
        e.f32s(&ck.global);
        e.u64(ck.outer_t);
        e.f64s(&ck.outer_m);
        e.f64s(&ck.outer_v);
        e.u64(ck.clients.len() as u64);
        for c in &ck.clients {
            match c {
                None => e.u32(0),
                Some(c) => {
                    e.u32(1);
                    // v2 client record: no residual field.
                    e.f32s(&c.opt_m);
                    e.f32s(&c.opt_v);
                    e.i64(c.local_step);
                    e.u64(c.cursors.len() as u64);
                    for cur in &c.cursors {
                        e.cursor(cur);
                    }
                }
            }
        }
        let sum = fnv1a(&e.buf);
        e.u64(sum);
        e.buf
    }

    #[test]
    fn v2_checkpoints_still_decode_with_empty_residuals() {
        // A pre-codec run has no error-feedback state by definition, so a
        // v2 file must upgrade losslessly instead of killing the resume.
        let mut want = toy();
        let v2 = encode_as_v2(&want);
        if let Some(c) = want.clients[1].as_mut() {
            c.residual = Vec::new();
        }
        assert_eq!(Checkpoint::decode(&v2).unwrap(), want);
        // v1 stays rejected.
        let mut v1 = v2.clone();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        let body_len = v1.len() - 8;
        let sum = fnv1a(&v1[..body_len]);
        v1[body_len..].copy_from_slice(&sum.to_le_bytes());
        let err = Checkpoint::decode(&v1).unwrap_err().to_string();
        assert!(err.contains("unsupported"), "{err}");
    }
}
