//! Memory-bounded client-state store for the deployment plane.
//!
//! The root server owns every client's inter-round state
//! ([`ClientCkpt`]). At paper scale (§5: millions of sampled clients)
//! keeping all of them resident is exactly the memory wall the
//! aggregator must not hit, so [`StateStore`] caps the *resident*
//! encoded bytes at a configured budget and spills least-recently-used
//! entries to disk, checksummed, reloading them byte-identically on
//! demand.
//!
//! Determinism contract: eviction order is a pure function of the access
//! sequence (a logical tick counter, never a wall clock), and the stored
//! representation is the canonical `Enc::client` encoding — the same
//! bytes that travel in a `RoundAssign` and persist in a checkpoint — so
//! a state that round-trips through a spill is the state, not a
//! re-encoding of it. Generation counters (bumped on every `put`) let
//! the server prove a worker already holds a state before shipping a
//! `proto::AssignState::Ref` instead of the full bytes.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::{fnv1a, ClientCkpt, Dec, Enc};

/// One resident entry: the state's canonical encoding plus its
/// last-use tick (the key into the LRU index).
struct Resident {
    bytes: Vec<u8>,
    tick: u64,
}

/// Spill-to-disk LRU cache of client states, keyed by client id, bounded
/// by resident encoded bytes.
///
/// Two modes:
///
/// * **Retaining** ([`StateStore::new`]) — keeps encoded copies resident
///   up to the budget, spilling the coldest to disk. For servers whose
///   authoritative states would not fit in memory.
/// * **Generation-only** ([`StateStore::gen_only`]) — tracks generations
///   but retains no bytes and never touches disk; [`StateStore::get`]
///   always returns `None` and the caller serves states from its own
///   authoritative copy. This is the no-budget default of `net::server`,
///   which already owns every client state inside its `Federation` — a
///   second resident copy would double client-state memory for nothing.
pub struct StateStore {
    budget: u64,
    /// False in generation-only mode: `put` bumps the generation but
    /// discards the bytes, `get` always misses.
    retain: bool,
    spill_dir: PathBuf,
    resident: BTreeMap<usize, Resident>,
    /// LRU index: ordered `(last_use_tick, client)` pairs — the first
    /// element is always the coldest resident entry.
    lru: BTreeSet<(u64, usize)>,
    resident_bytes: u64,
    /// High-water mark of `resident_bytes` over the store's lifetime
    /// (survives `cleanup` — the boundedness witness for reports).
    resident_peak: u64,
    tick: u64,
    /// Per-client state generation, bumped on every `put`.
    gens: BTreeMap<usize, u64>,
    /// Clients whose current state lives only on disk.
    spilled: BTreeSet<usize>,
    spill_count: u64,
    load_count: u64,
}

/// Canonical state encoding: the same `Enc::client` bytes a
/// `RoundAssign` ships and a checkpoint persists.
fn encode_state(c: &ClientCkpt) -> Vec<u8> {
    let mut e = Enc::new();
    e.client(c);
    e.buf
}

fn decode_state(bytes: &[u8]) -> Result<ClientCkpt> {
    let mut d = Dec::new(bytes);
    let c = d.client()?;
    if !d.done() {
        bail!("trailing bytes after client state");
    }
    Ok(c)
}

impl StateStore {
    /// A store that keeps at most `budget_bytes` of encoded client state
    /// resident, spilling the coldest entries into `spill_dir`. The
    /// directory is created lazily on first spill.
    pub fn new(budget_bytes: u64, spill_dir: impl Into<PathBuf>) -> StateStore {
        StateStore {
            budget: budget_bytes,
            retain: true,
            spill_dir: spill_dir.into(),
            resident: BTreeMap::new(),
            lru: BTreeSet::new(),
            resident_bytes: 0,
            resident_peak: 0,
            tick: 0,
            gens: BTreeMap::new(),
            spilled: BTreeSet::new(),
            spill_count: 0,
            load_count: 0,
        }
    }

    /// A generation-only store: `put` bumps the per-client generation but
    /// retains nothing, `get` always returns `None`, and the spill
    /// directory is never created. For callers that already hold the
    /// authoritative states and only need the generation ledger behind
    /// `proto::AssignState::Ref`.
    pub fn gen_only(spill_dir: impl Into<PathBuf>) -> StateStore {
        StateStore { retain: false, ..StateStore::new(0, spill_dir) }
    }

    /// Insert or overwrite `client`'s state; returns the new generation.
    /// May spill colder entries (or, if this state alone exceeds the
    /// budget, the state itself) to keep `resident_bytes() <= budget()`.
    /// In generation-only mode the bytes are discarded outright.
    pub fn put(&mut self, client: usize, state: &ClientCkpt) -> Result<u64> {
        if self.retain {
            let bytes = encode_state(state);
            self.insert_resident(client, bytes);
            self.spilled.remove(&client);
        }
        // A put supersedes any spilled copy of an older generation; the
        // stale file (if any) is overwritten on the next spill.
        let gen = self.gens.entry(client).or_insert(0);
        *gen += 1;
        let gen = *gen;
        self.enforce_budget()?;
        Ok(gen)
    }

    /// Fetch `client`'s state: resident hit, or a checksummed reload
    /// from the spill file (which re-promotes the entry to resident).
    /// `Ok(None)` means the store has never seen this client.
    pub fn get(&mut self, client: usize) -> Result<Option<ClientCkpt>> {
        if self.resident.contains_key(&client) {
            self.touch(client);
            if let Some(ent) = self.resident.get(&client) {
                return Ok(Some(decode_state(&ent.bytes)?));
            }
        }
        if !self.spilled.contains(&client) {
            return Ok(None);
        }
        let bytes = self.load_spill(client)?;
        let state = decode_state(&bytes)?;
        self.spilled.remove(&client);
        self.insert_resident(client, bytes);
        self.enforce_budget()?;
        Ok(Some(state))
    }

    /// Current generation of `client`'s state (`None` if never stored).
    pub fn gen_of(&self, client: usize) -> Option<u64> {
        self.gens.get(&client).copied()
    }

    /// True if the client's state is tracked (resident or spilled).
    pub fn contains(&self, client: usize) -> bool {
        self.resident.contains_key(&client) || self.spilled.contains(&client)
    }

    /// Encoded bytes currently held in memory. Always `<= budget()`.
    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// High-water mark of resident encoded bytes over the store's lifetime.
    /// Survives [`StateStore::cleanup`]; always 0 in generation-only mode.
    pub fn resident_peak(&self) -> u64 {
        self.resident_peak
    }

    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Number of entries spilled to disk over the store's lifetime.
    pub fn spill_count(&self) -> u64 {
        self.spill_count
    }

    /// Number of entries reloaded from disk over the store's lifetime.
    pub fn load_count(&self) -> u64 {
        self.load_count
    }

    /// Clients currently resident (the rest of the tracked set is on disk).
    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    /// Where spilled entries live (only ever created on first spill).
    pub fn spill_dir(&self) -> &std::path::Path {
        &self.spill_dir
    }

    /// Drop every tracked state and remove the spill directory from disk.
    /// The store is a transport cache — the authoritative states live in
    /// the federation and its checkpoints — so a server tears this down
    /// on shutdown instead of leaving `state_*.bin` files to accumulate
    /// across runs. Lifetime statistics (`spill_count`/`load_count`)
    /// survive for post-run reporting. Removal is best-effort: a failure
    /// leaves stale files behind, never fails the shutdown.
    pub fn cleanup(&mut self) {
        self.resident.clear();
        self.lru.clear();
        self.resident_bytes = 0;
        self.spilled.clear();
        self.gens.clear();
        if self.spill_dir.exists() {
            let _ = std::fs::remove_dir_all(&self.spill_dir);
        }
    }

    fn insert_resident(&mut self, client: usize, bytes: Vec<u8>) {
        if let Some(old) = self.resident.remove(&client) {
            self.resident_bytes -= old.bytes.len() as u64;
            self.lru.remove(&(old.tick, client));
        }
        self.tick += 1;
        self.resident_bytes += bytes.len() as u64;
        self.lru.insert((self.tick, client));
        self.resident.insert(client, Resident { bytes, tick: self.tick });
    }

    fn touch(&mut self, client: usize) {
        if let Some(ent) = self.resident.get_mut(&client) {
            self.lru.remove(&(ent.tick, client));
            self.tick += 1;
            ent.tick = self.tick;
            self.lru.insert((self.tick, client));
        }
    }

    /// Spill coldest-first until the resident set fits the budget. Ends
    /// with `resident_bytes <= budget` unconditionally: a single state
    /// larger than the whole budget ends up on disk with nothing
    /// resident.
    fn enforce_budget(&mut self) -> Result<()> {
        while self.resident_bytes > self.budget {
            let coldest = match self.lru.iter().next() {
                Some(&(_, c)) => c,
                None => break,
            };
            self.spill(coldest)?;
        }
        // Post-enforcement is the only steady state callers observe: the
        // peak witnesses every budget-bounded resident level, never the
        // transient insert that enforcement is about to spill away.
        self.resident_peak = self.resident_peak.max(self.resident_bytes);
        Ok(())
    }

    fn spill_path(&self, client: usize) -> PathBuf {
        self.spill_dir.join(format!("state_{client}.bin"))
    }

    fn spill(&mut self, client: usize) -> Result<()> {
        let ent = match self.resident.remove(&client) {
            Some(e) => e,
            None => return Ok(()),
        };
        self.lru.remove(&(ent.tick, client));
        self.resident_bytes -= ent.bytes.len() as u64;
        std::fs::create_dir_all(&self.spill_dir)
            .with_context(|| format!("creating spill dir {}", self.spill_dir.display()))?;
        let path = self.spill_path(client);
        let tmp = path.with_extension("tmp");
        // Payload + FNV-1a trailer, same tamper guard as a checkpoint.
        let sum = fnv1a(&ent.bytes);
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(&ent.bytes)
            .and_then(|_| f.write_all(&sum.to_le_bytes()))
            .with_context(|| format!("writing {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {}", path.display()))?;
        self.spilled.insert(client);
        self.spill_count += 1;
        Ok(())
    }

    fn load_spill(&mut self, client: usize) -> Result<Vec<u8>> {
        let path = self.spill_path(client);
        let mut raw = std::fs::read(&path)
            .with_context(|| format!("reading spill file {}", path.display()))?;
        if raw.len() < 8 {
            bail!("spill file {} too short", path.display());
        }
        let body_len = raw.len() - 8;
        let mut trailer = [0u8; 8];
        trailer.copy_from_slice(&raw[body_len..]);
        let trailer = u64::from_le_bytes(trailer);
        raw.truncate(body_len);
        if fnv1a(&raw) != trailer {
            bail!("spill file {} checksum mismatch", path.display());
        }
        self.load_count += 1;
        Ok(raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::stream::StreamCursor;

    fn state(step: i64, n: usize) -> ClientCkpt {
        ClientCkpt {
            opt_m: (0..n).map(|i| i as f32 * 0.5).collect(),
            opt_v: (0..n).map(|i| i as f32 * 0.25).collect(),
            local_step: step,
            cursors: vec![StreamCursor {
                mix_state: [step as u64, 2, 3, 4],
                bucket_states: vec![([5, 6, 7, 8], 9)],
            }],
            residual: vec![0.125; n / 2],
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("photon_store_{tag}_{}", std::process::id()))
    }

    #[test]
    fn put_get_roundtrip_and_gens() {
        let dir = tmp_dir("rt");
        let mut st = StateStore::new(1 << 20, &dir);
        let s = state(7, 16);
        assert_eq!(st.put(3, &s).unwrap(), 1);
        assert_eq!(st.put(3, &s).unwrap(), 2, "every put bumps the generation");
        assert_eq!(st.gen_of(3), Some(2));
        assert_eq!(st.gen_of(9), None);
        assert_eq!(st.get(3).unwrap().unwrap(), s);
        assert!(st.get(9).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn budget_is_enforced_by_spilling_lru() {
        let dir = tmp_dir("lru");
        let one = encode_state(&state(0, 32)).len() as u64;
        // Room for exactly two entries.
        let mut st = StateStore::new(2 * one, &dir);
        st.put(0, &state(0, 32)).unwrap();
        st.put(1, &state(1, 32)).unwrap();
        assert_eq!(st.resident_len(), 2);
        // Touch 0 so 1 becomes the cold one.
        st.get(0).unwrap();
        st.put(2, &state(2, 32)).unwrap();
        assert!(st.resident_bytes() <= st.budget());
        assert_eq!(st.resident_len(), 2);
        assert!(st.contains(1), "spilled, not lost");
        assert_eq!(st.spill_count(), 1);
        // Reload promotes 1 back and spills the new coldest (0).
        assert_eq!(st.get(1).unwrap().unwrap(), state(1, 32));
        assert!(st.resident_bytes() <= st.budget());
        assert_eq!(st.load_count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spilled_entry_round_trips_byte_identically() {
        let dir = tmp_dir("bytes");
        let s = state(42, 64);
        let want = encode_state(&s);
        let mut st = StateStore::new(0, &dir); // everything spills
        st.put(5, &s).unwrap();
        assert_eq!(st.resident_bytes(), 0);
        let got = st.get(5).unwrap().unwrap();
        assert_eq!(encode_state(&got), want, "spill round-trip must be byte-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_spill_file_is_rejected() {
        let dir = tmp_dir("corrupt");
        let mut st = StateStore::new(0, &dir);
        st.put(1, &state(1, 8)).unwrap();
        let path = dir.join("state_1.bin");
        let mut raw = std::fs::read(&path).unwrap();
        raw[0] ^= 0xFF;
        std::fs::write(&path, &raw).unwrap();
        assert!(st.get(1).is_err(), "flipped byte must fail the checksum");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gen_only_store_tracks_generations_without_retaining_bytes() {
        let dir = tmp_dir("genonly");
        let mut st = StateStore::gen_only(&dir);
        assert_eq!(st.put(4, &state(1, 32)).unwrap(), 1);
        assert_eq!(st.put(4, &state(2, 32)).unwrap(), 2);
        assert_eq!(st.gen_of(4), Some(2));
        assert_eq!(st.resident_bytes(), 0, "gen-only retains nothing");
        assert!(st.get(4).unwrap().is_none(), "gen-only always misses");
        assert!(!st.contains(4));
        assert_eq!(st.spill_count(), 0);
        assert!(!dir.exists(), "gen-only must never touch the disk");
    }

    #[test]
    fn cleanup_removes_the_spill_directory() {
        let dir = tmp_dir("cleanup");
        let mut st = StateStore::new(0, &dir); // everything spills
        st.put(0, &state(0, 32)).unwrap();
        st.put(1, &state(1, 32)).unwrap();
        assert!(dir.exists(), "spills must have created the directory");
        assert!(st.spill_count() >= 2);
        st.cleanup();
        assert!(!dir.exists(), "cleanup must remove the spill directory");
        assert!(!st.contains(0));
        assert_eq!(st.gen_of(0), None);
        assert!(st.spill_count() >= 2, "lifetime stats survive cleanup");
    }

    #[test]
    fn oversized_state_never_exceeds_budget_resident() {
        let dir = tmp_dir("oversize");
        let mut st = StateStore::new(8, &dir); // smaller than any state
        st.put(0, &state(0, 128)).unwrap();
        assert_eq!(st.resident_bytes(), 0);
        assert!(st.contains(0));
        assert_eq!(st.get(0).unwrap().unwrap(), state(0, 128));
        // The reload re-promoted then re-spilled: still within budget.
        assert!(st.resident_bytes() <= st.budget());
        std::fs::remove_dir_all(&dir).ok();
    }
}
