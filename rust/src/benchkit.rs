//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline; see DESIGN.md §1). Used by every `cargo bench` target
//! (`harness = false`). Reports mean / p50 / p95 / throughput after a
//! warmup phase, with iteration counts adapted to the measured cost.

// Wall-clock reads are this module's whole job (throughput reporting) —
// allowlisted; see docs/ANALYSIS.md (nondet-time).
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>7} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }

    pub fn print_with_throughput(&self, unit: &str, units_per_iter: f64) {
        let per_sec = units_per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} {:>7} iters  mean {:>12?}  p50 {:>12?}  {:>12.3e} {unit}/s",
            self.name, self.iters, self.mean, self.p50, per_sec
        );
    }
}

/// Benchmark `f`, auto-calibrating the iteration count to fill
/// `target_secs` of measurement (min 5, max 10_000 iters).
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once) as usize).clamp(5, 10_000);
    for _ in 0..(iters / 10).min(20) {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Standard bench-main prologue: print a header and return whether we are
/// in quick mode (`PHOTON_BENCH_QUICK=1`, used by CI-style runs).
pub fn bench_header(title: &str) -> bool {
    println!("== {title} ==");
    std::env::var("PHOTON_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 0.02, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }
}
