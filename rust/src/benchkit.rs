//! Criterion-style micro-benchmark harness (criterion is unavailable
//! offline; see DESIGN.md §1). Used by every `cargo bench` target
//! (`harness = false`). Reports mean / p50 / p95 / throughput after a
//! warmup phase, with iteration counts adapted to the measured cost.
//!
//! Since the perf plane landed this is a **recording** harness, not just a
//! printer: each bench target feeds its results into a [`Recorder`], which
//! emits a `BENCH_<area>.json` snapshot on [`Recorder::finish`] — an array
//! of `{bench, iters, mean_ns, p50_ns, p95_ns, units_per_sec, git_rev}`
//! records ([`validate_snapshot`] is the schema's single source of truth).
//! `tools/bench_compare.py` diffs two snapshots and flags >15% regressions;
//! the committed `BENCH_*.json` baselines at the repo root are the perf
//! trajectory (docs/REPRODUCTION.md explains how to refresh them). Output
//! directory: `PHOTON_BENCH_DIR` (default: the current directory, i.e.
//! `rust/` under `cargo bench`); `PHOTON_GIT_REV` overrides the recorded
//! revision when `git` is unavailable (CI detached checkouts).

// Wall-clock reads are this module's whole job (throughput reporting) —
// allowlisted; see docs/ANALYSIS.md (nondet-time).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::util::json::{self, Json};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>7} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }

    pub fn print_with_throughput(&self, unit: &str, units_per_iter: f64) {
        let per_sec = units_per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} {:>7} iters  mean {:>12?}  p50 {:>12?}  {:>12.3e} {unit}/s",
            self.name, self.iters, self.mean, self.p50, per_sec
        );
    }
}

/// One recorded benchmark row — the `BENCH_<area>.json` record schema.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub bench: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub units_per_sec: f64,
}

impl BenchRecord {
    /// Convert a measured result. `units_per_iter` is the work one
    /// iteration performs in the bench's natural unit (params folded,
    /// bytes framed, rounds simulated …); `units_per_sec` derives from the
    /// mean. Nanosecond fields are floored at 1 so a sub-granularity
    /// measurement can never produce a zero/∞ record.
    pub fn from_result(r: &BenchResult, units_per_iter: f64) -> BenchRecord {
        let mean_ns = (r.mean.as_nanos() as f64).max(1.0);
        BenchRecord {
            bench: r.name.clone(),
            iters: r.iters,
            mean_ns,
            p50_ns: (r.p50.as_nanos() as f64).max(1.0),
            p95_ns: (r.p95.as_nanos() as f64).max(1.0),
            units_per_sec: units_per_iter * 1e9 / mean_ns,
        }
    }

    fn to_json(&self, git_rev: &str) -> Json {
        json::obj(vec![
            ("bench", json::s(&self.bench)),
            ("iters", json::num(self.iters as f64)),
            ("mean_ns", json::num(self.mean_ns)),
            ("p50_ns", json::num(self.p50_ns)),
            ("p95_ns", json::num(self.p95_ns)),
            ("units_per_sec", json::num(self.units_per_sec)),
            ("git_rev", json::s(git_rev)),
        ])
    }
}

/// Collects every [`BenchResult`] a bench target produces and writes the
/// area's `BENCH_<area>.json` snapshot at the end. Printing still happens
/// per result (via [`Recorder::add`]/[`Recorder::add_result`]), so the
/// human-readable output is unchanged; the snapshot is additive.
pub struct Recorder {
    area: String,
    git_rev: String,
    records: Vec<BenchRecord>,
}

impl Recorder {
    pub fn new(area: &str) -> Recorder {
        Recorder { area: area.to_string(), git_rev: resolve_git_rev(), records: Vec::new() }
    }

    /// Print with throughput and record. `units_per_iter` must be > 0.
    pub fn add(&mut self, r: &BenchResult, unit: &str, units_per_iter: f64) {
        r.print_with_throughput(unit, units_per_iter);
        self.records.push(BenchRecord::from_result(r, units_per_iter));
    }

    /// Print without a throughput unit and record (1 unit ≡ 1 iteration,
    /// so `units_per_sec` reads as iterations/second).
    pub fn add_result(&mut self, r: &BenchResult) {
        r.print();
        self.records.push(BenchRecord::from_result(r, 1.0));
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The snapshot as a JSON array (the exact on-disk shape).
    pub fn snapshot_json(&self) -> Json {
        json::arr(self.records.iter().map(|r| r.to_json(&self.git_rev)))
    }

    /// Write `BENCH_<area>.json` into `dir` and return its path.
    pub fn write_to(&self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.area));
        std::fs::write(&path, self.snapshot_json().to_string() + "\n")
            .map_err(|e| anyhow!("writing {}: {e}", path.display()))?;
        println!("[bench] wrote {} ({} records)", path.display(), self.records.len());
        Ok(path)
    }

    /// Write the snapshot into `PHOTON_BENCH_DIR` (default: the current
    /// directory). Every bench target calls this last.
    pub fn finish(self) -> Result<PathBuf> {
        let dir = std::env::var("PHOTON_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_to(Path::new(&dir))
    }
}

/// Recorded git revision: `PHOTON_GIT_REV` if set (CI detached checkouts),
/// else `git rev-parse --short HEAD`, else `"unknown"`.
fn resolve_git_rev() -> String {
    if let Ok(v) = std::env::var("PHOTON_GIT_REV") {
        if !v.trim().is_empty() {
            return v.trim().to_string();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn check_record(r: &Json, seen: &mut BTreeSet<String>) -> Result<()> {
    let bench = r.get("bench")?.as_str()?;
    ensure!(!bench.is_empty(), "empty bench name");
    ensure!(seen.insert(bench.to_string()), "duplicate bench name {bench:?}");
    ensure!(r.get("iters")?.as_usize()? >= 1, "iters must be ≥ 1");
    for key in ["mean_ns", "p50_ns", "p95_ns", "units_per_sec"] {
        let x = r.get(key)?.as_f64()?;
        ensure!(x.is_finite() && x > 0.0, "{key} must be finite and positive, got {x}");
    }
    let p50 = r.get("p50_ns")?.as_f64()?;
    let p95 = r.get("p95_ns")?.as_f64()?;
    ensure!(p95 >= p50, "p95_ns {p95} < p50_ns {p50}");
    ensure!(!r.get("git_rev")?.as_str()?.is_empty(), "empty git_rev");
    Ok(())
}

/// Validate a parsed `BENCH_*.json` snapshot against the record schema:
/// a non-empty array of records with unique non-empty `bench` names,
/// `iters ≥ 1`, finite positive nanosecond/throughput fields, `p95 ≥ p50`,
/// and a non-empty `git_rev`. Returns the record count. Used by the
/// benchkit unit tests and the `photon benchck` CLI gate.
pub fn validate_snapshot(v: &Json) -> Result<usize> {
    let records = v.as_arr().map_err(|_| anyhow!("bench snapshot must be a JSON array"))?;
    ensure!(!records.is_empty(), "bench snapshot has no records");
    let mut seen = BTreeSet::new();
    for (i, r) in records.iter().enumerate() {
        check_record(r, &mut seen).map_err(|e| anyhow!("record {i}: {e}"))?;
    }
    Ok(records.len())
}

/// Benchmark `f`, auto-calibrating the iteration count to fill
/// `target_secs` of measurement (min 5, max 10_000 iters).
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once) as usize).clamp(5, 10_000);
    for _ in 0..(iters / 10).min(20) {
        f(); // warmup
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
    }
}

/// Standard bench-main prologue: print a header and return whether we are
/// in quick mode (`PHOTON_BENCH_QUICK=1`, used by CI-style runs).
pub fn bench_header(title: &str) -> bool {
    println!("== {title} ==");
    std::env::var("PHOTON_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 0.02, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    fn fake_result(name: &str, mean_ns: u64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iters: 42,
            mean: Duration::from_nanos(mean_ns),
            p50: Duration::from_nanos(mean_ns),
            p95: Duration::from_nanos(mean_ns * 2),
        }
    }

    #[test]
    fn recorder_snapshot_matches_schema() {
        let mut rec = Recorder::new("unit");
        rec.add(&fake_result("fold/1k", 1_000), "param", 1000.0);
        rec.add_result(&fake_result("roundtrip", 500));
        assert_eq!(rec.len(), 2);
        let snap = rec.snapshot_json();
        // Round-trip through text exactly as the file would.
        let back = Json::parse(&snap.to_string()).unwrap();
        assert_eq!(validate_snapshot(&back).unwrap(), 2);
        let r0 = &back.as_arr().unwrap()[0];
        assert_eq!(r0.get("bench").unwrap().as_str().unwrap(), "fold/1k");
        assert_eq!(r0.get("iters").unwrap().as_usize().unwrap(), 42);
        assert_eq!(r0.get("mean_ns").unwrap().as_f64().unwrap(), 1_000.0);
        // 1000 units in 1000 ns → 1e9 units/s.
        assert_eq!(r0.get("units_per_sec").unwrap().as_f64().unwrap(), 1e9);
        assert!(!r0.get("git_rev").unwrap().as_str().unwrap().is_empty());
    }

    #[test]
    fn recorder_writes_a_parseable_file() {
        let dir = std::env::temp_dir()
            .join(format!("photon_benchkit_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rec = Recorder::new("unitfile");
        rec.add(&fake_result("x", 10_000), "op", 3.0);
        let path = rec.write_to(&dir).unwrap();
        assert!(path.ends_with("BENCH_unitfile.json"));
        let v = Json::parse_file(&path).unwrap();
        assert_eq!(validate_snapshot(&v).unwrap(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn zero_duration_results_are_floored_not_invalid() {
        // Clock granularity can report 0 ns; the record must stay valid.
        let r = BenchResult {
            name: "instant".into(),
            iters: 5,
            mean: Duration::ZERO,
            p50: Duration::ZERO,
            p95: Duration::ZERO,
        };
        let rec = BenchRecord::from_result(&r, 7.0);
        assert_eq!(rec.mean_ns, 1.0);
        assert!(rec.units_per_sec.is_finite() && rec.units_per_sec > 0.0);
    }

    #[test]
    fn validate_rejects_malformed_snapshots() {
        let ok = r#"[{"bench":"a","iters":5,"mean_ns":10,"p50_ns":9,
                      "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"}]"#;
        assert_eq!(validate_snapshot(&Json::parse(ok).unwrap()).unwrap(), 1);
        for bad in [
            r#"{}"#,                                               // not an array
            r#"[]"#,                                               // empty
            r#"[{"bench":"a"}]"#,                                  // missing fields
            r#"[{"bench":"","iters":5,"mean_ns":10,"p50_ns":9,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"}]"#, // empty name
            r#"[{"bench":"a","iters":0,"mean_ns":10,"p50_ns":9,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"}]"#, // iters 0
            r#"[{"bench":"a","iters":5,"mean_ns":-10,"p50_ns":9,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"}]"#, // negative
            r#"[{"bench":"a","iters":5,"mean_ns":10,"p50_ns":13,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"}]"#, // p95 < p50
            r#"[{"bench":"a","iters":5,"mean_ns":10,"p50_ns":9,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":""}]"#,    // empty rev
            r#"[{"bench":"a","iters":5,"mean_ns":10,"p50_ns":9,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"},
                {"bench":"a","iters":5,"mean_ns":10,"p50_ns":9,
                 "p95_ns":12,"units_per_sec":1.5,"git_rev":"abc"}]"#, // dup name
        ] {
            assert!(
                validate_snapshot(&Json::parse(bad).unwrap()).is_err(),
                "must reject: {bad}"
            );
        }
    }
}
