//! Model-state substrate: the manifest (flat-parameter layout exported by
//! the python AOT pipeline), deterministic initialization, and the vector
//! math the aggregation path is built from.
//!
//! The entire model lives in one flat `Vec<f32>`; `Manifest::params` gives
//! per-tensor views for the paper's per-layer monitoring (§6.2).

pub mod init;
pub mod manifest;
pub mod vecmath;

pub use init::init_params;
pub use manifest::{Manifest, ParamEntry, StepSig, TensorSig};
pub use vecmath::*;
