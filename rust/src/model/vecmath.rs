//! Flat-vector math for the aggregation path.
//!
//! These loops ARE the Photon Aggregator's hot path (outer optimizers run on
//! the full parameter vector every round), so they are written allocation-
//! free over slices; `bench_aggregate` tracks their throughput.

/// L2 norm.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Euclidean distance between two vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Cosine similarity (paper §6.2: federated metric between client models).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// `out = mean(rows)` — the FedAvg client-model average. `rows` must be
/// non-empty and equal length.
pub fn mean_into(rows: &[&[f32]], out: &mut [f32]) {
    assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f64;
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for row in rows {
        debug_assert_eq!(row.len(), out.len());
        for (o, &v) in out.iter_mut().zip(*row) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o = (*o as f64 * inv) as f32;
    }
}

/// Weighted mean with weights summing to anything positive (normalized
/// internally) — FedAvg with per-client sample counts.
pub fn weighted_mean_into(rows: &[&[f32]], weights: &[f64], out: &mut [f32]) {
    assert_eq!(rows.len(), weights.len());
    assert!(!rows.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    for o in out.iter_mut() {
        *o = 0.0;
    }
    let mut acc: Vec<f64> = vec![0.0; out.len()];
    for (row, &w) in rows.iter().zip(weights) {
        debug_assert_eq!(row.len(), out.len());
        let wn = w / total;
        for (a, &v) in acc.iter_mut().zip(*row) {
            *a += wn * v as f64;
        }
    }
    for (o, a) in out.iter_mut().zip(acc) {
        *o = a as f32;
    }
}

/// `out = a - b` (pseudo-gradient: Δ = θ_global − θ_client).
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `y = alpha * y` in place.
pub fn scale(alpha: f32, y: &mut [f32]) {
    for yv in y.iter_mut() {
        *yv *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_and_dist() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_dist(&[1.0, 1.0], &[4.0, 5.0]), 5.0);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[2.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-3.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn mean_is_elementwise() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn weighted_mean_normalizes() {
        let a = [0.0f32, 0.0];
        let b = [4.0f32, 8.0];
        let mut out = [0.0f32; 2];
        weighted_mean_into(&[&a, &b], &[1.0, 3.0], &mut out);
        assert_eq!(out, [3.0, 6.0]);
        // Scaling all weights is a no-op.
        let mut out2 = [0.0f32; 2];
        weighted_mean_into(&[&a, &b], &[10.0, 30.0], &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn equal_weights_match_mean() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 4.0, 1.5];
        let c = [2.0f32, 1.0, -1.0];
        let rows: Vec<&[f32]> = vec![&a, &b, &c];
        let mut m1 = [0.0f32; 3];
        let mut m2 = [0.0f32; 3];
        mean_into(&rows, &mut m1);
        weighted_mean_into(&rows, &[1.0, 1.0, 1.0], &mut m2);
        for (x, y) in m1.iter().zip(&m2) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn sub_axpy_scale() {
        let a = [5.0f32, 7.0];
        let b = [2.0f32, 3.0];
        let mut d = [0.0f32; 2];
        sub_into(&a, &b, &mut d);
        assert_eq!(d, [3.0, 4.0]);
        let mut y = [1.0f32, 1.0];
        axpy(2.0, &d, &mut y);
        assert_eq!(y, [7.0, 9.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [3.5, 4.5]);
    }

    #[test]
    #[should_panic]
    fn weighted_mean_rejects_zero_weights() {
        let a = [1.0f32];
        let mut out = [0.0f32];
        weighted_mean_into(&[&a], &[0.0], &mut out);
    }
}
