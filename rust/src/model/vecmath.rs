//! Flat-vector math for the aggregation path.
//!
//! These loops ARE the Photon Aggregator's hot path (outer optimizers run on
//! the full parameter vector every round), so they are written allocation-
//! free over slices (O(1) or caller-owned scratch — never O(N) per call);
//! `bench_aggregate` tracks their throughput.
//!
//! `streaming_aggregate` is the round-level entry point: one blocked pass
//! over the K client parameter vectors producing the weighted mean, the
//! pseudo-gradient, and the K×K delta Gram matrix (per-client delta norms +
//! pairwise cosines) without ever materializing the K full-size delta
//! vectors.

/// Block width (elements) of the blocked accumulators. Small enough that a
/// per-client f32 delta block for K=64 clients stays cache-resident, large
/// enough to amortize the loop overhead.
pub const AGG_BLOCK: usize = 2048;

/// L2 norm.
pub fn l2_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Euclidean distance between two vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Cosine similarity (paper §6.2: federated metric between client models).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        dot += x as f64 * y as f64;
        na += x as f64 * x as f64;
        nb += y as f64 * y as f64;
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// `out = mean(rows)` — the FedAvg client-model average. `rows` must be
/// non-empty and equal length.
pub fn mean_into(rows: &[&[f32]], out: &mut [f32]) {
    assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f64;
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for row in rows {
        debug_assert_eq!(row.len(), out.len());
        for (o, &v) in out.iter_mut().zip(*row) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o = (*o as f64 * inv) as f32;
    }
}

/// Accumulate the weighted mean of `rows[..][lo..lo+acc.len()]` into `acc`
/// (zeroed here; f64; rows in order, `w/total` normalization). The ONE
/// per-block accumulation loop shared by `weighted_mean_into` and
/// `streaming_aggregate`, so their per-element operation order — and hence
/// their bit-identical-results contract — can never diverge.
fn weighted_mean_block(rows: &[&[f32]], weights: &[f64], total: f64, lo: usize, acc: &mut [f64]) {
    acc.fill(0.0);
    for (row, &w) in rows.iter().zip(weights) {
        let wn = w / total;
        for (a, &v) in acc.iter_mut().zip(&row[lo..lo + acc.len()]) {
            *a += wn * v as f64;
        }
    }
}

/// Weighted mean with weights summing to anything positive (normalized
/// internally) — FedAvg with per-client sample counts. Accumulates in f64
/// block-by-block over a fixed stack buffer, so no heap allocation happens
/// regardless of the parameter count. Per element, rows are accumulated in
/// order, so the result is bit-identical to a whole-vector f64 accumulator.
pub fn weighted_mean_into(rows: &[&[f32]], weights: &[f64], out: &mut [f32]) {
    assert_eq!(rows.len(), weights.len());
    assert!(!rows.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    let n = out.len();
    for row in rows {
        debug_assert_eq!(row.len(), n);
    }
    let mut acc = [0.0f64; AGG_BLOCK];
    let mut lo = 0;
    while lo < n {
        let b = AGG_BLOCK.min(n - lo);
        weighted_mean_block(rows, weights, total, lo, &mut acc[..b]);
        for (o, &a) in out[lo..lo + b].iter_mut().zip(&acc[..b]) {
            *o = a as f32;
        }
        lo += b;
    }
}

/// Caller-owned scratch for `streaming_aggregate`: one f64 accumulator
/// block plus one f32 delta block per client. Grows to the largest K seen
/// and is reused across rounds (federation keeps one per instance).
#[derive(Default)]
pub struct AggScratch {
    acc: Vec<f64>,
    deltas: Vec<f32>,
}

impl AggScratch {
    pub fn new() -> AggScratch {
        AggScratch::default()
    }

    fn ensure(&mut self, k: usize) {
        self.acc.resize(AGG_BLOCK, 0.0);
        if self.deltas.len() < k * AGG_BLOCK {
            self.deltas.resize(k * AGG_BLOCK, 0.0);
        }
    }
}

/// Round statistics produced by `streaming_aggregate` in the same pass as
/// the mean: the K×K Gram matrix of client deltas `d_k = θ_k − mean`
/// (row-major; diagonal = squared delta norms).
pub struct AggStats {
    pub k: usize,
    pub gram: Vec<f64>,
}

impl AggStats {
    /// L2 norm of client `i`'s delta from the round mean.
    pub fn delta_norm(&self, i: usize) -> f64 {
        self.gram[i * self.k + i].sqrt()
    }
}

/// One blocked pass over the K client parameter vectors computing, without
/// materializing any full-size intermediate:
///
/// * `mean_out`  = weighted mean of `rows` (bit-identical to
///   `weighted_mean_into` — same per-element accumulation order),
/// * `pg_out`    = `global − mean` (bit-identical to `sub_into`),
/// * the returned delta Gram matrix `G[i][j] = Σ d_i·d_j` with
///   `d_k = rows[k] − mean` computed in f32 (matching the former
///   explicitly-materialized delta vectors) and accumulated in f64.
///
/// Replaces the old per-round `O(K·N)` delta clones: scratch is `O(K)`
/// blocks and the Gram matrix is `O(K²)`, independent of N.
pub fn streaming_aggregate(
    rows: &[&[f32]],
    weights: &[f64],
    global: &[f32],
    mean_out: &mut [f32],
    pg_out: &mut [f32],
    scratch: &mut AggScratch,
) -> AggStats {
    let k = rows.len();
    assert_eq!(k, weights.len());
    assert!(k > 0, "streaming_aggregate needs at least one row");
    let n = global.len();
    assert_eq!(mean_out.len(), n);
    assert_eq!(pg_out.len(), n);
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    for row in rows {
        debug_assert_eq!(row.len(), n);
    }
    scratch.ensure(k);
    let mut gram = vec![0.0f64; k * k];

    let mut lo = 0;
    while lo < n {
        let b = AGG_BLOCK.min(n - lo);
        // Weighted mean of this block (the shared per-block loop, so the
        // result stays bit-identical to `weighted_mean_into`) → mean + pg.
        let acc = &mut scratch.acc[..b];
        weighted_mean_block(rows, weights, total, lo, acc);
        for i in 0..b {
            let m = acc[i] as f32;
            mean_out[lo + i] = m;
            pg_out[lo + i] = global[lo + i] - m;
        }
        // Per-client delta blocks (f32 subtraction, as the materialized
        // deltas were) and the upper-triangle Gram contribution.
        for (c, row) in rows.iter().enumerate() {
            let d = &mut scratch.deltas[c * AGG_BLOCK..c * AGG_BLOCK + b];
            for i in 0..b {
                d[i] = row[lo + i] - mean_out[lo + i];
            }
        }
        for i in 0..k {
            let di = &scratch.deltas[i * AGG_BLOCK..i * AGG_BLOCK + b];
            for j in i..k {
                let dj = &scratch.deltas[j * AGG_BLOCK..j * AGG_BLOCK + b];
                let mut dot = 0.0f64;
                for (&x, &y) in di.iter().zip(dj) {
                    dot += x as f64 * y as f64;
                }
                gram[i * k + j] += dot;
            }
        }
        lo += b;
    }
    for i in 0..k {
        for j in 0..i {
            gram[i * k + j] = gram[j * k + i];
        }
    }
    AggStats { k, gram }
}

/// `out = a - b` (pseudo-gradient: Δ = θ_global − θ_client).
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
        *o = x - y;
    }
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

/// `y = alpha * y` in place.
pub fn scale(alpha: f32, y: &mut [f32]) {
    for yv in y.iter_mut() {
        *yv *= alpha;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_and_dist() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_dist(&[1.0, 1.0], &[4.0, 5.0]), 5.0);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[2.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-3.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn mean_is_elementwise() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn weighted_mean_normalizes() {
        let a = [0.0f32, 0.0];
        let b = [4.0f32, 8.0];
        let mut out = [0.0f32; 2];
        weighted_mean_into(&[&a, &b], &[1.0, 3.0], &mut out);
        assert_eq!(out, [3.0, 6.0]);
        // Scaling all weights is a no-op.
        let mut out2 = [0.0f32; 2];
        weighted_mean_into(&[&a, &b], &[10.0, 30.0], &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn equal_weights_match_mean() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 4.0, 1.5];
        let c = [2.0f32, 1.0, -1.0];
        let rows: Vec<&[f32]> = vec![&a, &b, &c];
        let mut m1 = [0.0f32; 3];
        let mut m2 = [0.0f32; 3];
        mean_into(&rows, &mut m1);
        weighted_mean_into(&rows, &[1.0, 1.0, 1.0], &mut m2);
        for (x, y) in m1.iter().zip(&m2) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn sub_axpy_scale() {
        let a = [5.0f32, 7.0];
        let b = [2.0f32, 3.0];
        let mut d = [0.0f32; 2];
        sub_into(&a, &b, &mut d);
        assert_eq!(d, [3.0, 4.0]);
        let mut y = [1.0f32, 1.0];
        axpy(2.0, &d, &mut y);
        assert_eq!(y, [7.0, 9.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [3.5, 4.5]);
    }

    #[test]
    #[should_panic]
    fn weighted_mean_rejects_zero_weights() {
        let a = [1.0f32];
        let mut out = [0.0f32];
        weighted_mean_into(&[&a], &[0.0], &mut out);
    }

    #[test]
    fn weighted_mean_spans_block_boundaries() {
        // n > AGG_BLOCK exercises the blocked path end-to-end.
        let n = AGG_BLOCK + 17;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| (2 * i) as f32).collect();
        let mut out = vec![0.0f32; n];
        weighted_mean_into(&[&a, &b], &[1.0, 1.0], &mut out);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, 1.5 * i as f32, "element {i}");
        }
    }

    #[test]
    fn streaming_aggregate_matches_composed_path() {
        let n = AGG_BLOCK + 100;
        let k = 3;
        let rowsv: Vec<Vec<f32>> = (0..k)
            .map(|c| (0..n).map(|i| ((i * (c + 1)) % 17) as f32 * 0.25 - 1.0).collect())
            .collect();
        let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
        let weights = [1.0, 2.5, 0.5];
        let global: Vec<f32> = (0..n).map(|i| (i % 13) as f32 * 0.1).collect();

        // Reference: the old materializing path.
        let mut ref_mean = vec![0.0f32; n];
        weighted_mean_into(&rows, &weights, &mut ref_mean);
        let mut ref_pg = vec![0.0f32; n];
        sub_into(&global, &ref_mean, &mut ref_pg);
        let deltas: Vec<Vec<f32>> = rowsv
            .iter()
            .map(|r| {
                let mut d = vec![0.0f32; n];
                sub_into(r, &ref_mean, &mut d);
                d
            })
            .collect();

        let mut mean = vec![0.0f32; n];
        let mut pg = vec![0.0f32; n];
        let mut scratch = AggScratch::new();
        let stats =
            streaming_aggregate(&rows, &weights, &global, &mut mean, &mut pg, &mut scratch);

        assert_eq!(mean, ref_mean, "mean must be bit-identical");
        assert_eq!(pg, ref_pg, "pseudo-gradient must be bit-identical");
        for i in 0..k {
            let rel = (stats.delta_norm(i) - l2_norm(&deltas[i])).abs()
                / l2_norm(&deltas[i]).max(1e-12);
            assert!(rel < 1e-12, "delta norm {i}: rel err {rel}");
            for j in 0..k {
                let dot: f64 = deltas[i]
                    .iter()
                    .zip(&deltas[j])
                    .map(|(&x, &y)| x as f64 * y as f64)
                    .sum();
                let g = stats.gram[i * k + j];
                assert!(
                    (g - dot).abs() <= 1e-9 * dot.abs().max(1.0),
                    "gram[{i}][{j}]: {g} vs {dot}"
                );
            }
        }
        // Gram is symmetric.
        for i in 0..k {
            for j in 0..k {
                assert_eq!(stats.gram[i * k + j], stats.gram[j * k + i]);
            }
        }
    }

    #[test]
    fn streaming_aggregate_single_row() {
        let a = [1.0f32, 2.0, 3.0];
        let g = [2.0f32, 2.0, 2.0];
        let mut mean = [0.0f32; 3];
        let mut pg = [0.0f32; 3];
        let mut scratch = AggScratch::new();
        let stats = streaming_aggregate(&[&a], &[4.0], &g, &mut mean, &mut pg, &mut scratch);
        assert_eq!(mean, a);
        assert_eq!(pg, [1.0, 0.0, -1.0]);
        // Single client: delta from the mean is identically zero.
        assert_eq!(stats.delta_norm(0), 0.0);
    }
}
