//! Flat-vector math for the aggregation path.
//!
//! These loops ARE the Photon Aggregator's hot path (outer optimizers run on
//! the full parameter vector every round), so they are written allocation-
//! free over slices (O(1) or caller-owned scratch — never O(N) per call) and
//! as chunked, autovectorization-friendly kernels: every loop walks
//! fixed-width [`LANES`] blocks with an explicit scalar remainder, so the
//! compiler sees a constant trip count it can turn into SIMD without any
//! target-specific intrinsics. `bench_aggregate` tracks their throughput and
//! `BENCH_aggregate.json` records the trajectory.
//!
//! ## Bit-exactness under vectorization
//!
//! Two different contracts, both load-bearing for the repo's parity
//! invariants (docs/TESTING.md):
//!
//! * **Element-wise folds** (`weighted_mean_into`, `mean_into`, `sub_into`,
//!   `axpy`, `scale`, the mean/pg halves of `streaming_aggregate`): each
//!   output element accumulates over *rows*, and chunking only regroups the
//!   loop over *elements*. The per-element operation sequence — f64
//!   accumulator, rows in order, `w/total` normalization — is untouched, so
//!   the chunked kernels are **bit-identical** to the naive scalar
//!   [`reference`] kernels. `tests/props_perf.rs` pins this with `assert_eq`
//!   across lengths 0, 1, lane±1, and non-multiple-of-block remainders.
//! * **Reductions** (`l2_norm`, `l2_dist`, `cosine`, the delta Gram dots):
//!   a single f64 sum is split across [`LANES`] striped accumulators folded
//!   pairwise at the end. That changes the *grouping* of the sum, so results
//!   are not bit-equal to a sequential fold — but the grouping is fixed at
//!   compile time, identical on every call, platform, and plane, so
//!   determinism and cross-plane parity hold exactly as before (every plane
//!   runs the same kernel). Tests compare reductions against [`reference`]
//!   at 1e-9 relative tolerance.
//!
//! `streaming_aggregate` is the round-level entry point: one blocked pass
//! over the K client parameter vectors producing the weighted mean, the
//! pseudo-gradient, and the K×K delta Gram matrix (per-client delta norms +
//! pairwise cosines) without ever materializing the K full-size delta
//! vectors. `streaming_fold` is the gram-free variant for fleets large
//! enough that the O(K²·N) Gram pass would dominate (hierarchical
//! aggregation, ROADMAP item 1).

/// Block width (elements) of the blocked accumulators. Small enough that a
/// per-client f32 delta block for K=64 clients stays cache-resident, large
/// enough to amortize the loop overhead.
pub const AGG_BLOCK: usize = 2048;

/// Fixed lane width of the chunked kernels: 8 f32 lanes (= one AVX2 f64
/// accumulator pair, two NEON quads). Every chunked loop walks
/// `chunks_exact(LANES)` with a scalar remainder tail.
pub const LANES: usize = 8;

// The blocked fold hands `chunks_exact(LANES)` windows of an AGG_BLOCK
// buffer to the lane loops; a remainder inside a *full* block would split
// one block's accumulation into two differently-shaped passes.
const _: () = assert!(AGG_BLOCK % LANES == 0);

/// Fold [`LANES`] striped partial sums in a fixed pairwise tree. One shape
/// for every reduction in this module, so regrouping decisions live in
/// exactly one place.
#[inline]
fn sum_lanes(l: &[f64; LANES]) -> f64 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Lane-striped dot product `Σ a[i]·b[i]` in f64. The kernel under every
/// reduction here (`l2_norm` is `dot(x,x)`, the Gram entries are block
/// dots).
#[inline]
fn dot_lanes(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..LANES {
            lanes[l] += xa[l] as f64 * xb[l] as f64;
        }
    }
    for (l, (&x, &y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        lanes[l] += x as f64 * y as f64;
    }
    sum_lanes(&lanes)
}

/// L2 norm.
pub fn l2_norm(x: &[f32]) -> f64 {
    dot_lanes(x, x).sqrt()
}

/// Euclidean distance between two vectors.
pub fn l2_dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f64; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        for l in 0..LANES {
            let d = (xa[l] - xb[l]) as f64;
            lanes[l] += d * d;
        }
    }
    for (l, (&x, &y)) in ca.remainder().iter().zip(cb.remainder()).enumerate() {
        let d = (x - y) as f64;
        lanes[l] += d * d;
    }
    sum_lanes(&lanes).sqrt()
}

/// Cosine similarity (paper §6.2: federated metric between client models).
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let dot = dot_lanes(a, b);
    let na = dot_lanes(a, a);
    let nb = dot_lanes(b, b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// `out = mean(rows)` — the FedAvg client-model average. `rows` must be
/// non-empty and equal length. Accumulates in f32 over rows (the historical
/// semantics every plane shares), scaling once in f64 at the end; chunking
/// regroups only the element loop, so results are bit-identical to
/// [`reference::mean_into`].
pub fn mean_into(rows: &[&[f32]], out: &mut [f32]) {
    assert!(!rows.is_empty());
    let inv = 1.0 / rows.len() as f64;
    out.fill(0.0);
    for row in rows {
        debug_assert_eq!(row.len(), out.len());
        let mut oc = out.chunks_exact_mut(LANES);
        let mut rc = row.chunks_exact(LANES);
        for (ob, rb) in (&mut oc).zip(&mut rc) {
            for l in 0..LANES {
                ob[l] += rb[l];
            }
        }
        for (o, &v) in oc.into_remainder().iter_mut().zip(rc.remainder()) {
            *o += v;
        }
    }
    for o in out.iter_mut() {
        *o = (*o as f64 * inv) as f32;
    }
}

/// Accumulate the weighted mean of `rows[..][lo..lo+acc.len()]` into `acc`
/// (zeroed here; f64; rows in order, `w/total` normalization). The ONE
/// per-block accumulation loop shared by `weighted_mean_into`,
/// `streaming_aggregate`, and `streaming_fold`, so their per-element
/// operation order — and hence their bit-identical-results contract — can
/// never diverge. The lane chunking regroups only the element loop: element
/// `i` still sees `acc[i] += (w/total) * v` over rows in order, bit-equal to
/// the scalar fold.
fn weighted_mean_block(rows: &[&[f32]], weights: &[f64], total: f64, lo: usize, acc: &mut [f64]) {
    acc.fill(0.0);
    for (row, &w) in rows.iter().zip(weights) {
        let wn = w / total;
        let src = &row[lo..lo + acc.len()];
        let mut ac = acc.chunks_exact_mut(LANES);
        let mut sc = src.chunks_exact(LANES);
        for (ab, sb) in (&mut ac).zip(&mut sc) {
            for l in 0..LANES {
                ab[l] += wn * sb[l] as f64;
            }
        }
        for (a, &v) in ac.into_remainder().iter_mut().zip(sc.remainder()) {
            *a += wn * v as f64;
        }
    }
}

/// Emit one accumulated block as `mean` (f64→f32 narrow) and `pg = global −
/// mean` (f32 subtraction). Shared by `streaming_aggregate` and
/// `streaming_fold` so the two entry points cannot drift bit-wise.
fn emit_mean_pg(acc: &[f64], global: &[f32], mean_out: &mut [f32], pg_out: &mut [f32]) {
    let mut ac = acc.chunks_exact(LANES);
    let mut gc = global.chunks_exact(LANES);
    let mut mc = mean_out.chunks_exact_mut(LANES);
    let mut pc = pg_out.chunks_exact_mut(LANES);
    for (((ab, gb), mb), pb) in (&mut ac).zip(&mut gc).zip(&mut mc).zip(&mut pc) {
        for l in 0..LANES {
            let m = ab[l] as f32;
            mb[l] = m;
            pb[l] = gb[l] - m;
        }
    }
    for (((&a, &g), m), p) in ac
        .remainder()
        .iter()
        .zip(gc.remainder())
        .zip(mc.into_remainder())
        .zip(pc.into_remainder())
    {
        let mv = a as f32;
        *m = mv;
        *p = g - mv;
    }
}

/// Weighted mean with weights summing to anything positive (normalized
/// internally) — FedAvg with per-client sample counts. Accumulates in f64
/// block-by-block over a fixed stack buffer, so no heap allocation happens
/// regardless of the parameter count. Per element, rows are accumulated in
/// order, so the result is bit-identical to a whole-vector f64 accumulator
/// ([`reference::weighted_mean_into`]).
pub fn weighted_mean_into(rows: &[&[f32]], weights: &[f64], out: &mut [f32]) {
    assert_eq!(rows.len(), weights.len());
    assert!(!rows.is_empty());
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    let n = out.len();
    for row in rows {
        debug_assert_eq!(row.len(), n);
    }
    let mut acc = [0.0f64; AGG_BLOCK];
    let mut lo = 0;
    while lo < n {
        let b = AGG_BLOCK.min(n - lo);
        weighted_mean_block(rows, weights, total, lo, &mut acc[..b]);
        for (o, &a) in out[lo..lo + b].iter_mut().zip(&acc[..b]) {
            *o = a as f32;
        }
        lo += b;
    }
}

/// Caller-owned scratch for `streaming_aggregate`: one f64 accumulator
/// block plus one f32 delta block per client. Grows to the largest K seen
/// and is reused across rounds (federation keeps one per instance).
#[derive(Default)]
pub struct AggScratch {
    acc: Vec<f64>,
    deltas: Vec<f32>,
}

impl AggScratch {
    pub fn new() -> AggScratch {
        AggScratch::default()
    }

    fn ensure_acc(&mut self) {
        self.acc.resize(AGG_BLOCK, 0.0);
    }

    fn ensure(&mut self, k: usize) {
        self.ensure_acc();
        if self.deltas.len() < k * AGG_BLOCK {
            self.deltas.resize(k * AGG_BLOCK, 0.0);
        }
    }
}

/// Round statistics produced by `streaming_aggregate` in the same pass as
/// the mean: the K×K Gram matrix of client deltas `d_k = θ_k − mean`
/// (row-major; diagonal = squared delta norms).
pub struct AggStats {
    pub k: usize,
    pub gram: Vec<f64>,
}

impl AggStats {
    /// L2 norm of client `i`'s delta from the round mean.
    pub fn delta_norm(&self, i: usize) -> f64 {
        self.gram[i * self.k + i].sqrt()
    }
}

/// One blocked pass over the K client parameter vectors computing, without
/// materializing any full-size intermediate:
///
/// * `mean_out`  = weighted mean of `rows` (bit-identical to
///   `weighted_mean_into` — same per-element accumulation order),
/// * `pg_out`    = `global − mean` (bit-identical to `sub_into`),
/// * the returned delta Gram matrix `G[i][j] = Σ d_i·d_j` with
///   `d_k = rows[k] − mean` computed in f32 (matching the former
///   explicitly-materialized delta vectors) and accumulated in
///   lane-striped f64 ([`dot_lanes`] per block).
///
/// Replaces the old per-round `O(K·N)` delta clones: scratch is `O(K)`
/// blocks and the Gram matrix is `O(K²)`, independent of N.
pub fn streaming_aggregate(
    rows: &[&[f32]],
    weights: &[f64],
    global: &[f32],
    mean_out: &mut [f32],
    pg_out: &mut [f32],
    scratch: &mut AggScratch,
) -> AggStats {
    let k = rows.len();
    assert_eq!(k, weights.len());
    assert!(k > 0, "streaming_aggregate needs at least one row");
    let n = global.len();
    assert_eq!(mean_out.len(), n);
    assert_eq!(pg_out.len(), n);
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    for row in rows {
        debug_assert_eq!(row.len(), n);
    }
    scratch.ensure(k);
    let mut gram = vec![0.0f64; k * k];

    let mut lo = 0;
    while lo < n {
        let b = AGG_BLOCK.min(n - lo);
        // Weighted mean of this block (the shared per-block loop, so the
        // result stays bit-identical to `weighted_mean_into`) → mean + pg.
        let acc = &mut scratch.acc[..b];
        weighted_mean_block(rows, weights, total, lo, acc);
        emit_mean_pg(
            acc,
            &global[lo..lo + b],
            &mut mean_out[lo..lo + b],
            &mut pg_out[lo..lo + b],
        );
        // Per-client delta blocks (f32 subtraction, as the materialized
        // deltas were) and the upper-triangle Gram contribution.
        for (c, row) in rows.iter().enumerate() {
            let d = &mut scratch.deltas[c * AGG_BLOCK..c * AGG_BLOCK + b];
            let m = &mean_out[lo..lo + b];
            let r = &row[lo..lo + b];
            let mut dc = d.chunks_exact_mut(LANES);
            let mut rc = r.chunks_exact(LANES);
            let mut mc = m.chunks_exact(LANES);
            for ((db, rb), mb) in (&mut dc).zip(&mut rc).zip(&mut mc) {
                for l in 0..LANES {
                    db[l] = rb[l] - mb[l];
                }
            }
            for ((dv, &rv), &mv) in
                dc.into_remainder().iter_mut().zip(rc.remainder()).zip(mc.remainder())
            {
                *dv = rv - mv;
            }
        }
        for i in 0..k {
            let di = &scratch.deltas[i * AGG_BLOCK..i * AGG_BLOCK + b];
            for j in i..k {
                let dj = &scratch.deltas[j * AGG_BLOCK..j * AGG_BLOCK + b];
                gram[i * k + j] += dot_lanes(di, dj);
            }
        }
        lo += b;
    }
    for i in 0..k {
        for j in 0..i {
            gram[i * k + j] = gram[j * k + i];
        }
    }
    AggStats { k, gram }
}

/// The Gram-free fold: one blocked pass producing only the weighted mean
/// and the pseudo-gradient. Bit-identical to `weighted_mean_into` followed
/// by `sub_into(global, mean)` (it runs the same `weighted_mean_block` /
/// `emit_mean_pg` kernels as `streaming_aggregate`), but skips the
/// O(K²·N) delta Gram pass — the right entry point for fleets of hundreds
/// to thousands of clients where pairwise cosines are not consumed.
/// `bench_aggregate`'s 1k-client × 1M-param acceptance ladder prices this
/// path against [`reference::weighted_mean_into`].
pub fn streaming_fold(
    rows: &[&[f32]],
    weights: &[f64],
    global: &[f32],
    mean_out: &mut [f32],
    pg_out: &mut [f32],
    scratch: &mut AggScratch,
) {
    let k = rows.len();
    assert_eq!(k, weights.len());
    assert!(k > 0, "streaming_fold needs at least one row");
    let n = global.len();
    assert_eq!(mean_out.len(), n);
    assert_eq!(pg_out.len(), n);
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    for row in rows {
        debug_assert_eq!(row.len(), n);
    }
    scratch.ensure_acc();
    let mut lo = 0;
    while lo < n {
        let b = AGG_BLOCK.min(n - lo);
        let acc = &mut scratch.acc[..b];
        weighted_mean_block(rows, weights, total, lo, acc);
        emit_mean_pg(
            acc,
            &global[lo..lo + b],
            &mut mean_out[lo..lo + b],
            &mut pg_out[lo..lo + b],
        );
        lo += b;
    }
}

/// The group-structured (hierarchical) fold: partition the K rows into
/// contiguous `groups` (in row order), take each group's weighted mean
/// (exactly [`weighted_mean_into`] over the slice — the fold a
/// sub-aggregator runs locally), then fold the group means as rows with
/// their **carried weights** `W_g = Σ w_i` (sequential sum in group order)
/// via [`streaming_fold`] — the root's fold over `FoldedPush` pairs.
///
/// Two contracts, both pinned by `tests/props_tree.rs`:
///
/// * **Single group** (`groups = [0..k]`): `W/W = 1.0` normalization makes
///   the stage-2 pass an exact f32→f64→f32 identity, so the result is
///   **bit-identical** to the flat [`streaming_fold`] — `tiers = 1` costs
///   nothing and changes nothing.
/// * **Any partition**: the result is a deterministic function of the
///   partition (which the federation derives from the round plan, so every
///   plane — in-process, flat fleet, aggregation tree — computes the same
///   grouping and stays bit-equal). Different partitions may differ in the
///   last ulp (f64 addition is not associative); that is why the partition
///   is *config*, never an emergent property of arrival order.
///
/// Stage 1 materializes one f32 mean per group (`O(G·N)`) — the same
/// memory shape a real tree has (each sub-aggregator holds one folded
/// mean), and far below the `O(K·N)` the flat fold's caller already holds.
pub fn tiered_fold(
    rows: &[&[f32]],
    weights: &[f64],
    groups: &[std::ops::Range<usize>],
    global: &[f32],
    mean_out: &mut [f32],
    pg_out: &mut [f32],
    scratch: &mut AggScratch,
) {
    let k = rows.len();
    assert_eq!(k, weights.len());
    assert!(!groups.is_empty(), "tiered_fold needs at least one group");
    let n = global.len();
    assert_eq!(mean_out.len(), n);
    assert_eq!(pg_out.len(), n);
    let mut cursor = 0usize;
    for g in groups {
        assert_eq!(g.start, cursor, "groups must partition rows contiguously in order");
        assert!(g.end > g.start, "empty sub-fold group");
        cursor = g.end;
    }
    assert_eq!(cursor, k, "groups must cover every row");
    let mut group_means: Vec<Vec<f32>> = Vec::with_capacity(groups.len());
    let mut group_weights: Vec<f64> = Vec::with_capacity(groups.len());
    for g in groups {
        let mut m = vec![0.0f32; n];
        weighted_mean_into(&rows[g.clone()], &weights[g.clone()], &mut m);
        group_means.push(m);
        group_weights.push(weights[g.clone()].iter().sum());
    }
    let mean_rows: Vec<&[f32]> = group_means.iter().map(|v| v.as_slice()).collect();
    streaming_fold(&mean_rows, &group_weights, global, mean_out, pg_out, scratch);
}

/// `out = a - b` (pseudo-gradient: Δ = θ_global − θ_client).
pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for ((ob, ab), bb) in (&mut oc).zip(&mut ac).zip(&mut bc) {
        for l in 0..LANES {
            ob[l] = ab[l] - bb[l];
        }
    }
    for ((o, &x), &y) in oc.into_remainder().iter_mut().zip(ac.remainder()).zip(bc.remainder()) {
        *o = x - y;
    }
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut yc = y.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (yb, xb) in (&mut yc).zip(&mut xc) {
        for l in 0..LANES {
            yb[l] += alpha * xb[l];
        }
    }
    for (yv, &xv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yv += alpha * xv;
    }
}

/// `y = alpha * y` in place.
pub fn scale(alpha: f32, y: &mut [f32]) {
    for yv in y.iter_mut() {
        *yv *= alpha;
    }
}

pub mod reference {
    //! Naive scalar reference kernels: the pre-vectorization semantics, one
    //! element at a time, audit-by-eye simple. Retained so the props_perf
    //! suite can pin the chunked kernels' bit-exactness contract against an
    //! independent implementation, and so `bench_aggregate` can price the
    //! vectorization win. Never called on a hot path.

    use super::AggStats;

    /// Scalar weighted mean: per element, a whole-vector f64 accumulator
    /// over rows in order. The chunked [`super::weighted_mean_into`] must be
    /// bit-identical to this.
    pub fn weighted_mean_into(rows: &[&[f32]], weights: &[f64], out: &mut [f32]) {
        assert_eq!(rows.len(), weights.len());
        assert!(!rows.is_empty());
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        for row in rows {
            debug_assert_eq!(row.len(), out.len());
        }
        for (i, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for (row, &w) in rows.iter().zip(weights) {
                acc += (w / total) * row[i] as f64;
            }
            *o = acc as f32;
        }
    }

    /// Scalar unweighted mean (f32 accumulation over rows, one f64 scale at
    /// the end — the historical `mean_into` semantics).
    pub fn mean_into(rows: &[&[f32]], out: &mut [f32]) {
        assert!(!rows.is_empty());
        let inv = 1.0 / rows.len() as f64;
        for (i, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for row in rows {
                acc += row[i];
            }
            *o = (acc as f64 * inv) as f32;
        }
    }

    /// Scalar `out = a - b`.
    pub fn sub_into(a: &[f32], b: &[f32], out: &mut [f32]) {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x - y;
        }
    }

    /// Scalar `y += alpha * x`.
    pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
        for (yv, &xv) in y.iter_mut().zip(x) {
            *yv += alpha * xv;
        }
    }

    /// Scalar sequential dot in f64.
    pub fn dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
    }

    /// Scalar sequential L2 norm.
    pub fn l2_norm(x: &[f32]) -> f64 {
        dot(x, x).sqrt()
    }

    /// Scalar streaming aggregate: materializes every delta vector and uses
    /// sequential dots for the Gram matrix. `mean_out`/`pg_out` must be
    /// bit-identical to [`super::streaming_aggregate`]; the Gram entries
    /// agree to reduction tolerance (the lane-striped sum regroups them).
    pub fn streaming_aggregate(
        rows: &[&[f32]],
        weights: &[f64],
        global: &[f32],
        mean_out: &mut [f32],
        pg_out: &mut [f32],
    ) -> AggStats {
        let k = rows.len();
        weighted_mean_into(rows, weights, mean_out);
        sub_into(global, mean_out, pg_out);
        let deltas: Vec<Vec<f32>> = rows
            .iter()
            .map(|r| {
                let mut d = vec![0.0f32; mean_out.len()];
                sub_into(r, mean_out, &mut d);
                d
            })
            .collect();
        let mut gram = vec![0.0f64; k * k];
        for i in 0..k {
            for j in 0..k {
                gram[i * k + j] = dot(&deltas[i], &deltas[j]);
            }
        }
        AggStats { k, gram }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_and_dist() {
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        assert_eq!(l2_dist(&[1.0, 1.0], &[4.0, 5.0]), 5.0);
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[2.0, 0.0]) - 1.0).abs() < 1e-12);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
        assert!((cosine(&[1.0, 0.0], &[-3.0, 0.0]) + 1.0).abs() < 1e-12);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn mean_is_elementwise() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, [2.0, 4.0]);
    }

    #[test]
    fn weighted_mean_normalizes() {
        let a = [0.0f32, 0.0];
        let b = [4.0f32, 8.0];
        let mut out = [0.0f32; 2];
        weighted_mean_into(&[&a, &b], &[1.0, 3.0], &mut out);
        assert_eq!(out, [3.0, 6.0]);
        // Scaling all weights is a no-op.
        let mut out2 = [0.0f32; 2];
        weighted_mean_into(&[&a, &b], &[10.0, 30.0], &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn equal_weights_match_mean() {
        let a = [1.0f32, -2.0, 0.5];
        let b = [0.0f32, 4.0, 1.5];
        let c = [2.0f32, 1.0, -1.0];
        let rows: Vec<&[f32]> = vec![&a, &b, &c];
        let mut m1 = [0.0f32; 3];
        let mut m2 = [0.0f32; 3];
        mean_into(&rows, &mut m1);
        weighted_mean_into(&rows, &[1.0, 1.0, 1.0], &mut m2);
        for (x, y) in m1.iter().zip(&m2) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn sub_axpy_scale() {
        let a = [5.0f32, 7.0];
        let b = [2.0f32, 3.0];
        let mut d = [0.0f32; 2];
        sub_into(&a, &b, &mut d);
        assert_eq!(d, [3.0, 4.0]);
        let mut y = [1.0f32, 1.0];
        axpy(2.0, &d, &mut y);
        assert_eq!(y, [7.0, 9.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [3.5, 4.5]);
    }

    #[test]
    #[should_panic]
    fn weighted_mean_rejects_zero_weights() {
        let a = [1.0f32];
        let mut out = [0.0f32];
        weighted_mean_into(&[&a], &[0.0], &mut out);
    }

    #[test]
    fn weighted_mean_spans_block_boundaries() {
        // n > AGG_BLOCK exercises the blocked path end-to-end.
        let n = AGG_BLOCK + 17;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..n).map(|i| (2 * i) as f32).collect();
        let mut out = vec![0.0f32; n];
        weighted_mean_into(&[&a, &b], &[1.0, 1.0], &mut out);
        for (i, &o) in out.iter().enumerate() {
            assert_eq!(o, 1.5 * i as f32, "element {i}");
        }
    }

    // Deterministic awkward lengths: lane remainders, block remainders,
    // degenerate sizes. The randomized version lives in tests/props_perf.rs.
    fn awkward_lengths() -> Vec<usize> {
        vec![
            0,
            1,
            LANES - 1,
            LANES,
            LANES + 1,
            3 * LANES + 5,
            AGG_BLOCK - 1,
            AGG_BLOCK,
            AGG_BLOCK + 1,
            AGG_BLOCK + LANES + 3,
        ]
    }

    fn test_rows(n: usize, k: usize) -> Vec<Vec<f32>> {
        (0..k)
            .map(|c| (0..n).map(|i| ((i * (c + 2)) % 23) as f32 * 0.17 - 1.3).collect())
            .collect()
    }

    #[test]
    fn chunked_kernels_match_scalar_reference_bitwise() {
        for n in awkward_lengths() {
            let rowsv = test_rows(n, 4);
            let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
            let weights = [1.0, 0.25, 3.5, 2.0];

            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            weighted_mean_into(&rows, &weights, &mut got);
            reference::weighted_mean_into(&rows, &weights, &mut want);
            assert_eq!(got, want, "weighted_mean n={n}");

            mean_into(&rows, &mut got);
            reference::mean_into(&rows, &mut want);
            assert_eq!(got, want, "mean n={n}");

            sub_into(&rowsv[0], &rowsv[1], &mut got);
            reference::sub_into(&rowsv[0], &rowsv[1], &mut want);
            assert_eq!(got, want, "sub n={n}");

            got.copy_from_slice(&rowsv[2]);
            want.copy_from_slice(&rowsv[2]);
            axpy(0.75, &rowsv[3], &mut got);
            reference::axpy(0.75, &rowsv[3], &mut want);
            assert_eq!(got, want, "axpy n={n}");
        }
    }

    #[test]
    fn reductions_match_scalar_reference_to_tolerance() {
        for n in awkward_lengths() {
            let rowsv = test_rows(n, 2);
            let got = l2_norm(&rowsv[0]);
            let want = reference::l2_norm(&rowsv[0]);
            assert!(
                (got - want).abs() <= 1e-9 * want.abs().max(1.0),
                "l2_norm n={n}: {got} vs {want}"
            );
            let gd = dot_lanes(&rowsv[0], &rowsv[1]);
            let wd = reference::dot(&rowsv[0], &rowsv[1]);
            assert!(
                (gd - wd).abs() <= 1e-9 * wd.abs().max(1.0),
                "dot n={n}: {gd} vs {wd}"
            );
        }
    }

    #[test]
    fn streaming_aggregate_matches_composed_path() {
        let n = AGG_BLOCK + 100;
        let k = 3;
        let rowsv: Vec<Vec<f32>> = (0..k)
            .map(|c| (0..n).map(|i| ((i * (c + 1)) % 17) as f32 * 0.25 - 1.0).collect())
            .collect();
        let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
        let weights = [1.0, 2.5, 0.5];
        let global: Vec<f32> = (0..n).map(|i| (i % 13) as f32 * 0.1).collect();

        // Reference: the old materializing path.
        let mut ref_mean = vec![0.0f32; n];
        weighted_mean_into(&rows, &weights, &mut ref_mean);
        let mut ref_pg = vec![0.0f32; n];
        sub_into(&global, &ref_mean, &mut ref_pg);
        let deltas: Vec<Vec<f32>> = rowsv
            .iter()
            .map(|r| {
                let mut d = vec![0.0f32; n];
                sub_into(r, &ref_mean, &mut d);
                d
            })
            .collect();

        let mut mean = vec![0.0f32; n];
        let mut pg = vec![0.0f32; n];
        let mut scratch = AggScratch::new();
        let stats =
            streaming_aggregate(&rows, &weights, &global, &mut mean, &mut pg, &mut scratch);

        assert_eq!(mean, ref_mean, "mean must be bit-identical");
        assert_eq!(pg, ref_pg, "pseudo-gradient must be bit-identical");
        for i in 0..k {
            let rel = (stats.delta_norm(i) - l2_norm(&deltas[i])).abs()
                / l2_norm(&deltas[i]).max(1e-12);
            assert!(rel < 1e-12, "delta norm {i}: rel err {rel}");
            for j in 0..k {
                let dot: f64 = deltas[i]
                    .iter()
                    .zip(&deltas[j])
                    .map(|(&x, &y)| x as f64 * y as f64)
                    .sum();
                let g = stats.gram[i * k + j];
                assert!(
                    (g - dot).abs() <= 1e-9 * dot.abs().max(1.0),
                    "gram[{i}][{j}]: {g} vs {dot}"
                );
            }
        }
        // Gram is symmetric.
        for i in 0..k {
            for j in 0..k {
                assert_eq!(stats.gram[i * k + j], stats.gram[j * k + i]);
            }
        }
    }

    #[test]
    fn streaming_aggregate_single_row() {
        let a = [1.0f32, 2.0, 3.0];
        let g = [2.0f32, 2.0, 2.0];
        let mut mean = [0.0f32; 3];
        let mut pg = [0.0f32; 3];
        let mut scratch = AggScratch::new();
        let stats = streaming_aggregate(&[&a], &[4.0], &g, &mut mean, &mut pg, &mut scratch);
        assert_eq!(mean, a);
        assert_eq!(pg, [1.0, 0.0, -1.0]);
        // Single client: delta from the mean is identically zero.
        assert_eq!(stats.delta_norm(0), 0.0);
    }

    #[test]
    fn tiered_fold_single_group_is_flat_fold_bitwise() {
        for n in awkward_lengths() {
            let rowsv = test_rows(n, 5);
            let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
            let weights = [2.0, 1.0, 1.0, 0.5, 4.0];
            let global: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.3 - 0.8).collect();

            let mut flat_mean = vec![0.0f32; n];
            let mut flat_pg = vec![0.0f32; n];
            let mut scratch = AggScratch::new();
            streaming_fold(&rows, &weights, &global, &mut flat_mean, &mut flat_pg, &mut scratch);

            let mut mean = vec![0.0f32; n];
            let mut pg = vec![0.0f32; n];
            tiered_fold(&rows, &weights, &[0..5], &global, &mut mean, &mut pg, &mut scratch);
            assert_eq!(mean, flat_mean, "single-group tiered mean n={n}");
            assert_eq!(pg, flat_pg, "single-group tiered pg n={n}");
        }
    }

    #[test]
    fn tiered_fold_matches_manual_two_stage() {
        let n = AGG_BLOCK + 31;
        let rowsv = test_rows(n, 5);
        let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
        let weights = [2.0, 1.0, 1.0, 0.5, 4.0];
        let global: Vec<f32> = (0..n).map(|i| (i % 11) as f32 * 0.2 - 0.9).collect();
        let groups = [0..2, 2..3, 3..5];

        // Manual two-stage: per-group reference means with carried weights,
        // then the reference weighted mean over the group means.
        let mut gm: Vec<Vec<f32>> = Vec::new();
        let mut gw: Vec<f64> = Vec::new();
        for g in &groups {
            let mut m = vec![0.0f32; n];
            reference::weighted_mean_into(&rows[g.clone()], &weights[g.clone()], &mut m);
            gm.push(m);
            gw.push(weights[g.clone()].iter().sum());
        }
        let gm_rows: Vec<&[f32]> = gm.iter().map(|v| v.as_slice()).collect();
        let mut want_mean = vec![0.0f32; n];
        reference::weighted_mean_into(&gm_rows, &gw, &mut want_mean);
        let mut want_pg = vec![0.0f32; n];
        reference::sub_into(&global, &want_mean, &mut want_pg);

        let mut mean = vec![0.0f32; n];
        let mut pg = vec![0.0f32; n];
        let mut scratch = AggScratch::new();
        tiered_fold(&rows, &weights, &groups, &global, &mut mean, &mut pg, &mut scratch);
        assert_eq!(mean, want_mean, "tiered mean must be bit-identical to manual stages");
        assert_eq!(pg, want_pg);
    }

    #[test]
    #[should_panic]
    fn tiered_fold_rejects_gappy_partition() {
        let a = [1.0f32; 4];
        let rows: Vec<&[f32]> = vec![&a, &a, &a];
        let g = [0.0f32; 4];
        let (mut m, mut p) = ([0.0f32; 4], [0.0f32; 4]);
        let mut s = AggScratch::new();
        tiered_fold(&rows, &[1.0, 1.0, 1.0], &[0..1, 2..3], &g, &mut m, &mut p, &mut s);
    }

    #[test]
    fn streaming_fold_matches_composed_path_bitwise() {
        for n in awkward_lengths() {
            let rowsv = test_rows(n, 5);
            let rows: Vec<&[f32]> = rowsv.iter().map(|v| v.as_slice()).collect();
            let weights = [2.0, 1.0, 1.0, 0.5, 4.0];
            let global: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.3 - 0.8).collect();

            let mut ref_mean = vec![0.0f32; n];
            weighted_mean_into(&rows, &weights, &mut ref_mean);
            let mut ref_pg = vec![0.0f32; n];
            sub_into(&global, &ref_mean, &mut ref_pg);

            let mut mean = vec![0.0f32; n];
            let mut pg = vec![0.0f32; n];
            let mut scratch = AggScratch::new();
            streaming_fold(&rows, &weights, &global, &mut mean, &mut pg, &mut scratch);
            assert_eq!(mean, ref_mean, "fold mean n={n}");
            assert_eq!(pg, ref_pg, "fold pg n={n}");

            // And against streaming_aggregate's outputs (shared kernels).
            let mut mean2 = vec![0.0f32; n];
            let mut pg2 = vec![0.0f32; n];
            let stats = streaming_aggregate(
                &rows, &weights, &global, &mut mean2, &mut pg2, &mut scratch,
            );
            assert_eq!(mean, mean2);
            assert_eq!(pg, pg2);
            assert_eq!(stats.k, 5);
        }
    }
}
