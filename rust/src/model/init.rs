//! Deterministic model initialization from the manifest's per-tensor init
//! specs (Algorithm 1 L.2 `InitModel`). Seeded per tensor so the result is
//! independent of iteration order and reproducible across runs — the paper's
//! reproducibility-by-design requirement (§6.1).

use crate::model::manifest::{InitSpec, Manifest};
use crate::util::rng::Rng;

/// Initialize the flat parameter vector for a model.
pub fn init_params(manifest: &Manifest, seed: u64) -> Vec<f32> {
    let mut flat = vec![0.0f32; manifest.n_params];
    let root = Rng::new(seed);
    for (ti, p) in manifest.params.iter().enumerate() {
        let seg = &mut flat[p.offset..p.offset + p.size];
        match p.init {
            InitSpec::Ones => seg.fill(1.0),
            InitSpec::Normal { std } => {
                let mut rng = root.derive(&p.name, ti as u64);
                for v in seg.iter_mut() {
                    *v = rng.gauss_f32() * std;
                }
            }
        }
    }
    flat
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{ModelConfig, ParamEntry, StepSig};

    fn toy_manifest() -> Manifest {
        let step = StepSig { file: "x".into(), inputs: vec![], outputs: vec![] };
        #[allow(clippy::redundant_clone)]
        Manifest {
            config: ModelConfig {
                name: "toy".into(),
                paper_alias: "t".into(),
                vocab: 16,
                d_model: 4,
                n_heads: 2,
                n_blocks: 1,
                seq_len: 8,
                batch_size: 2,
                attn_impl: "jnp".into(),
            },
            n_params: 5000 + 8,
            params: vec![
                ParamEntry {
                    name: "wte".into(),
                    shape: vec![1250, 4],
                    offset: 0,
                    size: 5000,
                    init: InitSpec::Normal { std: 0.02 },
                },
                ParamEntry {
                    name: "ln_f_g".into(),
                    shape: vec![8],
                    offset: 5000,
                    size: 8,
                    init: InitSpec::Ones,
                },
            ],
            train_chunk_size: 8,
            train_step: step.clone(),
            train_chunk: step.clone(),
            eval_step: step.clone(),
            score_step: step,
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let m = toy_manifest();
        let a = init_params(&m, 7);
        let b = init_params(&m, 7);
        let c = init_params(&m, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn respects_init_specs() {
        let m = toy_manifest();
        let flat = init_params(&m, 3);
        // LN gains exactly one.
        assert!(flat[5000..].iter().all(|&v| v == 1.0));
        // Normal segment: mean ~ 0, std ~ 0.02.
        let seg = &flat[..5000];
        let mean: f64 = seg.iter().map(|&v| v as f64).sum::<f64>() / 5000.0;
        let var: f64 =
            seg.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / 5000.0;
        assert!(mean.abs() < 0.002, "mean {mean}");
        assert!((var.sqrt() - 0.02).abs() < 0.003, "std {}", var.sqrt());
    }
}
