//! `manifest.json` — the contract between the python AOT pipeline and this
//! coordinator. Parsed once per model config at startup; everything the
//! coordinator knows about model structure comes from here.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Per-tensor initialization spec (mirrors python `model.layout`).
#[derive(Clone, Debug, PartialEq)]
pub enum InitSpec {
    Normal { std: f32 },
    Ones,
}

/// One tensor's slice of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: InitSpec,
}

/// Shape+dtype of one step input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String, // "f32" | "i32"
    pub shape: Vec<usize>,
}

/// I/O signature + file of one AOT-lowered step function.
#[derive(Clone, Debug)]
pub struct StepSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// Architecture + local-training hyperparameters (paper Tables 2/3).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub paper_alias: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_blocks: usize,
    pub seq_len: usize,
    pub batch_size: usize,
    pub attn_impl: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub config: ModelConfig,
    pub n_params: usize,
    pub params: Vec<ParamEntry>,
    /// Local steps fused per `train_chunk` dispatch (perf pass).
    pub train_chunk_size: usize,
    pub train_step: StepSig,
    pub train_chunk: StepSig,
    pub eval_step: StepSig,
    pub score_step: StepSig,
}

fn tensor_sigs(v: &Json) -> Result<Vec<TensorSig>> {
    v.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSig {
                name: t.get("name")?.as_str()?.to_string(),
                dtype: t.get("dtype")?.as_str()?.to_string(),
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

fn step_sig(v: &Json) -> Result<StepSig> {
    Ok(StepSig {
        file: v.get("file")?.as_str()?.to_string(),
        inputs: tensor_sigs(v.get("inputs")?)?,
        outputs: tensor_sigs(v.get("outputs")?)?,
    })
}

impl Manifest {
    pub fn parse(json: &Json) -> Result<Manifest> {
        let schema = json.get("schema_version")?.as_usize()?;
        if schema != 1 {
            bail!("unsupported manifest schema_version {schema}");
        }
        let c = json.get("config")?;
        let config = ModelConfig {
            name: c.get("name")?.as_str()?.to_string(),
            paper_alias: c.get("paper_alias")?.as_str()?.to_string(),
            vocab: c.get("vocab")?.as_usize()?,
            d_model: c.get("d_model")?.as_usize()?,
            n_heads: c.get("n_heads")?.as_usize()?,
            n_blocks: c.get("n_blocks")?.as_usize()?,
            seq_len: c.get("seq_len")?.as_usize()?,
            batch_size: c.get("batch_size")?.as_usize()?,
            attn_impl: c.get("attn_impl")?.as_str()?.to_string(),
        };
        let n_params = json.get("n_params")?.as_usize()?;
        let mut params = Vec::new();
        for p in json.get("params")?.as_arr()? {
            let init = p.get("init")?;
            let kind = init.get("kind")?.as_str()?;
            let spec = match kind {
                "normal" => InitSpec::Normal { std: init.get("std")?.as_f64()? as f32 },
                "ones" => InitSpec::Ones,
                other => bail!("unknown init kind {other:?}"),
            };
            params.push(ParamEntry {
                name: p.get("name")?.as_str()?.to_string(),
                shape: p
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
                offset: p.get("offset")?.as_usize()?,
                size: p.get("size")?.as_usize()?,
                init: spec,
            });
        }
        // Validate contiguity — the flat-vector contract.
        let mut off = 0;
        for p in &params {
            if p.offset != off {
                bail!("non-contiguous layout at tensor {}", p.name);
            }
            let prod: usize = p.shape.iter().product();
            if prod != p.size {
                bail!("size/shape mismatch at tensor {}", p.name);
            }
            off += p.size;
        }
        if off != n_params {
            bail!("layout covers {off} params, manifest says {n_params}");
        }
        let steps = json.get("steps")?;
        Ok(Manifest {
            config,
            n_params,
            params,
            train_chunk_size: json.get("train_chunk_size")?.as_usize()?,
            train_step: step_sig(steps.get("train_step")?)?,
            train_chunk: step_sig(steps.get("train_chunk")?)?,
            eval_step: step_sig(steps.get("eval_step")?)?,
            score_step: step_sig(steps.get("score_step")?)?,
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let json = Json::parse_file(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Manifest::parse(&json)
    }

    /// Tensor entry by name (per-layer monitoring).
    pub fn tensor(&self, name: &str) -> Option<&ParamEntry> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Bytes of one full model payload (f32).
    pub fn payload_bytes(&self) -> usize {
        self.n_params * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    pub(crate) fn toy_manifest_json() -> String {
        r#"{
          "schema_version": 1,
          "config": {"name":"toy","paper_alias":"75M","vocab":16,"d_model":4,
                     "n_heads":2,"n_blocks":1,"seq_len":8,"batch_size":2,
                     "attn_impl":"jnp","head_dim":2,"mlp_dim":16,
                     "beta1":0.9,"beta2":0.95,"eps":1e-8,
                     "weight_decay":0.1,"clip_norm":1.0},
          "n_params": 72,
          "train_chunk_size": 8,
          "params": [
            {"name":"wte","shape":[16,4],"offset":0,"size":64,
             "init":{"kind":"normal","std":0.02}},
            {"name":"ln_f_g","shape":[8],"offset":64,"size":8,
             "init":{"kind":"ones"}}
          ],
          "steps": {
            "train_step": {"file":"train_step.hlo.txt",
              "inputs":[{"name":"params","dtype":"f32","shape":[72]}],
              "outputs":[{"name":"loss","dtype":"f32","shape":[]}]},
            "train_chunk": {"file":"train_chunk.hlo.txt","inputs":[],"outputs":[]},
            "eval_step": {"file":"eval_step.hlo.txt","inputs":[],"outputs":[]},
            "score_step": {"file":"score_step.hlo.txt","inputs":[],"outputs":[]}
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_toy_manifest() {
        let m = Manifest::parse(&Json::parse(&toy_manifest_json()).unwrap()).unwrap();
        assert_eq!(m.config.name, "toy");
        assert_eq!(m.n_params, 72);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.tensor("wte").unwrap().shape, vec![16, 4]);
        assert_eq!(m.tensor("ln_f_g").unwrap().init, InitSpec::Ones);
        assert_eq!(m.train_step.inputs[0].shape, vec![72]);
        assert_eq!(m.payload_bytes(), 288);
    }

    #[test]
    fn rejects_gap_in_layout() {
        let bad = toy_manifest_json().replace("\"offset\":64", "\"offset\":65");
        assert!(Manifest::parse(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_wrong_total() {
        let bad = toy_manifest_json().replace("\"n_params\": 72", "\"n_params\": 80");
        assert!(Manifest::parse(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn rejects_unknown_schema() {
        let bad = toy_manifest_json().replace("\"schema_version\": 1", "\"schema_version\": 9");
        assert!(Manifest::parse(&Json::parse(&bad).unwrap()).is_err());
    }
}
