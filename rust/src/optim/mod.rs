//! Optimization substrate: the server-side (outer) federated optimizers and
//! the cosine learning-rate schedule driving the clients' local AdamW.

pub mod outer;
pub mod schedule;

pub use outer::{OuterOpt, OuterOptKind};
pub use schedule::CosineSchedule;
