//! Cosine LR schedule with linear warmup, synchronized across *sequential*
//! optimizer steps (paper Table 3: `S_C` — the scheduler state advances with
//! the client's cumulative local step count, not with rounds).
//!
//! lr(t) = η_max · t/w                      for t < w (warmup)
//!       = η_min + ½(η_max−η_min)(1+cos(π·p))  for w ≤ t < T, p=(t−w)/(T−w)
//!       = η_min                            for t ≥ T
//! with η_min = α · η_max.

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CosineSchedule {
    pub eta_max: f64,
    /// α: min-lr factor (paper Table 3).
    pub alpha: f64,
    /// T: total scheduled steps.
    pub total_steps: u64,
    pub warmup_steps: u64,
}

impl CosineSchedule {
    pub fn new(eta_max: f64, alpha: f64, total_steps: u64, warmup_steps: u64) -> Self {
        assert!(eta_max > 0.0 && (0.0..=1.0).contains(&alpha));
        assert!(warmup_steps < total_steps.max(1));
        CosineSchedule { eta_max, alpha, total_steps, warmup_steps }
    }

    pub fn eta_min(&self) -> f64 {
        self.alpha * self.eta_max
    }

    /// LR at (1-based) sequential step `t`.
    pub fn lr(&self, t: u64) -> f64 {
        if self.warmup_steps > 0 && t <= self.warmup_steps {
            return self.eta_max * t as f64 / self.warmup_steps as f64;
        }
        if t >= self.total_steps {
            return self.eta_min();
        }
        let p = (t - self.warmup_steps) as f64
            / (self.total_steps - self.warmup_steps) as f64;
        self.eta_min()
            + 0.5 * (self.eta_max - self.eta_min()) * (1.0 + (std::f64::consts::PI * p).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = CosineSchedule::new(1e-3, 0.1, 1000, 100);
        assert!((s.lr(50) - 0.5e-3).abs() < 1e-12);
        assert!((s.lr(100) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn peak_then_decay_to_min() {
        let s = CosineSchedule::new(4e-4, 0.1, 88_000, 0);
        assert!((s.lr(0) - 4e-4).abs() < 1e-9, "starts at max without warmup");
        assert!((s.lr(100_000) - 4e-5).abs() < 1e-12, "clamps to eta_min");
        // Midpoint = mean of max and min.
        let mid = s.lr(44_000);
        assert!((mid - (4e-4 + 4e-5) / 2.0).abs() < 1e-8, "mid {mid}");
    }

    #[test]
    fn monotone_decay_after_warmup() {
        let s = CosineSchedule::new(1e-3, 0.1, 500, 50);
        let mut prev = f64::MAX;
        for t in 50..500 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-15, "not monotone at {t}");
            prev = lr;
        }
    }

    #[test]
    #[should_panic]
    fn rejects_warmup_beyond_total() {
        CosineSchedule::new(1e-3, 0.1, 10, 20);
    }
}
