//! Server-side (outer) federated optimizers — the aggregation step of
//! Algorithm 1 (L.8–9): turn the averaged client *pseudo-gradient*
//! Δ_t = θ_t − mean_k(θ_k) into a global-model update.
//!
//! Implemented family (paper §7.8 + FedOPT [77]):
//! * `FedAvg`        — θ ← θ − η_s·Δ (η_s = 1 recovers plain model averaging;
//!                      the paper's preferred, most robust choice)
//! * `FedMomentum`   — heavy-ball / Nesterov server momentum (FedMom [47],
//!                      SGD+N in fig10; the paper uses η_s, μ_s from Table 3)
//! * `FedAdam` / `FedYogi` / `FedAdagrad` — adaptive FedOPT variants [77].
//!
//! All operate in-place on the flat f32 parameter vector with f64
//! accumulators where stability matters; closed-form behaviour is pinned by
//! unit tests and property tests (rust/tests/props.rs).

use anyhow::{bail, Result};

/// Which outer optimizer to run (parsed from CLI/config).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OuterOptKind {
    FedAvg,
    FedMomentum { nesterov: bool },
    FedAdam,
    FedYogi,
    FedAdagrad,
}

impl OuterOptKind {
    pub fn parse(name: &str) -> Result<OuterOptKind> {
        Ok(match name {
            "fedavg" => OuterOptKind::FedAvg,
            "fedmom" | "sgdm" => OuterOptKind::FedMomentum { nesterov: false },
            "fednesterov" | "sgdn" => OuterOptKind::FedMomentum { nesterov: true },
            "fedadam" => OuterOptKind::FedAdam,
            "fedyogi" => OuterOptKind::FedYogi,
            "fedadagrad" => OuterOptKind::FedAdagrad,
            other => bail!("unknown outer optimizer {other:?}"),
        })
    }
}

/// Hyperparameters for the outer step.
#[derive(Clone, Copy, Debug)]
pub struct OuterHyper {
    /// Server learning rate η_s (paper Table 3; 1.0 for plain FedAvg).
    pub lr: f64,
    /// Server momentum μ_s.
    pub momentum: f64,
    /// Adam/Yogi betas + eps/tau (FedOPT defaults).
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
}

impl Default for OuterHyper {
    fn default() -> Self {
        OuterHyper { lr: 1.0, momentum: 0.9, beta1: 0.9, beta2: 0.99, eps: 1e-3 }
    }
}

/// Stateful outer optimizer.
pub struct OuterOpt {
    pub kind: OuterOptKind,
    pub hyper: OuterHyper,
    /// Momentum / first-moment buffer (f64 for drift-free accumulation).
    pub buf_m: Vec<f64>,
    /// Second-moment buffer (Adam/Yogi/Adagrad).
    pub buf_v: Vec<f64>,
    pub t: u64,
}

impl OuterOpt {
    pub fn new(kind: OuterOptKind, hyper: OuterHyper, n_params: usize) -> OuterOpt {
        // Only optimizers that actually keep a first moment get a buffer;
        // FedAdagrad is momentum-free (its buf_m stays empty so
        // `momentum_norm` reports 0, not the pseudo-gradient norm).
        let needs_m = matches!(
            kind,
            OuterOptKind::FedMomentum { .. } | OuterOptKind::FedAdam | OuterOptKind::FedYogi
        );
        let needs_v = matches!(
            kind,
            OuterOptKind::FedAdam | OuterOptKind::FedYogi | OuterOptKind::FedAdagrad
        );
        OuterOpt {
            kind,
            hyper,
            buf_m: if needs_m { vec![0.0; n_params] } else { Vec::new() },
            buf_v: if needs_v { vec![0.0; n_params] } else { Vec::new() },
            t: 0,
        }
    }

    /// Apply one outer step. `pseudo_grad[i] = θ_global[i] − avg_clients[i]`
    /// (so a *descent* step is θ ← θ − lr·direction).
    pub fn step(&mut self, global: &mut [f32], pseudo_grad: &[f32]) {
        assert_eq!(global.len(), pseudo_grad.len());
        self.t += 1;
        let h = self.hyper;
        match self.kind {
            OuterOptKind::FedAvg => {
                for (g, &d) in global.iter_mut().zip(pseudo_grad) {
                    *g -= (h.lr * d as f64) as f32;
                }
            }
            OuterOptKind::FedMomentum { nesterov } => {
                for ((g, &d), m) in
                    global.iter_mut().zip(pseudo_grad).zip(self.buf_m.iter_mut())
                {
                    *m = h.momentum * *m + d as f64;
                    let dir = if nesterov { d as f64 + h.momentum * *m } else { *m };
                    *g -= (h.lr * dir) as f32;
                }
            }
            OuterOptKind::FedAdam => {
                let bc1 = 1.0 - h.beta1.powi(self.t as i32);
                let bc2 = 1.0 - h.beta2.powi(self.t as i32);
                for ((g, &d), (m, v)) in global
                    .iter_mut()
                    .zip(pseudo_grad)
                    .zip(self.buf_m.iter_mut().zip(self.buf_v.iter_mut()))
                {
                    let df = d as f64;
                    *m = h.beta1 * *m + (1.0 - h.beta1) * df;
                    *v = h.beta2 * *v + (1.0 - h.beta2) * df * df;
                    let mh = *m / bc1;
                    let vh = *v / bc2;
                    *g -= (h.lr * mh / (vh.sqrt() + h.eps)) as f32;
                }
            }
            OuterOptKind::FedYogi => {
                let bc1 = 1.0 - h.beta1.powi(self.t as i32);
                for ((g, &d), (m, v)) in global
                    .iter_mut()
                    .zip(pseudo_grad)
                    .zip(self.buf_m.iter_mut().zip(self.buf_v.iter_mut()))
                {
                    let df = d as f64;
                    *m = h.beta1 * *m + (1.0 - h.beta1) * df;
                    let d2 = df * df;
                    *v -= (1.0 - h.beta2) * d2 * (*v - d2).signum();
                    let mh = *m / bc1;
                    *g -= (h.lr * mh / (v.sqrt() + h.eps)) as f32;
                }
            }
            OuterOptKind::FedAdagrad => {
                for ((g, &d), v) in
                    global.iter_mut().zip(pseudo_grad).zip(self.buf_v.iter_mut())
                {
                    let df = d as f64;
                    *v += df * df;
                    *g -= (h.lr * df / (v.sqrt() + h.eps)) as f32;
                }
            }
        }
    }

    /// L2 norm of the server momentum buffer (fig11's tracked quantity).
    /// Momentum-free optimizers (FedAvg, FedAdagrad) keep no first-moment
    /// buffer and report 0.
    pub fn momentum_norm(&self) -> f64 {
        self.buf_m.iter().map(|&m| m * m).sum::<f64>().sqrt()
    }

    /// Serializable optimizer state (ckpt module).
    pub fn state(&self) -> (u64, &[f64], &[f64]) {
        (self.t, &self.buf_m, &self.buf_v)
    }

    pub fn restore(&mut self, t: u64, m: Vec<f64>, v: Vec<f64>) {
        self.t = t;
        self.buf_m = m;
        self.buf_v = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper(lr: f64, mu: f64) -> OuterHyper {
        OuterHyper { lr, momentum: mu, ..OuterHyper::default() }
    }

    #[test]
    fn fedavg_lr1_recovers_client_mean() {
        // θ' = θ − (θ − mean) = mean.
        let mut global = vec![1.0f32, 2.0, 3.0];
        let client_mean = [0.5f32, 2.5, 2.0];
        let pg: Vec<f32> =
            global.iter().zip(&client_mean).map(|(g, c)| g - c).collect();
        let mut opt = OuterOpt::new(OuterOptKind::FedAvg, hyper(1.0, 0.0), 3);
        opt.step(&mut global, &pg);
        for (g, c) in global.iter().zip(&client_mean) {
            assert!((g - c).abs() < 1e-6);
        }
    }

    #[test]
    fn fedavg_lr_scales_step() {
        let mut g = vec![1.0f32];
        let mut opt = OuterOpt::new(OuterOptKind::FedAvg, hyper(0.5, 0.0), 1);
        opt.step(&mut g, &[1.0]);
        assert!((g[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates_closed_form() {
        // Constant pseudo-grad d: buf after t steps = d·(1−μ^t)/(1−μ).
        let mu = 0.9;
        let mut opt =
            OuterOpt::new(OuterOptKind::FedMomentum { nesterov: false }, hyper(1.0, mu), 1);
        let mut g = vec![0.0f32];
        for _ in 0..5 {
            opt.step(&mut g, &[1.0]);
        }
        let expect = (1.0 - mu_pow(mu, 5)) / (1.0 - mu);
        assert!((opt.buf_m[0] - expect).abs() < 1e-9, "{} vs {expect}", opt.buf_m[0]);
    }

    fn mu_pow(mu: f64, t: u32) -> f64 {
        mu.powi(t as i32)
    }

    #[test]
    fn nesterov_takes_lookahead_step() {
        let mu = 0.5;
        let mut plain =
            OuterOpt::new(OuterOptKind::FedMomentum { nesterov: false }, hyper(1.0, mu), 1);
        let mut nest =
            OuterOpt::new(OuterOptKind::FedMomentum { nesterov: true }, hyper(1.0, mu), 1);
        let mut gp = vec![0.0f32];
        let mut gn = vec![0.0f32];
        plain.step(&mut gp, &[1.0]);
        nest.step(&mut gn, &[1.0]);
        // First step: plain moves by 1, nesterov by 1 + μ·1.
        assert!((gp[0] + 1.0).abs() < 1e-6);
        assert!((gn[0] + 1.5).abs() < 1e-6);
    }

    #[test]
    fn fedadam_bounded_unit_steps() {
        // With constant grad, Adam's first step ≈ lr·(1/(1+eps·..)) ≤ lr.
        let mut opt = OuterOpt::new(OuterOptKind::FedAdam, hyper(0.1, 0.0), 2);
        let mut g = vec![0.0f32, 0.0];
        opt.step(&mut g, &[10.0, -10.0]);
        // Direction sign follows grad, magnitude ≈ lr.
        assert!(g[0] < 0.0 && g[1] > 0.0);
        assert!((g[0].abs() - 0.1).abs() < 0.01);
        assert!((g[1].abs() - 0.1).abs() < 0.01);
    }

    #[test]
    fn fedyogi_and_adagrad_run_and_shrink_steps() {
        for kind in [OuterOptKind::FedYogi, OuterOptKind::FedAdagrad] {
            let mut opt = OuterOpt::new(kind, hyper(0.1, 0.0), 1);
            let mut g = vec![0.0f32];
            opt.step(&mut g, &[1.0]);
            let first = g[0].abs();
            let before = g[0];
            opt.step(&mut g, &[1.0]);
            let second = (g[0] - before).abs();
            assert!(second <= first + 1e-9, "{kind:?}: {second} > {first}");
        }
    }

    #[test]
    fn momentum_norm_reported() {
        let mut opt =
            OuterOpt::new(OuterOptKind::FedMomentum { nesterov: true }, hyper(1.0, 0.7), 2);
        assert_eq!(opt.momentum_norm(), 0.0);
        let mut g = vec![0.0f32, 0.0];
        opt.step(&mut g, &[3.0, 4.0]);
        assert!((opt.momentum_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn momentum_free_optimizers_report_zero_momentum_norm() {
        // Regression: FedAdagrad used to write the raw pseudo-gradient into
        // buf_m "for norm reporting", so fig11's momentum_norm column showed
        // the gradient norm for a momentum-free optimizer.
        for kind in [OuterOptKind::FedAvg, OuterOptKind::FedAdagrad] {
            let mut opt = OuterOpt::new(kind, hyper(0.1, 0.9), 3);
            let mut g = vec![0.0f32; 3];
            for _ in 0..4 {
                opt.step(&mut g, &[3.0, -4.0, 1.0]);
            }
            assert_eq!(opt.momentum_norm(), 0.0, "{kind:?}");
            assert!(opt.buf_m.is_empty(), "{kind:?} must not keep a moment buffer");
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(OuterOptKind::parse("fedavg").unwrap(), OuterOptKind::FedAvg);
        assert_eq!(
            OuterOptKind::parse("sgdn").unwrap(),
            OuterOptKind::FedMomentum { nesterov: true }
        );
        assert!(OuterOptKind::parse("bogus").is_err());
    }

    #[test]
    fn state_roundtrip() {
        let mut opt = OuterOpt::new(OuterOptKind::FedAdam, OuterHyper::default(), 2);
        let mut g = vec![0.0f32, 0.0];
        opt.step(&mut g, &[1.0, 2.0]);
        let (t, m, v) = opt.state();
        let (m, v) = (m.to_vec(), v.to_vec());
        let mut opt2 = OuterOpt::new(OuterOptKind::FedAdam, OuterHyper::default(), 2);
        opt2.restore(t, m, v);
        let mut g1 = g.clone();
        let mut g2 = g.clone();
        opt.step(&mut g1, &[1.0, 2.0]);
        opt2.step(&mut g2, &[1.0, 2.0]);
        assert_eq!(g1, g2);
    }
}
