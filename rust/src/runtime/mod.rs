//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! This is the only module that touches the `xla` crate. Pattern follows
//! /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format (the
//! bundled xla_extension 0.5.1 rejects jax≥0.5 serialized protos).
//!
//! Python never runs here: once `make artifacts` has produced
//! `artifacts/<config>/`, everything in this module is self-contained.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::manifest::{Manifest, StepSig};
use crate::util;

/// How concurrent callers of a `ModelRuntime` are allowed to enter PJRT.
///
/// The round execution engine (`coordinator::round_exec`) runs client local
/// rounds on a worker pool; host-side work (batch assembly, literal
/// construction, output reads) always overlaps freely, and this policy
/// decides whether the XLA executable dispatch itself may too.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// One dispatch at a time through a per-model mutex (the default, and
    /// always safe: the compiled CPU executable is treated as non-reentrant).
    Serialized,
    /// Dispatches run concurrently, relying on PJRT's documented
    /// thread-safe `Execute`. Opt-in (`--parallel-dispatch`).
    Concurrent,
}

/// The per-model gate implementing `DispatchPolicy`. Kept separate from the
/// step functions so one policy covers train/eval/score uniformly.
struct DispatchGate {
    serialize: AtomicBool,
    lock: Mutex<()>,
}

impl DispatchGate {
    fn new() -> DispatchGate {
        DispatchGate { serialize: AtomicBool::new(true), lock: Mutex::new(()) }
    }

    /// Returns a guard that must be held across the PJRT dispatch when the
    /// policy is `Serialized`, or `None` under `Concurrent`.
    fn acquire(&self) -> Option<MutexGuard<'_, ()>> {
        if self.serialize.load(Ordering::Acquire) {
            // The gate protects no data of its own, so a poisoned lock
            // (a worker panicked mid-dispatch) is still a usable gate.
            Some(self.lock.lock().unwrap_or_else(|p| p.into_inner()))
        } else {
            None
        }
    }
}

/// Process-wide PJRT client handle.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

/// One compiled step function plus its manifest signature.
pub struct StepFn {
    pub name: String,
    sig: StepSig,
    exe: xla::PjRtLoadedExecutable,
}

/// A loaded model: the three compiled steps + the manifest.
pub struct ModelRuntime {
    pub manifest: Manifest,
    pub train: StepFn,
    /// Fused multi-step variant (perf pass): `train_chunk_size` local steps
    /// per dispatch via an in-HLO `lax.scan`.
    pub train_chunk: StepFn,
    pub eval: StepFn,
    pub score: StepFn,
    pub dir: PathBuf,
    dispatch: DispatchGate,
}

// SAFETY: `ModelRuntime` is shared across the round engine's worker threads
// behind `Arc`. Every field except the `StepFn`s is plain owned data, and
// all Rust-side state (`sig`, `name`, manifest, `dir`) is immutable after
// load. The `StepFn`s wrap PJRT handles whose C API
// (`PJRT_LoadedExecutable_Execute` and buffer syncs) is specified as
// thread-safe; additionally, under the default
// `DispatchPolicy::Serialized` the `DispatchGate` admits at most one thread
// into executable dispatch per model, so even a non-thread-safe build of
// the bundled xla_extension never executes concurrently. The only xla calls
// made outside the gate construct or read `xla::Literal` host buffers that
// are created, used, and dropped by a single thread — no shared object is
// touched on those paths.
unsafe impl Send for ModelRuntime {}
unsafe impl Sync for ModelRuntime {}

/// Host-resident training state for one Photon LLM Node replica.
/// `step` counts *sequential* optimizer steps (1-based at first use), which
/// also drives the cosine LR schedule (paper Table 3: schedule synchronized
/// across sequential steps).
#[derive(Clone)]
pub struct TrainState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: i64,
}

impl TrainState {
    pub fn new(params: Vec<f32>) -> Self {
        let n = params.len();
        TrainState { params, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }

    /// Drop local optimizer state (the paper's recommended *stateless client*
    /// policy, §7.8) while keeping parameters.
    pub fn reset_opt_state(&mut self) {
        self.m.fill(0.0);
        self.v.fill(0.0);
        self.step = 0;
    }
}

/// Scalar metrics emitted by one train step (paper §6.2 monitors).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub loss: f32,
    pub grad_norm: f32,
    pub update_norm: f32,
    pub act_norm: f32,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
        Ok(Runtime { client })
    }

    /// Load a model by config name from the repo `artifacts/` directory.
    pub fn load_model(&self, config_name: &str) -> Result<ModelRuntime> {
        let dir = util::artifacts_dir().join(config_name);
        if !dir.is_dir() {
            bail!(
                "artifacts for config {config_name:?} not found at {} — run `make artifacts`",
                dir.display()
            );
        }
        self.load_model_dir(&dir)
    }

    /// Load a model from an explicit artifact directory.
    pub fn load_model_dir(&self, dir: &Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let compile = |sig: &StepSig, name: &str| -> Result<StepFn> {
            let path = dir.join(&sig.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
            Ok(StepFn { name: name.to_string(), sig: sig.clone(), exe })
        };
        Ok(ModelRuntime {
            train: compile(&manifest.train_step, "train_step")
                .with_context(|| format!("config {}", manifest.config.name))?,
            train_chunk: compile(&manifest.train_chunk, "train_chunk")?,
            eval: compile(&manifest.eval_step, "eval_step")?,
            score: compile(&manifest.score_step, "score_step")?,
            dir: dir.to_path_buf(),
            manifest,
            dispatch: DispatchGate::new(),
        })
    }
}

impl StepFn {
    /// Execute with literal inputs; returns the decomposed output tuple.
    /// (Artifacts are lowered with `return_tuple=True`, so PJRT hands back a
    /// single tuple buffer; we sync it to host and decompose.)
    pub fn execute(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.sig.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.sig.inputs.len(),
                inputs.len()
            );
        }
        let outputs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("{}: execute failed: {e}", self.name))?;
        let tuple = outputs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: output sync failed: {e}", self.name))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("{}: output decompose failed: {e}", self.name))?;
        if parts.len() != self.sig.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.sig.outputs.len(),
                parts.len()
            );
        }
        Ok(parts)
    }

    pub fn sig(&self) -> &StepSig {
        &self.sig
    }
}

fn lit_f32_vec(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

fn lit_tokens(tokens: &[i32], batch: usize, width: usize) -> Result<xla::Literal> {
    if tokens.len() != batch * width {
        bail!("token batch has {} elements, want {}x{}", tokens.len(), batch, width);
    }
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(tokens.as_ptr() as *const u8, tokens.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        &[batch, width],
        bytes,
    )
    .map_err(|e| anyhow!("building token literal: {e}"))
}

fn lit_mask(mask: &[f32], batch: usize, width: usize) -> Result<xla::Literal> {
    if mask.len() != batch * width {
        bail!("mask has {} elements, want {}x{}", mask.len(), batch, width);
    }
    let bytes: &[u8] =
        unsafe { std::slice::from_raw_parts(mask.as_ptr() as *const u8, mask.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        &[batch, width],
        bytes,
    )
    .map_err(|e| anyhow!("building mask literal: {e}"))
}

fn scalar_of<T: xla::NativeType>(v: T) -> xla::Literal {
    xla::Literal::scalar(v)
}

fn read_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("reading scalar: {e}"))
}

fn read_into(lit: &xla::Literal, dst: &mut [f32]) -> Result<()> {
    lit.copy_raw_to(dst).map_err(|e| anyhow!("copying output: {e}"))
}

impl ModelRuntime {
    /// Set how concurrent callers may enter PJRT (see `DispatchPolicy`).
    /// Takes `&self`: the runtime is shared behind `Arc` and the policy is
    /// an execution knob, not model state.
    ///
    /// The policy is **per-model process state**, not per-caller: every
    /// `Federation` built over the same `Arc<ModelRuntime>` (e.g. through
    /// `exp::common::ModelCache`) shares one gate, and
    /// `Federation::with_model` resets it from its config. Sequential use
    /// is always fine; if federations sharing a model ever run rounds
    /// concurrently, they must agree on the policy — a late
    /// `Concurrent` flip would remove the mutex other workers' safety
    /// argument relies on.
    pub fn set_dispatch_policy(&self, policy: DispatchPolicy) {
        self.dispatch
            .serialize
            .store(policy == DispatchPolicy::Serialized, Ordering::Release);
    }

    pub fn dispatch_policy(&self) -> DispatchPolicy {
        if self.dispatch.serialize.load(Ordering::Acquire) {
            DispatchPolicy::Serialized
        } else {
            DispatchPolicy::Concurrent
        }
    }

    pub fn n_params(&self) -> usize {
        self.manifest.n_params
    }

    pub fn batch_size(&self) -> usize {
        self.manifest.config.batch_size
    }

    pub fn seq_len(&self) -> usize {
        self.manifest.config.seq_len
    }

    /// Token count expected per training sequence (`seq_len + 1`).
    pub fn seq_width(&self) -> usize {
        self.manifest.config.seq_len + 1
    }

    /// Run one fused local AdamW step; updates `state` in place.
    ///
    /// `tokens` is a row-major `[batch, seq_len+1]` i32 batch.
    pub fn train_step(
        &self,
        state: &mut TrainState,
        lr: f32,
        tokens: &[i32],
    ) -> Result<StepStats> {
        state.step += 1;
        let inputs = [
            lit_f32_vec(&state.params),
            lit_f32_vec(&state.m),
            lit_f32_vec(&state.v),
            scalar_of(state.step as i32),
            scalar_of(lr),
            lit_tokens(tokens, self.batch_size(), self.seq_width())?,
        ];
        // Literals above are built outside the gate so host-side batch
        // assembly overlaps across workers even under Serialized dispatch.
        let out = {
            let _gate = self.dispatch.acquire();
            self.train.execute(&inputs)?
        };
        read_into(&out[0], &mut state.params)?;
        read_into(&out[1], &mut state.m)?;
        read_into(&out[2], &mut state.v)?;
        Ok(StepStats {
            loss: read_f32_scalar(&out[3])?,
            grad_norm: read_f32_scalar(&out[4])?,
            update_norm: read_f32_scalar(&out[5])?,
            act_norm: read_f32_scalar(&out[6])?,
        })
    }

    /// Fused steps per `train_chunk` dispatch.
    pub fn chunk_size(&self) -> usize {
        self.manifest.train_chunk_size
    }

    /// Run `chunk_size()` fused local AdamW steps in ONE dispatch (the L3
    /// hot-path optimization recorded in EXPERIMENTS.md §Perf): parameters
    /// and moments cross the host boundary once per chunk instead of once
    /// per step, and PJRT dispatch overhead is amortized by `lax.scan`.
    ///
    /// `lrs` has `chunk_size()` entries; `tokens` is row-major
    /// `[chunk, batch, seq_len+1]`. Numerically identical to `chunk_size()`
    /// calls of `train_step` (asserted by integration tests).
    pub fn train_chunk(
        &self,
        state: &mut TrainState,
        lrs: &[f32],
        tokens: &[i32],
    ) -> Result<Vec<StepStats>> {
        let k = self.chunk_size();
        if lrs.len() != k {
            bail!("train_chunk: expected {k} lrs, got {}", lrs.len());
        }
        if tokens.len() != k * self.batch_size() * self.seq_width() {
            bail!("train_chunk: token block has wrong arity");
        }
        let tok_bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(tokens.as_ptr() as *const u8, tokens.len() * 4)
        };
        let tok_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[k, self.batch_size(), self.seq_width()],
            tok_bytes,
        )
        .map_err(|e| anyhow!("building chunk token literal: {e}"))?;
        let inputs = [
            lit_f32_vec(&state.params),
            lit_f32_vec(&state.m),
            lit_f32_vec(&state.v),
            scalar_of(state.step as i32),
            lit_f32_vec(lrs),
            tok_lit,
        ];
        let out = {
            let _gate = self.dispatch.acquire();
            self.train_chunk.execute(&inputs)?
        };
        read_into(&out[0], &mut state.params)?;
        read_into(&out[1], &mut state.m)?;
        read_into(&out[2], &mut state.v)?;
        state.step += k as i64;
        let losses = out[3].to_vec::<f32>().map_err(|e| anyhow!("chunk out: {e}"))?;
        let gns = out[4].to_vec::<f32>().map_err(|e| anyhow!("chunk out: {e}"))?;
        let uns = out[5].to_vec::<f32>().map_err(|e| anyhow!("chunk out: {e}"))?;
        let ans = out[6].to_vec::<f32>().map_err(|e| anyhow!("chunk out: {e}"))?;
        Ok((0..k)
            .map(|i| StepStats {
                loss: losses[i],
                grad_norm: gns[i],
                update_norm: uns[i],
                act_norm: ans[i],
            })
            .collect())
    }

    /// Summed negative log-likelihood + token count for one batch.
    pub fn eval_batch(&self, params: &[f32], tokens: &[i32]) -> Result<(f64, f64)> {
        let inputs = [
            lit_f32_vec(params),
            lit_tokens(tokens, self.batch_size(), self.seq_width())?,
        ];
        let out = {
            let _gate = self.dispatch.acquire();
            self.eval.execute(&inputs)?
        };
        Ok((read_f32_scalar(&out[0])? as f64, read_f32_scalar(&out[1])? as f64))
    }

    /// Mean NLL over a sequence of batches → (nll, perplexity).
    pub fn eval_nll(&self, params: &[f32], batches: &[Vec<i32>]) -> Result<(f64, f64)> {
        let mut sum = 0.0;
        let mut count = 0.0;
        for b in batches {
            let (s, c) = self.eval_batch(params, b)?;
            sum += s;
            count += c;
        }
        if count == 0.0 {
            bail!("eval_nll: no tokens evaluated");
        }
        let nll = sum / count;
        Ok((nll, nll.exp()))
    }

    /// Masked per-sequence log-likelihood (downstream eval harness).
    /// Returns `(option_ll[B], option_len[B])`.
    pub fn score_batch(
        &self,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let inputs = [
            lit_f32_vec(params),
            lit_tokens(tokens, self.batch_size(), self.seq_width())?,
            lit_mask(mask, self.batch_size(), self.manifest.config.seq_len)?,
        ];
        let out = {
            let _gate = self.dispatch.acquire();
            self.score.execute(&inputs)?
        };
        let ll = out[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("score output: {e}"))?;
        let len = out[1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("score output: {e}"))?;
        Ok((ll, len))
    }
}

#[cfg(test)]
mod tests {
    // The runtime requires built artifacts; full coverage lives in
    // rust/tests/integration_runtime.rs. Here we only test the pure helpers.
    use super::*;

    #[test]
    fn train_state_reset() {
        let mut st = TrainState::new(vec![1.0, 2.0]);
        st.m[0] = 5.0;
        st.v[1] = 6.0;
        st.step = 10;
        st.reset_opt_state();
        assert_eq!(st.m, vec![0.0, 0.0]);
        assert_eq!(st.v, vec![0.0, 0.0]);
        assert_eq!(st.step, 0);
        assert_eq!(st.params, vec![1.0, 2.0]);
    }

    #[test]
    fn token_literal_shape_checked() {
        assert!(lit_tokens(&[1, 2, 3], 2, 2).is_err());
        assert!(lit_tokens(&[1, 2, 3, 4], 2, 2).is_ok());
    }

    #[test]
    fn dispatch_gate_serializes_by_default() {
        let gate = DispatchGate::new();
        assert!(gate.acquire().is_some(), "default policy must serialize");
        gate.serialize.store(false, Ordering::Release);
        assert!(gate.acquire().is_none(), "concurrent policy takes no lock");
        gate.serialize.store(true, Ordering::Release);
        let g1 = gate.acquire();
        assert!(g1.is_some());
        drop(g1);
        assert!(gate.acquire().is_some(), "gate is reusable after release");
    }
}
