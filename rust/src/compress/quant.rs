//! Block-scaled stochastic-rounding quantization (the `q8`/`q4` codecs).
//!
//! Each block of `block` consecutive values shares one f32 scale
//! `max|x| / levels` (levels = 127 for q8, 7 for q4); values quantize to
//! integer multiples of the scale with **stochastic rounding** — the
//! fractional part becomes the probability of rounding up — so the
//! quantizer is unbiased in expectation and the per-element error is
//! bounded by one quantization step (the block's scale). Rounding draws
//! come from a seeded [`Rng`], so an encode is a pure function of
//! `(delta, block, seed)`: both federation planes emit identical bytes.
//!
//! The kernels are chunked over [`LANES`]-wide lanes like the vecmath fold
//! (scale scan, floor/frac precompute, decode multiply), but the rounding
//! draws themselves stay strictly sequential — one `rng.f64()` per element
//! in index order, and none at all for a zero-scale block — because the
//! draw stream is part of the wire contract: reordering it would change the
//! emitted bytes. `tests/props_perf.rs` pins the bodies against golden
//! vectors in `tests/fixtures/codec/`, and the unit tests below pin the
//! chunked kernels byte-for-byte against the retained scalar reference.
//!
//! Body layout (little-endian), after the leading wire codec id byte:
//!
//! ```text
//! q8:  id(1) | block u32 | n u64 | scale f32 × ⌈n/block⌉ | q i8 × n
//! q4:  id(1) | block u32 | n u64 | scale f32 × ⌈n/block⌉ | nibbles × ⌈n/2⌉
//! ```
//!
//! q4 nibbles store `q + 8` (q ∈ −7..=7 ⇒ nibble ∈ 1..=15, low nibble
//! first); nibble 0 is never emitted and is rejected on decode, as is a
//! nonzero pad nibble for odd `n` — a corrupted body fails structurally
//! instead of decoding to a different model.

use anyhow::{ensure, Result};

use crate::compress::{CODEC_Q4, CODEC_Q8};
use crate::model::vecmath::LANES;
use crate::util::rng::Rng;

/// Per-block scales for `levels`-level quantization (`max|x| / levels`).
/// Lane-striped max scan; `f32::max` is order-insensitive for the finite
/// inputs the encoder sees, so the scales are bit-identical to a
/// sequential fold.
fn block_scales(delta: &[f32], block: usize, levels: f64) -> Vec<f32> {
    delta
        .chunks(block)
        .map(|ch| {
            let mut lanes = [0.0f32; LANES];
            let mut it = ch.chunks_exact(LANES);
            for b in &mut it {
                for l in 0..LANES {
                    lanes[l] = lanes[l].max(b[l].abs());
                }
            }
            let mut max = it.remainder().iter().fold(0.0f32, |m, x| m.max(x.abs()));
            for &l in &lanes {
                max = max.max(l);
            }
            (max as f64 / levels) as f32
        })
        .collect()
}

/// Stochastically round `x/scale` to an integer in `[-levels, levels]`.
/// The scalar reference kernel: the chunked encoders below must emit
/// exactly these values with exactly this draw schedule (one draw per
/// element, none when the block scale is ≤ 0).
#[cfg(test)]
fn stochastic_q(x: f32, scale: f32, levels: i32, rng: &mut Rng) -> i32 {
    if scale <= 0.0 {
        return 0;
    }
    let t = x as f64 / scale as f64;
    let f = t.floor();
    let frac = t - f;
    let mut q = f as i32;
    if rng.f64() < frac {
        q += 1;
    }
    q.clamp(-levels, levels)
}

fn header(id: u8, block: usize, n: usize, cap: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(cap);
    out.push(id);
    out.extend_from_slice(&(block as u32).to_le_bytes());
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out
}

pub(crate) fn encode_q8(delta: &[f32], block: usize, seed: u64) -> Vec<u8> {
    let block = block.max(1);
    let n = delta.len();
    let scales = block_scales(delta, block, 127.0);
    let mut out = header(CODEC_Q8, block, n, 13 + 4 * scales.len() + n);
    for s in &scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    let mut rng = Rng::new(seed);
    for (ch, &scale) in delta.chunks(block).zip(&scales) {
        if scale <= 0.0 {
            // Zero block: q = 0 for every element and — critically — no
            // rounding draws, so the rng stream stays element-aligned with
            // the scalar kernel (byte-identical bodies).
            out.extend(std::iter::repeat(0u8).take(ch.len()));
            continue;
        }
        let s = scale as f64;
        for sub in ch.chunks(LANES) {
            let mut fl = [0i32; LANES];
            let mut fr = [0.0f64; LANES];
            // Phase 1 (vectorizable): floor + fractional part per lane.
            for (l, &x) in sub.iter().enumerate() {
                let t = x as f64 / s;
                let f = t.floor();
                fl[l] = f as i32;
                fr[l] = t - f;
            }
            // Phase 2 (sequential by contract): one draw per element in
            // index order.
            for l in 0..sub.len() {
                let mut q = fl[l];
                if rng.f64() < fr[l] {
                    q += 1;
                }
                out.push(q.clamp(-127, 127) as i8 as u8);
            }
        }
    }
    out
}

pub(crate) fn encode_q4(delta: &[f32], block: usize, seed: u64) -> Vec<u8> {
    let block = block.max(1);
    let n = delta.len();
    let scales = block_scales(delta, block, 7.0);
    let mut out = header(CODEC_Q4, block, n, 13 + 4 * scales.len() + n.div_ceil(2));
    for s in &scales {
        out.extend_from_slice(&s.to_le_bytes());
    }
    let mut rng = Rng::new(seed);
    // Nibble packing crosses block boundaries (odd-length blocks), so the
    // pending low nibble threads through the whole pass.
    let mut pending: Option<u8> = None;
    for (ch, &scale) in delta.chunks(block).zip(&scales) {
        if scale <= 0.0 {
            for _ in 0..ch.len() {
                // q = 0 ⇒ nibble 8; no rounding draw (see encode_q8).
                match pending.take() {
                    None => pending = Some(8),
                    Some(lo) => out.push(lo | (8 << 4)),
                }
            }
            continue;
        }
        let s = scale as f64;
        for sub in ch.chunks(LANES) {
            let mut fl = [0i32; LANES];
            let mut fr = [0.0f64; LANES];
            for (l, &x) in sub.iter().enumerate() {
                let t = x as f64 / s;
                let f = t.floor();
                fl[l] = f as i32;
                fr[l] = t - f;
            }
            for l in 0..sub.len() {
                let mut q = fl[l];
                if rng.f64() < fr[l] {
                    q += 1;
                }
                let nib = (q.clamp(-7, 7) + 8) as u8; // 1..=15
                match pending.take() {
                    None => pending = Some(nib),
                    Some(lo) => out.push(lo | (nib << 4)),
                }
            }
        }
    }
    if let Some(lo) = pending {
        // Odd n: pad the high nibble with 8 (q = 0).
        out.push(lo | (8 << 4));
    }
    out
}

/// Shared header parse + structural validation. Returns the scales and the
/// quantized-data slice. The caller (`UpdateCodec::decode_delta`) has
/// already verified the codec id and the exact total body length, so every
/// slice below is in bounds by construction — but each field is still
/// cross-checked against the negotiated parameters.
fn parse_header<'a>(
    body: &'a [u8],
    id: u8,
    block: usize,
    n: usize,
    data_bytes: usize,
) -> Result<(Vec<f32>, &'a [u8])> {
    ensure!(body.len() >= 13, "quantized body shorter than its header");
    ensure!(body[0] == id, "codec id mismatch inside quantized body");
    let wire_block = u32::from_le_bytes(body[1..5].try_into().unwrap()) as usize;
    let wire_n = u64::from_le_bytes(body[5..13].try_into().unwrap()) as usize;
    ensure!(
        wire_block == block,
        "body quantized with block {wire_block}, negotiated block is {block}"
    );
    ensure!(wire_n == n, "body encodes {wire_n} values, expected {n}");
    let nb = n.div_ceil(block.max(1));
    ensure!(
        body.len() == 13 + 4 * nb + data_bytes,
        "quantized body is {} bytes, layout implies {}",
        body.len(),
        13 + 4 * nb + data_bytes
    );
    let mut scales = Vec::with_capacity(nb);
    for ch in body[13..13 + 4 * nb].chunks_exact(4) {
        let s = f32::from_le_bytes(ch.try_into().unwrap());
        ensure!(s.is_finite() && s >= 0.0, "non-finite or negative scale {s}");
        scales.push(s);
    }
    Ok((scales, &body[13 + 4 * nb..]))
}

pub(crate) fn decode_q8(body: &[u8], block: usize, n: usize) -> Result<Vec<f32>> {
    let block = block.max(1);
    let (scales, data) = parse_header(body, CODEC_Q8, block, n, n)?;
    let mut out = vec![0.0f32; n];
    for ((qch, och), &scale) in data.chunks(block).zip(out.chunks_mut(block)).zip(&scales) {
        // Structural validation first, then a branch-free dequantize sweep
        // the compiler can vectorize. `q as f32 * scale` — the same single
        // multiply as the scalar decoder, so values are bit-identical.
        for &b in qch {
            let q = b as i8 as i32;
            ensure!((-127..=127).contains(&q), "q8 level {q} out of range");
        }
        for (o, &b) in och.iter_mut().zip(qch) {
            *o = (b as i8) as f32 * scale;
        }
    }
    Ok(out)
}

pub(crate) fn decode_q4(body: &[u8], block: usize, n: usize) -> Result<Vec<f32>> {
    let block = block.max(1);
    let (scales, data) = parse_header(body, CODEC_Q4, block, n, n.div_ceil(2))?;
    let mut out = vec![0.0f32; n];
    // Pass 1: unpack nibbles into centered q values, validating structure
    // byte-by-byte (nibble 0 and a bad pad nibble are refused, as before).
    for (och, &byte) in out.chunks_mut(2).zip(data) {
        let lo = byte & 0x0F;
        ensure!(lo != 0, "q4 nibble 0 is never emitted — corrupted body");
        och[0] = (lo as i32 - 8) as f32;
        let hi = byte >> 4;
        if let Some(o1) = och.get_mut(1) {
            ensure!(hi != 0, "q4 nibble 0 is never emitted — corrupted body");
            *o1 = (hi as i32 - 8) as f32;
        } else {
            ensure!(hi == 8, "q4 pad nibble must be 8, got {hi}");
        }
    }
    // Pass 2: per-block scale sweep (vectorizable); one multiply per
    // element, same as the scalar decoder.
    for (och, &scale) in out.chunks_mut(block).zip(&scales) {
        for o in och {
            *o *= scale;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn delta(n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.61).sin() * scale).collect()
    }

    fn max_block_err(d: &[f32], back: &[f32], block: usize, levels: f64) -> f64 {
        d.chunks(block)
            .zip(back.chunks(block))
            .map(|(dc, bc)| {
                let max = dc.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                let scale = (max as f64 / levels).max(1e-300);
                dc.iter()
                    .zip(bc)
                    .map(|(a, b)| (*a as f64 - *b as f64).abs() / scale)
                    .fold(0.0f64, f64::max)
            })
            .fold(0.0f64, f64::max)
    }

    // The pre-vectorization encoders, verbatim: one stochastic_q per
    // element. The chunked kernels must match these byte-for-byte.
    fn encode_q8_scalar(delta: &[f32], block: usize, seed: u64) -> Vec<u8> {
        let block = block.max(1);
        let n = delta.len();
        let scales = block_scales(delta, block, 127.0);
        let mut out = header(CODEC_Q8, block, n, 13 + 4 * scales.len() + n);
        for s in &scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        let mut rng = Rng::new(seed);
        for (i, &x) in delta.iter().enumerate() {
            let q = stochastic_q(x, scales[i / block], 127, &mut rng);
            out.push(q as i8 as u8);
        }
        out
    }

    fn encode_q4_scalar(delta: &[f32], block: usize, seed: u64) -> Vec<u8> {
        let block = block.max(1);
        let n = delta.len();
        let scales = block_scales(delta, block, 7.0);
        let mut out = header(CODEC_Q4, block, n, 13 + 4 * scales.len() + n.div_ceil(2));
        for s in &scales {
            out.extend_from_slice(&s.to_le_bytes());
        }
        let mut rng = Rng::new(seed);
        let mut pending: Option<u8> = None;
        for (i, &x) in delta.iter().enumerate() {
            let q = stochastic_q(x, scales[i / block], 7, &mut rng);
            let nib = (q + 8) as u8;
            match pending.take() {
                None => pending = Some(nib),
                Some(lo) => out.push(lo | (nib << 4)),
            }
        }
        if let Some(lo) = pending {
            out.push(lo | (8 << 4));
        }
        out
    }

    #[test]
    fn chunked_encode_matches_scalar_reference_bytes() {
        // Ragged shapes: lane remainders, odd n (q4 pad), block remainders,
        // block sizes that are not lane multiples.
        for (n, block) in [
            (0usize, 8usize),
            (1, 8),
            (7, 8),
            (8, 8),
            (9, 8),
            (33, 7),
            (100, 16),
            (101, 16),
            (257, 64),
        ] {
            let d = delta(n, 0.4);
            assert_eq!(
                encode_q8(&d, block, 77),
                encode_q8_scalar(&d, block, 77),
                "q8 n={n} block={block}"
            );
            assert_eq!(
                encode_q4(&d, block, 77),
                encode_q4_scalar(&d, block, 77),
                "q4 n={n} block={block}"
            );
        }
        // Zero blocks skip rounding draws in both kernels — the draw
        // streams must stay aligned across the skip.
        let mut d = delta(64, 0.4);
        for x in d.iter_mut().take(16) {
            *x = 0.0;
        }
        assert_eq!(encode_q8(&d, 16, 5), encode_q8_scalar(&d, 16, 5));
        assert_eq!(encode_q4(&d, 16, 5), encode_q4_scalar(&d, 16, 5));
    }

    #[test]
    fn q8_error_bounded_by_one_step() {
        let d = delta(1337, 0.3);
        let body = encode_q8(&d, 100, 42);
        let back = decode_q8(&body, 100, d.len()).unwrap();
        assert_eq!(back.len(), d.len());
        let err = max_block_err(&d, &back, 100, 127.0);
        assert!(err <= 1.001, "relative error {err} steps");
    }

    #[test]
    fn q4_error_bounded_and_odd_n_padded() {
        for n in [7, 8, 255] {
            let d = delta(n, 1.5);
            let body = encode_q4(&d, 32, 7);
            let back = decode_q4(&body, 32, n).unwrap();
            assert_eq!(back.len(), n);
            let err = max_block_err(&d, &back, 32, 7.0);
            assert!(err <= 1.001, "n={n}: relative error {err} steps");
        }
    }

    #[test]
    fn stochastic_rounding_is_unbiased_in_expectation() {
        // Quantize a constant vector many times with different seeds: the
        // mean reconstruction converges to the value, not to a lattice
        // point (the whole point of stochastic over nearest rounding).
        let d2: Vec<f32> =
            (0..64).map(|i| 0.013 * (1.0 + i as f32 / 100.0)).collect();
        let n_trials = 400;
        let mut mean = vec![0.0f64; d2.len()];
        for s in 0..n_trials {
            let body = encode_q8(&d2, 64, s as u64);
            let back = decode_q8(&body, 64, d2.len()).unwrap();
            for (m, b) in mean.iter_mut().zip(&back) {
                *m += *b as f64 / n_trials as f64;
            }
        }
        for (m, x) in mean.iter().zip(&d2) {
            let scale = d2.iter().fold(0.0f32, |a, b| a.max(b.abs())) as f64 / 127.0;
            assert!(
                (m - *x as f64).abs() < scale * 0.2,
                "mean {m} vs {x} (step {scale})"
            );
        }
    }

    #[test]
    fn zero_blocks_encode_to_zero() {
        let mut d = delta(200, 0.1);
        for x in d.iter_mut().take(50) {
            *x = 0.0;
        }
        let body = encode_q8(&d, 50, 1);
        let back = decode_q8(&body, 50, 200).unwrap();
        assert!(back[..50].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn encode_is_deterministic_per_seed() {
        let d = delta(500, 0.2);
        assert_eq!(encode_q8(&d, 64, 9), encode_q8(&d, 64, 9));
        assert_ne!(encode_q8(&d, 64, 9), encode_q8(&d, 64, 10));
        assert_eq!(encode_q4(&d, 64, 9), encode_q4(&d, 64, 9));
    }

    #[test]
    fn structural_corruption_rejected() {
        let d = delta(100, 0.5);
        let body = encode_q8(&d, 10, 3);
        // Wrong negotiated block.
        assert!(decode_q8(&body, 20, 100).is_err());
        // Wrong n.
        assert!(decode_q8(&body, 10, 99).is_err());
        // Non-finite scale.
        let mut bad = body.clone();
        bad[13..17].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(decode_q8(&bad, 10, 100).is_err());
        // Truncation.
        assert!(decode_q8(&body[..body.len() - 1], 10, 100).is_err());
        // q4: nibble 0 / bad pad.
        let d7 = delta(7, 0.5);
        let b4 = encode_q4(&d7, 7, 3);
        let mut bad4 = b4.clone();
        let data_start = 13 + 4;
        bad4[data_start] &= 0xF0; // low nibble → 0
        assert!(decode_q4(&bad4, 7, 7).is_err());
        let mut badpad = b4.clone();
        let last = badpad.len() - 1;
        badpad[last] = (badpad[last] & 0x0F) | (9 << 4); // pad nibble ≠ 8
        assert!(decode_q4(&badpad, 7, 7).is_err());
    }
}
