//! Lossy pseudo-gradient codecs (the communication-efficient update plane).
//!
//! The paper's economic argument (§4, §6) holds only while cross-institution
//! communication stays cheap relative to local compute. `link` ships model
//! payloads losslessly (raw f32 + optional deflate); this module adds the
//! *lossy* half of the trade-off so the repo can measure the
//! bandwidth/convergence frontier that Photon (arXiv:2411.02908) and
//! OpenFedLLM identify as the deployment bottleneck:
//!
//! | codec     | wire id | what ships                                      |
//! |-----------|---------|-------------------------------------------------|
//! | `none`    | 0       | raw f32 pseudo-gradient (pre-codec behavior)    |
//! | `deflate` | 0       | raw f32 + the frame's lossless deflate flag     |
//! | `q8`      | 2       | 8-bit stochastic-rounding quant, per-block scale|
//! | `q4`      | 3       | 4-bit stochastic-rounding quant, per-block scale|
//! | `topk`    | 4       | magnitude top-k entries + error-feedback residual|
//!
//! `none` and `deflate` are *lossless*: they produce no coded body and the
//! wire carries dense f32s exactly as before this module existed (wire
//! codec id 0). The lossy codecs encode the **pseudo-delta**
//! `params − global` into a self-describing body whose first byte repeats
//! the wire codec id; decoders verify that byte against the negotiated
//! codec, so a corrupted or renegotiated codec id is rejected, never
//! mis-decoded.
//!
//! ## Determinism and parity
//!
//! Quantization uses stochastic rounding seeded by
//! [`transit_seed`]`(seed, round, client)` — both the in-process federation
//! and a remote worker derive the identical seed from the task spec, so
//! they emit byte-identical bodies and the deployment plane stays
//! bit-reproducible against `Federation::run` (the `distributed` parity
//! sweep asserts this with `q8` negotiated).
//!
//! ## Error feedback
//!
//! `topk` keeps the un-sent mass as a client-side residual added to the
//! next round's delta (Seide et al.-style error feedback). The residual
//! lives in [`crate::ckpt::ClientCkpt::residual`], so it checkpoints with
//! the federation and ships to stateless workers like every other piece of
//! client state.
//!
//! # Example: encode → decode round-trip
//!
//! ```
//! use photon::compress::UpdateCodec;
//!
//! let delta: Vec<f32> = (0..512).map(|i| (i as f32 * 0.1).sin() * 0.01).collect();
//! let codec = UpdateCodec::Q8 { block: 128 };
//! let mut residual = Vec::new();
//! let body = codec.encode_delta(&delta, 7, &mut residual).unwrap().unwrap();
//! // ~1 byte per value + per-block scales, vs 4 bytes per value dense.
//! assert!(body.len() < delta.len() * 4 / 3);
//! let back = codec.decode_delta(&body, delta.len()).unwrap();
//! let max_err = delta
//!     .iter()
//!     .zip(&back)
//!     .map(|(a, b)| (a - b).abs())
//!     .fold(0.0f32, f32::max);
//! // Per-block error is bounded by the block's quantization step.
//! assert!(max_err <= 0.01 / 127.0 * 1.01, "{max_err}");
//! ```

pub mod quant;
pub mod topk;

use anyhow::{bail, ensure, Result};

use crate::link;

/// Wire codec id for a raw dense f32 payload (what `none`/`deflate` ship).
pub const CODEC_RAW: u8 = 0;
/// Reserved: deflate is a Photon-Link frame flag, never a payload codec.
pub const CODEC_DEFLATE_RESERVED: u8 = 1;
/// Wire codec id for 8-bit block quantization.
pub const CODEC_Q8: u8 = 2;
/// Wire codec id for 4-bit block quantization.
pub const CODEC_Q4: u8 = 3;
/// Wire codec id for top-k sparsification.
pub const CODEC_TOPK: u8 = 4;

/// Default quantization block (values per scale).
pub const DEFAULT_BLOCK: u32 = 256;
/// Default top-k density (entries kept per 1000).
pub const DEFAULT_KEEP_PERMILLE: u32 = 50;

/// One entry of the update-codec registry: how a pseudo-gradient moves
/// through the Photon Link.
///
/// Negotiated once per session (`net::proto::TaskSpec::codec`) and applied
/// identically by the in-process federation, the wall-clock simulator's
/// byte pricing, and the TCP deployment plane.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateCodec {
    /// Raw f32, no frame deflate requested by the codec (pre-codec path).
    None,
    /// Raw f32 with the frame's lossless deflate (bit-exact decode).
    Deflate,
    /// 8-bit stochastic-rounding quantization, one f32 scale per `block`
    /// values (levels −127..=127).
    Q8 {
        /// Values per scale block (≥ 1).
        block: u32,
    },
    /// 4-bit stochastic-rounding quantization, one f32 scale per `block`
    /// values (levels −7..=7, two values per byte).
    Q4 {
        /// Values per scale block (≥ 1).
        block: u32,
    },
    /// Magnitude top-k sparsification with client-side error feedback.
    TopK {
        /// Entries kept per 1000 (1..=1000); k = max(1, n·permille/1000).
        keep_permille: u32,
    },
}

impl UpdateCodec {
    /// Parse a CLI codec spec: `none`, `deflate`, `q8[:block]`,
    /// `q4[:block]`, `topk[:permille]`.
    pub fn parse(s: &str) -> Result<UpdateCodec> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (s, None),
        };
        let num = |default: u32| -> Result<u32> {
            match param {
                None => Ok(default),
                Some(p) => p
                    .parse()
                    .map_err(|_| anyhow::anyhow!("codec parameter {p:?} is not an integer")),
            }
        };
        let codec = match name {
            "none" => UpdateCodec::None,
            "deflate" => UpdateCodec::Deflate,
            "q8" => UpdateCodec::Q8 { block: num(DEFAULT_BLOCK)? },
            "q4" => UpdateCodec::Q4 { block: num(DEFAULT_BLOCK)? },
            "topk" => UpdateCodec::TopK { keep_permille: num(DEFAULT_KEEP_PERMILLE)? },
            other => bail!("unknown codec {other:?} (none|deflate|q8[:block]|q4[:block]|topk[:permille])"),
        };
        if !matches!(codec, UpdateCodec::None | UpdateCodec::Deflate) {
            codec.validate()?;
        } else if param.is_some() {
            bail!("codec {name:?} takes no parameter");
        }
        Ok(codec)
    }

    /// Human-readable registry label (`q8:256` style).
    pub fn label(&self) -> String {
        match *self {
            UpdateCodec::None => "none".into(),
            UpdateCodec::Deflate => "deflate".into(),
            UpdateCodec::Q8 { block } => format!("q8:{block}"),
            UpdateCodec::Q4 { block } => format!("q4:{block}"),
            UpdateCodec::TopK { keep_permille } => format!("topk:{keep_permille}"),
        }
    }

    /// Codec id carried in Photon-Link frame flags (bits 8–15) and as the
    /// first byte of every coded body. `none` and `deflate` both ship raw
    /// f32 payloads (id 0); deflate is a frame *flag*, not a payload codec.
    pub fn wire_id(&self) -> u8 {
        match self {
            UpdateCodec::None | UpdateCodec::Deflate => CODEC_RAW,
            UpdateCodec::Q8 { .. } => CODEC_Q8,
            UpdateCodec::Q4 { .. } => CODEC_Q4,
            UpdateCodec::TopK { .. } => CODEC_TOPK,
        }
    }

    /// True when decode(encode(x)) ≠ x in general.
    pub fn is_lossy(&self) -> bool {
        !matches!(self, UpdateCodec::None | UpdateCodec::Deflate)
    }

    /// `(tag, param)` pair for the control-protocol encoding
    /// (`net::proto::TaskSpec`). Tags follow the registry order.
    pub fn tag_param(&self) -> (u8, u32) {
        match *self {
            UpdateCodec::None => (0, 0),
            UpdateCodec::Deflate => (1, 0),
            UpdateCodec::Q8 { block } => (2, block),
            UpdateCodec::Q4 { block } => (3, block),
            UpdateCodec::TopK { keep_permille } => (4, keep_permille),
        }
    }

    /// Inverse of [`tag_param`](UpdateCodec::tag_param); rejects unknown
    /// tags and out-of-range parameters (wire hardening: a malformed spec
    /// is refused at the handshake, not at the first round).
    pub fn from_tag_param(tag: u8, param: u32) -> Result<UpdateCodec> {
        let codec = match tag {
            0 => UpdateCodec::None,
            1 => UpdateCodec::Deflate,
            2 => UpdateCodec::Q8 { block: param },
            3 => UpdateCodec::Q4 { block: param },
            4 => UpdateCodec::TopK { keep_permille: param },
            t => bail!("unknown codec tag {t}"),
        };
        if codec.is_lossy() {
            codec.validate()?;
        }
        Ok(codec)
    }

    /// Structural parameter validation.
    pub fn validate(&self) -> Result<()> {
        match *self {
            UpdateCodec::None | UpdateCodec::Deflate => {}
            UpdateCodec::Q8 { block } | UpdateCodec::Q4 { block } => {
                ensure!(block >= 1, "quantization block must be ≥ 1, got {block}");
            }
            UpdateCodec::TopK { keep_permille } => {
                ensure!(
                    (1..=1000).contains(&keep_permille),
                    "topk keep_permille must be in 1..=1000, got {keep_permille}"
                );
            }
        }
        Ok(())
    }

    /// Entries a top-k encode of an `n`-element delta keeps.
    pub fn keep_count(&self, n: usize) -> usize {
        match *self {
            UpdateCodec::TopK { keep_permille } => {
                ((n as u64 * keep_permille as u64) / 1000).max(1) as usize
            }
            _ => n,
        }
    }

    /// Exact pre-deflate body size of one encoded `n`-element update —
    /// deterministic given the codec, which is what lets the wall-clock
    /// simulator price rounds from actual encoded bytes instead of the
    /// dense `link::round_bytes` estimate. Lossless codecs ship `4·n`
    /// dense bytes (deflate's data-dependent saving is measured, not
    /// assumed).
    pub fn encoded_body_bytes(&self, n: usize) -> u64 {
        match *self {
            UpdateCodec::None | UpdateCodec::Deflate => 4 * n as u64,
            UpdateCodec::Q8 { block } => {
                let nb = (n as u64).div_ceil(block.max(1) as u64);
                13 + 4 * nb + n as u64
            }
            UpdateCodec::Q4 { block } => {
                let nb = (n as u64).div_ceil(block.max(1) as u64);
                13 + 4 * nb + (n as u64).div_ceil(2)
            }
            UpdateCodec::TopK { .. } => 17 + 8 * self.keep_count(n) as u64,
        }
    }

    /// Encode a pseudo-delta. Returns `None` for the lossless codecs (the
    /// wire carries dense f32s) and `Some(body)` for the lossy ones. `seed`
    /// drives stochastic rounding; `residual` is the client's
    /// error-feedback state (only `topk` reads/writes it — empty means
    /// zero).
    pub fn encode_delta(
        &self,
        delta: &[f32],
        seed: u64,
        residual: &mut Vec<f32>,
    ) -> Result<Option<Vec<u8>>> {
        self.validate()?;
        Ok(match *self {
            UpdateCodec::None | UpdateCodec::Deflate => None,
            UpdateCodec::Q8 { block } => {
                Some(quant::encode_q8(delta, block as usize, seed))
            }
            UpdateCodec::Q4 { block } => {
                Some(quant::encode_q4(delta, block as usize, seed))
            }
            UpdateCodec::TopK { .. } => {
                Some(topk::encode(delta, self.keep_count(delta.len()), residual)?)
            }
        })
    }

    /// Decode a coded body back to a dense `expect_len`-element delta.
    ///
    /// Hardening (PR 3 rules apply): the leading codec-id byte must match
    /// this (negotiated) codec, every length is cross-checked against
    /// `expect_len` before allocation, the body size must match the
    /// codec-implied size exactly, and all scales/values must be finite —
    /// a malformed body is an error the caller turns into a cut, never a
    /// crash or a silently wrong model.
    pub fn decode_delta(&self, body: &[u8], expect_len: usize) -> Result<Vec<f32>> {
        ensure!(!body.is_empty(), "empty codec body");
        ensure!(self.is_lossy(), "codec {} carries no coded body", self.label());
        ensure!(
            body[0] == self.wire_id(),
            "coded body claims codec id {}, negotiated codec is {} (id {}) — \
             corrupted frame or codec renegotiation drift",
            body[0],
            self.label(),
            self.wire_id()
        );
        ensure!(
            body.len() as u64 == self.encoded_body_bytes(expect_len),
            "coded body is {} bytes, codec {} implies {} for {} elements",
            body.len(),
            self.label(),
            self.encoded_body_bytes(expect_len),
            expect_len
        );
        match *self {
            UpdateCodec::Q8 { block } => quant::decode_q8(body, block as usize, expect_len),
            UpdateCodec::Q4 { block } => quant::decode_q4(body, block as usize, expect_len),
            UpdateCodec::TopK { .. } => {
                topk::decode(body, self.keep_count(expect_len), expect_len)
            }
            UpdateCodec::None | UpdateCodec::Deflate => unreachable!("checked above"),
        }
    }
}

impl std::fmt::Display for UpdateCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// The registry's CLI-facing codec names (default parameters).
pub const REGISTRY: [&str; 5] = ["none", "deflate", "q8", "q4", "topk"];

/// What one client's update looks like in transit.
#[derive(Clone, Debug)]
pub struct Transit {
    /// The coded body the wire carries (`None` = dense f32s).
    pub body: Option<Vec<u8>>,
    /// Framed update bytes on the wire, pre-deflate: coded body (or dense
    /// f32 payload) plus one Photon-Link header. Both federation planes
    /// compute this identically, so it lands bit-equal in the round
    /// records (`RoundRecord::comm_bytes_wire`).
    pub wire_bytes: u64,
}

/// Deterministic per-(round, client) stream for stochastic rounding. Both
/// the in-process federation and remote workers derive this from the
/// experiment seed in the task spec, which is what keeps their encoded
/// bodies byte-identical.
pub fn transit_seed(seed: u64, round: u64, client: u64) -> u64 {
    crate::util::rng::Rng::new(seed)
        .derive("update-codec", (round << 20) ^ client)
        .state()[0]
}

/// Client-side half of the wire transform: encode `params − global`
/// through `codec`, updating the error-feedback `residual`. The server
/// reconstructs with [`decode_transit`]; the in-process path applies both
/// halves back-to-back so its folded updates match the deployment plane
/// bit for bit.
pub fn encode_transit(
    codec: &UpdateCodec,
    global: &[f32],
    params: &[f32],
    seed: u64,
    residual: &mut Vec<f32>,
) -> Result<Transit> {
    ensure!(
        params.len() == global.len(),
        "update has {} params, global model {}",
        params.len(),
        global.len()
    );
    if !codec.is_lossy() {
        return Ok(Transit { body: None, wire_bytes: link::dense_frame_bytes(params.len()) });
    }
    let delta: Vec<f32> = params.iter().zip(global).map(|(p, g)| p - g).collect();
    let body = codec
        .encode_delta(&delta, seed, residual)?
        .expect("lossy codec produces a coded body");
    let wire_bytes = link::framed_bytes(body.len());
    Ok(Transit { body: Some(body), wire_bytes })
}

/// Server-side half: decode a coded body and rebuild the dense client
/// params `global + deltâ` the aggregation folds (decode-then-fold keeps
/// `Federation::commit_round` record-compatible across all three planes).
pub fn decode_transit(codec: &UpdateCodec, global: &[f32], body: &[u8]) -> Result<Vec<f32>> {
    let delta = codec.decode_delta(body, global.len())?;
    Ok(global.iter().zip(&delta).map(|(g, d)| g + d).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wavy(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 * 0.37).sin() * 0.05).collect()
    }

    #[test]
    fn parse_registry_and_params() {
        assert_eq!(UpdateCodec::parse("none").unwrap(), UpdateCodec::None);
        assert_eq!(UpdateCodec::parse("deflate").unwrap(), UpdateCodec::Deflate);
        assert_eq!(
            UpdateCodec::parse("q8").unwrap(),
            UpdateCodec::Q8 { block: DEFAULT_BLOCK }
        );
        assert_eq!(UpdateCodec::parse("q4:64").unwrap(), UpdateCodec::Q4 { block: 64 });
        assert_eq!(
            UpdateCodec::parse("topk:20").unwrap(),
            UpdateCodec::TopK { keep_permille: 20 }
        );
        assert!(UpdateCodec::parse("gzip").is_err());
        assert!(UpdateCodec::parse("q8:zero").is_err());
        assert!(UpdateCodec::parse("q8:0").is_err());
        assert!(UpdateCodec::parse("topk:0").is_err());
        assert!(UpdateCodec::parse("topk:2000").is_err());
        assert!(UpdateCodec::parse("none:3").is_err());
        for name in REGISTRY {
            assert_eq!(
                UpdateCodec::parse(name).unwrap().label().split(':').next().unwrap(),
                name
            );
        }
    }

    #[test]
    fn tag_param_roundtrip() {
        for codec in [
            UpdateCodec::None,
            UpdateCodec::Deflate,
            UpdateCodec::Q8 { block: 32 },
            UpdateCodec::Q4 { block: 1024 },
            UpdateCodec::TopK { keep_permille: 125 },
        ] {
            let (t, p) = codec.tag_param();
            assert_eq!(UpdateCodec::from_tag_param(t, p).unwrap(), codec);
        }
        assert!(UpdateCodec::from_tag_param(9, 0).is_err());
        assert!(UpdateCodec::from_tag_param(2, 0).is_err(), "block 0 refused at decode");
        assert!(UpdateCodec::from_tag_param(4, 0).is_err());
    }

    #[test]
    fn encoded_body_bytes_matches_actual_encode() {
        let delta = wavy(1000);
        let mut residual = Vec::new();
        for codec in [
            UpdateCodec::Q8 { block: 64 },
            UpdateCodec::Q8 { block: 7 },
            UpdateCodec::Q4 { block: 256 },
            UpdateCodec::Q4 { block: 3 },
            UpdateCodec::TopK { keep_permille: 50 },
            UpdateCodec::TopK { keep_permille: 1 },
        ] {
            residual.clear();
            let body = codec.encode_delta(&delta, 3, &mut residual).unwrap().unwrap();
            assert_eq!(
                body.len() as u64,
                codec.encoded_body_bytes(delta.len()),
                "{}",
                codec.label()
            );
        }
        // Lossless codecs: dense accounting, no body.
        assert_eq!(UpdateCodec::None.encoded_body_bytes(1000), 4000);
        assert!(UpdateCodec::Deflate
            .encode_delta(&delta, 3, &mut residual)
            .unwrap()
            .is_none());
    }

    #[test]
    fn lossy_codecs_shrink_the_payload() {
        let n = 10_000;
        for codec in [
            UpdateCodec::Q8 { block: DEFAULT_BLOCK },
            UpdateCodec::Q4 { block: DEFAULT_BLOCK },
            UpdateCodec::TopK { keep_permille: DEFAULT_KEEP_PERMILLE },
        ] {
            let coded = codec.encoded_body_bytes(n);
            let dense = 4 * n as u64;
            assert!(
                coded * 3 < dense,
                "{}: {coded} vs dense {dense}",
                codec.label()
            );
        }
    }

    #[test]
    fn transit_roundtrip_and_wire_accounting() {
        let global = wavy(600);
        let params: Vec<f32> = global.iter().map(|g| g + 0.01).collect();
        // Lossless: no body, dense wire bytes, params untouched.
        let mut residual = Vec::new();
        let t = encode_transit(&UpdateCodec::None, &global, &params, 1, &mut residual)
            .unwrap();
        assert!(t.body.is_none());
        assert_eq!(t.wire_bytes, (600 * 4 + link::HEADER_BYTES) as u64);
        // Lossy: decode_transit(encode_transit(..)) approximates params.
        let codec = UpdateCodec::Q8 { block: 100 };
        let t = encode_transit(&codec, &global, &params, 1, &mut residual).unwrap();
        let body = t.body.unwrap();
        assert_eq!(t.wire_bytes, (body.len() + link::HEADER_BYTES) as u64);
        let back = decode_transit(&codec, &global, &body).unwrap();
        assert_eq!(back.len(), params.len());
        let max_err = params
            .iter()
            .zip(&back)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err <= 0.01 / 127.0 * 1.01, "{max_err}");
    }

    #[test]
    fn transit_seed_is_deterministic_and_disjoint() {
        assert_eq!(transit_seed(42, 3, 5), transit_seed(42, 3, 5));
        assert_ne!(transit_seed(42, 3, 5), transit_seed(42, 3, 6));
        assert_ne!(transit_seed(42, 3, 5), transit_seed(42, 4, 5));
        assert_ne!(transit_seed(42, 3, 5), transit_seed(43, 3, 5));
    }

    #[test]
    fn codec_id_byte_is_verified_against_negotiation() {
        let delta = wavy(300);
        let mut residual = Vec::new();
        let codec = UpdateCodec::Q8 { block: 50 };
        let mut body = codec.encode_delta(&delta, 9, &mut residual).unwrap().unwrap();
        assert!(codec.decode_delta(&body, 300).is_ok());
        for wrong in [CODEC_RAW, CODEC_DEFLATE_RESERVED, CODEC_Q4, CODEC_TOPK, 200] {
            body[0] = wrong;
            assert!(
                codec.decode_delta(&body, 300).is_err(),
                "codec id {wrong} must be rejected"
            );
        }
        body[0] = CODEC_Q8;
        // Wrong expected length ⇒ size mismatch, refused before parsing.
        assert!(codec.decode_delta(&body, 299).is_err());
        assert!(codec.decode_delta(&[], 300).is_err());
    }
}
