//! Magnitude top-k sparsification with error feedback (the `topk` codec).
//!
//! The encoder adds the client's residual (the mass previous rounds did
//! not send) to this round's delta, keeps the `k` largest-magnitude
//! entries of the sum, and stores the rest back into the residual — so
//! over rounds the compressed stream reconstructs the dense sum up to the
//! final residual (property-tested in `rust/tests/props.rs`). Selection is
//! fully deterministic: ties break on the lower index, and kept entries
//! are emitted in increasing index order (the canonical form decode
//! enforces).
//!
//! Body layout (little-endian), after the leading wire codec id byte:
//!
//! ```text
//! id(1) | n u64 | k u64 | index u32 × k | value f32 × k
//! ```

use anyhow::{ensure, Result};

use crate::compress::CODEC_TOPK;

/// Encode the k largest-magnitude entries of `delta + residual`, leaving
/// the un-sent remainder in `residual` (resized to `delta.len()` on first
/// use; a non-empty residual of any other length is a config-drift error).
pub(crate) fn encode(delta: &[f32], k: usize, residual: &mut Vec<f32>) -> Result<Vec<u8>> {
    let n = delta.len();
    let k = k.min(n).max(if n == 0 { 0 } else { 1 });
    if residual.is_empty() {
        residual.resize(n, 0.0);
    }
    ensure!(
        residual.len() == n,
        "error-feedback residual has {} entries, delta {}",
        residual.len(),
        n
    );
    // Effective signal = this round's delta + what was withheld before.
    let eff: Vec<f32> = delta.iter().zip(residual.iter()).map(|(d, r)| d + r).collect();
    let mut order: Vec<u32> = (0..n as u32).collect();
    // Deterministic total order: descending |eff|, ties on the lower index.
    let by_mag = |a: &u32, b: &u32| {
        eff[*b as usize]
            .abs()
            .total_cmp(&eff[*a as usize].abs())
            .then(a.cmp(b))
    };
    if k > 0 && k < n {
        // Partial select — O(n) expected instead of the former full
        // O(n log n) sort. The comparator is a *strict* total order, so the
        // set landing in the first k slots is exactly the sorted prefix:
        // after the ascending index re-sort below, the wire body is
        // byte-identical to the full-sort path (pinned by the unit test).
        order.select_nth_unstable_by(k - 1, by_mag);
    }
    order.truncate(k);
    order.sort_unstable();

    let mut out = Vec::with_capacity(17 + 8 * k);
    out.push(CODEC_TOPK);
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out.extend_from_slice(&(k as u64).to_le_bytes());
    for &i in &order {
        out.extend_from_slice(&i.to_le_bytes());
    }
    // New residual: everything not sent (sent entries transmit eff exactly,
    // so their residual is zero by construction — no arithmetic, no drift).
    residual.copy_from_slice(&eff);
    for &i in &order {
        out.extend_from_slice(&eff[i as usize].to_le_bytes());
        residual[i as usize] = 0.0;
    }
    Ok(out)
}

/// Decode a top-k body into a dense `n`-element delta. `expect_k` is the
/// negotiated keep count — the body must match it exactly, indices must be
/// strictly increasing and in range, and values finite (hardening: a
/// malformed body is refused structurally, never folded).
pub(crate) fn decode(body: &[u8], expect_k: usize, n: usize) -> Result<Vec<f32>> {
    ensure!(body.len() >= 17, "top-k body shorter than its header");
    ensure!(body[0] == CODEC_TOPK, "codec id mismatch inside top-k body");
    let wire_n = u64::from_le_bytes(body[1..9].try_into().unwrap()) as usize;
    let k = u64::from_le_bytes(body[9..17].try_into().unwrap()) as usize;
    ensure!(wire_n == n, "top-k body encodes {wire_n} values, expected {n}");
    ensure!(k == expect_k.min(n), "top-k body keeps {k} entries, negotiated {expect_k}");
    ensure!(
        body.len() == 17 + 8 * k,
        "top-k body is {} bytes, layout implies {}",
        body.len(),
        17 + 8 * k
    );
    let idx_bytes = &body[17..17 + 4 * k];
    let val_bytes = &body[17 + 4 * k..];
    let mut out = vec![0.0f32; n];
    let mut prev: Option<u32> = None;
    for (ib, vb) in idx_bytes.chunks_exact(4).zip(val_bytes.chunks_exact(4)) {
        let i = u32::from_le_bytes(ib.try_into().unwrap());
        ensure!((i as usize) < n, "top-k index {i} out of range ({n} values)");
        if let Some(p) = prev {
            ensure!(i > p, "top-k indices not strictly increasing ({p} then {i})");
        }
        prev = Some(i);
        let v = f32::from_le_bytes(vb.try_into().unwrap());
        ensure!(v.is_finite(), "non-finite top-k value at index {i}");
        out[i as usize] = v;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_the_largest_magnitudes() {
        let delta = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 0.0, 4.0, -0.3];
        let mut residual = Vec::new();
        let body = encode(&delta, 3, &mut residual).unwrap();
        let back = decode(&body, 3, delta.len()).unwrap();
        assert_eq!(back, vec![0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 4.0, 0.0]);
        // Residual holds exactly the un-sent mass.
        assert_eq!(residual, vec![0.1, 0.0, 0.2, 0.0, -0.05, 0.0, 0.0, -0.3]);
    }

    #[test]
    fn error_feedback_flushes_small_entries_eventually() {
        // A persistently small coordinate accumulates in the residual until
        // it outranks the big ones and gets sent.
        let n = 4;
        let mut residual = Vec::new();
        let mut got_small = false;
        for _ in 0..50 {
            let delta = vec![0.05f32, 1.0, -1.0, 0.9];
            let body = encode(&delta, 1, &mut residual).unwrap();
            let back = decode(&body, 1, n).unwrap();
            if back[0] != 0.0 {
                got_small = true;
            }
        }
        assert!(got_small, "error feedback must eventually send coordinate 0");
    }

    #[test]
    fn partial_select_matches_full_sort_prefix() {
        // The select_nth path must keep exactly the indices the old full
        // sort kept — including ragged k near 1 and near n, and ties.
        for (n, k) in [(1usize, 1usize), (8, 3), (57, 8), (57, 57), (200, 1), (200, 199)] {
            let delta: Vec<f32> =
                (0..n).map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.01).collect();
            let mut residual = Vec::new();
            let body = encode(&delta, k, &mut residual).unwrap();
            // Reference selection: the former full sort over eff = delta
            // (residual starts empty, so eff == delta here).
            let mut order: Vec<u32> = (0..n as u32).collect();
            order.sort_unstable_by(|&a, &b| {
                delta[b as usize]
                    .abs()
                    .total_cmp(&delta[a as usize].abs())
                    .then(a.cmp(&b))
            });
            let mut expect = order[..k].to_vec();
            expect.sort_unstable();
            let got: Vec<u32> = body[17..17 + 4 * k]
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            assert_eq!(got, expect, "n={n} k={k}");
        }
    }

    #[test]
    fn deterministic_ties_break_on_lower_index() {
        let delta = vec![1.0f32, 1.0, 1.0, 1.0];
        let mut residual = Vec::new();
        let body = encode(&delta, 2, &mut residual).unwrap();
        let back = decode(&body, 2, 4).unwrap();
        assert_eq!(back, vec![1.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn structural_corruption_rejected() {
        let delta: Vec<f32> = (0..40).map(|i| i as f32 - 20.0).collect();
        let mut residual = Vec::new();
        let body = encode(&delta, 5, &mut residual).unwrap();
        assert!(decode(&body, 5, 40).is_ok());
        // Wrong negotiated k / n.
        assert!(decode(&body, 6, 40).is_err());
        assert!(decode(&body, 5, 41).is_err());
        // Out-of-range index.
        let mut bad = body.clone();
        bad[17..21].copy_from_slice(&1000u32.to_le_bytes());
        assert!(decode(&bad, 5, 40).is_err());
        // Non-increasing indices.
        let mut dup = body.clone();
        let second = body[17..21].to_vec();
        dup[21..25].copy_from_slice(&second);
        assert!(decode(&dup, 5, 40).is_err());
        // Non-finite value.
        let mut nan = body.clone();
        let vstart = 17 + 4 * 5;
        nan[vstart..vstart + 4].copy_from_slice(&f32::INFINITY.to_le_bytes());
        assert!(decode(&nan, 5, 40).is_err());
        // Truncation / wrong size.
        assert!(decode(&body[..body.len() - 1], 5, 40).is_err());
    }

    #[test]
    fn residual_length_drift_is_an_error() {
        let delta = vec![1.0f32; 8];
        let mut residual = vec![0.0f32; 5];
        assert!(encode(&delta, 2, &mut residual).is_err());
    }
}
