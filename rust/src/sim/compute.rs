//! Hardware → seconds-per-local-step: the compute side of the wall-clock
//! model (paper §4.3 does this accounting with A100 throughput; §6.5's
//! fleets mix A40/A100/H100).
//!
//! One optimizer step over `tokens` tokens of an `N`-parameter model costs
//! ≈ `6·N·tokens` FLOPs (forward + backward). A client delivers
//! `Σ gpu.tflops · MFU` of that; multi-GPU clients additionally pay a
//! per-step ring-allreduce of the gradient payload over their slowest
//! intra-client fabric, priced by [`crate::netsim`].

use crate::cluster::hardware::{ClientHardware, FleetSpec};
use crate::netsim::{ring_allreduce_bytes_per_step, Link};

/// Default model-FLOPs-utilization for dense transformer pre-training.
pub const DEFAULT_MFU: f64 = 0.4;

/// Intra-client interconnect latency per allreduce round.
pub const INTRA_NODE_LATENCY_S: f64 = 5e-6;

/// One client's simulated compute rate.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientProfile {
    /// Wall-clock seconds per local optimizer step.
    pub step_secs: f64,
}

/// FLOPs of one optimizer step: ≈ 6·N per token (fwd 2·N + bwd 4·N).
pub fn step_flops(n_params: u64, tokens_per_step: u64) -> f64 {
    6.0 * n_params as f64 * tokens_per_step as f64
}

/// Seconds per local step on `hw`: compute at `mfu` utilization plus the
/// per-step DDP gradient allreduce across the client's GPUs (bounded by
/// its slowest fabric — inter-node bandwidth for multi-node clients).
pub fn step_secs(hw: &ClientHardware, n_params: u64, tokens_per_step: u64, mfu: f64) -> f64 {
    let gpus = hw.total_gpus().max(1);
    let tflops: f64 = hw.nodes.iter().map(|n| n.gpu.tflops * n.n_gpus as f64).sum();
    let compute = step_flops(n_params, tokens_per_step) / (tflops.max(1e-9) * 1e12 * mfu);
    if gpus <= 1 {
        return compute;
    }
    let mut fabric = hw
        .nodes
        .iter()
        .map(|n| n.intra_gbps)
        .fold(f64::INFINITY, f64::min);
    if hw.nodes.len() > 1 {
        fabric = fabric.min(hw.inter_gbps);
    }
    let bytes = ring_allreduce_bytes_per_step(n_params * 4, gpus);
    let sync = Link { gbps: fabric, latency_s: INTRA_NODE_LATENCY_S }.transfer_secs(bytes);
    compute + sync
}

/// One [`ClientProfile`] per fleet client, indexed by client id.
pub fn fleet_profiles(
    fleet: &FleetSpec,
    n_params: u64,
    tokens_per_step: u64,
    mfu: f64,
) -> Vec<ClientProfile> {
    fleet
        .clients
        .iter()
        .map(|hw| ClientProfile { step_secs: step_secs(hw, n_params, tokens_per_step, mfu) })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::hardware::{ClientHardware, NodeSpec, A100, A40, H100};

    const N: u64 = 110_890_000; // paper 125M
    const TOKENS: u64 = 256 * 2048;

    #[test]
    fn h100_beats_a40() {
        let a40 = step_secs(&ClientHardware::single(A40, 1), N, TOKENS, DEFAULT_MFU);
        let h100 = step_secs(&ClientHardware::single(H100, 1), N, TOKENS, DEFAULT_MFU);
        assert!(h100 < a40, "{h100} vs {a40}");
        // Sanity: single A100 ≈ 6·N·tokens / (312e12·0.4) ≈ 2.8 s.
        let a100 = step_secs(&ClientHardware::single(A100, 1), N, TOKENS, DEFAULT_MFU);
        assert!((a100 - 2.79).abs() < 0.1, "{a100}");
    }

    #[test]
    fn single_gpu_has_no_sync_term() {
        let hw = ClientHardware::single(A100, 1);
        let got = step_secs(&hw, N, TOKENS, DEFAULT_MFU);
        let want = step_flops(N, TOKENS) / (A100.tflops * 1e12 * DEFAULT_MFU);
        assert!((got - want).abs() < 1e-12);
    }

    #[test]
    fn more_gpus_are_faster_despite_allreduce() {
        // NVLink-class intra (600 GB/s): sync cost ≪ compute saving.
        let one = step_secs(&ClientHardware::single(A100, 1), N, TOKENS, DEFAULT_MFU);
        let four = step_secs(&ClientHardware::single(A100, 4), N, TOKENS, DEFAULT_MFU);
        assert!(four < one / 3.0, "{four} vs {one}");
        assert!(four > one / 4.0, "allreduce term is charged");
    }

    #[test]
    fn multi_node_bound_by_inter_bandwidth() {
        let node = NodeSpec { gpu: A100, n_gpus: 2, intra_gbps: 600.0 };
        let fast = ClientHardware { nodes: vec![node; 2], inter_gbps: 50.0 };
        let slow = ClientHardware { nodes: vec![node; 2], inter_gbps: 0.1 };
        let f = step_secs(&fast, N, TOKENS, DEFAULT_MFU);
        let s = step_secs(&slow, N, TOKENS, DEFAULT_MFU);
        assert!(s > f, "WAN-bridged client pays for gradient sync: {s} vs {f}");
    }

    #[test]
    fn mfu_scales_inversely() {
        let hw = ClientHardware::single(H100, 1);
        let half = step_secs(&hw, N, TOKENS, 0.2);
        let full = step_secs(&hw, N, TOKENS, 0.4);
        assert!((half - 2.0 * full).abs() < 1e-9);
    }

    #[test]
    fn fleet_profiles_indexed_by_client() {
        let fleet = FleetSpec::heterogeneous(6);
        let profs = fleet_profiles(&fleet, N, TOKENS, DEFAULT_MFU);
        assert_eq!(profs.len(), 6);
        assert!(profs.iter().all(|p| p.step_secs > 0.0));
        // Client 0 is A40×1 — the slowest single in the cycle.
        let max = profs.iter().map(|p| p.step_secs).fold(0.0f64, f64::max);
        assert_eq!(profs[0].step_secs, max);
    }
}
