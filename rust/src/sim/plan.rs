//! Round-schedule replay: the exact per-round client sample, dropouts,
//! stragglers, and effective local-step counts a [`Federation`] with the
//! same [`ExperimentConfig`] executes (Algorithm 1 L.3–7), extracted
//! without touching the model runtime so the simulator runs artifact-free.
//!
//! [`Federation`]: crate::coordinator::Federation

use crate::config::ExperimentConfig;
use crate::coordinator::sampler::ClientSampler;

/// One sampled, non-dropped client in one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Participant {
    pub client: usize,
    /// Effective local steps (stragglers complete `straggler_fraction·τ`).
    pub steps: u64,
    pub straggler: bool,
}

/// The realized schedule of one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundSpec {
    pub round: usize,
    /// Sampled clients that will contribute an update, in sampled order.
    pub participants: Vec<Participant>,
    /// Sampled clients that dropped (contribute nothing, known at
    /// dispatch — the aggregator's dropped-client path).
    pub dropped: Vec<usize>,
}

/// The full federation schedule, replayable through [`crate::sim::Simulator`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundPlan {
    pub n_clients: usize,
    /// Nominal τ (drives the semi-sync deadline; stragglers run fewer
    /// effective steps).
    pub tau: u64,
    pub rounds: Vec<RoundSpec>,
}

impl RoundPlan {
    /// Derive the schedule from a config exactly as `Federation::run_round`
    /// does: `ClientSampler::sample(round, P, K)` then
    /// `FaultPlan::for_round` over the sample. Same seed + config ⇒ the
    /// training run and the simulation see identical rounds.
    pub fn from_config(cfg: &ExperimentConfig) -> RoundPlan {
        let sampler = ClientSampler::new(cfg.seed);
        let mut rounds = Vec::with_capacity(cfg.rounds);
        for round in 0..cfg.rounds {
            let sampled = sampler.sample(round, cfg.n_clients, cfg.clients_per_round);
            let faults = cfg.faults.for_round(round, &sampled);
            let participants = sampled
                .iter()
                .filter(|c| !faults.is_dropped(**c))
                .map(|&client| Participant {
                    client,
                    steps: faults.effective_steps(client, cfg.local_steps),
                    straggler: faults.stragglers.contains(&client),
                })
                .collect();
            rounds.push(RoundSpec { round, participants, dropped: faults.dropped.clone() });
        }
        RoundPlan { n_clients: cfg.n_clients, tau: cfg.local_steps, rounds }
    }

    /// Price a chaos schedule's worker churn into this plan: clients of
    /// crashed/hung workers drop (or keep running when `migrate` models
    /// client-lease migration), flake victims drop, clients of slowed
    /// workers straggle — derived from the *same* seed-derived
    /// [`crate::chaos::Schedule`] the deployment plane injects, so
    /// `photon exp chaos` prices wall-clock from the identical fault
    /// plan it runs live. See [`crate::chaos::Schedule::apply_to_plan`].
    pub fn with_chaos(
        &self,
        schedule: &crate::chaos::Schedule,
        migrate: bool,
    ) -> RoundPlan {
        schedule.apply_to_plan(self, migrate)
    }

    /// Total effective local steps scheduled across all rounds/clients.
    pub fn total_client_steps(&self) -> u64 {
        self.rounds
            .iter()
            .flat_map(|r| r.participants.iter().map(|p| p.steps))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::faults::FaultPlan;
    use crate::coordinator::sampler::ClientSampler;

    fn cfg(p: usize, k: usize, rounds: usize, tau: u64, seed: u64) -> ExperimentConfig {
        let mut c = ExperimentConfig::quickstart("m75a");
        c.n_clients = p;
        c.clients_per_round = k;
        c.rounds = rounds;
        c.local_steps = tau;
        c.seed = seed;
        c
    }

    #[test]
    fn replays_sampler_exactly() {
        let c = cfg(16, 4, 6, 20, 99);
        let plan = RoundPlan::from_config(&c);
        assert_eq!(plan.rounds.len(), 6);
        let sampler = ClientSampler::new(99);
        for (r, spec) in plan.rounds.iter().enumerate() {
            let sampled = sampler.sample(r, 16, 4);
            let scheduled: Vec<usize> = spec
                .participants
                .iter()
                .map(|p| p.client)
                .chain(spec.dropped.iter().copied())
                .collect();
            let mut scheduled_sorted = scheduled.clone();
            scheduled_sorted.sort_unstable();
            assert_eq!(scheduled_sorted, sampled, "round {r}");
        }
    }

    #[test]
    fn faults_shape_the_plan() {
        let mut c = cfg(8, 8, 20, 100, 5);
        c.faults = FaultPlan::new(0.3, 0.4, 5);
        let plan = RoundPlan::from_config(&c);
        let mut saw_drop = false;
        let mut saw_straggler = false;
        for spec in &plan.rounds {
            assert_eq!(spec.participants.len() + spec.dropped.len(), 8);
            saw_drop |= !spec.dropped.is_empty();
            for p in &spec.participants {
                if p.straggler {
                    saw_straggler = true;
                    assert_eq!(p.steps, 50, "straggler_fraction 0.5 of τ=100");
                } else {
                    assert_eq!(p.steps, 100);
                }
            }
        }
        assert!(saw_drop && saw_straggler, "rates 0.3/0.4 over 160 draws");
    }

    #[test]
    fn plan_is_deterministic() {
        let mut c = cfg(12, 6, 8, 30, 7);
        c.faults = FaultPlan::new(0.2, 0.2, 7);
        assert_eq!(RoundPlan::from_config(&c), RoundPlan::from_config(&c));
        assert!(RoundPlan::from_config(&c).total_client_steps() > 0);
    }
}
