//! Deterministic event-driven wall-clock federation simulator (paper §4.3
//! + Photon's headline systems claim: federated rounds hide WAN
//! communication behind τ local steps, so wall-clock throughput stays
//! near-datacenter even over 100 Mbit/s links).
//!
//! The simulator composes the existing analytic pieces into an
//! end-to-end timeline:
//!
//! * [`plan::RoundPlan`] replays a real [`crate::coordinator::Federation`]
//!   round schedule — the exact `ClientSampler` draws and `FaultPlan`
//!   dropouts/stragglers a training run with the same config executes;
//! * [`compute`] turns per-client hardware profiles
//!   ([`crate::cluster::hardware`]) into seconds per local step
//!   (FLOPs / (TFLOP/s · MFU) + intra-client gradient sync priced by
//!   [`crate::netsim`]);
//! * [`crate::netsim::Link`] prices every broadcast/upload transfer
//!   (the payload bytes can come from measured [`crate::link`] frames);
//! * three aggregation policies ([`AggregationPolicy`]) decide when the
//!   server closes a round.
//!
//! Every round produces a [`crate::metrics::TimelineRow`]; the
//! `wallclock` experiment (`exp::fig_wallclock`) sweeps link ladders ×
//! τ × participation and writes the timeline CSVs.
//!
//! ## Determinism
//!
//! All times are integer microseconds derived once from the f64 inputs;
//! the event queue orders by `(time, kind-priority, sequence)` where the
//! sequence number is assigned in deterministic push order. The same
//! seed + config therefore produces an identical timeline, bit for bit
//! (property-tested in `rust/tests/props.rs`).
//!
//! # Example
//!
//! The simulator never loads model artifacts — only the schedule, the
//! fleet, and the payload size matter — so it runs anywhere:
//!
//! ```
//! use photon::config::ExperimentConfig;
//! use photon::netsim::CLOUD_WAN;
//! use photon::sim::{AggregationPolicy, RoundPlan, SimConfig, Simulator};
//!
//! let cfg = ExperimentConfig::quickstart("m75a");
//! let plan = RoundPlan::from_config(&cfg);
//! let sim = SimConfig::new(28_000_000, CLOUD_WAN, AggregationPolicy::Sync);
//! let report = Simulator::uniform(&plan, 0.1, sim).run();
//! assert_eq!(report.rows.len(), cfg.rounds);
//! assert!(report.total_secs > 0.0);
//! ```

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::path::Path;

use anyhow::{bail, Result};

use crate::metrics::{TimelineLog, TimelineRow};
use crate::netsim::Link;

pub mod compute;
pub mod plan;

pub use compute::{fleet_profiles, step_secs, ClientProfile, DEFAULT_MFU};
pub use plan::{Participant, RoundPlan, RoundSpec};

/// When does the Aggregator close a round? (Paper §4.3 / Photon.)
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggregationPolicy {
    /// Wait for every runnable sampled client's upload (stragglers gate
    /// the round).
    Sync,
    /// Deadline-based semi-synchronous: aggregate whatever arrived by
    /// `deadline_factor ×` the slowest *nominal* client's round time;
    /// late clients are cut, reusing the dropped-client aggregation path
    /// (PR 1). Arrivals exactly at the deadline count as arrived.
    SemiSync { deadline_factor: f64 },
    /// Broadcast overlapped with tail local steps: during the dead time
    /// between a client's upload and the next broadcast completing, the
    /// client keeps stepping on its local model; those tail steps count
    /// toward the next round's τ. Credit accrues only for clients
    /// sampled in consecutive rounds (a client with no model cannot run
    /// tail steps). The sim prices time, not learning — the staleness of
    /// tail steps is an optimizer-semantics question outside its scope.
    Overlap,
    /// Buffered asynchronous aggregation (FedBuff-style, the `net` async
    /// plane): the server folds the first `k` arrivals and immediately
    /// re-leases — stragglers never gate a fold, their late uploads land
    /// in a later one with staleness-discounted weight `w·γ^staleness`.
    /// The sim prices time only (the fold epoch closes at the `k`-th
    /// arrival); `gamma` is carried so sweep rows stay self-describing.
    Async { k: usize, gamma: f64 },
}

/// The valid `AggregationPolicy::parse` spellings, quoted verbatim in the
/// unknown-policy error so callers can enumerate their options.
pub const POLICY_NAMES: &str = "sync|semisync|overlap|async[:K[:gamma]]";

impl AggregationPolicy {
    /// Parse a CLI policy name (see [`POLICY_NAMES`]). `async` takes
    /// optional colon-separated knobs — `async:4:0.5` folds every 4
    /// arrivals at discount γ=0.5; the defaults are K=4, γ=0.5.
    pub fn parse(s: &str, deadline_factor: f64) -> Result<AggregationPolicy> {
        if let Some(rest) = s.strip_prefix("async") {
            let mut k = 4usize;
            let mut gamma = 0.5f64;
            let mut parts = rest.strip_prefix(':').unwrap_or("").split(':');
            if !rest.is_empty() && !rest.starts_with(':') {
                bail!("unknown policy {s:?} (valid: {POLICY_NAMES})");
            }
            if let Some(ks) = parts.next().filter(|p| !p.is_empty()) {
                k = ks.parse().map_err(|_| {
                    anyhow::anyhow!("async buffer size K must be an integer, got {ks:?}")
                })?;
            }
            if let Some(gs) = parts.next().filter(|p| !p.is_empty()) {
                gamma = gs.parse().map_err(|_| {
                    anyhow::anyhow!("async discount gamma must be a float, got {gs:?}")
                })?;
            }
            anyhow::ensure!(k >= 1, "async buffer size K must be >= 1");
            anyhow::ensure!(
                gamma > 0.0 && gamma <= 1.0,
                "async discount gamma must be in (0, 1], got {gamma}"
            );
            return Ok(AggregationPolicy::Async { k, gamma });
        }
        Ok(match s {
            "sync" => AggregationPolicy::Sync,
            "semisync" | "semi-sync" => {
                AggregationPolicy::SemiSync { deadline_factor }
            }
            "overlap" => AggregationPolicy::Overlap,
            other => bail!("unknown policy {other:?} (valid: {POLICY_NAMES})"),
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            AggregationPolicy::Sync => "sync",
            AggregationPolicy::SemiSync { .. } => "semisync",
            AggregationPolicy::Overlap => "overlap",
            AggregationPolicy::Async { .. } => "async",
        }
    }
}

/// Wall-clock simulation knobs.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// Client ↔ server link (uniform across the fleet; per-client compute
    /// heterogeneity comes from the fleet profiles).
    pub link: Link,
    /// Broadcast payload bytes (server → client). Use measured
    /// `link::encode_model` frame sizes for compressed accounting.
    pub payload_down_bytes: u64,
    /// Update payload bytes (client → server).
    pub payload_up_bytes: u64,
    pub policy: AggregationPolicy,
    /// Per-step slowdown multiplier applied to clients the `FaultPlan`
    /// marks as stragglers (they also complete fewer steps).
    pub straggler_slowdown: f64,
    /// Server-side aggregation cost charged at the end of every round.
    pub server_agg_secs: f64,
    /// Aggregation-tree fan-in (`ExperimentConfig::tiers`). With
    /// `tiers > 1`, every round pays one extra sub-aggregator → root hop
    /// of [`SimConfig::folded_up_bytes`] after the last client arrival
    /// (sub-aggregators push their folded pairs in parallel, so one
    /// transfer's latency covers all of them) and the round's upload
    /// accounting gains `tiers × folded_up_bytes`. `1` leaves every row
    /// bit-identical to the pre-tree simulator.
    pub tiers: usize,
    /// Bytes of one pre-folded `(weight, mean)` upload — a dense frame
    /// (`link::dense_frame_bytes`), since folded means are never re-coded.
    pub folded_up_bytes: u64,
}

impl SimConfig {
    /// Symmetric-payload config with default straggler slowdown (4×) and
    /// free server aggregation.
    pub fn new(payload_bytes: u64, link: Link, policy: AggregationPolicy) -> SimConfig {
        SimConfig {
            link,
            payload_down_bytes: payload_bytes,
            payload_up_bytes: payload_bytes,
            policy,
            straggler_slowdown: 4.0,
            server_agg_secs: 0.0,
            tiers: 1,
            folded_up_bytes: 0,
        }
    }

    /// Price a `tiers`-group aggregation tree: one extra folded-pair hop
    /// per round (see the `tiers` field docs). No-op when `tiers <= 1`.
    pub fn with_tiers(mut self, tiers: usize, folded_up_bytes: u64) -> SimConfig {
        self.tiers = tiers.max(1);
        self.folded_up_bytes = folded_up_bytes;
        self
    }

    /// Asymmetric payloads: dense broadcast down, (possibly codec-shrunk)
    /// update up. `Federation::simulate_wallclock` and the `wallclock`
    /// experiment use this to price uploads from the update codec's
    /// **actual encoded bytes**
    /// (`compress::UpdateCodec::encoded_body_bytes`) instead of the dense
    /// `link::round_bytes` estimate.
    pub fn asymmetric(
        down_bytes: u64,
        up_bytes: u64,
        link: Link,
        policy: AggregationPolicy,
    ) -> SimConfig {
        SimConfig {
            payload_down_bytes: down_bytes,
            payload_up_bytes: up_bytes,
            ..SimConfig::new(0, link, policy)
        }
    }
}

// --- event engine ----------------------------------------------------------

const US_PER_SEC: f64 = 1e6;

fn to_us(secs: f64) -> u64 {
    (secs * US_PER_SEC).round() as u64
}

fn us_to_secs(us: u64) -> f64 {
    us as f64 / US_PER_SEC
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum EventKind {
    BroadcastDone,
    ComputeDone,
    UploadDone,
    Deadline,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Event {
    at_us: u64,
    seq: u64,
    kind: EventKind,
    /// Participant slot (usize::MAX for Deadline).
    slot: usize,
}

impl Event {
    /// Deadline sorts after same-time arrivals so "arrived by the
    /// deadline" is inclusive.
    fn key(&self) -> (u64, u8, u64) {
        let prio = if self.kind == EventKind::Deadline { 1 } else { 0 };
        (self.at_us, prio, self.seq)
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key().cmp(&other.key())
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The aggregate outcome of one simulated schedule.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub policy: AggregationPolicy,
    pub rows: Vec<TimelineRow>,
    /// End-to-end wall-clock of the whole schedule.
    pub total_secs: f64,
    /// Total bytes moved over the client↔server link (down + up).
    pub total_bytes: u64,
    pub arrived_total: usize,
    pub late_total: usize,
    pub dropped_total: usize,
}

impl SimReport {
    pub fn mean_round_secs(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().map(|r| r.round_secs).sum::<f64>() / self.rows.len() as f64
    }

    /// Fraction of mean round wall-clock spent on the two transfers
    /// (§4.3's "communication is negligible at large τ" quantity).
    pub fn comm_fraction(&self) -> f64 {
        let mean = self.mean_round_secs();
        if mean <= 0.0 {
            return 0.0;
        }
        let comm = self
            .rows
            .iter()
            .map(|r| r.broadcast_secs + r.upload_secs)
            .sum::<f64>()
            / self.rows.len() as f64;
        (comm / mean).min(1.0)
    }

    /// Write the per-round timeline CSV (`metrics::TIMELINE_CSV_HEADER`).
    pub fn write_csv(&self, path: &Path) -> Result<()> {
        TimelineLog { rows: self.rows.clone() }.write_csv(path)
    }
}

/// The event-driven simulator: replays a [`RoundPlan`] against per-client
/// compute profiles and a [`SimConfig`].
pub struct Simulator {
    plan: RoundPlan,
    /// Indexed by client id (0..plan.n_clients).
    profiles: Vec<ClientProfile>,
    cfg: SimConfig,
    now_us: u64,
    /// Per client: when it became free to run tail steps for the *next*
    /// round (Overlap). `u64::MAX` = no tail credit — the client did not
    /// participate in the previous round (or the run just started), so
    /// it holds no model to step on.
    free_from_us: Vec<u64>,
}

impl Simulator {
    pub fn new(plan: RoundPlan, profiles: Vec<ClientProfile>, cfg: SimConfig) -> Simulator {
        assert_eq!(
            profiles.len(),
            plan.n_clients,
            "one compute profile per client"
        );
        let n = plan.n_clients;
        Simulator { plan, profiles, cfg, now_us: 0, free_from_us: vec![u64::MAX; n] }
    }

    /// Uniform fleet: every client takes `step_secs` per local step.
    pub fn uniform(plan: &RoundPlan, step_secs: f64, cfg: SimConfig) -> Simulator {
        Simulator::new(
            plan.clone(),
            vec![ClientProfile { step_secs }; plan.n_clients],
            cfg,
        )
    }

    /// Run the whole schedule, consuming the simulator.
    pub fn run(mut self) -> SimReport {
        let mut rows = Vec::with_capacity(self.plan.rounds.len());
        for i in 0..self.plan.rounds.len() {
            let spec = self.plan.rounds[i].clone();
            rows.push(self.run_round(&spec));
        }
        let total_bytes = rows.iter().map(|r| r.bytes_down + r.bytes_up).sum();
        SimReport {
            policy: self.cfg.policy,
            total_secs: us_to_secs(self.now_us),
            total_bytes,
            arrived_total: rows.iter().map(|r| r.n_arrived).sum(),
            late_total: rows.iter().map(|r| r.n_late).sum(),
            dropped_total: rows.iter().map(|r| r.n_dropped).sum(),
            rows,
        }
    }

    fn run_round(&mut self, spec: &RoundSpec) -> TimelineRow {
        let d_secs = self.cfg.link.transfer_secs(self.cfg.payload_down_bytes);
        let u_secs = self.cfg.link.transfer_secs(self.cfg.payload_up_bytes);
        let (d_us, u_us) = (to_us(d_secs), to_us(u_secs));
        let t0 = self.now_us;
        let n = spec.participants.len();

        let mut heap: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
        let mut seq = 0u64;

        if let AggregationPolicy::SemiSync { deadline_factor } = self.cfg.policy {
            // Deadline anchored to the slowest *nominal* participant,
            // assembled from the SAME µs-discretized quantities arrivals
            // use (each step is to_us(·).max(1)); with factor ≥ 1 an
            // un-faulted fleet therefore always makes it, exactly —
            // only fault-injected stragglers (slowed
            // `straggler_slowdown ×`) can miss and get cut.
            let slowest = spec
                .participants
                .iter()
                .map(|p| self.profiles[p.client].step_secs)
                .fold(0.0f64, f64::max);
            let nominal_us =
                d_us + self.plan.tau.saturating_mul(to_us(slowest).max(1)) + u_us;
            heap.push(Reverse(Event {
                at_us: t0 + (deadline_factor * nominal_us as f64).round() as u64,
                seq,
                kind: EventKind::Deadline,
                slot: usize::MAX,
            }));
            seq += 1;
        }

        // Per-slot schedule state.
        let mut compute_us = vec![0u64; n];
        let mut finish_us: Vec<Option<u64>> = vec![None; n];
        for (i, p) in spec.participants.iter().enumerate() {
            let nominal = self.profiles[p.client].step_secs;
            let step = if p.straggler {
                nominal * self.cfg.straggler_slowdown
            } else {
                nominal
            };
            let step_us = to_us(step).max(1);
            let mut steps = p.steps;
            if self.cfg.policy == AggregationPolicy::Overlap {
                // Tail steps accrued between the client's previous upload
                // and this broadcast completing, at this round's effective
                // rate (a straggler's tail steps are slowed too) — so the
                // credited saving never exceeds the physical window.
                let window = (t0 + d_us).saturating_sub(self.free_from_us[p.client]);
                let tail = (window / step_us).min(steps);
                steps -= tail;
            }
            compute_us[i] = steps.saturating_mul(step_us);
            heap.push(Reverse(Event {
                at_us: t0 + d_us,
                seq,
                kind: EventKind::BroadcastDone,
                slot: i,
            }));
            seq += 1;
        }

        // Event loop: the round closes at the last expected arrival, at
        // the deadline, or (async) at the K-th arrival — whichever the
        // policy dictates. All sampled clients having dropped is known at
        // dispatch — the round closes immediately (mirroring the
        // aggregator's all-dropped path).
        let close_at = match self.cfg.policy {
            AggregationPolicy::Async { k, .. } => k.min(n).max(1),
            _ => n,
        };
        let mut n_arrived = 0usize;
        let mut end_core = t0;
        if n > 0 {
            while let Some(Reverse(ev)) = heap.pop() {
                match ev.kind {
                    EventKind::BroadcastDone => {
                        heap.push(Reverse(Event {
                            at_us: ev.at_us + compute_us[ev.slot],
                            seq,
                            kind: EventKind::ComputeDone,
                            slot: ev.slot,
                        }));
                        seq += 1;
                    }
                    EventKind::ComputeDone => {
                        heap.push(Reverse(Event {
                            at_us: ev.at_us + u_us,
                            seq,
                            kind: EventKind::UploadDone,
                            slot: ev.slot,
                        }));
                        seq += 1;
                    }
                    EventKind::UploadDone => {
                        finish_us[ev.slot] = Some(ev.at_us);
                        n_arrived += 1;
                        end_core = ev.at_us; // events pop in time order
                        if n_arrived == close_at {
                            break;
                        }
                    }
                    EventKind::Deadline => {
                        end_core = ev.at_us;
                        break;
                    }
                }
            }
        }
        // Tree topologies pay one extra hop: after the last worker upload
        // lands at its sub-aggregator, the pre-folded pairs travel to the
        // root (in parallel — one transfer of latency) before the server
        // aggregation runs. Flat rounds (tiers <= 1) charge nothing here.
        let tree_hop_us = if self.cfg.tiers > 1 {
            to_us(self.cfg.link.transfer_secs(self.cfg.folded_up_bytes))
        } else {
            0
        };
        let end_us = end_core + tree_hop_us + to_us(self.cfg.server_agg_secs);

        let mut slowest = -1i64;
        let mut slowest_t = 0u64;
        for (i, f) in finish_us.iter().enumerate() {
            if let Some(t) = f {
                if *t >= slowest_t {
                    slowest_t = *t;
                    slowest = spec.participants[i].client as i64;
                }
            }
        }

        // Tail-credit bookkeeping: only this round's participants hold a
        // fresh model. Arrived clients are free from their own upload
        // time (the Overlap window); late clients from the round
        // boundary; everyone else gets no credit next round.
        for c in 0..self.plan.n_clients {
            self.free_from_us[c] = u64::MAX;
        }
        for (i, p) in spec.participants.iter().enumerate() {
            self.free_from_us[p.client] = finish_us[i].unwrap_or(end_us);
        }

        let row = TimelineRow {
            round: spec.round,
            t_start_secs: us_to_secs(t0),
            t_end_secs: us_to_secs(end_us),
            round_secs: us_to_secs(end_us - t0),
            broadcast_secs: d_secs,
            compute_secs: us_to_secs(compute_us.iter().copied().max().unwrap_or(0)),
            upload_secs: u_secs,
            n_arrived,
            n_late: n - n_arrived,
            n_dropped: spec.dropped.len(),
            bytes_down: self.cfg.payload_down_bytes * n as u64,
            bytes_up: self.cfg.payload_up_bytes * n_arrived as u64
                + if self.cfg.tiers > 1 {
                    self.cfg.tiers as u64 * self.cfg.folded_up_bytes
                } else {
                    0
                },
            slowest_client: slowest,
        };
        self.now_us = end_us;
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Link;

    fn plan1(rounds: usize, tau: u64, n_clients: usize) -> RoundPlan {
        // Full participation, no faults.
        RoundPlan {
            n_clients,
            tau,
            rounds: (0..rounds)
                .map(|round| RoundSpec {
                    round,
                    participants: (0..n_clients)
                        .map(|client| Participant { client, steps: tau, straggler: false })
                        .collect(),
                    dropped: vec![],
                })
                .collect(),
        }
    }

    fn link(gbps: f64, latency_s: f64) -> Link {
        Link { gbps, latency_s }
    }

    #[test]
    fn sync_round_time_is_broadcast_compute_upload() {
        // 1 client, d = u = 1 s (latency-only link), 10 steps × 0.5 s.
        let plan = plan1(3, 10, 1);
        let cfg = SimConfig::new(0, link(1.0, 1.0), AggregationPolicy::Sync);
        let rep = Simulator::uniform(&plan, 0.5, cfg).run();
        assert_eq!(rep.rows.len(), 3);
        for r in &rep.rows {
            assert!((r.round_secs - 7.0).abs() < 1e-6, "{}", r.round_secs);
            assert_eq!(r.n_arrived, 1);
            assert_eq!(r.n_late, 0);
        }
        assert!((rep.total_secs - 21.0).abs() < 1e-6);
    }

    #[test]
    fn sync_waits_for_slowest_client() {
        let plan = plan1(1, 10, 3);
        let cfg = SimConfig::new(0, link(1.0, 0.0), AggregationPolicy::Sync);
        let profiles = vec![
            ClientProfile { step_secs: 0.1 },
            ClientProfile { step_secs: 1.0 },
            ClientProfile { step_secs: 0.2 },
        ];
        let rep = Simulator::new(plan, profiles, cfg).run();
        assert!((rep.rows[0].round_secs - 10.0).abs() < 1e-6);
        assert_eq!(rep.rows[0].slowest_client, 1);
    }

    #[test]
    fn semisync_cuts_straggler_at_deadline() {
        // Two clients, same nominal rate; client 1 straggles (4× slower,
        // same steps here). Deadline = 1.5 × 10 s; straggler needs 40 s.
        let mut plan = plan1(1, 10, 2);
        plan.rounds[0].participants[1].straggler = true;
        let cfg = SimConfig {
            policy: AggregationPolicy::SemiSync { deadline_factor: 1.5 },
            ..SimConfig::new(0, link(1.0, 0.0), AggregationPolicy::Sync)
        };
        let rep = Simulator::uniform(&plan, 1.0, cfg).run();
        let row = &rep.rows[0];
        assert_eq!((row.n_arrived, row.n_late), (1, 1));
        assert!((row.round_secs - 15.0).abs() < 1e-6, "{}", row.round_secs);
        assert_eq!(row.bytes_up, 0, "zero-byte payload"); // payload 0
    }

    #[test]
    fn tree_hop_prices_folded_upload_and_tiers_one_is_identity() {
        // d = u = 1 s (latency-only link), folded hop adds another 1 s and
        // tiers × folded bytes to the upload accounting.
        let plan = plan1(2, 10, 2);
        let flat = SimConfig::new(8, link(1.0, 1.0), AggregationPolicy::Sync);
        let tree = flat.with_tiers(2, 16);
        let a = Simulator::uniform(&plan, 0.5, flat).run();
        let b = Simulator::uniform(&plan, 0.5, tree).run();
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert!((y.round_secs - (x.round_secs + 1.0)).abs() < 1e-6);
            assert_eq!(y.bytes_up, x.bytes_up + 2 * 16);
            assert_eq!(y.bytes_down, x.bytes_down);
        }
        // tiers = 1 (even with folded bytes set) is bitwise the flat sim.
        let one = Simulator::uniform(&plan, 0.5, flat.with_tiers(1, 16)).run();
        for (x, y) in a.rows.iter().zip(&one.rows) {
            assert_eq!(x.round_secs, y.round_secs);
            assert_eq!(x.bytes_up, y.bytes_up);
        }
    }

    #[test]
    fn semisync_without_stragglers_matches_sync() {
        let plan = plan1(4, 20, 3);
        let base = SimConfig::new(1_000_000, link(0.001, 0.01), AggregationPolicy::Sync);
        let semi = SimConfig {
            policy: AggregationPolicy::SemiSync { deadline_factor: 1.5 },
            ..base
        };
        let a = Simulator::uniform(&plan, 0.05, base).run();
        let b = Simulator::uniform(&plan, 0.05, semi).run();
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.round_secs, y.round_secs);
            assert_eq!(y.n_late, 0);
        }
    }

    #[test]
    fn deadline_tie_counts_as_arrived() {
        // finish = d + τ·step + u = deadline exactly (factor 1.0, all µs
        // values exact): the arrival must win the tie.
        let plan = plan1(1, 10, 1);
        let cfg = SimConfig {
            policy: AggregationPolicy::SemiSync { deadline_factor: 1.0 },
            ..SimConfig::new(0, link(1.0, 0.05), AggregationPolicy::Sync)
        };
        let rep = Simulator::uniform(&plan, 0.001, cfg).run();
        assert_eq!(rep.rows[0].n_arrived, 1);
        assert_eq!(rep.rows[0].n_late, 0);
    }

    #[test]
    fn overlap_hides_broadcast_after_first_round() {
        // d = 5.5 s, step = 1 s, τ = 20: from round 1 on, 5 tail steps run
        // during the broadcast, shortening the round by 5 s.
        let plan = plan1(3, 20, 1);
        let d = 5.5;
        let base = SimConfig::new(0, link(1.0, d), AggregationPolicy::Sync);
        let over = SimConfig { policy: AggregationPolicy::Overlap, ..base };
        let s = Simulator::uniform(&plan, 1.0, base).run();
        let o = Simulator::uniform(&plan, 1.0, over).run();
        // Round 0 identical: no prior upload to overlap from.
        assert_eq!(s.rows[0].round_secs, o.rows[0].round_secs);
        assert!((s.rows[1].round_secs - (2.0 * d + 20.0)).abs() < 1e-6);
        assert!((o.rows[1].round_secs - (2.0 * d + 15.0)).abs() < 1e-6);
        assert!(o.total_secs < s.total_secs);
    }

    #[test]
    fn all_dropped_round_is_instant_and_advances() {
        let plan = RoundPlan {
            n_clients: 4,
            tau: 50,
            rounds: vec![
                RoundSpec { round: 0, participants: vec![], dropped: vec![0, 1, 2, 3] },
                RoundSpec {
                    round: 1,
                    participants: vec![Participant { client: 2, steps: 50, straggler: false }],
                    dropped: vec![0, 1, 3],
                },
            ],
        };
        let cfg = SimConfig {
            policy: AggregationPolicy::SemiSync { deadline_factor: 2.0 },
            server_agg_secs: 0.25,
            ..SimConfig::new(1000, link(1.0, 0.0), AggregationPolicy::Sync)
        };
        let rep = Simulator::uniform(&plan, 0.1, cfg).run();
        let r0 = &rep.rows[0];
        assert_eq!((r0.n_arrived, r0.n_late, r0.n_dropped), (0, 0, 4));
        assert!((r0.round_secs - 0.25).abs() < 1e-9, "agg cost only");
        assert_eq!(r0.bytes_down, 0);
        assert_eq!(r0.slowest_client, -1);
        assert_eq!(rep.rows[1].n_arrived, 1);
        assert_eq!(rep.dropped_total, 7);
    }

    #[test]
    fn timeline_is_deterministic() {
        let plan = plan1(5, 30, 6);
        let mk = || {
            let cfg = SimConfig::new(
                500_000_000,
                link(0.0125, 0.03),
                AggregationPolicy::Overlap,
            );
            let profiles: Vec<ClientProfile> = (0..6)
                .map(|i| ClientProfile { step_secs: 0.1 + 0.07 * i as f64 })
                .collect();
            Simulator::new(plan.clone(), profiles, cfg).run()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.total_secs, b.total_secs);
    }

    #[test]
    fn policy_parse_and_labels() {
        assert_eq!(
            AggregationPolicy::parse("sync", 1.5).unwrap(),
            AggregationPolicy::Sync
        );
        assert_eq!(
            AggregationPolicy::parse("semisync", 1.5).unwrap(),
            AggregationPolicy::SemiSync { deadline_factor: 1.5 }
        );
        assert_eq!(
            AggregationPolicy::parse("overlap", 1.5).unwrap().label(),
            "overlap"
        );
        assert_eq!(
            AggregationPolicy::parse("async", 1.5).unwrap(),
            AggregationPolicy::Async { k: 4, gamma: 0.5 }
        );
        assert_eq!(
            AggregationPolicy::parse("async:2", 1.5).unwrap(),
            AggregationPolicy::Async { k: 2, gamma: 0.5 }
        );
        assert_eq!(
            AggregationPolicy::parse("async:8:0.9", 1.5).unwrap(),
            AggregationPolicy::Async { k: 8, gamma: 0.9 }
        );
        assert_eq!(AggregationPolicy::parse("async:8:0.9", 1.5).unwrap().label(), "async");
        // The unknown-policy error enumerates every valid spelling.
        let err = AggregationPolicy::parse("bogus", 1.5).unwrap_err().to_string();
        for name in ["sync", "semisync", "overlap", "async"] {
            assert!(err.contains(name), "error {err:?} must list {name:?}");
        }
        assert!(err.contains(POLICY_NAMES));
        // Bad async knobs are rejected with their own messages.
        assert!(AggregationPolicy::parse("async:0", 1.5).is_err());
        assert!(AggregationPolicy::parse("async:4:1.5", 1.5).is_err());
        assert!(AggregationPolicy::parse("async:4:-0.1", 1.5).is_err());
        assert!(AggregationPolicy::parse("asynchronous", 1.5).is_err());
    }

    #[test]
    fn async_closes_at_kth_arrival_and_beats_semisync_on_stragglers() {
        // 4 clients, one straggler (4× slower). Async K=3 folds when the
        // three healthy clients land; semi-sync waits for its deadline.
        let mut plan = plan1(2, 10, 4);
        for spec in &mut plan.rounds {
            spec.participants[3].straggler = true;
        }
        let base = SimConfig::new(0, link(1.0, 0.0), AggregationPolicy::Sync);
        let semi = SimConfig {
            policy: AggregationPolicy::SemiSync { deadline_factor: 1.5 },
            ..base
        };
        let asyn = SimConfig {
            policy: AggregationPolicy::Async { k: 3, gamma: 0.5 },
            ..base
        };
        let s = Simulator::uniform(&plan, 1.0, semi).run();
        let a = Simulator::uniform(&plan, 1.0, asyn).run();
        for (x, y) in s.rows.iter().zip(&a.rows) {
            // K-th (healthy) arrival at 10 s vs the 15 s deadline.
            assert!((y.round_secs - 10.0).abs() < 1e-6, "{}", y.round_secs);
            assert!(y.round_secs <= x.round_secs + 1e-9);
            assert_eq!((y.n_arrived, y.n_late), (3, 1));
        }
        assert!(a.total_secs < s.total_secs);
        // K larger than the cohort degrades to sync (close at last arrival).
        let all = SimConfig {
            policy: AggregationPolicy::Async { k: 99, gamma: 1.0 },
            ..base
        };
        let sync = Simulator::uniform(&plan, 1.0, base).run();
        let capped = Simulator::uniform(&plan, 1.0, all).run();
        for (x, y) in sync.rows.iter().zip(&capped.rows) {
            assert_eq!(x.round_secs, y.round_secs);
            assert_eq!(x.n_arrived, y.n_arrived);
        }
    }

    #[test]
    fn asymmetric_payloads_price_down_and_up_separately() {
        let plan = plan1(1, 10, 2);
        let cfg =
            SimConfig::asymmetric(1000, 250, link(1.0, 0.1), AggregationPolicy::Sync);
        let rep = Simulator::uniform(&plan, 0.5, cfg).run();
        assert_eq!(rep.rows[0].bytes_down, 2 * 1000);
        assert_eq!(rep.rows[0].bytes_up, 2 * 250);
        assert_eq!(cfg.straggler_slowdown, 4.0, "defaults inherited from new()");
    }

    #[test]
    fn report_accounting() {
        let plan = plan1(2, 10, 2);
        let cfg = SimConfig::new(1_000, link(1.0, 0.1), AggregationPolicy::Sync);
        let rep = Simulator::uniform(&plan, 0.5, cfg).run();
        assert_eq!(rep.arrived_total, 4);
        assert_eq!(rep.total_bytes, 2 * (2 * 1_000 + 2 * 1_000));
        assert!(rep.comm_fraction() > 0.0 && rep.comm_fraction() < 0.1);
        assert!((rep.mean_round_secs() * 2.0 - rep.total_secs).abs() < 1e-9);
    }
}
