//! Mid-tier sub-aggregator: the deployment-plane role behind a
//! multi-tier federation (`cfg.tiers > 1`). A sub-aggregator joins the
//! root Aggregator as a `SubJoin` peer, leases a whole contiguous slice
//! of each round's sampled cohort, re-leases the member clients to its
//! own downstream workers, folds the arriving updates locally with the
//! *same* `weighted_mean_into` kernel the in-process `tiered_fold` runs,
//! and pushes one pre-folded `(weight, mean)` pair — plus the member
//! bookkeeping — upstream as a `FoldedPush`.
//!
//! ## Equivalence contract
//!
//! The committed global model is bit-identical to the in-process
//! `Federation::run` at the same `cfg.tiers`: the sub-aggregator folds
//! its arrived members in slot (= sampled) order via
//! [`crate::model::vecmath::weighted_mean_into`], carries the weight as
//! the *sequential* f64 sum of the member sample counts, and ships the
//! mean dense (f32 rows are never re-coded through a lossy codec on the
//! subagg→root leg — re-quantizing a mean would break parity). The root
//! re-derives the carried weight from the members at commit and folds the
//! group means with `streaming_fold`, exactly stage two of `tiered_fold`.
//!
//! ## Faults
//!
//! Downstream workers get the full flat-server treatment minus
//! migration: a per-round deadline (measured from assignment receipt)
//! cuts stragglers, a crashed worker's leases survive for an identity
//! rejoin within the deadline, and a malformed frame drops the payload,
//! never the process. Members lost downstream are simply absent from the
//! `FoldedPush`; the root cuts them through the dropped path.

// Wall-clock reads here are transport concerns (deadlines, liveness) —
// allowlisted; see docs/ANALYSIS.md (nondet-time).
#![allow(clippy::disallowed_methods)]

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::chaos::LeaseBook;
use crate::ckpt::ClientCkpt;
use crate::coordinator::ClientUpdate;
use crate::model::vecmath::weighted_mean_into;
use crate::net::poll::{spawn_poller, Event, NbWriter};
use crate::net::proto::{
    self, AssignState, AssignTask, FoldedMember, FoldedPush, Heartbeat, JoinAck, Msg,
    Reject, RoundAssign, TaskSpec, PROTO_VERSION,
};

/// Sub-aggregator knobs.
#[derive(Clone, Debug)]
pub struct SubaggOpts {
    /// Display name sent upstream in the SubJoin (logs only).
    pub name: String,
    /// Downstream bind address for workers (`:0` picks a free port).
    pub bind: String,
    /// Wait for this many downstream workers before serving round 0.
    pub min_workers: usize,
    /// Downstream straggler deadline per round, measured from assignment
    /// receipt; `None` = disconnects only (plus the stall backstop).
    pub deadline_secs: Option<f64>,
    /// How long to wait for the downstream admission barrier.
    pub join_timeout_secs: f64,
    /// Downstream socket write stall tolerance.
    pub io_timeout_secs: f64,
    /// Liveness backstop when no deadline is configured.
    pub stall_secs: f64,
    pub verbose: bool,
}

impl Default for SubaggOpts {
    fn default() -> SubaggOpts {
        SubaggOpts {
            name: "subagg".into(),
            bind: "127.0.0.1:0".into(),
            min_workers: 1,
            deadline_secs: None,
            join_timeout_secs: 120.0,
            io_timeout_secs: 30.0,
            stall_secs: 3600.0,
            verbose: false,
        }
    }
}

/// What a sub-aggregator did during one session.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SubaggReport {
    /// Rounds for which a `FoldedPush` went upstream.
    pub rounds_served: u64,
    /// Member updates folded across all served rounds.
    pub members_folded: u64,
    /// Downstream worker connections admitted (rejoins included).
    pub workers_admitted: u64,
    /// Framed-but-undecodable downstream frames dropped.
    pub malformed_frames: u64,
}

/// The single event stream the sub-aggregator's main loop drains:
/// downstream poller events and upstream frames, funneled into one
/// channel by two adapter threads.
enum Ev {
    Down(Event),
    Up(Msg),
    UpGone,
}

/// One admitted downstream worker connection.
struct DownConn {
    conn: usize,
    name: String,
    stream: NbWriter,
    alive: bool,
}

enum AfterRound {
    Continue,
    Shutdown,
}

struct Subagg {
    opts: SubaggOpts,
    session: u64,
    spec: TaskSpec,
    /// Upstream write half (the read half lives in the reader thread).
    up: TcpStream,
    workers: Vec<DownConn>,
    report: SubaggReport,
}

/// Connect to the root Aggregator at `upstream`, join as a sub-aggregator,
/// serve downstream workers on `opts.bind`, and run rounds until the root
/// sends `Shutdown`. Blocking. `addr_tx`, when given, receives the bound
/// downstream address (the harness wires workers to it).
pub fn run_subagg(
    upstream: &str,
    opts: SubaggOpts,
    addr_tx: Option<Sender<SocketAddr>>,
) -> Result<SubaggReport> {
    let mut up = TcpStream::connect(upstream)
        .with_context(|| format!("connecting to root {upstream}"))?;
    up.set_nodelay(true).ok();
    proto::write_msg(
        &mut up,
        &Msg::SubJoin(proto::Join {
            proto: PROTO_VERSION,
            name: opts.name.clone(),
            identity: 0,
        }),
        false,
    )?;
    let mut up_read = up.try_clone().context("cloning upstream stream")?;
    let ack = match proto::read_msg(&mut up_read)? {
        Msg::JoinAck(a) => a,
        Msg::Reject(r) => bail!("root rejected sub-aggregator join: {}", r.reason),
        other => bail!("expected JoinAck from root, got {:?}", other.kind()),
    };
    ensure!(
        ack.proto == PROTO_VERSION,
        "root speaks photon-net v{}, this sub-aggregator v{PROTO_VERSION} — upgrade",
        ack.proto
    );

    let listener = TcpListener::bind(&opts.bind)
        .with_context(|| format!("binding downstream {}", opts.bind))?;
    let addr = listener.local_addr()?;
    if let Some(tx) = addr_tx {
        let _ = tx.send(addr);
    }
    if opts.verbose {
        println!(
            "[subagg {}] joined root as slot {}; serving workers on {addr}",
            opts.name, ack.worker_slot
        );
    }

    let (etx, erx) = mpsc::channel::<Ev>();
    let stop = Arc::new(AtomicBool::new(false));
    // Downstream poller → funnel adapter.
    let (ptx, prx) = mpsc::channel::<Event>();
    spawn_poller(listener, ptx, stop.clone())?;
    {
        let etx = etx.clone();
        std::thread::spawn(move || {
            for ev in prx {
                if etx.send(Ev::Down(ev)).is_err() {
                    return;
                }
            }
        });
    }
    // Upstream reader → funnel adapter.
    std::thread::spawn(move || loop {
        match proto::read_msg(&mut up_read) {
            Ok(msg) => {
                if etx.send(Ev::Up(msg)).is_err() {
                    return;
                }
            }
            Err(_) => {
                let _ = etx.send(Ev::UpGone);
                return;
            }
        }
    });

    let mut sa = Subagg {
        opts,
        session: ack.session,
        spec: ack.spec,
        up,
        workers: Vec::new(),
        report: SubaggReport::default(),
    };
    let result = sa.run(&erx);
    // Whatever ended the session, release the fleet and the poller.
    let shutdown = Msg::Shutdown;
    for w in sa.workers.iter_mut().filter(|w| w.alive) {
        let _ = proto::write_msg(&mut w.stream, &shutdown, false);
    }
    stop.store(true, Ordering::Release);
    result?;
    Ok(sa.report)
}

impl Subagg {
    fn run(&mut self, rx: &Receiver<Ev>) -> Result<()> {
        // Downstream admission barrier. A RoundAssign may arrive from the
        // root while the local fleet is still connecting — stash it and
        // serve it the moment the barrier clears.
        let mut stashed: Option<RoundAssign> = None;
        let give_up =
            Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while self.workers.iter().filter(|w| w.alive).count() < self.opts.min_workers {
            let now = Instant::now();
            if now >= give_up {
                bail!(
                    "timed out waiting for {} downstream workers ({} joined)",
                    self.opts.min_workers,
                    self.workers.len()
                );
            }
            match rx.recv_timeout(give_up - now) {
                Ok(Ev::Down(Event::Joined { conn, stream, join, sub })) => {
                    self.admit_or_rejoin(conn, stream, join, sub);
                }
                Ok(Ev::Down(Event::Gone { conn })) => self.mark_gone(conn),
                Ok(Ev::Down(_)) => {}
                Ok(Ev::Up(Msg::RoundAssign(ra))) => stashed = Some(ra),
                Ok(Ev::Up(Msg::Shutdown)) => return Ok(()),
                Ok(Ev::Up(Msg::Reject(r))) => {
                    bail!("root rejected mid-session: {}", r.reason)
                }
                Ok(Ev::Up(_)) => {}
                Ok(Ev::UpGone) => bail!("upstream connection lost during admission"),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("event funnel died"),
            }
        }
        if let Some(ra) = stashed.take() {
            if let AfterRound::Shutdown = self.serve_round(rx, ra)? {
                return Ok(());
            }
        }
        loop {
            match rx.recv() {
                Ok(Ev::Up(Msg::RoundAssign(ra))) => {
                    if let AfterRound::Shutdown = self.serve_round(rx, ra)? {
                        return Ok(());
                    }
                }
                Ok(Ev::Up(Msg::RoundCommit(c))) => self.broadcast(&Msg::RoundCommit(c)),
                Ok(Ev::Up(Msg::Shutdown)) => return Ok(()),
                Ok(Ev::Up(Msg::Reject(r))) => {
                    bail!("root rejected mid-session: {}", r.reason)
                }
                Ok(Ev::Up(_)) => {}
                Ok(Ev::UpGone) => bail!("upstream connection lost"),
                Ok(Ev::Down(Event::Joined { conn, stream, join, sub })) => {
                    self.admit_or_rejoin(conn, stream, join, sub);
                }
                Ok(Ev::Down(Event::Gone { conn })) => self.mark_gone(conn),
                // Stale pushes / malformed frames between rounds.
                Ok(Ev::Down(_)) => {}
                Err(_) => bail!("event funnel died"),
            }
        }
    }

    /// Admit a fresh downstream worker or re-attach a returning one to its
    /// slot. Nested sub-aggregators are refused — the tree is two levels
    /// of aggregation deep by design (root + this tier).
    fn admit_or_rejoin(
        &mut self,
        conn: usize,
        stream: TcpStream,
        join: proto::Join,
        sub: bool,
    ) -> Option<usize> {
        let mut stream = NbWriter::new(stream, self.opts.io_timeout_secs);
        if sub {
            let reject = Msg::Reject(Reject {
                reason: "sub-aggregators do not nest: connect workers here, \
                         sub-aggregators to the root"
                    .to_string(),
            });
            let _ = proto::write_msg(&mut stream, &reject, false);
            return None;
        }
        if join.proto != PROTO_VERSION {
            let reject = Msg::Reject(Reject {
                reason: format!(
                    "worker speaks photon-net v{}, sub-aggregator requires \
                     v{PROTO_VERSION}",
                    join.proto
                ),
            });
            let _ = proto::write_msg(&mut stream, &reject, false);
            return None;
        }
        if join.identity > 0 {
            let slot = (join.identity - 1) as usize;
            if slot >= self.workers.len() || self.workers[slot].alive {
                let reject = Msg::Reject(Reject {
                    reason: format!(
                        "identity {} does not name a reclaimable worker slot",
                        join.identity
                    ),
                });
                let _ = proto::write_msg(&mut stream, &reject, false);
                return None;
            }
            let ack = Msg::JoinAck(JoinAck {
                proto: PROTO_VERSION,
                session: self.session,
                worker_slot: slot as u64,
                spec: self.spec.clone(),
            });
            if proto::write_msg(&mut stream, &ack, false).is_err() {
                return None;
            }
            if self.opts.verbose {
                println!(
                    "[subagg {}] worker {:?} rejoined slot {slot}",
                    self.opts.name, join.name
                );
            }
            self.workers[slot] =
                DownConn { conn, name: join.name, stream, alive: true };
            self.report.workers_admitted += 1;
            return Some(slot);
        }
        let ack = Msg::JoinAck(JoinAck {
            proto: PROTO_VERSION,
            session: self.session,
            worker_slot: self.workers.len() as u64,
            spec: self.spec.clone(),
        });
        if proto::write_msg(&mut stream, &ack, false).is_err() {
            return None;
        }
        if self.opts.verbose {
            println!(
                "[subagg {}] admitted worker {:?} (slot {})",
                self.opts.name,
                join.name,
                self.workers.len()
            );
        }
        self.workers.push(DownConn { conn, name: join.name, stream, alive: true });
        self.report.workers_admitted += 1;
        None
    }

    fn mark_gone(&mut self, conn: usize) {
        if let Some(w) = self.workers.iter_mut().find(|w| w.conn == conn) {
            if w.alive {
                w.alive = false;
                if self.opts.verbose {
                    println!(
                        "[subagg {}] worker {:?} disconnected",
                        self.opts.name, w.name
                    );
                }
            }
        }
    }

    fn broadcast(&mut self, msg: &Msg) {
        for w in self.workers.iter_mut().filter(|w| w.alive) {
            if proto::write_msg(&mut w.stream, msg, false).is_err() {
                w.alive = false;
            }
        }
    }

    /// Re-lease `clients` (their states held in `held`) to downstream
    /// worker `widx` as one full RoundAssign.
    fn send_down(
        &mut self,
        widx: usize,
        clients: &[usize],
        ra: &RoundAssign,
        held: &BTreeMap<usize, (u64, ClientCkpt)>,
    ) -> Result<()> {
        if clients.is_empty() {
            return Ok(());
        }
        let mut tasks = Vec::with_capacity(clients.len());
        for &c in clients {
            let Some((steps, state)) = held.get(&c) else {
                bail!("re-leasing client {c} whose state this sub-aggregator never held");
            };
            tasks.push(AssignTask {
                client: c as u64,
                steps: *steps,
                state: AssignState::Full(state.clone()),
            });
        }
        let msg = Msg::RoundAssign(RoundAssign {
            session: ra.session,
            round: ra.round,
            seq_base: ra.seq_base,
            lease_epoch: ra.lease_epoch,
            tasks,
            global: ra.global.clone(),
        });
        if proto::write_msg(&mut self.workers[widx].stream, &msg, self.spec.compress)
            .is_err()
        {
            self.workers[widx].alive = false;
        }
        Ok(())
    }

    /// Serve one leased slice: re-lease to downstream workers, collect the
    /// member updates, fold them in slot order, push the folded pair
    /// upstream.
    fn serve_round(&mut self, rx: &Receiver<Ev>, ra: RoundAssign) -> Result<AfterRound> {
        let t0 = Instant::now();
        // Signal receipt: the root ignores heartbeats, but a live frame
        // right after dispatch is cheap diagnostics.
        let _ = proto::write_msg(
            &mut self.up,
            &Msg::Heartbeat(Heartbeat { session: ra.session, round: ra.round }),
            false,
        );
        if ra.session != self.session {
            return Ok(AfterRound::Continue); // stale root incarnation
        }

        // Unpack the slice. The root always ships Full states to a
        // sub-aggregator; a Ref here is a protocol violation.
        let mut held: BTreeMap<usize, (u64, ClientCkpt)> = BTreeMap::new();
        let mut runnable: Vec<(usize, u64)> = Vec::with_capacity(ra.tasks.len());
        for task in &ra.tasks {
            let AssignState::Full(state) = &task.state else {
                bail!(
                    "root sent a state reference for client {} — sub-aggregators \
                     hold no cache the root can reference",
                    task.client
                );
            };
            held.insert(task.client as usize, (task.steps, state.clone()));
            runnable.push((task.client as usize, task.steps));
        }
        if runnable.is_empty() {
            return Ok(AfterRound::Continue);
        }

        // Wait out a momentarily empty fleet (crash/rejoin window).
        let give_up =
            Instant::now() + Duration::from_secs_f64(self.opts.join_timeout_secs);
        while !self.workers.iter().any(|w| w.alive) {
            let now = Instant::now();
            if now >= give_up {
                bail!("no downstream workers left for round {}", ra.round);
            }
            match rx.recv_timeout(give_up - now) {
                Ok(Ev::Down(Event::Joined { conn, stream, join, sub })) => {
                    self.admit_or_rejoin(conn, stream, join, sub);
                }
                Ok(Ev::Down(Event::Gone { conn })) => self.mark_gone(conn),
                Ok(Ev::Down(_)) => {}
                Ok(Ev::Up(Msg::Shutdown)) => return Ok(AfterRound::Shutdown),
                Ok(Ev::Up(_)) => {}
                Ok(Ev::UpGone) => bail!("upstream connection lost"),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => bail!("event funnel died"),
            }
        }
        let live: Vec<usize> =
            (0..self.workers.len()).filter(|&i| self.workers[i].alive).collect();

        // Round-robin re-lease in slot order. Which worker runs a member
        // never affects the math — the fold happens here, in slot order.
        let mut book = LeaseBook::new(&runnable);
        let mut per_worker: Vec<Vec<usize>> = vec![Vec::new(); self.workers.len()];
        for (slot, &(client, _)) in runnable.iter().enumerate() {
            let widx = live[slot % live.len()];
            book.lease(client, widx);
            per_worker[widx].push(client);
        }
        for &widx in &live {
            let clients = std::mem::take(&mut per_worker[widx]);
            if clients.is_empty() {
                continue;
            }
            self.send_down(widx, &clients, &ra, &held)?;
            if !self.workers[widx].alive && self.opts.deadline_secs.is_none() {
                let _ = book.cut_pending_of(widx);
            }
        }

        let deadline = self
            .opts
            .deadline_secs
            .map(|s| t0 + Duration::from_secs_f64(s));
        let mut arrived: BTreeMap<usize, (ClientUpdate, ClientCkpt)> = BTreeMap::new();
        while book.pending_count() > 0 {
            let now = Instant::now();
            if let Some(dl) = deadline {
                if now >= dl {
                    book.cut_all_pending();
                    break;
                }
            }
            let timeout = match deadline {
                Some(t) => t.saturating_duration_since(now),
                None => Duration::from_secs_f64(self.opts.stall_secs),
            };
            match rx.recv_timeout(timeout) {
                Ok(Ev::Down(Event::Joined { conn, stream, join, sub })) => {
                    if let Some(widx) = self.admit_or_rejoin(conn, stream, join, sub) {
                        let reclaimed = book.pending_of(widx);
                        self.send_down(widx, &reclaimed, &ra, &held)?;
                    }
                }
                Ok(Ev::Down(Event::Frame { conn, msg })) => match msg {
                    Msg::UpdatePush(p)
                        if p.session == self.session && p.round == ra.round =>
                    {
                        let client = p.update.client_id;
                        let Some(widx) =
                            self.workers.iter().position(|w| w.conn == conn)
                        else {
                            continue;
                        };
                        if book.owner(client) != Some(widx) {
                            continue;
                        }
                        // Decode-then-fold, exactly the flat server's
                        // acceptance: shape must match the negotiated
                        // codec, defects cut the member, never the round.
                        let codec = self.spec.codec;
                        let mut update = p.update;
                        let reconstructed: Option<u64> =
                            match (codec.is_lossy(), &p.body) {
                                (false, None) => Some(crate::link::dense_frame_bytes(
                                    update.params.len(),
                                )),
                                (true, Some(body)) if update.params.is_empty() => {
                                    match crate::compress::decode_transit(
                                        &codec, &ra.global, body,
                                    ) {
                                        Ok(params) => {
                                            update.params = params;
                                            Some(crate::link::framed_bytes(body.len()))
                                        }
                                        Err(_) => None,
                                    }
                                }
                                _ => None,
                            };
                        let ok = reconstructed.is_some()
                            && update.params.len() == ra.global.len();
                        if !ok {
                            book.cut(client);
                            continue;
                        }
                        update.wire_bytes = reconstructed.unwrap_or(0);
                        if book.accept(client, widx) {
                            let Some(slot) = book.slot(client) else {
                                bail!("lease ledger accepted unleased client {client}");
                            };
                            arrived.insert(slot, (update, p.state));
                        }
                    }
                    _ => {}
                },
                Ok(Ev::Down(Event::Malformed { conn })) => {
                    self.report.malformed_frames += 1;
                    let who = self
                        .workers
                        .iter()
                        .find(|w| w.conn == conn)
                        .map(|w| w.name.as_str())
                        .unwrap_or("?");
                    println!(
                        "[subagg {}] round {}: dropped undecodable frame from {who:?}",
                        self.opts.name, ra.round
                    );
                }
                Ok(Ev::Down(Event::Gone { conn })) => {
                    self.mark_gone(conn);
                    if let Some(widx) =
                        self.workers.iter().position(|w| w.conn == conn)
                    {
                        if deadline.is_none() {
                            let _ = book.cut_pending_of(widx);
                        }
                        // else: leases stay pending for an identity rejoin.
                    }
                }
                Ok(Ev::Up(Msg::RoundCommit(c))) => {
                    // The root committed without us (deadline cut this
                    // slice): the round is over, nothing to push.
                    let committed = c.round == ra.round;
                    self.broadcast(&Msg::RoundCommit(c));
                    if committed {
                        return Ok(AfterRound::Continue);
                    }
                }
                Ok(Ev::Up(Msg::Shutdown)) => return Ok(AfterRound::Shutdown),
                Ok(Ev::Up(Msg::RoundAssign(_))) => {
                    bail!("overlapping round assignments from root")
                }
                Ok(Ev::Up(Msg::Reject(r))) => {
                    bail!("root rejected mid-session: {}", r.reason)
                }
                Ok(Ev::Up(_)) => {}
                Ok(Ev::UpGone) => bail!("upstream connection lost mid-round"),
                Err(RecvTimeoutError::Timeout) => {
                    if deadline.is_none() {
                        println!(
                            "[subagg {}] round {}: stall backstop ({}s) fired with \
                             {} lease(s) pending — cutting",
                            self.opts.name,
                            ra.round,
                            self.opts.stall_secs,
                            book.pending_count()
                        );
                        book.cut_all_pending();
                    }
                }
                Err(RecvTimeoutError::Disconnected) => bail!("event funnel died"),
            }
        }

        if arrived.is_empty() {
            // Every member was lost downstream: push nothing — the root's
            // deadline (or stall backstop) cuts the slice.
            return Ok(AfterRound::Continue);
        }

        // Fold in slot order — bit-identical to `tiered_fold` stage one.
        // The weight carried upstream is the *sequential* sum of the
        // member sample counts in the same order (the weight-carry rule);
        // the root verifies it bit-exactly against the members at commit.
        let arrived: Vec<(ClientUpdate, ClientCkpt)> = arrived.into_values().collect();
        let rows: Vec<&[f32]> =
            arrived.iter().map(|(u, _)| u.params.as_slice()).collect();
        let weights: Vec<f64> = arrived.iter().map(|(u, _)| u.n_samples).collect();
        let mut mean = vec![0.0f32; ra.global.len()];
        weighted_mean_into(&rows, &weights, &mut mean);
        let weight: f64 = weights.iter().sum();
        drop(rows);
        let n_members = arrived.len() as u64;
        let members: Vec<FoldedMember> = arrived
            .into_iter()
            .map(|(mut update, state)| {
                // The dense params fold into `mean`; only the metadata —
                // sample count, losses, measured wire bytes — and the
                // advanced state travel upstream per member.
                update.params = Vec::new();
                FoldedMember { update, state }
            })
            .collect();
        proto::write_msg(
            &mut self.up,
            &Msg::FoldedPush(FoldedPush {
                session: ra.session,
                round: ra.round,
                weight,
                mean,
                members,
            }),
            self.spec.compress,
        )
        .context("pushing folded round upstream")?;
        self.report.rounds_served += 1;
        self.report.members_folded += n_members;
        if self.opts.verbose {
            println!(
                "[subagg {}] round {}: folded {}/{} member(s), weight {weight}",
                self.opts.name,
                ra.round,
                n_members,
                ra.tasks.len()
            );
        }
        Ok(AfterRound::Continue)
    }
}
